"""End-to-end LM training driver: data pipeline -> distributed train step ->
checkpoint/restart, on any of the 10 assigned architectures (reduced or
custom scale).

    PYTHONPATH=src python examples/train_lm.py --arch qwen15_05b \
        --steps 120 --preset small --ckpt /tmp/ckpt_demo

Defaults run a ~2M-param model for 120 steps in a couple of minutes on CPU;
``--preset demo100m`` is the ~100M-configuration used on real hardware.
Kill it mid-run and rerun the same command: it resumes from the latest
checkpoint (fault-tolerance path).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import batch_at, for_model
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, param_count
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_train_step

PRESETS = {
    # name -> (d_model, layers, heads, d_ff, vocab, seq, batch)
    "tiny": (64, 2, 4, 128, 512, 64, 2),
    "small": (128, 4, 4, 384, 2048, 128, 4),
    "demo100m": (768, 12, 12, 2048, 32000, 1024, 8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--preset", default="small", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=40)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    d, l, h, f, v, seq, gb = PRESETS[args.preset]
    cfg = get_config(args.arch).reduced(
        d_model=d, num_layers=l, num_heads=h, num_kv_heads=max(h // 2, 1),
        d_ff=f, vocab_size=v, head_dim=d // h)
    print(f"arch={cfg.name} params={param_count(cfg)/1e6:.1f}M "
          f"seq={seq} batch={gb}")

    mesh = make_host_mesh()
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step, psh, osh = make_train_step(cfg, opt_cfg, mesh,
                                     num_microbatches=args.microbatches,
                                     dtype=jnp.float32)
    dcfg = for_model(cfg, seq_len=seq, global_batch=gb)

    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    start = 0
    restored = ckpt.restore_latest(args.ckpt, params, opt_state,
                                   param_sh=psh, opt_sh=osh)
    if restored is not None:
        params, opt_state, meta = restored
        start = meta["step"]
        print(f"resumed from checkpoint step {start}")
    else:
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, osh)

    t0 = time.time()
    for i in range(start, args.steps):
        batch = batch_at(dcfg, i, cfg)
        batch.pop("prefix_embeds", None)  # text-only demo
        params, opt_state, m = step(params, opt_state, batch)
        if (i + 1) % 10 == 0 or i == start:
            rate = (i + 1 - start) * gb * seq / max(time.time() - t0, 1e-9)
            print(f"step {i+1:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} tok/s={rate:,.0f}",
                  flush=True)
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, i + 1, params, opt_state,
                      extra={"arch": cfg.name})
            print(f"  checkpoint @ {i+1}")
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
