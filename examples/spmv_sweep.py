"""End-to-end driver mirroring the paper's evaluation harness (run.sh):
sweep the (synthetic) SuiteSparse-like corpus with every schedule and write
the paper's CSV format: ``kernel,dataset,rows,cols,nnzs,elapsed``.

    PYTHONPATH=src python examples/spmv_sweep.py [--out results.csv]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import Schedule, blocked_tile_reduce, make_partition
from repro.sparse import suite_like_corpus

SCHEDULES = [Schedule.MERGE_PATH, Schedule.THREAD_MAPPED,
             Schedule.GROUP_MAPPED, Schedule.NONZERO_SPLIT]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="-")
    ap.add_argument("--num-blocks", type=int, default=64)
    args = ap.parse_args()
    out = sys.stdout if args.out == "-" else open(args.out, "w")

    print("kernel,dataset,rows,cols,nnzs,elapsed", file=out)
    for name, A in suite_like_corpus():
        x = jax.random.normal(jax.random.PRNGKey(0), (A.shape[1],),
                              jnp.float32)
        spec = A.workspec()
        for sched in SCHEDULES:
            part = make_partition(spec, sched, args.num_blocks)

            @jax.jit
            def f(vals, cols, xx, _p=part, _s=spec):
                return blocked_tile_reduce(
                    _s, _p, lambda nz: vals[nz] * xx[cols[nz]])

            jax.block_until_ready(f(A.values, A.col_indices, x))  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(f(A.values, A.col_indices, x))
            ms = (time.perf_counter() - t0) * 1e3
            print(f"{sched.value},{name},{A.shape[0]},{A.shape[1]},"
                  f"{A.nnz},{ms:.4f}", file=out, flush=True)
    if out is not sys.stdout:
        out.close()


if __name__ == "__main__":
    main()
