"""Graph analytics on the load-balancing abstraction (paper §5.3,
Listing 5): BFS, SSSP and PageRank over a scale-free graph, where atoms =
edges and tiles = frontier vertices — the same vocabulary that drives SpMV.
The graph is inspected once into an AdvancePlan *pair* (pull + push views,
schedules chosen by the cost-model autotuner's "advance"/"advance_push"
families); every traversal reuses it, switching push/pull per iteration
from the measured frontier density, and `bfs_multi` batches sources over
the same pair.

    PYTHONPATH=src python examples/graph_traversal.py
"""
import numpy as np
import jax

from repro.core import ImbalanceStats
from repro.sparse import (CSR, Graph, bfs, bfs_multi, build_advance,
                          delta_stepping, pagerank, random_csr, sssp)


def main():
    # scale-free directed graph: heavy-tailed out-degrees = the classic
    # frontier load-imbalance problem (paper's SSSP/BFS motivation)
    A = random_csr(rows=2000, cols=2000, nnz_target=16_000, skew=1.2,
                   empty_frac=0.1, seed=7)
    w = CSR(A.row_offsets, A.col_indices,
            jax.numpy.abs(A.values) + 0.05, A.shape, A.nnz)
    g = Graph(w)
    stats = ImbalanceStats.measure(w.workspec())
    print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
          f"max out-degree={stats.max_atoms_per_tile} "
          f"(cv={stats.cv_atoms_per_tile:.2f})")

    # one inspector pass (transpose + both partitions + autotuned
    # schedules + modeled direction threshold), shared by every traversal
    plan = build_advance(g, schedule="auto")
    print(f"advance plan pair: pull={plan.schedule}@{plan.path} "
          f"push={plan.push_schedule}@{plan.push_path} "
          f"blocks={plan.part.num_blocks} "
          f"direction_threshold={plan.direction_threshold:.2f}")

    depth, counts = bfs(g, source=0, plan=plan,
                        return_direction_counts=True)
    depth, counts = np.asarray(depth), np.asarray(counts)
    reached = (depth >= 0).sum()
    print(f"BFS from 0: reached {reached}/{g.num_vertices} vertices, "
          f"max depth {depth.max()} "
          f"({counts[0]} push / {counts[1]} pull iterations)")

    batched = np.asarray(bfs_multi(g, [0, 1, 2, 3], plan=plan))
    print(f"batched BFS over 4 sources (one plan pair): "
          f"reached per source {[(d >= 0).sum() for d in batched]}")

    dist = np.asarray(sssp(g, source=0, plan=plan))
    finite = np.isfinite(dist)
    print(f"SSSP from 0: reached {finite.sum()} vertices, "
          f"mean distance {dist[finite].mean():.3f}, "
          f"max {dist[finite].max():.3f}")

    # bucketed SSSP: light/heavy split + compacted push windows on the
    # same graph; distances are bit-identical to the Bellman-Ford above
    ddist, dcounts = delta_stepping(g, source=0,
                                    return_direction_counts=True)
    ddist, dcounts = np.asarray(ddist), np.asarray(dcounts)
    assert (ddist.view(np.uint32) == dist.view(np.uint32)).all()
    print(f"delta-stepping from 0: bit-identical to Bellman-Ford "
          f"({dcounts[0]} push / {dcounts[1]} pull bucket phases)")

    pr = np.asarray(pagerank(g, num_iters=30, plan=plan))
    top = np.argsort(-pr)[:3]
    print(f"PageRank (30 iters): sum={pr.sum():.4f}, "
          f"top vertices {top.tolist()} with mass {pr[top].sum():.3f}")


if __name__ == "__main__":
    main()
