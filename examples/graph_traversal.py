"""Graph analytics on the load-balancing abstraction (paper §5.3,
Listing 5): BFS and SSSP over a scale-free graph, where atoms = edges and
tiles = frontier vertices — the same vocabulary that drives SpMV.

    PYTHONPATH=src python examples/graph_traversal.py
"""
import numpy as np
import jax

from repro.core import ImbalanceStats
from repro.sparse import CSR, Graph, bfs, random_csr, sssp


def main():
    # scale-free directed graph: heavy-tailed out-degrees = the classic
    # frontier load-imbalance problem (paper's SSSP/BFS motivation)
    A = random_csr(rows=2000, cols=2000, nnz_target=16_000, skew=1.2,
                   empty_frac=0.1, seed=7)
    w = CSR(A.row_offsets, A.col_indices,
            jax.numpy.abs(A.values) + 0.05, A.shape, A.nnz)
    g = Graph(w)
    stats = ImbalanceStats.measure(w.workspec())
    print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
          f"max out-degree={stats.max_atoms_per_tile} "
          f"(cv={stats.cv_atoms_per_tile:.2f})")

    depth = np.asarray(bfs(g, source=0))
    reached = (depth >= 0).sum()
    print(f"BFS from 0: reached {reached}/{g.num_vertices} vertices, "
          f"max depth {depth.max()}")

    dist = np.asarray(sssp(g, source=0))
    finite = np.isfinite(dist)
    print(f"SSSP from 0: reached {finite.sum()} vertices, "
          f"mean distance {dist[finite].mean():.3f}, "
          f"max {dist[finite].max():.3f}")


if __name__ == "__main__":
    main()
