"""Quickstart: the load-balancing abstraction in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a skewed sparse matrix, shows the three abstraction stages (work
definition -> schedule -> execution), runs SpMV under every schedule plus
the paper's heuristic, and validates against the dense oracle.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ImbalanceStats, Schedule, choose_schedule,
                        make_partition)
from repro.sparse import random_csr, spmv
from repro.kernels.spmv_merge import ops as kops


def main():
    # --- stage 1: work definition (atoms = nonzeros, tiles = rows) ---------
    A = random_csr(rows=1000, cols=800, nnz_target=20_000, skew=1.3,
                   empty_frac=0.2, seed=0)
    spec = A.workspec()
    stats = ImbalanceStats.measure(spec)
    print(f"matrix: {A.shape} nnz={A.nnz}")
    print(f"imbalance: max/row={stats.max_atoms_per_tile} "
          f"cv={stats.cv_atoms_per_tile:.2f} "
          f"empty={stats.empty_tile_fraction:.0%} gini={stats.gini:.2f}\n")

    # --- stage 2: load-balancing schedules ----------------------------------
    for sched in (Schedule.THREAD_MAPPED, Schedule.NONZERO_SPLIT,
                  Schedule.MERGE_PATH):
        part = make_partition(spec, sched, num_blocks=16)
        atoms = np.diff(np.asarray(part.atom_starts))
        print(f"{sched.value:15s} atoms/block: min={atoms.min():6d} "
              f"max={atoms.max():6d} (balance ratio "
              f"{atoms.max() / max(atoms.mean(), 1):.2f}x)")

    # --- stage 3: schedule-agnostic execution -------------------------------
    x = jnp.asarray(np.random.default_rng(1).standard_normal(800)
                    .astype(np.float32))
    want = np.asarray(A.to_dense() @ np.asarray(x))
    print()
    for sched in (Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
                  Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH):
        y = spmv(A, x, schedule=sched, num_blocks=16)
        err = float(np.max(np.abs(np.asarray(y) - want)))
        print(f"spmv[{sched.value:15s}] max|err| = {err:.2e}")

    # the paper's heuristic picks for you
    print(f"\nheuristic for this matrix: "
          f"{choose_schedule(A.shape[0], A.nnz).value}")

    # the Pallas TPU kernel (interpret mode on CPU)
    y = kops.spmv_merge_path(A, x)
    print(f"pallas merge-path kernel  max|err| = "
          f"{float(np.max(np.abs(np.asarray(y) - want))):.2e}")


if __name__ == "__main__":
    main()
