"""Serving example: prefill a batch of prompts, then batched autoregressive
decode with temperature sampling — on any assigned architecture.

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6_3b --tokens 24
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.models.lm import prefill
from repro.serve.decode import sample_logits
from repro.models import decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))
    total = args.prompt_len + args.tokens

    pre = jax.jit(lambda p, t: prefill(p, cfg, t, dtype=jnp.float32,
                                       cache_len=total))
    dec = jax.jit(lambda p, t, pos, c: decode_step(p, cfg, t, pos, c,
                                                   dtype=jnp.float32))

    t0 = time.time()
    logits, cache = pre(params, prompts)
    print(f"prefill {args.prompt_len} tokens x {args.batch}: "
          f"{time.time()-t0:.2f}s")

    key = jax.random.PRNGKey(7)
    out = []
    tok = sample_logits(key, logits, args.temperature)
    t0 = time.time()
    for t in range(args.prompt_len, total):
        out.append(tok)
        logits, cache = dec(params, tok, jnp.int32(t), cache)
        key, sub = jax.random.split(key)
        tok = sample_logits(sub, logits, args.temperature)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens} x {args.batch} tokens in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s incl. dispatch)")
    for b in range(args.batch):
        print(f"  seq{b}: {np.asarray(gen[b])[:16]} ...")


if __name__ == "__main__":
    main()
