"""Continuous-batching traversal serving: queries/sec + tail latency.

Measures the serving layer (``repro.serve.graph.GraphServer``) against the
single-query drivers on a mixed BFS/SSSP arrival stream:

* **batched**: all queries through one ``GraphServer`` — W lanes over one
  shared plan pair, retire-and-backfill, exactly one trace of the jitted
  serving step for the whole stream (asserted).
* **sequential**: the shipped single-query path — one driver call per
  query.  Each eager call re-traces its fresh loop closures, which is
  precisely the cost the serving layer's no-retrace contract removes.
* **sequential_precompiled**: the best-case hand-rolled baseline — a
  ``jax.jit`` wrapper per (kind, graph, plan) compiled once, then called
  per query.  Recorded for honesty but not rank-gated: on the CPU bench
  harness vmapped lanes serialize, so batching's win over this baseline
  is dispatch amortization only (a real-accelerator trajectory number).

Latency percentiles (p50/p99, submit-to-retire, queueing included) come
from the per-query timestamps every ``ServedResult`` carries.

A correctness phase serves a small mixed stream *including PageRank* and
asserts every retired answer is bitwise-identical to its driver — the
serving acceptance contract, re-checked on the benchmark graph.

Results merge into ``BENCH_graph.json`` (never clobbering the fig_graph
entries) as a ``_serving`` section plus a ``serving`` marker in
``_summary``; ``rank_check.py`` gates on them.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse import (CSR, Graph, bfs, pagerank, random_csr, sssp,
                          suite_like_corpus)
from repro.serve.graph import GraphServer

#: The serving acceptance graph — the power-law corpus entry the other
#: graph gates (direction switch, delta-stepping, sharding) target too.
SERVE_GRAPH = "corpus/scalefree_web"


def _as_graph(A: CSR) -> Graph:
    return Graph(CSR(A.row_offsets, A.col_indices,
                     jnp.abs(A.values) + 0.05, A.shape, A.nnz))


def _pick_graph(smoke: bool):
    if smoke:
        A = random_csr(120, 120, 700, skew=1.3, empty_frac=0.1, seed=17)
        return "powerlaw/powerlaw_small", _as_graph(A)
    fallback = None
    for cname, A in suite_like_corpus(smoke=False):
        rows, cols = A.shape
        if rows != cols or A.nnz == 0:
            continue
        if f"corpus/{cname}" == SERVE_GRAPH:
            return SERVE_GRAPH, _as_graph(A)
        if fallback is None and A.nnz <= 150_000:
            fallback = (f"corpus/{cname}", _as_graph(A))
    return fallback


def _stream_sources(g: Graph, n: int, target_deg: int = 8):
    """Deterministic medium-degree sources (hubs saturate in one step)."""
    outdeg = np.asarray(g.out_degrees())
    return [int(s) for s in np.argsort(np.abs(outdeg - target_deg))[:n]]


def _driver(kind: str):
    return {"bfs": bfs, "sssp": sssp, "pagerank": pagerank}[kind]


def _driver_answer(g, plan, kind, source):
    if kind == "pagerank":
        return np.asarray(pagerank(g, plan=plan, direction="pull"))
    return np.asarray(_driver(kind)(g, source, plan=plan, direction="pull"))


def run(csv_rows, smoke: bool = False):
    name, g = _pick_graph(smoke)
    lanes = 2 if smoke else 8
    n_queries = 4 if smoke else 16
    srv = GraphServer(g, lanes=lanes, direction="pull", schedule="auto")
    plan = srv.plan

    # -- correctness phase: mixed stream incl. PageRank, bitwise ---------
    sources = _stream_sources(g, max(n_queries, 4))
    mixed = [("bfs", sources[0]), ("sssp", sources[1]), ("pagerank", 0),
             ("bfs", sources[2])]
    qk = {}
    for kind, s in mixed:
        qk[srv.submit(kind, source=s)] = (kind, s)
    mixed_ok = True
    for r in srv.drain():
        kind, s = qk[r.qid]
        want = _driver_answer(g, plan, kind, s)
        got = np.asarray(r.value)
        if got.dtype != want.dtype or not np.array_equal(got, want):
            mixed_ok = False
    one_trace = srv.step_traces == 1 and srv.admit_traces == 1

    # -- throughput phase: BFS+SSSP stream, batched vs sequential --------
    queries = [("bfs" if i % 2 == 0 else "sssp", s)
               for i, s in enumerate(sources[:n_queries])]

    t0 = time.perf_counter()
    for kind, s in queries:
        srv.submit(kind, source=s)
    results = srv.drain()
    batched_s = time.perf_counter() - t0
    one_trace = one_trace and srv.step_traces == 1 and srv.admit_traces == 1
    lat_ms = sorted(r.latency * 1e3 for r in results)
    p50 = lat_ms[len(lat_ms) // 2]
    p99 = lat_ms[min(len(lat_ms) - 1, int(np.ceil(0.99 * len(lat_ms))) - 1)]

    # sequential: the shipped per-query path (re-traces per call)
    t0 = time.perf_counter()
    for kind, s in queries:
        jax.block_until_ready(
            _driver(kind)(g, s, plan=plan, direction="pull"))
    sequential_s = time.perf_counter() - t0

    # precompiled best-case: one jit per kind, compile outside the clock
    jitted = {k: jax.jit(lambda s, _k=k: _driver(_k)(g, s, plan=plan,
                                                     direction="pull"))
              for k in ("bfs", "sssp")}
    for k in jitted:
        jax.block_until_ready(jitted[k](jnp.int32(queries[0][1])))
    t0 = time.perf_counter()
    for kind, s in queries:
        jax.block_until_ready(jitted[kind](jnp.int32(s)))
    precompiled_s = time.perf_counter() - t0

    n = len(queries)
    serving = {
        "graph": name, "V": g.num_vertices, "E": g.num_edges,
        "lanes": lanes, "queries": n,
        "batched_qps": round(n / batched_s, 2),
        "sequential_qps": round(n / sequential_s, 2),
        "sequential_precompiled_qps": round(n / precompiled_s, 2),
        "p50_ms": round(p50, 2), "p99_ms": round(p99, 2),
        "step_traces": srv.step_traces, "admit_traces": srv.admit_traces,
        "mixed_bitwise": mixed_ok,
    }
    ok = (mixed_ok and one_trace
          and serving["batched_qps"] >= serving["sequential_qps"])

    # merge (never clobber) into the fig_graph-owned JSON
    out_dir = os.environ.get("REPRO_BENCH_DIR")
    if out_dir or not smoke:
        path = pathlib.Path(out_dir or ".") / "BENCH_graph.json"
        try:
            bench = json.loads(path.read_text()) if path.exists() else {}
            bench["_serving"] = serving
            bench.setdefault("_summary", {})["serving"] = (
                "ok" if ok else "regressed")
            path.write_text(json.dumps(bench, indent=1))
        except OSError:
            pass   # read-only CWD: the CSV rows still carry the numbers

    csv_rows.append((
        f"fig_serve/{name}", round(batched_s * 1e6 / n, 1),
        f"serving={'ok' if ok else 'regressed'};"
        f"batched_qps={serving['batched_qps']};"
        f"sequential_qps={serving['sequential_qps']};"
        f"precompiled_qps={serving['sequential_precompiled_qps']};"
        f"p50_ms={serving['p50_ms']};p99_ms={serving['p99_ms']};"
        f"step_traces={srv.step_traces};"
        f"mixed_bitwise={mixed_ok}"))
