"""Beyond-paper benchmark — load-balanced document packing efficiency.

Merge-path packing of power-law documents into batch rows vs the naive
one-document-per-row padding (tokens kept / tokens padded)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.data.packing import packing_efficiency


def run(csv_rows, smoke=False):
    rng = np.random.default_rng(5)
    ndocs = 64 if smoke else 512
    for tail in ((1.2,) if smoke else (0.8, 1.2, 2.0)):
        lens = (rng.pareto(tail, ndocs) * 80 + 1).astype(np.int64)
        stats = packing_efficiency(lens, 32)
        csv_rows.append(
            (f"packing/pareto{tail}", 0.0,
             f"balanced_eff={stats['balanced_efficiency']:.3f};"
             f"naive_eff={stats['naive_efficiency']:.3f};"
             f"tokens={stats['tokens']}"))
