"""Paper Fig. 4 — heuristic schedule selection vs a fixed baseline.

The paper combines its schedules with the §6.2 heuristic (merge-path unless
rows/cols < alpha and nnz < beta) and beats cuSparse by geomean 2.7x.  Our
stand-in for the vendor baseline is the fixed merge-path-only configuration
(the strongest single schedule); the benchmark reports the per-dataset and
geomean speedup of heuristic selection, on both measured time and modeled
lockstep cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (Schedule, blocked_tile_reduce, choose_schedule,
                        make_partition, modeled_cost)
from repro.sparse import suite_like_corpus

from benchmarks._timing import geomean, time_fn

NUM_BLOCKS = 64


def run(csv_rows, smoke=False):
    key = jax.random.PRNGKey(2)
    speedups_t, speedups_m = [], []
    for name, A in suite_like_corpus(smoke=smoke):
        x = jax.random.normal(jax.random.fold_in(key, hash(name) % 2**31),
                              (A.shape[1],), jnp.float32)
        spec = A.workspec()
        chosen = choose_schedule(A.shape[0], A.nnz)

        def timed(sched):
            part = make_partition(spec, sched, NUM_BLOCKS)

            @jax.jit
            def f(vals, cols, x, _p=part, _s=spec):
                atom_fn = lambda nz: vals[nz] * x[cols[nz]]
                return blocked_tile_reduce(_s, _p, atom_fn)

            return time_fn(f, A.values, A.col_indices, x, warmup=1, iters=3)

        t_heur = timed(chosen)
        t_base = timed(Schedule.MERGE_PATH)
        m_heur = modeled_cost(spec, chosen, NUM_BLOCKS)
        m_base = modeled_cost(spec, Schedule.MERGE_PATH, NUM_BLOCKS)
        speedups_t.append(t_base / t_heur)
        speedups_m.append(m_base / max(m_heur, 1e-9))
        csv_rows.append((f"fig4/{name}", t_heur,
                         f"chosen={chosen};speedup_t={t_base/t_heur:.2f};"
                         f"speedup_model={m_base/max(m_heur,1e-9):.2f}"))
    csv_rows.append(("fig4/geomean", 0.0,
                     f"speedup_t={geomean(speedups_t):.2f};"
                     f"speedup_model={geomean(speedups_m):.2f};"
                     f"peak_t={max(speedups_t):.2f};"
                     f"peak_model={max(speedups_m):.2f}"))
