"""Ordinal perf-ranking gate over a committed ``BENCH_graph.json``.

CI boxes are too noisy for wall-clock thresholds, but *rankings* are stable:
on a scale-free graph the chunked work queue beats every static schedule by
integer factors, and a direction-optimizing BFS beats pull-only whenever
sparse-frontier iterations exist — orderings that survive machine jitter
even when absolute microseconds do not.  This script asserts those ordinal
invariants against the committed benchmark JSON (refreshed by full
``fig_graph`` runs, uploaded fresh per CI run for trajectory grooming) and
exits non-zero on any violation, so a perf regression that flips an
ordering fails the ``bench-rank`` job without a single timing threshold.

Usage: ``python benchmarks/rank_check.py [BENCH_graph.json]``
"""
from __future__ import annotations

import json
import pathlib
import sys

STATIC_SCHEDULES = ("thread_mapped", "group_mapped", "nonzero_split",
                    "merge_path")

#: The scale-free corpus entry where the dynamic queue must stay on top.
QUEUE_WINS_ON = "corpus/scalefree_web"

#: Modeled-regret ceiling: "auto" must pick the modeled argmin (regret 1.0);
#: the epsilon only absorbs the JSON rounding.
MAX_AUTO_REGRET = 1.001

#: Degree-aware boundary floor: equal_width's best sweep point over
#: edge_balanced's best sweep point on the skewed corpus graph (each
#: schedule at its own best shard count).  Every point runs the identical
#: compiled program (only the boundary placement differs), so >= 1.0 is
#: the structural expectation on a hub-skewed graph; the floor sits just
#: below it to absorb min-of-5 timer noise on shared CI boxes.
EB_VS_EW_FLOOR = 0.95


def check(bench: dict) -> list:
    failures = []

    def ensure(cond: bool, msg: str) -> None:
        if not cond:
            failures.append(msg)

    # 1. dynamic-queue ranking: chunked beats every static schedule on the
    #    scale-free web graph (the Atos regime) in measured wall-clock.
    entry = bench.get(QUEUE_WINS_ON)
    ensure(entry is not None, f"missing benchmark entry: {QUEUE_WINS_ON}")
    if entry:
        us = entry["schedules_us"]
        for sched in STATIC_SCHEDULES:
            ensure(us["chunked"] < us[sched],
                   f"{QUEUE_WINS_ON}: chunked ({us['chunked']}us) no longer "
                   f"beats {sched} ({us.get(sched)}us)")

    # 2. autotuner regret: "auto" is the modeled argmin on every workload.
    for name, e in bench.items():
        if name.startswith("_"):
            continue
        ensure(e.get("auto_regret", 1.0) <= MAX_AUTO_REGRET,
               f"{name}: auto_regret {e.get('auto_regret')} > "
               f"{MAX_AUTO_REGRET}")

    # 2b. measured-cost feedback loop (PR 6): on every workload carrying
    #     both fields, the measured-mode choice's *measured* regret must
    #     not exceed the model-only choice's — measured mode saw every
    #     candidate's wall-clock, so ranking by it can only improve the
    #     pick.  Asserted hardest on the advance-family acceptance graph.
    for name, e in bench.items():
        if name.startswith("_") or "measured_mode_regret" not in e:
            continue
        ensure(e["measured_mode_regret"]
               <= e.get("model_only_regret_measured", float("inf")) + 1e-3,
               f"{name}: measured-mode regret {e['measured_mode_regret']} "
               f"worse than model-only "
               f"{e.get('model_only_regret_measured')}")
    entry_acc = bench.get(QUEUE_WINS_ON, {})
    ensure("measured_mode_regret" in entry_acc,
           f"{QUEUE_WINS_ON}: missing measured_mode_regret (measured-mode "
           f"autotuning never ran on the acceptance graph)")
    ensure(bench.get("_summary", {}).get("measured_loop") == "ok",
           f"measured-cost loop regressed: "
           f"{bench.get('_summary', {}).get('measured_loop')}")

    # 3. push-direction ranking: with a ~30%-active frontier the push
    #    scatter must not be slower than the pull tile-reduce under
    #    merge-path (pull pays the full local-binning contraction; push
    #    windows skip it) on the queue-wins graph.
    if entry and "schedules_push_us" in entry:
        ensure(entry["schedules_push_us"]["merge_path"]
               < entry["schedules_us"]["merge_path"],
               f"{QUEUE_WINS_ON}: push merge_path advance "
               f"({entry['schedules_push_us']['merge_path']}us) not faster "
               f"than pull ({entry['schedules_us']['merge_path']}us)")

    # 4. direction-optimizing BFS: beats pull-only on the power-law corpus
    #    graph, and both directions actually ran.
    d = bench.get("_bfs_direction")
    ensure(d is not None, "missing _bfs_direction entry")
    if d:
        ensure(d["direction_optimizing_us"] < d["pull_only_us"],
               f"direction-optimizing BFS ({d['direction_optimizing_us']}us)"
               f" not faster than pull-only ({d['pull_only_us']}us)")
        ensure(d["push_iters"] > 0, "direction sweep never ran push")
        ensure(d["pull_iters"] > 0, "direction sweep never ran pull")

    # 5. delta-stepping SSSP: the best bucket width is no slower than the
    #    frontier Bellman-Ford on the weighted scale-free corpus graph.
    #    Near-structural rather than strictly so: the Delta -> inf sweep
    #    point runs Bellman-Ford's exact advance sequence but pays small
    #    bucket bookkeeping on top, and the committed best (width = mean
    #    weight) wins by staying on sparse push frontiers (~1.7x in the
    #    committed run) — min-of-5 sweep sampling plus that margin is
    #    what absorbs refresh noise.  Width tuning is delta-stepping's
    #    own game (Meyer & Sanders' Delta is a free parameter).
    ds = bench.get("_sssp_delta")
    ensure(ds is not None, "missing _sssp_delta entry")
    if ds:
        ensure(ds["best_us"] <= ds["bellman_ford_us"],
               f"delta-stepping best ({ds['best_us']}us, width "
               f"{ds.get('best')}) slower than Bellman-Ford "
               f"({ds['bellman_ford_us']}us) on {ds.get('graph')}")
        ensure(len(ds.get("sweep_us", {})) >= 3,
               "delta-stepping width sweep too small")
        ensure(ds.get("compact_us", 0) > 0,
               "compacted-window delta ride-along missing")
        # the SSSP direction switch must actually fire: the best width's
        # sparse bucket frontiers run push phases (counts threaded
        # through the carry by sssp/delta_stepping's
        # return_direction_counts)
        best_advances = ds.get("advances", {}).get(ds.get("best"), [0, 0])
        ensure(best_advances[0] > 0,
               f"best-width delta-stepping never ran a push phase "
               f"({best_advances})")

    # 6. mesh-sharded BFS (PR 7): the 1-shard mesh must reproduce the
    #    unsharded driver bitwise (the recursion's base case — any halo or
    #    padding defect breaks it even on one device), and the measured
    #    count selection can never regret more than the model-only pick
    #    (same closed-loop argument as 2b: measured mode saw every
    #    candidate's wall-clock).  Shard *speedup* is recorded but not
    #    ranked — on a forced-host-device CPU harness the collective
    #    round-trips swamp the per-shard compute shrink; the speedup
    #    column is a real-hardware trajectory number.
    sh = bench.get("_sharded")
    ensure(sh is not None, "missing _sharded entry (mesh-sharded BFS "
                           "sweep never ran)")
    if sh:
        ensure(sh.get("one_shard_bitwise") is True,
               f"{sh.get('graph')}: 1-shard sharded BFS no longer "
               f"bitwise-identical to the unsharded driver")
        ensure(sh.get("sharded_auto_regret", float("inf"))
               <= sh.get("sharded_model_only_regret", 0.0) + 1e-3,
               f"{sh.get('graph')}: measured shard-count selection regret "
               f"{sh.get('sharded_auto_regret')} worse than model-only "
               f"{sh.get('sharded_model_only_regret')}")
        ensure(len(sh.get("sweep_us", {})) >= 1,
               "sharded sweep recorded no shard counts")
        ensure(len(sh.get("sweep_us", {})) >= len(sh.get("counts", [])),
               "sharded sweep dropped candidate counts")

    # 6b. boundary schedules (PR 10): the sweep must cover every
    #     registered boundary schedule (each bitwise-asserted inside
    #     fig_graph before timing), and on the skewed scale-free graph
    #     the degree-aware edge_balanced placement's best sweep point
    #     must be no slower than uniform equal_width's best sweep point.
    #     That head-to-head is near-structural: the two builds run the
    #     identical compiled program and collective sequence, differing
    #     only in where the contiguous boundaries land, so on a
    #     hub-skewed graph balancing edges can only shrink the max-shard
    #     work — EB_VS_EW_FLOOR (just under 1.0) is the min-of-5
    #     timer-noise allowance, same role as the 2b epsilon.
    if sh:
        bsweep = sh.get("boundary_sweep_us", {})
        for bname in sh.get("boundaries", []):
            ensure(len(bsweep.get(bname, {})) >= 1,
                   f"boundary sweep missing schedule {bname!r}")
        ensure(len(bsweep.get("equal_width", {}))
               >= len(sh.get("counts", [])),
               "equal_width boundary sweep dropped candidate counts")
        ratio = sh.get("edge_balanced_vs_equal_width")
        if sh.get("devices", 1) > 1:
            ensure(ratio is not None,
                   "multi-device sweep missing the edge_balanced vs "
                   "equal_width head-to-head")
        if ratio is not None:
            ensure(ratio >= EB_VS_EW_FLOOR,
                   f"{sh.get('graph')}: edge_balanced best point "
                   f"{ratio}x equal_width's best point "
                   f"({sh.get('equal_width_best')}) — degree-aware "
                   f"boundaries regressed below {EB_VS_EW_FLOOR}x")
        # joint (count, boundary) auto-selection must honour the same
        # measured-beats-model ordering checked in 6 — re-assert here so
        # a boundary-dimension regression names itself
        ensure(sh.get("sharded_auto_regret", float("inf"))
               <= sh.get("sharded_model_only_regret", 0.0) + 1e-3,
               f"{sh.get('graph')}: joint (count, boundary) measured "
               f"selection regret {sh.get('sharded_auto_regret')} worse "
               f"than model-only "
               f"{sh.get('sharded_model_only_regret')}")

    # 7. continuous-batching serving (PR 8): the lane-batched server must
    #    beat the shipped sequential single-query path in queries/sec on
    #    the corpus stream (sequential re-traces its loop closures per
    #    call — exactly the cost the no-retrace serving step removes),
    #    the whole stream must have been served on ONE trace of the step,
    #    tail latency must be reported, and the mixed BFS/SSSP/PageRank
    #    correctness phase must have stayed bitwise vs the drivers.  The
    #    precompiled-baseline column is recorded but not ranked (CPU
    #    lanes serialize under vmap; see fig_serve.py).
    sv = bench.get("_serving")
    ensure(sv is not None, "missing _serving entry (fig_serve never ran)")
    if sv:
        ensure(sv.get("batched_qps", 0) >= sv.get("sequential_qps",
                                                  float("inf")),
               f"{sv.get('graph')}: batched serving "
               f"({sv.get('batched_qps')} qps) no longer beats sequential "
               f"single-query ({sv.get('sequential_qps')} qps)")
        ensure(sv.get("p99_ms", 0) > 0, "serving p99 latency not reported")
        ensure(sv.get("p50_ms", 0) > 0, "serving p50 latency not reported")
        ensure(sv.get("step_traces") == 1,
               f"serving step traced {sv.get('step_traces')} times "
               f"(no-retrace contract broken)")
        ensure(sv.get("mixed_bitwise") is True,
               "served mixed-stream answers no longer bitwise-identical "
               "to the single-query drivers")

    # 8. wavefront DAG evaluation (PR 9): on the fan-in-skewed forest —
    #    one hub aggregator owns hundreds of dependency in-edges while
    #    chain nodes own one, exactly the skew the dynamic work queue
    #    exists for — the chunked combine must not be slower than the
    #    *worst* static schedule (weaker than the scale-free advance gate
    #    in section 1: the combine replays per feature column under vmap,
    #    which flattens some of the queue's win).  The level count pins
    #    the multi-level structure (a 1-level "DAG" would vacuously pass
    #    everything), and auto must still be the modeled argmin.  The
    #    sequential-oracle speedup is recorded, not ranked — a Python
    #    per-node loop is not a serious baseline, just the recursion the
    #    scheduler replaces.
    wf = bench.get("_wavefront")
    ensure(wf is not None, "missing _wavefront entry (fig_wavefront never "
                           "ran)")
    if wf:
        q = wf.get("graphs", {}).get(wf.get("queue_graph", ""), {})
        ensure(bool(q), f"missing wavefront queue graph entry "
                        f"{wf.get('queue_graph')}")
        if q:
            cu = q.get("combine_us", {})
            worst_static = max((cu.get(s, 0.0) for s in STATIC_SCHEDULES),
                              default=0.0)
            ensure(cu.get("chunked", float("inf")) <= worst_static,
                   f"{wf.get('queue_graph')}: chunked combine "
                   f"({cu.get('chunked')}us) slower than the worst static "
                   f"schedule ({worst_static}us)")
            ensure(q.get("levels", 0) >= 3,
                   f"wavefront queue graph has {q.get('levels')} levels "
                   f"(need >= 3 for a real multi-level gate)")
        for gname, e in wf.get("graphs", {}).items():
            ensure(e.get("auto_regret", 1.0) <= MAX_AUTO_REGRET,
                   f"wavefront/{gname}: auto_regret "
                   f"{e.get('auto_regret')} > {MAX_AUTO_REGRET}")
        ensure(wf.get("status") == "ok",
               f"wavefront gate not healthy: {wf.get('status')}")

    # 9. liveness markers recorded by the full run.
    summary = bench.get("_summary", {})
    ensure(summary.get("native_path") == "ok",
           f"native path not exercised: {summary.get('native_path')}")
    ensure(summary.get("direction_switch") == "ok",
           f"direction switch not exercised: "
           f"{summary.get('direction_switch')}")
    ensure(summary.get("delta_stepping") == "ok",
           f"delta-stepping not competitive: "
           f"{summary.get('delta_stepping')}")
    ensure(summary.get("sharded") == "ok",
           f"sharded sweep not healthy: {summary.get('sharded')}")
    ensure(summary.get("serving") == "ok",
           f"serving gate not healthy: {summary.get('serving')}")
    ensure(summary.get("wavefront") == "ok",
           f"wavefront gate not healthy: {summary.get('wavefront')}")
    ensure(bench.get("_bfs_batched", {}).get("sources", 0) > 1,
           "batched multi-source BFS sweep missing")
    return failures


def main() -> None:
    path = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                        else "BENCH_graph.json")
    bench = json.loads(path.read_text())
    failures = check(bench)
    for f in failures:
        print(f"RANK-CHECK FAIL: {f}")
    print(f"rank_check: {len(failures)} failures over "
          f"{sum(not k.startswith('_') for k in bench)} workloads "
          f"({path})")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
