"""Beyond-paper benchmark — load-balanced graph frontier operators (§5.3).

The paper's §5.3 evaluation drives graph traversal through a balanced
``advance``; this figure measures what the schedule library buys that
workload on TPU.  Workload sweep:

* power-law digraphs across skew settings (the frontier load-imbalance
  regime — a few hubs own most out-edges), and
* corpus graphs: square matrices from the SuiteSparse-like corpus
  reinterpreted as adjacency (scale-free web, banded FEM, empty-heavy).

Per graph we report, for a ~30%-active frontier advance (min-combiner relax,
the SSSP inner loop): measured wall-time of every registered schedule on the
pure executor, the native chunk-walking path's wall-time (interpret-mode
liveness, not a TPU number), the modeled advance cost per schedule
(``workload="advance"`` family), and the auto plan + its regret vs the exact
argmin.  A BFS/SSSP equivalence guard cross-checks three schedules per
graph, so the figure doubles as an end-to-end liveness gate for the graph
subsystem (CI greps the ``graph_native_path=ok`` marker).

Results also land in ``BENCH_graph.json`` (cwd, override dir with
``REPRO_BENCH_DIR``): per-schedule advance timings + auto regret per
workload, so the perf trajectory captures the graph workload from this PR
on.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core import Schedule, modeled_advance_cost, select_plan
from repro.core.autotune import AutotuneCache, REGISTERED_PLANS, score_plans
from repro.sparse import (CSR, Graph, advance_relax_min, bfs, build_advance,
                          sssp, random_csr, suite_like_corpus)

from benchmarks._timing import time_fn

NUM_BLOCKS = 32
SCHEDULES = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
             Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH,
             Schedule.CHUNKED, Schedule.ADAPTIVE]

#: Native interpret-mode timing is CI liveness, not a TPU number — skip the
#: kernel interpreter on large edge sets to keep the job fast.
NATIVE_EDGE_CAP = 20_000


def _as_graph(A: CSR) -> Graph:
    """Adjacency from a corpus matrix: positive weights, same sparsity."""
    return Graph(CSR(A.row_offsets, A.col_indices,
                     jnp.abs(A.values) + 0.05, A.shape, A.nnz))


def graph_sweep(smoke: bool = False):
    out = []
    if smoke:
        cases = [("powerlaw_small", 120, 700, 1.3, 0.1),
                 ("uniform_small", 100, 500, 0.0, 0.0)]
    else:
        cases = [("powerlaw_mild", 2_000, 12_000, 0.9, 0.1),
                 ("powerlaw_heavy", 2_000, 16_000, 1.4, 0.2),
                 ("powerlaw_extreme", 1_000, 10_000, 1.8, 0.3),
                 ("uniform", 2_000, 10_000, 0.0, 0.0)]
    for name, V, E, skew, empty in cases:
        A = random_csr(V, V, E, skew=skew, empty_frac=empty, seed=17)
        out.append((f"powerlaw/{name}" if skew else f"uniform/{name}",
                    _as_graph(A)))
    for cname, A in suite_like_corpus(smoke=smoke):
        rows, cols = A.shape
        if rows != cols or A.nnz == 0:
            continue
        if smoke or A.nnz <= 150_000:
            out.append((f"corpus/{cname}", _as_graph(A)))
    return out


def _frontier(V: int, seed: int = 5, frac: float = 0.3) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    f = rng.random(V) < frac
    f[0] = True
    return jnp.asarray(f)


def run(csv_rows, smoke: bool = False):
    cache = AutotuneCache("/tmp/repro_fig_graph_cache.json")
    cache.clear()   # score fresh: this figure measures selection, not cache
    bench: dict = {}
    regrets = []
    native_ok = False
    guard_case = None            # first sweep entry, reused by the guard
    for name, g in graph_sweep(smoke):
        if guard_case is None:
            guard_case = (name, g)
        V, E = g.num_vertices, g.num_edges
        spec = g.csr.transpose().workspec()
        frontier = _frontier(V)
        pot = jnp.asarray(np.random.default_rng(3).integers(0, 32, V)
                          .astype(np.float32))

        entry = {"V": V, "E": E, "schedules_us": {}, "modeled": {}}
        timings = {}
        oracle = None
        for sched in SCHEDULES:
            plan = build_advance(g, schedule=sched, num_blocks=NUM_BLOCKS,
                                 path="pure")
            f = lambda p, fr, _plan=plan: advance_relax_min(_plan, p, fr)
            got = np.asarray(f(pot, frontier))
            if oracle is None:
                oracle = got
            else:
                np.testing.assert_array_equal(got, oracle, err_msg=str(sched))
            us = time_fn(f, pot, frontier, warmup=1, iters=3)
            timings[str(sched)] = us
            entry["schedules_us"][str(sched)] = round(us, 1)
            entry["modeled"][str(sched)] = modeled_advance_cost(
                spec, sched, NUM_BLOCKS)

        if E <= NATIVE_EDGE_CAP:
            nplan = build_advance(g, schedule="chunked_lpt",
                                  num_blocks=NUM_BLOCKS, path="native")
            fn = lambda p, fr, _plan=nplan: advance_relax_min(_plan, p, fr)
            np.testing.assert_array_equal(np.asarray(fn(pot, frontier)),
                                          oracle)
            entry["native_chunked_us"] = round(
                time_fn(fn, pot, frontier, warmup=1, iters=3), 1)
            native_ok = True

        # auto plan + regret vs the exact advance-family argmin
        auto_plan = select_plan(spec, NUM_BLOCKS, cache=cache,
                                workload="advance")
        scores = score_plans(spec, NUM_BLOCKS, REGISTERED_PLANS, "advance")
        regret = scores[auto_plan] / max(min(scores.values()), 1e-9)
        regrets.append(regret)
        entry["auto"] = auto_plan.encode()
        entry["auto_regret"] = round(regret, 4)
        bench[name] = entry

        best = min(timings, key=timings.get)
        detail = ";".join(f"{s}={timings[s]:.0f}" for s in timings)
        csv_rows.append((f"fig_graph/{name}", timings[best],
                         f"auto={auto_plan.encode()};regret={regret:.3f};"
                         f"best={best};{detail}"))

    # traversal liveness: BFS + SSSP agree across three schedule families
    gname, g = guard_case
    depth = {s: np.asarray(bfs(g, 0, schedule=s, num_blocks=8))
             for s in ("merge_path", "chunked_lpt", "adaptive")}
    dists = {s: np.asarray(sssp(g, 0, schedule=s, num_blocks=8))
             for s in ("merge_path", "chunked_lpt", "adaptive")}
    for s in depth:
        np.testing.assert_array_equal(depth[s], depth["merge_path"])
        np.testing.assert_array_equal(dists[s], dists["merge_path"])
    bench["_summary"] = {
        "max_auto_regret": round(max(regrets), 4),
        "traversal_guard": gname,
        "native_path": "ok" if native_ok else "skipped",
    }

    out_dir = pathlib.Path(os.environ.get("REPRO_BENCH_DIR", "."))
    try:
        (out_dir / "BENCH_graph.json").write_text(json.dumps(bench, indent=1))
    except OSError:
        pass   # read-only CWD: the CSV rows still carry the numbers
    csv_rows.append(
        ("fig_graph/summary", 0.0,
         f"max_auto_regret={max(regrets):.3f};"
         f"graph_native_path={'ok' if native_ok else 'skipped'};"
         f"json=BENCH_graph.json"))
