"""Beyond-paper benchmark — load-balanced graph frontier operators (§5.3).

The paper's §5.3 evaluation drives graph traversal through a balanced
``advance``; this figure measures what the schedule library buys that
workload on TPU.  Workload sweep:

* power-law digraphs across skew settings (the frontier load-imbalance
  regime — a few hubs own most out-edges), and
* corpus graphs: square matrices from the SuiteSparse-like corpus
  reinterpreted as adjacency (scale-free web, banded FEM, empty-heavy).

Per graph we report, for a ~30%-active frontier advance (min-combiner relax,
the SSSP inner loop): measured wall-time of every registered schedule on the
pure executor in *both* directions (pull tile-reduce and push
scatter-reduce — asserted equal against one oracle, so the figure doubles
as a direction-equivalence gate), the native chunk-walking path's wall-time
(interpret-mode liveness, not a TPU number), the modeled advance cost per
schedule (``workload="advance"`` family), the plan pair's modeled direction
threshold, and the auto plan + its regret vs the exact argmin.

Two traversal-level sweeps ride the same plans:

* **Direction-optimizing BFS** on the power-law corpus graph: pull-only vs
  measured-density push/pull switching from a medium-degree source (sparse
  frontiers long enough for push to pay).  Emits the
  ``direction_switch=ok`` marker CI greps — proof both directions actually
  ran — and the wall-clock pair the ``bench-rank`` job orders.
* **Batched multi-source BFS** (``bfs_multi``): one plan pair, vmapped
  carries — the inspect-once story at batch scale.
* **Mesh-sharded BFS** (``build_sharded_advance`` + ``sharded_bfs``): every
  (shard count, boundary schedule) point's labels asserted bitwise against
  the single-device driver (emits the ``sharded=ok`` marker) — the sweep
  crosses the candidate counts with every ``SHARD_SCHEDULES`` boundary
  placement — with shard speedup, the edge_balanced-vs-equal_width
  head-to-head at equal_width's best count, and measured-vs-model
  (count, boundary) selection regret recorded for the ``bench-rank``
  invariants.
* **Delta-stepping SSSP** (``delta_stepping``): a bucket-width sweep
  (including the Delta -> inf Bellman-Ford degeneration) vs the frontier
  Bellman-Ford ``sssp`` — every point asserted bitwise-identical first —
  plus a gather-compacted-window ride-along.  The best width's ordering
  (delta <= Bellman-Ford) is the ``bench-rank`` job's delta invariant.

A BFS/SSSP equivalence guard cross-checks three schedules per graph, so the
figure doubles as an end-to-end liveness gate for the graph subsystem (CI
greps the ``graph_native_path=ok`` marker).

Results also land in ``BENCH_graph.json`` (cwd, override dir with
``REPRO_BENCH_DIR``): per-schedule advance timings + auto regret per
workload plus the ``_bfs_direction``/``_bfs_batched`` traversal entries, so
the perf trajectory captures the graph workload from this PR on.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Schedule, modeled_advance_cost, select_plan
from repro.core.autotune import (AutotuneCache, REGISTERED_PLANS,
                                 select_sharded_plan, score_plans)
from repro.sparse import (CSR, SHARD_SCHEDULES, Graph, advance_relax_min,
                          bfs, bfs_multi, build_advance,
                          build_sharded_advance, delta_stepping,
                          estimate_delta, shard_boundaries, sharded_bfs,
                          sssp, random_csr, suite_like_corpus)
from repro.sparse.shard import _candidate_shard_counts

from benchmarks._timing import time_fn

NUM_BLOCKS = 32
SCHEDULES = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
             Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH,
             Schedule.CHUNKED, Schedule.ADAPTIVE]

#: Native interpret-mode timing is CI liveness, not a TPU number — skip the
#: kernel interpreter on large edge sets to keep the job fast.
NATIVE_EDGE_CAP = 20_000

#: The direction-optimizing BFS sweep targets this graph (the power-law
#: corpus entry of the acceptance gate) in full runs.
DIRECTION_GRAPH = "corpus/scalefree_web"


def _as_graph(A: CSR) -> Graph:
    """Adjacency from a corpus matrix: positive weights, same sparsity."""
    return Graph(CSR(A.row_offsets, A.col_indices,
                     jnp.abs(A.values) + 0.05, A.shape, A.nnz))


def graph_sweep(smoke: bool = False):
    out = []
    if smoke:
        cases = [("powerlaw_small", 120, 700, 1.3, 0.1),
                 ("uniform_small", 100, 500, 0.0, 0.0)]
    else:
        cases = [("powerlaw_mild", 2_000, 12_000, 0.9, 0.1),
                 ("powerlaw_heavy", 2_000, 16_000, 1.4, 0.2),
                 ("powerlaw_extreme", 1_000, 10_000, 1.8, 0.3),
                 ("uniform", 2_000, 10_000, 0.0, 0.0)]
    for name, V, E, skew, empty in cases:
        A = random_csr(V, V, E, skew=skew, empty_frac=empty, seed=17)
        out.append((f"powerlaw/{name}" if skew else f"uniform/{name}",
                    _as_graph(A)))
    for cname, A in suite_like_corpus(smoke=smoke):
        rows, cols = A.shape
        if rows != cols or A.nnz == 0:
            continue
        if smoke or A.nnz <= 150_000:
            out.append((f"corpus/{cname}", _as_graph(A)))
    return out


def _frontier(V: int, seed: int = 5, frac: float = 0.3) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    f = rng.random(V) < frac
    f[0] = True
    return jnp.asarray(f)


def _medium_degree_source(g: Graph, target: int = 8) -> int:
    """A deterministic source whose traversal stays sparse for a while.

    Hubs saturate the graph in one step (no direction story) and
    zero-degree vertices reach nothing; a medium out-degree source gives
    the multi-iteration sparse->dense frontier evolution the push/pull
    switch exists for.
    """
    outdeg = np.asarray(g.out_degrees())
    return int(np.argmin(np.abs(outdeg - target)))


def direction_sweep(name: str, g: Graph, plan, bench: dict,
                    csv_rows) -> bool:
    """Pull-only vs direction-optimizing BFS + the batched-BFS sweep.

    ``plan`` is the merge-path plan pair the schedule loop already built
    for this graph (one inspector pass serves the whole figure).  Returns
    True when the direction-optimizing run exercised *both* directions
    (the ``direction_switch=ok`` evidence).
    """
    source = _medium_degree_source(g)
    depth_pull = np.asarray(bfs(g, source, plan=plan, direction="pull"))
    depth_auto, counts = bfs(g, source, plan=plan, direction="auto",
                             return_direction_counts=True)
    np.testing.assert_array_equal(np.asarray(depth_auto), depth_pull,
                                  err_msg="direction changed BFS labels")
    pushes, pulls = (int(x) for x in np.asarray(counts))
    pull_us = time_fn(lambda: np.asarray(
        bfs(g, source, plan=plan, direction="pull")), warmup=1, iters=3)
    auto_us = time_fn(lambda: np.asarray(
        bfs(g, source, plan=plan, direction="auto")), warmup=1, iters=3)

    sources = list(range(0, g.num_vertices,
                         max(g.num_vertices // 4, 1)))[:4]
    batched = np.asarray(bfs_multi(g, sources, plan=plan,
                                   direction="pull"))
    for i, s in enumerate(sources):   # batched liveness: same labels
        np.testing.assert_array_equal(
            batched[i], np.asarray(bfs(g, s, plan=plan, direction="pull")),
            err_msg=f"bfs_multi diverged at source {s}")
    batched_us = time_fn(lambda: np.asarray(
        bfs_multi(g, sources, plan=plan, direction="pull")),
        warmup=1, iters=2)

    switched = pushes > 0 and pulls > 0
    bench["_bfs_direction"] = {
        "graph": name, "source": source,
        "direction_threshold": round(plan.direction_threshold, 4),
        "pull_only_us": round(pull_us, 1),
        "direction_optimizing_us": round(auto_us, 1),
        "push_iters": pushes, "pull_iters": pulls,
        "speedup": round(pull_us / max(auto_us, 1e-9), 3),
    }
    bench["_bfs_batched"] = {
        "graph": name, "sources": len(sources),
        "batched_us": round(batched_us, 1),
        "batched_us_per_source": round(batched_us / max(len(sources), 1), 1),
    }
    csv_rows.append(
        (f"fig_graph/bfs_direction/{name}", auto_us,
         f"pull_only={pull_us:.0f};speedup={pull_us / max(auto_us, 1e-9):.2f};"
         f"push_iters={pushes};pull_iters={pulls};"
         f"threshold={plan.direction_threshold:.3f}"))
    csv_rows.append(
        (f"fig_graph/bfs_batched/{name}", batched_us,
         f"sources={len(sources)};per_source={batched_us / len(sources):.0f}"))
    return switched


#: Bucket-width multipliers of the delta-stepping sweep (of the estimated
#: width); the huge last entry is the Delta -> inf Bellman-Ford
#: degeneration — one bucket, no heavy phase — so the sweep's best can
#: never structurally regress below the Bellman-Ford baseline.
DELTA_SWEEP = (("0.5x", 0.5), ("1x", 1.0), ("2x", 2.0), ("4x", 4.0),
               ("inf", 1e9))


def delta_sweep(name: str, g: Graph, plan, bench: dict, csv_rows) -> bool:
    """Delta-stepping vs frontier Bellman-Ford on the direction graph.

    Rides the same merge-path plan pair as the direction sweep.  Drivers
    are wrapped in ``jax.jit`` so the timings measure compiled execution,
    not per-call retracing of the nested bucket loops (unjitted
    ``lax.while_loop`` re-traces every call; the schedule sweep's single
    advances are cheap to retrace, a bucketed traversal is not).  Every
    sweep point is asserted **bitwise equal** to Bellman-Ford first — the
    figure doubles as the delta-equivalence gate.  The committed JSON
    carries the full width sweep plus the best pick; ``rank_check``
    asserts best <= Bellman-Ford (the Delta -> inf degeneration makes
    that ordering structural, and width tuning is the delta-stepping
    game — Meyer & Sanders' Delta is a free parameter).

    A gather-compacted plan rides along (``compact_us``): on this CPU
    harness the O(E) index build roughly cancels the window shrink, so it
    is recorded for the trajectory, not ranked — the compaction win is a
    DMA-volume story for real TPU runs (docs/graph.md).
    """
    source = _medium_degree_source(g)
    f_bf = jax.jit(lambda s: sssp(g, s, plan=plan, direction="auto"))
    want = np.asarray(f_bf(source))
    # same timing discipline as the sweep points below (block, no
    # device-to-host copy) so the ranked comparison is symmetric
    bf_us = time_fn(lambda: jax.block_until_ready(f_bf(source)),
                    warmup=1, iters=5)

    base = plan.delta if plan.delta is not None else estimate_delta(
        plan.push_weight)
    sweep = {}
    best_label, best_us = None, float("inf")
    counts = {}
    for label, mult in DELTA_SWEEP:
        p = plan.with_delta(base * mult)
        # one compiled callable serves the equality check, the counts and
        # the timing — an unjitted extra call would re-trace the nested
        # bucket loops per invocation (see docstring)
        f = jax.jit(lambda s, _p=p: delta_stepping(
            g, s, plan=_p, direction="auto",
            return_direction_counts=True))
        got, c = f(source)
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint32), want.view(np.uint32),
            err_msg=f"delta-stepping ({label}) diverged from Bellman-Ford")
        us = time_fn(lambda: jax.block_until_ready(f(source)[0]),
                     warmup=1, iters=5)
        counts[label] = [int(x) for x in np.asarray(c)]
        sweep[label] = round(us, 1)
        if us < best_us:
            best_label, best_us = label, us

    # compacted-window liveness ride-along (same width, fresh plan pair)
    cplan = build_advance(g, schedule="merge_path",
                          num_blocks=NUM_BLOCKS, path="pure",
                          delta=base, compact=True)
    f_c = jax.jit(lambda s: delta_stepping(g, s, plan=cplan,
                                           direction="auto"))
    np.testing.assert_array_equal(np.asarray(f_c(source)).view(np.uint32),
                                  want.view(np.uint32),
                                  err_msg="compacted delta diverged")
    compact_us = time_fn(lambda: np.asarray(f_c(source)), warmup=1, iters=3)

    bench["_sssp_delta"] = {
        "graph": name, "source": source, "delta": round(float(base), 4),
        "bellman_ford_us": round(bf_us, 1),
        "sweep_us": sweep, "advances": counts,
        "best": best_label, "best_us": round(best_us, 1),
        "speedup": round(bf_us / max(best_us, 1e-9), 3),
        "compact_capacity": cplan.compact_capacity,
        "compact_us": round(compact_us, 1),
    }
    csv_rows.append(
        (f"fig_graph/sssp_delta/{name}", best_us,
         f"bellman_ford={bf_us:.0f};best={best_label};"
         f"speedup={bf_us / max(best_us, 1e-9):.2f};"
         f"delta={base:.3f};compact={compact_us:.0f}"))
    return best_us <= bf_us


def sharded_sweep(name: str, g: Graph, bench: dict, csv_rows) -> bool:
    """Mesh-sharded BFS across shard counts x boundary schedules.

    Every (count, boundary) point's labels are asserted bitwise against
    the single-device direction-optimizing BFS first (sharding is a pure
    decomposition regardless of where the contiguous boundaries land —
    the figure doubles as the multi-device equivalence gate; the 1-shard
    point is the ``rank_check`` base-case invariant).  On a 1-device CI
    box the candidate set collapses to ``[1]`` and the sweep degrades to
    that base case; the committed JSON carries the full
    forced-host-device sweep.  Selection regret mirrors the measured-cost
    loop: :func:`select_sharded_plan` re-ranks the (count, boundary)
    candidates from the sweep's own wall-clock table, and both the
    measured-mode and the model-only picks' regrets are expressed in
    measured time — measured mode saw every candidate run, so its regret
    can never exceed model-only's (the ordering ``rank_check`` asserts).
    The target graph is the skewed power-law corpus graph, so the sweep
    also records how ``edge_balanced`` boundaries fare against
    ``equal_width`` at equal_width's own best shard count — the
    degree-aware-placement invariant ``rank_check`` gates.
    """
    counts = _candidate_shard_counts(g.num_vertices)
    source = _medium_degree_source(g)
    plan = build_advance(g, schedule="merge_path", num_blocks=NUM_BLOCKS,
                         path="pure")
    f_base = jax.jit(lambda s: bfs(g, s, plan=plan, direction="auto"))
    want = np.asarray(f_base(source))
    base_us = time_fn(lambda: jax.block_until_ready(f_base(source)),
                      warmup=1, iters=3)

    V = g.num_vertices
    timings, sweep = {}, {}      # (S, boundary) -> us; boundary -> {sN: us}
    one_shard_bitwise = False
    for S in counts:
        for bname in SHARD_SCHEDULES:
            if bname != "equal_width" and S > V:
                continue         # degree-aware schedules refuse S > V
            splan = build_sharded_advance(g, S, schedule="merge_path",
                                          path="pure",
                                          num_blocks=NUM_BLOCKS,
                                          shard_schedule=bname)
            f = jax.jit(lambda s, _sp=splan: sharded_bfs(_sp, s))
            got = np.asarray(f(source))
            np.testing.assert_array_equal(
                got, want, err_msg=f"sharded BFS (s{S}, {bname}) diverged "
                                   f"from single-device on {name}")
            if S == 1 and bname == "equal_width":
                one_shard_bitwise = True    # asserted bit-identical above
            us = time_fn(lambda: jax.block_until_ready(f(source)),
                         warmup=1, iters=5)
            timings[(S, bname)] = us
            sweep.setdefault(bname, {})[f"s{S}"] = round(us, 1)

    # joint (count, boundary) selection: model-only vs measured-mode,
    # regret in measured time.  Boundary candidates are deduplicated per
    # count (on near-uniform degree all three schedules coincide).
    rev = g.csr.transpose()
    bounds_by_count = {}
    for c in counts:
        cand, seen = {}, set()
        for bname in SHARD_SCHEDULES:
            if bname != "equal_width" and c > V:
                continue
            b = shard_boundaries(g, c, shard_schedule=bname)
            key = tuple(int(x) for x in b)
            if key in seen:
                continue
            seen.add(key)
            cand[bname] = b
        bounds_by_count[c] = cand
    n_cands = sum(len(v) for v in bounds_by_count.values())
    pure_merge = [p for p in REGISTERED_PLANS
                  if str(p.schedule) == "merge_path"
                  and str(p.path) == "pure"]
    model_pick = select_sharded_plan(rev.workspec(), bounds_by_count,
                                     NUM_BLOCKS, cache=None,
                                     push_spec=g.csr.workspec(),
                                     plans=pure_merge)
    prev_env = os.environ.get("REPRO_AUTOTUNE_MEASURE")
    os.environ["REPRO_AUTOTUNE_MEASURE"] = "1"
    try:
        measured_pick = select_sharded_plan(
            rev.workspec(), bounds_by_count, NUM_BLOCKS, cache=None,
            push_spec=g.csr.workspec(), plans=pure_merge,
            measure=lambda sp: timings[(sp.num_shards, sp.boundary)],
            measure_k=n_cands * len(pure_merge))
    finally:
        if prev_env is None:
            os.environ.pop("REPRO_AUTOTUNE_MEASURE", None)
        else:
            os.environ["REPRO_AUTOTUNE_MEASURE"] = prev_env
    best_us = max(min(timings.values()), 1e-9)
    model_only_regret = timings[(model_pick.num_shards,
                                 model_pick.boundary)] / best_us
    auto_regret = timings[(measured_pick.num_shards,
                           measured_pick.boundary)] / best_us
    best_S, best_b = min(timings, key=timings.get)

    # degree-aware placement vs uniform width, each schedule at its OWN
    # best count (the head-to-head rank_check gates; > 1 means
    # edge_balanced's best point beats equal_width's best point).
    # Pinning both at equal_width's best count would let one noisy
    # sample at that single count decide the ratio, and the counts where
    # degree-aware boundaries pay off most are the higher ones.
    ew = {S: us for (S, bname), us in timings.items()
          if bname == "equal_width"}
    ew_best_S = min(ew, key=ew.get)
    eb = {S: us for (S, bname), us in timings.items()
          if bname == "edge_balanced"}
    eb_ratio = None
    if eb:
        eb_ratio = round(ew[ew_best_S] / max(min(eb.values()), 1e-9), 4)

    bench["_sharded"] = {
        "graph": name, "source": source, "counts": counts,
        "boundaries": list(SHARD_SCHEDULES),
        "devices": len(jax.devices()),
        "unsharded_us": round(base_us, 1),
        "sweep_us": sweep["equal_width"],
        "boundary_sweep_us": sweep,
        "best": f"s{best_S}@{best_b}",
        "best_us": round(timings[(best_S, best_b)], 1),
        "shard_speedup": round(
            base_us / max(timings[(best_S, best_b)], 1e-9), 3),
        "one_shard_bitwise": one_shard_bitwise,
        "equal_width_best": f"s{ew_best_S}",
        "edge_balanced_vs_equal_width": eb_ratio,
        "auto": measured_pick.encode(),
        "model_only": model_pick.encode(),
        "sharded_auto_regret": round(auto_regret, 4),
        "sharded_model_only_regret": round(model_only_regret, 4),
    }
    csv_rows.append(
        (f"fig_graph/sharded_bfs/{name}", timings[(best_S, best_b)],
         f"unsharded={base_us:.0f};best=s{best_S}@{best_b};"
         f"speedup={base_us / max(timings[(best_S, best_b)], 1e-9):.2f};"
         f"counts={'/'.join(str(c) for c in counts)};"
         f"boundaries={'/'.join(SHARD_SCHEDULES)};"
         f"eb_vs_ew={eb_ratio};"
         f"auto={measured_pick.encode()};regret={auto_regret:.3f}"))
    return one_shard_bitwise and auto_regret <= model_only_regret + 1e-6


def run(csv_rows, smoke: bool = False):
    if smoke:
        # ride the shared smoke cache (REPRO_AUTOTUNE_CACHE, set by
        # run.py --smoke) so suites stop re-inspecting per suite
        cache = AutotuneCache()
    else:
        cache = AutotuneCache("/tmp/repro_fig_graph_cache.json")
        cache.clear()  # score fresh: this figure measures selection
    bench: dict = {}
    regrets = []
    measured_regrets = []        # measured-mode choice, in measured time
    model_only_regrets = []      # model-only choice, in measured time
    native_ok = False
    guard_case = None            # first sweep entry, reused by the guard
    direction_case = None        # the power-law corpus graph (or smoke's)
    for name, g in graph_sweep(smoke):
        if guard_case is None:
            guard_case = (name, g)
        V, E = g.num_vertices, g.num_edges
        spec = g.csr.transpose().workspec()
        frontier = _frontier(V)
        pot = jnp.asarray(np.random.default_rng(3).integers(0, 32, V)
                          .astype(np.float32))

        entry = {"V": V, "E": E, "schedules_us": {}, "schedules_push_us": {},
                 "modeled": {}}
        timings = {}
        oracle = None
        merge_plan = None           # reused for threshold + direction sweep
        for sched in SCHEDULES:
            plan = build_advance(g, schedule=sched, num_blocks=NUM_BLOCKS,
                                 path="pure")
            if sched == Schedule.MERGE_PATH:
                merge_plan = plan
            f = lambda p, fr, _plan=plan: advance_relax_min(_plan, p, fr)
            fp = lambda p, fr, _plan=plan: advance_relax_min(
                _plan, p, fr, direction="push")
            got = np.asarray(f(pot, frontier))
            if oracle is None:
                oracle = got
            else:
                np.testing.assert_array_equal(got, oracle, err_msg=str(sched))
            # direction equivalence is part of the figure's guarantee
            np.testing.assert_array_equal(np.asarray(fp(pot, frontier)),
                                          oracle,
                                          err_msg=f"push/{sched}")
            us = time_fn(f, pot, frontier, warmup=1, iters=3)
            timings[str(sched)] = us
            entry["schedules_us"][str(sched)] = round(us, 1)
            entry["schedules_push_us"][str(sched)] = round(
                time_fn(fp, pot, frontier, warmup=1, iters=3), 1)
            entry["modeled"][str(sched)] = modeled_advance_cost(
                spec, sched, NUM_BLOCKS)
        entry["direction_threshold"] = round(
            merge_plan.direction_threshold, 4)

        if E <= NATIVE_EDGE_CAP:
            nplan = build_advance(g, schedule="chunked_lpt",
                                  num_blocks=NUM_BLOCKS, path="native")
            fn = lambda p, fr, _plan=nplan: advance_relax_min(_plan, p, fr)
            np.testing.assert_array_equal(np.asarray(fn(pot, frontier)),
                                          oracle)
            entry["native_chunked_us"] = round(
                time_fn(fn, pot, frontier, warmup=1, iters=3), 1)
            # push through the chunk-walking kernel's emit="atoms" mode
            fnp = lambda p, fr, _plan=nplan: advance_relax_min(
                _plan, p, fr, direction="push")
            np.testing.assert_array_equal(np.asarray(fnp(pot, frontier)),
                                          oracle)
            entry["native_chunked_push_us"] = round(
                time_fn(fnp, pot, frontier, warmup=1, iters=3), 1)
            native_ok = True

        # auto plan + regret vs the exact advance-family argmin
        auto_plan = select_plan(spec, NUM_BLOCKS, cache=cache,
                                workload="advance")
        scores = score_plans(spec, NUM_BLOCKS, REGISTERED_PLANS, "advance")
        regret = scores[auto_plan] / max(min(scores.values()), 1e-9)
        regrets.append(regret)
        entry["auto"] = auto_plan.encode()
        entry["auto_regret"] = round(regret, 4)

        # measured-cost feedback loop: re-select over the pure plans with
        # the schedule sweep's own wall-clock table as the measurement
        # source (REPRO_AUTOTUNE_MEASURE scoped to this one call), then
        # express BOTH choices' regret in measured time.  Measured mode
        # sees every candidate's actual time, so its measured regret can
        # never exceed the model-only choice's — the closed-loop ordering
        # rank_check asserts on the committed JSON.  cache=None: a shared
        # cache would (a) let graph A's measured record answer for a
        # same-fingerprint graph B without consulting B's own timings and
        # (b) overwrite the model-only `auto` entry this figure compares
        # against.
        pure_plans = [p for p in REGISTERED_PLANS if str(p.path) == "pure"]
        prev_env = os.environ.get("REPRO_AUTOTUNE_MEASURE")
        os.environ["REPRO_AUTOTUNE_MEASURE"] = "1"
        try:
            measured_plan = select_plan(
                spec, NUM_BLOCKS, cache=None, workload="advance",
                plans=pure_plans,
                measure=lambda p: timings[str(p.schedule)],
                measure_k=len(pure_plans))
        finally:
            if prev_env is None:
                os.environ.pop("REPRO_AUTOTUNE_MEASURE", None)
            else:
                os.environ["REPRO_AUTOTUNE_MEASURE"] = prev_env
        best_meas = max(min(timings.values()), 1e-9)
        model_only_regret = timings[str(auto_plan.schedule)] / best_meas
        measured_regret = timings[str(measured_plan.schedule)] / best_meas
        model_only_regrets.append(model_only_regret)
        measured_regrets.append(measured_regret)
        entry["auto_measured"] = measured_plan.encode()
        entry["model_only_regret_measured"] = round(model_only_regret, 4)
        entry["measured_mode_regret"] = round(measured_regret, 4)
        bench[name] = entry
        if name == DIRECTION_GRAPH or direction_case is None:
            # first entry is the fallback if the target graph ever leaves
            # the sweep (renamed / over the nnz cap); the target wins
            direction_case = (name, g, merge_plan)

        best = min(timings, key=timings.get)
        detail = ";".join(f"{s}={timings[s]:.0f}" for s in timings)
        csv_rows.append((f"fig_graph/{name}", timings[best],
                         f"auto={auto_plan.encode()};regret={regret:.3f};"
                         f"best={best};{detail}"))

    # traversal liveness: BFS + SSSP agree across three schedule families
    gname, g = guard_case
    depth = {s: np.asarray(bfs(g, 0, schedule=s, num_blocks=8))
             for s in ("merge_path", "chunked_lpt", "adaptive")}
    dists = {s: np.asarray(sssp(g, 0, schedule=s, num_blocks=8))
             for s in ("merge_path", "chunked_lpt", "adaptive")}
    for s in depth:
        np.testing.assert_array_equal(depth[s], depth["merge_path"])
        np.testing.assert_array_equal(dists[s], dists["merge_path"])

    # direction-optimizing + batched BFS on the power-law corpus graph
    switched = direction_sweep(*direction_case, bench, csv_rows)

    # delta-stepping SSSP sweep on the same graph + plan pair
    delta_ok = delta_sweep(*direction_case, bench, csv_rows)

    # mesh-sharded BFS sweep on the same graph (counts = local devices)
    sharded_ok = sharded_sweep(direction_case[0], direction_case[1], bench,
                               csv_rows)

    measured_loop_ok = all(
        m <= mo + 1e-6 for m, mo in zip(measured_regrets,
                                        model_only_regrets))
    bench["_summary"] = {
        "max_auto_regret": round(max(regrets), 4),
        "max_measured_mode_regret": round(max(measured_regrets), 4),
        "max_model_only_regret_measured": round(max(model_only_regrets), 4),
        "measured_loop": "ok" if measured_loop_ok else "regressed",
        "traversal_guard": gname,
        "native_path": "ok" if native_ok else "skipped",
        "direction_switch": "ok" if switched else "missing",
        "delta_stepping": "ok" if delta_ok else "slower",
        "sharded": "ok" if sharded_ok else "regressed",
    }

    # Full runs refresh the committed JSON in cwd; smoke runs only write
    # when the caller pinned REPRO_BENCH_DIR (CI's fresh-artifact dir) —
    # otherwise a casual `run.py --smoke` would silently clobber the
    # committed full-run numbers the bench-rank gate asserts against.
    # Underscore entries owned by other figures (fig_serve's ``_serving``,
    # fig_wavefront's ``_wavefront``, and their status markers inside
    # ``_summary``) are carried over, mirroring their
    # never-clobber-fig_graph contract in the other direction.
    out_dir = os.environ.get("REPRO_BENCH_DIR")
    if out_dir or not smoke:
        path = pathlib.Path(out_dir or ".") / "BENCH_graph.json"
        try:
            prior = json.loads(path.read_text()) if path.exists() else {}
        except (OSError, ValueError):
            prior = {}
        if isinstance(prior, dict):
            for key, val in prior.items():
                if not key.startswith("_"):
                    continue
                if key not in bench:
                    bench[key] = val
                elif isinstance(val, dict) and isinstance(bench[key], dict):
                    for sub, subval in val.items():
                        bench[key].setdefault(sub, subval)
        try:
            path.write_text(json.dumps(bench, indent=1))
        except OSError:
            pass   # read-only CWD: the CSV rows still carry the numbers
    csv_rows.append(
        ("fig_graph/summary", 0.0,
         f"max_auto_regret={max(regrets):.3f};"
         f"measured_loop={'ok' if measured_loop_ok else 'regressed'};"
         f"graph_native_path={'ok' if native_ok else 'skipped'};"
         f"direction_switch={'ok' if switched else 'missing'};"
         f"delta_stepping={'ok' if delta_ok else 'slower'};"
         f"sharded={'ok' if sharded_ok else 'regressed'};"
         f"json=BENCH_graph.json"))
