"""Shared timing helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted callable (blocks until ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def geomean(xs) -> float:
    import math
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))
