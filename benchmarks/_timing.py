"""Shared timing helpers for the benchmark harness.

Thin re-export of :mod:`repro.core.measure` — promoted to a library module
in PR 6 so the autotuner's measured mode and the benchmark harness share
one warmup/median discipline (and one measurement counter).  Import from
``repro.core.measure`` in new code; this shim keeps the historical
``benchmarks._timing`` import path working.
"""
from __future__ import annotations

from repro.core.measure import geomean, measurement_count, time_fn

__all__ = ["time_fn", "geomean", "measurement_count"]
