"""Beyond-paper benchmark — static vs dynamic vs autotuned schedules.

The paper ships static schedules and a fixed heuristic; this figure measures
what the dynamic subsystem (repro.core.dynamic) and the cost-model autotuner
(repro.core.autotune) add.  Workload sweep:

* the SuiteSparse-like corpus (structural axes: uniform / zipf / scale-free /
  banded / empty-heavy), and
* document-length tile sets derived from the ``repro.data.synthetic`` LM
  stream (tiles = packed documents, atoms = tokens) across its power-law
  length settings — the sweep the autotuner acceptance criterion is stated
  over.

Per workload we report the modeled lockstep cost of every schedule, the
auto choice and its regret vs the best single schedule, plus measured
wall-time of the blocked executor under the best static and the chunked
dynamic partitions.  Summary rows: max auto regret (must stay <= 1.10) and
the power-law workloads where the chunked queue beats every static schedule
(must be >= 1).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Schedule, blocked_tile_reduce, execute_tile_reduce,
                        make_partition, modeled_cost, select_plan,
                        select_schedule, tile_reduce)
from repro.core.autotune import REGISTERED_PLANS, AutotuneCache
from repro.data.synthetic import DataConfig, batch_at
from repro.sparse import random_csr, suite_like_corpus

from benchmarks._timing import time_fn

NUM_BLOCKS = 64
STATIC = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
          Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]
DYNAMIC = [Schedule.CHUNKED, Schedule.ADAPTIVE]


def _doc_length_spec(mean_doc_len: int, seed: int, batches: int = 4):
    """Tile set from the synthetic LM stream: tiles = documents."""
    from repro.core import WorkSpec
    cfg = DataConfig(seed=seed, mean_doc_len=mean_doc_len, global_batch=8,
                     seq_len=512)
    sizes = []
    for step in range(batches):
        batch = batch_at(cfg, step)
        for row in np.asarray(batch["labels"]) >= 0:
            # document boundaries are the masked (-1) label positions
            cuts = np.flatnonzero(~row)
            lens = np.diff(np.concatenate([[0], cuts + 1, [row.size]]))
            sizes.extend(int(x) for x in lens if x > 0)
    sizes = np.asarray(sizes, np.int32)
    return WorkSpec.from_segment_sizes(jnp.asarray(sizes),
                                       num_atoms=int(sizes.sum()))


def workload_sweep(smoke: bool = False):
    """(name, spec, is_power_law, atom_values) triples for the sweep."""
    out = []
    for name, A in suite_like_corpus(smoke=smoke):
        out.append((f"corpus/{name}", A.workspec(),
                    ("zipf" in name or "scalefree" in name), A.values))
    if not smoke:
        for mean_len in (64, 256, 1024):
            spec = _doc_length_spec(mean_len, seed=7)
            out.append((f"synthetic/docs_mean{mean_len}", spec, True, None))
        for skew in (1.4, 1.9):
            A = random_csr(4_000, 4_000, 100_000, skew=skew, empty_frac=0.2,
                           seed=11)
            out.append((f"synthetic/powerlaw_skew{skew}", A.workspec(), True,
                        A.values))
        # frontier-style heavy tail (Atos's regime): a few vertices own a
        # large fraction of all edges, far past what bounded-column CSR
        # matrices can express
        from repro.core import WorkSpec
        rng = np.random.default_rng(13)
        for tail in (0.7, 1.0):
            sizes = (rng.pareto(tail, 2_000) * 50 + 1).astype(np.int32)
            spec = WorkSpec.from_segment_sizes(jnp.asarray(sizes),
                                               num_atoms=int(sizes.sum()))
            out.append((f"synthetic/frontier_tail{tail}", spec, True, None))
    return out


def run(csv_rows, smoke: bool = False):
    key = jax.random.PRNGKey(4)
    if smoke:
        # shared smoke cache (REPRO_AUTOTUNE_CACHE via run.py): selection
        # quality is not measured in smoke, so reuse beats re-inspection
        cache = AutotuneCache()
    else:
        cache = AutotuneCache("/tmp/repro_fig_dynamic_cache.json")
        cache.clear()   # score fresh: this figure measures selection
    regrets = []
    chunked_wins = []
    measured_mode_meas = []      # measured-mode choice, in measured time
    model_only_meas = []         # model-only choice, in measured time
    for name, spec, power_law, values in workload_sweep(smoke):
        costs = {s: modeled_cost(spec, s, NUM_BLOCKS)
                 for s in STATIC + DYNAMIC}
        best = min(costs, key=costs.get)
        best_static = min(STATIC, key=lambda s: costs[s])
        auto = select_schedule(spec, NUM_BLOCKS, cache=cache)
        regret = costs[auto] / max(costs[best], 1e-9)
        regrets.append(regret)
        beats_all_static = costs[Schedule.CHUNKED] < costs[best_static]
        if power_law and beats_all_static:
            chunked_wins.append(name)

        if values is not None:
            vals = values
        else:
            vals = jax.random.normal(jax.random.fold_in(key,
                                                        hash(name) % 2**31),
                                     (max(spec.num_atoms, 1),), jnp.float32)

        def timed(sched):
            part = make_partition(spec, sched, NUM_BLOCKS)

            @jax.jit
            def f(v, _p=part, _s=spec):
                return blocked_tile_reduce(_s, _p, lambda a: v[a])

            got = np.asarray(f(vals))
            want = np.asarray(tile_reduce(spec, lambda a: vals[a]))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
            return time_fn(f, vals, warmup=1, iters=3)

        t_static = timed(best_static)
        t_chunked = timed(Schedule.CHUNKED)

        # measured-cost feedback: let measured-mode select_plan time its
        # top-k model-ranked pure candidates on this very workload
        # (REPRO_AUTOTUNE_MEASURE scoped to the call), then express both
        # the measured-mode and the model-only choice's regret in measured
        # time.  The summary surfaces the worst of each — the fig_graph
        # committed artifact carries the asserted ordering; here the
        # numbers ride the CSV for the trajectory.
        pure_plans = [p for p in REGISTERED_PLANS if str(p.path) == "pure"]
        plan_times = {}

        def _measure(plan):
            us = timed(plan.schedule)
            plan_times[plan] = us
            return us

        prev_env = os.environ.get("REPRO_AUTOTUNE_MEASURE")
        os.environ["REPRO_AUTOTUNE_MEASURE"] = "1"
        try:
            measured_plan = select_plan(spec, NUM_BLOCKS, cache=None,
                                        plans=pure_plans, measure=_measure)
        finally:
            if prev_env is None:
                os.environ.pop("REPRO_AUTOTUNE_MEASURE", None)
            else:
                os.environ["REPRO_AUTOTUNE_MEASURE"] = prev_env
        if measured_plan not in plan_times:    # blend picked past top-k
            plan_times[measured_plan] = timed(measured_plan.schedule)
        model_plan = min(pure_plans,
                         key=lambda p: (costs[p.schedule],
                                        pure_plans.index(p)))
        if model_plan not in plan_times:
            plan_times[model_plan] = timed(model_plan.schedule)
        t_best_meas = max(min(plan_times.values()), 1e-9)
        measured_mode_meas.append(plan_times[measured_plan] / t_best_meas)
        model_only_meas.append(plan_times[model_plan] / t_best_meas)

        # native chunk-walking path (Pallas, interpret mode): correctness
        # vs the oracle + wall time.  Interpret-mode timing has no TPU
        # meaning — this is the CI liveness guard for the native path.
        native_detail = ""
        if smoke or spec.num_atoms <= 20_000:
            part_c = make_partition(spec, Schedule.CHUNKED, NUM_BLOCKS)

            def f_native(v, _p=part_c, _s=spec):
                return execute_tile_reduce(_s, _p, lambda a: v[a],
                                           path="native")

            got = np.asarray(f_native(vals))
            want = np.asarray(tile_reduce(spec, lambda a: vals[a]))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
            t_native = time_fn(f_native, vals, warmup=1, iters=3)
            native_detail = f"native_chunked_us={t_native:.0f};"

        detail = ";".join(f"{s}={costs[s]:.0f}" for s in STATIC + DYNAMIC)
        csv_rows.append(
            (f"fig_dynamic/{name}", t_static,
             f"auto={auto};best={best};regret={regret:.3f};"
             f"chunked_us={t_chunked:.0f};{native_detail}{detail}"))
    csv_rows.append(
        ("fig_dynamic/summary", 0.0,
         f"max_auto_regret={max(regrets):.3f};"
         f"max_measured_mode_regret={max(measured_mode_meas):.3f};"
         f"max_model_only_regret_measured={max(model_only_meas):.3f};"
         f"chunked_beats_static_on={len(chunked_wins)};"
         f"wins={'|'.join(chunked_wins) if chunked_wins else 'none'}"))
