"""Beyond-paper benchmark — topological wavefront DAG/tree evaluation.

The wavefront scheduler (``repro.sparse.wavefront``) recasts dependency-
ordered computation as the paper's abstraction — tiles = nodes, atoms =
dependency in-edges — so the same schedule library that balances frontier
advances balances TreeLSTM-style recursive evaluation.  This figure
measures what that buys on the workload's own skew axis: dependency
**fan-in** (a hub aggregator node owns hundreds of in-edges while chain
nodes own one).

Sweep, per DAG class (chain / balanced tree / random DAG / skewed forest):

* the **dependency combine** — the schedule-sensitive inner piece, one
  balanced pull advance per feature column over a half-resolved node set —
  timed for every registered schedule on the pure executor (the wavefront
  analogue of fig_graph's relax sweep);
* the **full wavefront evaluation** per schedule, each first asserted
  **bitwise identical** to a sequential per-node NumPy oracle (integer-
  valued fixtures + exact clip activation, the conformance contract of
  ``tests/test_wavefront.py`` re-checked at benchmark scale);
* a **native chunk-walking ride-along** under the edge cap (interpret-mode
  liveness, not a TPU number);
* the **auto plan + regret** for the ``workload="wavefront"`` autotune
  family, and the **level-batching speedup** over the sequential oracle
  (the whole point of wavefront scheduling: one balanced GEMM + two
  advances per *level* instead of per-node Python recursion).

The skewed forest is built through :func:`repro.sparse.wavefront.pack_forest`
(ragged trees -> one block-diagonal DAG), so the figure also exercises the
forest-batching path end to end.

Results merge into ``BENCH_graph.json`` (never clobbering fig_graph/
fig_serve entries) as a ``_wavefront`` section plus a ``wavefront`` marker
in ``_summary``; ``rank_check.py`` gates on the skewed-forest ranking
(chunked no slower than the worst static schedule on the combine — fan-in
skew is exactly the regime the work queue exists for) and the level count.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Schedule, select_plan
from repro.core.autotune import AutotuneCache, REGISTERED_PLANS, score_plans
from repro.sparse import (CSR, Graph, advance, build_wavefront, pack_forest,
                          wavefront_eval)

from benchmarks._timing import time_fn

NUM_BLOCKS = 32
SCHEDULES = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
             Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH,
             Schedule.CHUNKED, Schedule.ADAPTIVE]

#: Native interpret-mode timing is CI liveness, not a TPU number.
NATIVE_EDGE_CAP = 20_000

#: The fan-in-skewed forest where the dynamic queue must stay competitive.
QUEUE_DAG = "forest/skewed"

K_FEAT = 4
NUM_OPS = 3


def _dag_of(w: np.ndarray) -> Graph:
    return Graph(CSR.from_dense(np.asarray(w, np.float32)))


def _chain(n: int) -> Graph:
    w = np.zeros((n, n), np.float32)
    for v in range(n - 1):
        w[v, v + 1] = 1.0
    return _dag_of(w)


def _balanced_tree(depth: int) -> Graph:
    n = 2 ** depth - 1
    w = np.zeros((n, n), np.float32)
    for child in range(1, n):
        w[child, (child - 1) // 2] = 1.0
    return _dag_of(w)


def _random_dag(n: int, p: float, seed: int) -> Graph:
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    w = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p:
                w[order[i], order[j]] = 1.0
    return _dag_of(w)


def _skewed_forest(hub_fanin: int, cherries: int, singles: int) -> Graph:
    """Ragged forest through pack_forest: one hub aggregator tree (fan-in
    = ``hub_fanin``, the skew the queue balances) + cherries + single-node
    trees.  Three levels by construction."""
    n = hub_fanin + 3
    hub = np.zeros((n, n), np.float32)
    hub[:hub_fanin, hub_fanin] = 1.0             # leaves -> aggregator
    hub[hub_fanin, n - 1] = 1.0                  # aggregator -> root
    hub[hub_fanin + 1, n - 1] = 1.0              # side leaf -> root
    cherry = np.zeros((3, 3), np.float32)
    cherry[0, 2] = cherry[1, 2] = 1.0
    single = np.zeros((1, 1), np.float32)
    trees = ([_dag_of(hub)] + [_dag_of(cherry)] * cherries
             + [_dag_of(single)] * singles)
    return pack_forest(trees).dag


def dag_sweep(smoke: bool = False):
    if smoke:
        return [("chain/small", _chain(8)),
                (QUEUE_DAG, _skewed_forest(12, 2, 2))]
    # hub fan-in 3000: deep enough skew that the serialized hub tile
    # dominates the static schedules' critical path — the regime the
    # chunked queue exists for (the rank_check invariant)
    return [("chain/deep", _chain(32)),
            ("tree/balanced_d6", _balanced_tree(6)),
            ("random/dag", _random_dag(150, 0.05, seed=11)),
            (QUEUE_DAG, _skewed_forest(3000, 800, 400))]


def _fixtures(V: int, seed: int = 1):
    """Integer-valued f32 fixtures: every combine order exact, so the
    per-schedule asserts are bitwise (see tests/test_wavefront.py)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-4, 5, (V, K_FEAT)).astype(np.float32)
    W = rng.integers(-2, 3, (NUM_OPS, K_FEAT, K_FEAT)).astype(np.float32)
    b = rng.integers(-3, 4, (NUM_OPS, K_FEAT)).astype(np.float32)
    ops = rng.integers(0, NUM_OPS, V).astype(np.int32)
    return x, ops, W, b


_clip = lambda z: jnp.clip(z, -16.0, 16.0)


def _np_oracle(g: Graph, level_of: np.ndarray, x, ops, W, b) -> np.ndarray:
    """Sequential per-node topological evaluation — the recursion the
    wavefront replaces, and the bitwise reference for every schedule."""
    ro = np.asarray(g.csr.row_offsets, np.int64)
    ci = np.asarray(g.csr.col_indices, np.int64)
    srcs = np.repeat(np.arange(g.num_vertices), np.diff(ro))
    order = np.argsort(ci, kind="stable")
    by_dst_src, by_dst = srcs[order], ci[order]
    dst_off = np.searchsorted(by_dst, np.arange(g.num_vertices + 1))
    h = np.zeros_like(x)
    for v in np.argsort(level_of, kind="stable"):
        preds = by_dst_src[dst_off[v]:dst_off[v + 1]]
        comb = x[v] + h[preds].sum(axis=0, dtype=np.float32)
        z = (comb @ W[ops[v]] + b[ops[v]]).astype(np.float32)
        h[v] = np.clip(z, np.float32(-16.0), np.float32(16.0))
    return h


def run(csv_rows, smoke: bool = False):
    cache = AutotuneCache() if smoke else None
    graphs: dict = {}
    regrets = []
    native_ok = False
    rank_ok = True
    levels_on_queue = 0
    for name, g in dag_sweep(smoke):
        V, E = g.num_vertices, g.num_edges
        spec = g.csr.transpose().workspec()
        x, ops, W, b = _fixtures(V)
        xj, opsj = jnp.asarray(x), jnp.asarray(ops)
        Wj, bj = jnp.asarray(W), jnp.asarray(b)

        entry = {"V": V, "E": E, "combine_us": {}, "eval_us": {}}
        combine_timings, eval_timings = {}, {}
        oracle = None
        wp_mid = None
        for sched in SCHEDULES:
            wp = build_wavefront(g, schedule=sched, num_blocks=NUM_BLOCKS,
                                 path="pure")
            if oracle is None:
                entry["levels"] = wp.num_levels
                entry["max_fanin"] = int(np.asarray(
                    wp.in_degrees()).max(initial=0))
                oracle = _np_oracle(g, wp.level_of, x, ops, W, b)
                # the combine's timing frontier: the busiest prefix of
                # levels resolved (fan-in edges live, later nodes waiting)
                mid = max(wp.num_levels // 2, 1)
                resolved = jnp.asarray(wp.level_of < mid)
            # full evaluation: bitwise vs the sequential oracle, always
            f_eval = jax.jit(lambda xx, _wp=wp: wavefront_eval(
                _wp, xx, opsj, Wj, bias=bj, activation=_clip))
            got = np.asarray(f_eval(xj))
            np.testing.assert_array_equal(
                got, oracle, err_msg=f"{name}/{sched}: wavefront diverged "
                                     f"from sequential oracle")
            eval_us = time_fn(lambda: jax.block_until_ready(f_eval(xj)),
                              warmup=1, iters=3)
            eval_timings[str(sched)] = eval_us
            entry["eval_us"][str(sched)] = round(eval_us, 1)
            # the schedule-sensitive inner piece: per-column pull combine
            plan, src = wp.plan, wp.plan.src
            f_comb = jax.jit(lambda hh, _p=plan, _s=src: jax.vmap(
                lambda col: advance(_p, resolved,
                                    lambda e: col[_s[e]],
                                    combiner="sum"))(hh.T).T)
            jax.block_until_ready(f_comb(xj))
            us = time_fn(lambda: jax.block_until_ready(f_comb(xj)),
                         warmup=1, iters=3)
            combine_timings[str(sched)] = us
            entry["combine_us"][str(sched)] = round(us, 1)
            if sched == Schedule.MERGE_PATH:
                wp_mid = wp

        # native chunk-walking ride-along (interpret-mode liveness)
        if E <= NATIVE_EDGE_CAP:
            wpn = build_wavefront(g, schedule="chunked_lpt",
                                  num_blocks=NUM_BLOCKS, path="native")
            fn = jax.jit(lambda xx, _wp=wpn: wavefront_eval(
                _wp, xx, opsj, Wj, bias=bj, activation=_clip))
            np.testing.assert_array_equal(np.asarray(fn(xj)), oracle,
                                          err_msg=f"{name}/native")
            entry["native_chunked_us"] = round(
                time_fn(lambda: jax.block_until_ready(fn(xj)),
                        warmup=1, iters=2), 1)
            native_ok = True

        # auto plan + modeled regret for the wavefront autotune family
        auto_plan = select_plan(spec, NUM_BLOCKS, cache=cache,
                                workload="wavefront")
        scores = score_plans(spec, NUM_BLOCKS, REGISTERED_PLANS,
                             "wavefront")
        regret = scores[auto_plan] / max(min(scores.values()), 1e-9)
        regrets.append(regret)
        entry["auto"] = auto_plan.encode()
        entry["auto_regret"] = round(regret, 4)

        # level batching vs the sequential per-node recursion
        seq_us = time_fn(lambda: _np_oracle(g, wp_mid.level_of, x, ops,
                                            W, b), warmup=1, iters=2)
        best_eval = min(eval_timings.values())
        entry["sequential_oracle_us"] = round(seq_us, 1)
        entry["level_batch_speedup"] = round(
            seq_us / max(best_eval, 1e-9), 3)
        graphs[name] = entry

        if name == QUEUE_DAG:
            levels_on_queue = entry["levels"]
            worst_static = max(combine_timings[s] for s in
                               ("thread_mapped", "group_mapped",
                                "nonzero_split", "merge_path"))
            rank_ok = combine_timings["chunked"] <= worst_static

        best = min(combine_timings, key=combine_timings.get)
        detail = ";".join(f"{s}={combine_timings[s]:.0f}"
                          for s in combine_timings)
        csv_rows.append(
            (f"fig_wavefront/{name}", combine_timings[best],
             f"levels={entry['levels']};fanin={entry['max_fanin']};"
             f"auto={auto_plan.encode()};regret={regret:.3f};"
             f"speedup_vs_seq={entry['level_batch_speedup']:.2f};"
             f"best={best};{detail}"))

    # smoke is a liveness gate (bitwise asserts + native + level count);
    # the timing *ranking* is a full-run invariant — rank_check.py asserts
    # it on the committed JSON, where min-of-3 sweeps absorb the noise a
    # tiny smoke shape cannot
    ok = native_ok and levels_on_queue >= 3 and (rank_ok or smoke)
    wavefront = {
        "graphs": graphs,
        "queue_graph": QUEUE_DAG,
        "queue_levels": levels_on_queue,
        "max_auto_regret": round(max(regrets), 4),
        "native_path": "ok" if native_ok else "skipped",
        "status": "ok" if ok else "regressed",
    }

    # merge (never clobber) into the fig_graph-owned JSON; smoke runs only
    # write when CI pinned REPRO_BENCH_DIR (same discipline as fig_serve)
    out_dir = os.environ.get("REPRO_BENCH_DIR")
    if out_dir or not smoke:
        path = pathlib.Path(out_dir or ".") / "BENCH_graph.json"
        try:
            bench = json.loads(path.read_text()) if path.exists() else {}
            bench["_wavefront"] = wavefront
            bench.setdefault("_summary", {})["wavefront"] = (
                "ok" if ok else "regressed")
            path.write_text(json.dumps(bench, indent=1))
        except OSError:
            pass   # read-only CWD: the CSV rows still carry the numbers

    csv_rows.append(
        ("fig_wavefront/summary", 0.0,
         f"wavefront={'ok' if ok else 'regressed'};"
         f"max_auto_regret={max(regrets):.3f};"
         f"native_path={'ok' if native_ok else 'skipped'};"
         f"queue_levels={levels_on_queue};"
         f"json=BENCH_graph.json"))
