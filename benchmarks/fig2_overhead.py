"""Paper Fig. 2 — abstraction overhead: merge-path SpMV through our
load-balancing abstraction vs a hand-fused implementation of the SAME
algorithm.

The paper's question is whether *decoupling* load balancing from work
execution costs performance (CUB comparison: 2.5% geomean slowdown).  The
faithful analogue: the abstraction path (WorkSpec -> merge-path Partition ->
schedule-agnostic blocked executor) vs a hand-inlined merge-path SpMV with
no abstraction objects — identical algorithm, identical blocking — timed on
the same backend.  A ratio near 1.0 reproduces the paper's claim.

For context each row also reports the scalar segment-sum reference time:
on CPU the blocked/SIMD structure is *slower* than scalar code because this
host has no 1024-lane lockstep units — that column is hardware context, not
abstraction overhead (see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Schedule, blocked_tile_reduce, make_partition
from repro.sparse import spmv_reference, suite_like_corpus

from benchmarks._timing import geomean, time_fn

NUM_BLOCKS = 64


def hand_fused_spmv(row_offsets, col_indices, values, x, num_rows, nnz,
                    num_blocks):
    """Merge-path SpMV with everything inlined — no WorkSpec/Partition."""
    if nnz == 0:
        return jnp.zeros((num_rows,), jnp.float32)
    total = num_rows + nnz
    ipb = -(-max(total, 1) // num_blocks)
    diagonals = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * ipb, total)
    path = row_offsets.astype(jnp.int32) + jnp.arange(num_rows + 1,
                                                      dtype=jnp.int32)
    tile_starts = jnp.clip(
        jnp.searchsorted(path, diagonals, side="right").astype(jnp.int32) - 1,
        0, num_rows)
    atom_starts = (diagonals - tile_starts).astype(jnp.int32)

    window = max(ipb, 1)
    local_tiles = window + 1
    idx = atom_starts[:-1, None] + jnp.arange(window, dtype=jnp.int32)[None]
    valid = idx < atom_starts[1:, None]
    safe = jnp.clip(idx, 0, max(nnz - 1, 0))
    prods = values[safe] * x[col_indices[safe]]
    prods = jnp.where(valid, prods, 0.0)
    atoms = jnp.arange(nnz, dtype=jnp.int32)
    row_of = jnp.searchsorted(row_offsets, atoms, side="right").astype(
        jnp.int32) - 1
    local = jnp.where(valid, row_of[safe] - tile_starts[:-1, None],
                      local_tiles)
    onehot = (local[..., None]
              == jnp.arange(local_tiles, dtype=jnp.int32)[None, None, :])
    partials = jnp.einsum("gw,gwl->gl", prods, onehot.astype(jnp.float32))
    gtid = tile_starts[:-1, None] + jnp.arange(local_tiles,
                                               dtype=jnp.int32)[None, :]
    gtid = jnp.where(gtid < num_rows, gtid, num_rows)
    return jax.ops.segment_sum(partials.reshape(-1), gtid.reshape(-1),
                               num_rows + 1)[:-1]


def run(csv_rows, smoke=False):
    rng_key = jax.random.PRNGKey(0)
    ratios = []
    for name, A in suite_like_corpus(smoke=smoke):
        x = jax.random.normal(jax.random.fold_in(rng_key, hash(name) % 2**31),
                              (A.shape[1],), jnp.float32)
        spec = A.workspec()
        part = make_partition(spec, Schedule.MERGE_PATH, NUM_BLOCKS)

        @jax.jit
        def ours(vals, cols, xx, _p=part, _s=spec):
            atom_fn = lambda nz: vals[nz] * xx[cols[nz]]
            return blocked_tile_reduce(_s, _p, atom_fn)

        @jax.jit
        def hand(off, cols, vals, xx, _r=A.shape[0], _n=A.nnz):
            return hand_fused_spmv(off, cols, vals, xx, _r, _n, NUM_BLOCKS)

        @jax.jit
        def scalar_ref(vals, cols, xx, _A=A):
            return spmv_reference(_A, xx)

        # correctness guard: all three agree
        import numpy as np
        y0 = np.asarray(ours(A.values, A.col_indices, x))
        y1 = np.asarray(hand(A.row_offsets, A.col_indices, A.values, x))
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)

        t_ours = time_fn(ours, A.values, A.col_indices, x, warmup=1, iters=3)
        t_hand = time_fn(hand, A.row_offsets, A.col_indices, A.values, x,
                         warmup=1, iters=3)
        t_ref = time_fn(scalar_ref, A.values, A.col_indices, x, warmup=1,
                        iters=3)
        ratio = t_ours / t_hand
        ratios.append(ratio)
        csv_rows.append((f"fig2/{name}", t_ours,
                         f"hand_us={t_hand:.0f};overhead={ratio:.3f};"
                         f"scalar_ref_us={t_ref:.0f};nnz={A.nnz}"))
    csv_rows.append(("fig2/geomean_overhead", 0.0,
                     f"ratio={geomean(ratios):.3f}"))
