"""Paper Table 1 — lines of code per load-balancing schedule.

Counts non-comment, non-blank LoC of each schedule implementation in this
repo (partitioner + its share of the shared executor), compared against the
paper's numbers for CUB (merge-path: 503) and its own framework
(merge-path: 36, thread-mapped: 21, group/warp/block-mapped: 30).
"""
from __future__ import annotations

import inspect

from repro.core import dynamic, execute, schedules


def _loc(obj) -> int:
    src = inspect.getsource(obj)
    count = 0
    in_doc = False
    for line in src.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.startswith(('"""', "'''")):
            # toggle docstring state (handles one-line docstrings)
            if not (in_doc is False and stripped.endswith(('"""', "'''"))
                    and len(stripped) > 3):
                in_doc = not in_doc
            continue
        if in_doc or stripped.startswith("#"):
            continue
        count += 1
    return count


PAPER = {  # schedule -> (CUB LoC, paper-framework LoC)
    "merge_path": (503, 36),
    "thread_mapped": (22, 21),
    "group_mapped": (None, 30),
    "warp_mapped": (None, 30),
    "block_mapped": (None, 30),
    "nonzero_split": (None, None),
    "chunked": (None, None),       # dynamic: beyond the paper (Atos-style)
    "adaptive": (None, None),      # dynamic: beyond the paper
}


def run(csv_rows, smoke=False):
    executor_loc = _loc(execute.blocked_tile_reduce)
    ours = {
        "merge_path": _loc(schedules.merge_path_partition),
        "thread_mapped": _loc(schedules.tile_mapped_partition),
        "group_mapped": _loc(schedules.group_mapped_partition),
        "warp_mapped": 1,   # alias of group_mapped (paper: "free")
        "block_mapped": 1,  # alias of group_mapped (paper: "free")
        "nonzero_split": _loc(schedules.nonzero_split_partition),
        "chunked": _loc(dynamic.chunked_partition),
        "adaptive": _loc(dynamic.adaptive_partition),
    }
    for sched, loc in ours.items():
        cub, paper = PAPER[sched]
        csv_rows.append(
            (f"table1/{sched}", 0.0,
             f"ours_loc={loc};shared_executor_loc={executor_loc};"
             f"cub_loc={cub};paper_loc={paper}"))
