"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV.  Roofline terms for the
(arch x shape) cells come from the dry-run (see EXPERIMENTS.md §Roofline),
not from CPU wall time.

``--smoke``: run every suite on one tiny shape and fail on any exception —
the CI guard against benchmark bit-rot (no timing signal, just liveness).
Smoke mode additionally:

* points every suite at **one shared autotune cache** (a fresh tempdir via
  ``REPRO_AUTOTUNE_CACHE``, unless the caller already pinned one), so
  suites stop re-running partition inspection per suite for recurring
  shapes, and
* prints per-suite and total **partition inspector counts**
  (``partition_builds=``) and fails if the total exceeds
  ``SMOKE_PARTITION_BUILD_CEILING`` — the regression hook for the PR-2
  re-inspection bug class (a cache regression shows up as a count
  explosion long before anyone reads a timing).
"""
from __future__ import annotations

import os
import sys
import tempfile

#: Smoke-mode ceiling on total concrete partition builds across all suites.
#: Measured headroom: a healthy smoke run builds ~280 partitions
#: (cost-model scoring included); re-inspection regressions multiply that.
#: Raise this deliberately when a suite legitimately grows, never to
#: silence a jump.
SMOKE_PARTITION_BUILD_CEILING = 600


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    only = args[0] if args else None
    if smoke:
        # one shared cache dir for every suite (honoured lazily by
        # AutotuneCache, so setting it before the suite imports is enough)
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", os.path.join(
            tempfile.mkdtemp(prefix="repro_smoke_autotune_"),
            "autotune.json"))

    from benchmarks import (fig2_overhead, fig3_landscape, fig4_heuristic,
                            fig_dynamic, fig_graph, fig_serve,
                            fig_wavefront, moe_dispatch, packing_bench,
                            table1_loc)
    from repro.core import partition_build_count
    suites = [
        ("fig2_overhead", fig2_overhead),
        ("fig3_landscape", fig3_landscape),
        ("fig4_heuristic", fig4_heuristic),
        ("fig_dynamic", fig_dynamic),
        ("fig_graph", fig_graph),
        # fig_serve and fig_wavefront merge their sections into fig_graph's
        # JSON, so they must run after fig_graph in full runs
        ("fig_serve", fig_serve),
        ("fig_wavefront", fig_wavefront),
        ("table1_loc", table1_loc),
        ("moe_dispatch", moe_dispatch),
        ("packing_bench", packing_bench),
    ]
    rows = []
    failures = []
    builds_at_start = partition_build_count()
    print("name,us_per_call,derived")
    for name, mod in suites:
        if only and only not in name:
            continue
        start = len(rows)
        builds_before = partition_build_count()
        try:
            mod.run(rows, smoke=smoke)
        except Exception as exc:  # noqa: BLE001 - smoke mode reports & fails
            if not smoke:
                raise
            failures.append((name, exc))
            print(f"{name}/SMOKE_FAILED,0.0,{type(exc).__name__}: {exc}")
        if smoke:
            rows.append((f"{name}/inspector", 0.0,
                         f"partition_builds="
                         f"{partition_build_count() - builds_before}"))
        for r in rows[start:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        sys.stdout.flush()
    if smoke:
        total_builds = partition_build_count() - builds_at_start
        over = total_builds > SMOKE_PARTITION_BUILD_CEILING
        print(f"smoke,0.0,suites_failed={len(failures)};"
              f"partition_builds_total={total_builds};"
              f"build_ceiling={SMOKE_PARTITION_BUILD_CEILING};"
              f"reinspection={'REGRESSED' if over else 'ok'}")
        if failures or over:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
