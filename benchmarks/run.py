"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV.  Roofline terms for the
(arch x shape) cells come from the dry-run (see EXPERIMENTS.md §Roofline),
not from CPU wall time.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig2_overhead, fig3_landscape, fig4_heuristic,
                            moe_dispatch, packing_bench, table1_loc)
    suites = [
        ("fig2_overhead", fig2_overhead),
        ("fig3_landscape", fig3_landscape),
        ("fig4_heuristic", fig4_heuristic),
        ("table1_loc", table1_loc),
        ("moe_dispatch", moe_dispatch),
        ("packing_bench", packing_bench),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    rows = []
    print("name,us_per_call,derived")
    for name, mod in suites:
        if only and only not in name:
            continue
        start = len(rows)
        mod.run(rows)
        for r in rows[start:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
