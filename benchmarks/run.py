"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV.  Roofline terms for the
(arch x shape) cells come from the dry-run (see EXPERIMENTS.md §Roofline),
not from CPU wall time.

``--smoke``: run every suite on one tiny shape and fail on any exception —
the CI guard against benchmark bit-rot (no timing signal, just liveness).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig2_overhead, fig3_landscape, fig4_heuristic,
                            fig_dynamic, fig_graph, moe_dispatch,
                            packing_bench, table1_loc)
    suites = [
        ("fig2_overhead", fig2_overhead),
        ("fig3_landscape", fig3_landscape),
        ("fig4_heuristic", fig4_heuristic),
        ("fig_dynamic", fig_dynamic),
        ("fig_graph", fig_graph),
        ("table1_loc", table1_loc),
        ("moe_dispatch", moe_dispatch),
        ("packing_bench", packing_bench),
    ]
    args = sys.argv[1:]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    only = args[0] if args else None
    rows = []
    failures = []
    print("name,us_per_call,derived")
    for name, mod in suites:
        if only and only not in name:
            continue
        start = len(rows)
        try:
            mod.run(rows, smoke=smoke)
        except Exception as exc:  # noqa: BLE001 - smoke mode reports & fails
            if not smoke:
                raise
            failures.append((name, exc))
            print(f"{name}/SMOKE_FAILED,0.0,{type(exc).__name__}: {exc}")
        for r in rows[start:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        sys.stdout.flush()
    if smoke:
        print(f"smoke,0.0,suites_failed={len(failures)}")
        if failures:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
