"""Paper Fig. 3 — performance landscape of every schedule per dataset.

Two views per (dataset, schedule):
* measured wall-time of the jitted blocked executor on CPU, and
* the modeled lockstep cost (what a SIMD machine pays: max over lanes) —
  the hardware-independent signal that drives the Fig. 4 heuristic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (ImbalanceStats, Schedule, blocked_tile_reduce,
                        make_partition, modeled_cost)
from repro.sparse import suite_like_corpus

from benchmarks._timing import time_fn

NUM_BLOCKS = 64
SCHEDULES = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
             Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]


def run(csv_rows, smoke=False):
    key = jax.random.PRNGKey(1)
    for name, A in suite_like_corpus(smoke=smoke):
        x = jax.random.normal(jax.random.fold_in(key, hash(name) % 2**31),
                              (A.shape[1],), jnp.float32)
        spec = A.workspec()
        stats = ImbalanceStats.measure(spec)
        for sched in SCHEDULES:
            part = make_partition(spec, sched, NUM_BLOCKS)

            @jax.jit
            def f(vals, cols, x, _p=part, _s=spec):
                atom_fn = lambda nz: vals[nz] * x[cols[nz]]
                return blocked_tile_reduce(_s, _p, atom_fn)

            t = time_fn(f, A.values, A.col_indices, x, warmup=1, iters=3)
            cost = modeled_cost(spec, sched, NUM_BLOCKS)
            csv_rows.append(
                (f"fig3/{name}/{sched}", t,
                 f"modeled_cost={cost:.0f};cv={stats.cv_atoms_per_tile:.2f};"
                 f"nnz={A.nnz}"))
