"""Beyond-paper benchmark — MoE dispatch through the LB abstraction.

Compares the three dispatch executors on one routed batch at increasing
router skew (Zipf temperature): the einsum reference, the production
sort-based capacity dispatch, and the paper-style sorted + balanced Pallas
segmented GEMM (drop-free).  Reports wall time and token-drop fraction —
the quality/throughput trade the LB schedule removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as M

from benchmarks._timing import time_fn

D, DFF, E, TOPK, T = 64, 128, 16, 4, 512


def _routed_batch(skew: float, seed: int):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, T, D)).astype(np.float32)) * 0.5
    # bias the router by skewing the logits toward low expert ids
    bias = jnp.asarray((np.arange(E) * -skew).astype(np.float32))
    return x, bias


def run(csv_rows, smoke=False):
    params, _ = M.moe_init(jax.random.PRNGKey(3), D, DFF, E, 0, "silu_glu")
    for skew in ((2.0,) if smoke else (0.0, 0.5, 2.0)):
        x, bias = _routed_batch(skew, int(skew * 10))
        p = dict(params)
        p["router"] = params["router"] + bias[None, :]

        cap = jax.jit(lambda xx, _p=p: M.moe_capacity(
            _p, xx, num_experts=E, top_k=TOPK, capacity_factor=1.25)[0])
        srt = jax.jit(lambda xx, _p=p: M.moe_sorted(
            _p, xx, num_experts=E, top_k=TOPK)[0])

        t_cap = time_fn(cap, x, warmup=1, iters=3)
        t_srt = time_fn(srt, x, warmup=1, iters=3)

        # drop fraction under capacity dispatch
        logits = x.reshape(T, D) @ p["router"]
        topk_idx = jax.lax.top_k(jax.nn.softmax(logits), TOPK)[1]
        counts = np.bincount(np.asarray(topk_idx).ravel(), minlength=E)
        capacity = int(1.25 * T * TOPK / E)
        dropped = np.maximum(counts - capacity, 0).sum() / (T * TOPK)

        csv_rows.append((f"moe/skew{skew}/capacity", t_cap,
                         f"drop_frac={dropped:.3f}"))
        csv_rows.append((f"moe/skew{skew}/sorted_lb", t_srt,
                         "drop_frac=0.000"))
