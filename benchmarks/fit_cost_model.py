"""Report-only CLI: re-fit cost-model coefficients from measured records.

Closes the measured-cost feedback loop end to end (ROADMAP item 3's last
mile): run the autotuner in measured mode over a workload sweep so its
cache accumulates v2 records (median wall-times *plus* the model-feature
decomposition of each measured plan), then least-squares re-fit the
tunable :mod:`repro.core.balance` coefficients against those measurements
via :func:`repro.core.balance.fit_coefficients` and print the report.

**Report-only by design**: the tool never rewrites ``balance.py``.  On
this container the executors run under Pallas interpret mode on CPU, so
fitted values describe the *measurement host*, not a TPU — the printed
table is for a human to read next to ``docs/autotune.md`` before deciding
whether any hand-set constant deserves to move.

Usage::

    PYTHONPATH=src python benchmarks/fit_cost_model.py --smoke
    PYTHONPATH=src python benchmarks/fit_cost_model.py --cache /tmp/c.json
    PYTHONPATH=src python benchmarks/fit_cost_model.py \
        --cache /tmp/c.json --fit-only   # no new measurements

``--fit-only`` skips the measuring sweep and fits from whatever v2
records the cache already holds (e.g. one populated by a previous run or
by ``REPRO_AUTOTUNE_MEASURE=1`` production runs).
"""
from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Plan, WorkSpec, collect_fit_samples,
                        execute_scatter_reduce, execute_tile_reduce,
                        fit_coefficients, make_partition, select_plan,
                        time_fn)
from repro.core.autotune import AutotuneCache

NUM_BLOCKS = 64


def _workloads(smoke: bool):
    """(name, spec, out_ids, num_out, values) tuples for the measuring sweep."""
    from repro.sparse import random_csr, suite_like_corpus
    out = []
    for name, A in suite_like_corpus(smoke=True):
        out.append((f"corpus/{name}", A))
    if not smoke:
        out.append(("synthetic/powerlaw_skew1.4",
                    random_csr(2_000, 2_000, 50_000, skew=1.4,
                               empty_frac=0.1, seed=11)))
        out.append(("synthetic/scalefree",
                    random_csr(4_000, 4_000, 60_000, skew=1.3,
                               empty_frac=0.3, seed=13)))
    rows = []
    for name, A in out:
        spec = A.workspec()
        rows.append((name, spec, A.col_indices, int(A.shape[1]), A.values))
    return rows


def _measure_reduce(spec: WorkSpec, vals: jax.Array):
    """Timing closure for the reduce family: one plan -> median us."""
    def run(plan: Plan) -> float:
        part = make_partition(spec, plan.schedule, NUM_BLOCKS)

        @jax.jit
        def f(v):
            return execute_tile_reduce(spec, part, lambda a: v[a],
                                       path=plan.path, interpret=True)

        return time_fn(f, vals, warmup=1, iters=3)
    return run


def _measure_push(spec: WorkSpec, vals: jax.Array, out_ids: jax.Array,
                  num_out: int, mask: jax.Array):
    """Timing closure for the push-advance family (scatter-reduce)."""
    def run(plan: Plan) -> float:
        part = make_partition(spec, plan.schedule, NUM_BLOCKS)

        @jax.jit
        def f(v):
            return execute_scatter_reduce(spec, part, lambda a: v[a],
                                          out_ids, num_out,
                                          path=plan.path, atom_mask=mask,
                                          interpret=True)

        return time_fn(f, vals, warmup=1, iters=3)
    return run


def populate(cache: AutotuneCache, smoke: bool) -> int:
    """Measured-mode sweep: reduce + push-advance per workload."""
    # the sweep *is* the measured mode — force the gate on for this process
    os.environ["REPRO_AUTOTUNE_MEASURE"] = "1"
    rng = np.random.default_rng(5)
    n = 0
    for name, spec, out_ids, num_out, vals in _workloads(smoke):
        select_plan(spec, NUM_BLOCKS, cache=cache,
                    measure=_measure_reduce(spec, vals))
        mask = jnp.asarray(rng.random(spec.num_atoms) < 0.4)
        select_plan(spec, NUM_BLOCKS, cache=cache, workload="advance_push",
                    measure=_measure_push(spec, vals, out_ids, num_out, mask))
        n += 2
        print(f"  measured {name}: reduce + advance_push", flush=True)
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cache", default="/tmp/repro_fit_cache.json",
                    help="autotune cache JSON accumulating v2 records")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus only (CI liveness)")
    ap.add_argument("--fit-only", action="store_true",
                    help="skip measuring; fit from existing cache records")
    ap.add_argument("--fresh", action="store_true",
                    help="clear the cache before measuring")
    args = ap.parse_args(argv)

    cache = AutotuneCache(args.cache)
    if args.fresh and not args.fit_only:
        cache.clear()
    if not args.fit_only:
        print(f"[fit_cost_model] measuring sweep -> {args.cache}")
        populate(cache, smoke=args.smoke)

    samples = collect_fit_samples(cache)
    print(f"[fit_cost_model] {len(samples)} fit samples in {args.cache}")
    if not samples:
        print("no measured records with stored features; run without "
              "--fit-only (or point --cache at a measured-mode cache)")
        return 1
    fit = fit_coefficients(samples)
    print(fit.report())
    print("FIT_OK" if fit.num_samples > 0 else "FIT_EMPTY")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
