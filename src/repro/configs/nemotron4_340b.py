"""Nemotron-4-340B: GQA + squared-ReLU MLP [arXiv:2402.16819; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense", num_layers=96, d_model=18432,
    num_heads=96, num_kv_heads=8, d_ff=73728, vocab_size=256000,
    head_dim=192, activation="sq_relu", rope_theta=10_000.0,
    loss_seq_chunk=512, grad_accum_bf16=True, attn_query_chunk=1024,
    notes="memory-limiting arch; perf cell C: chunked CE + bf16 grad accum "
          "by default, seq_sharded_activations as the HBM-bound lever")
