"""InternVL2-1B: InternViT (stub) + Qwen2-0.5B-flavoured LM backbone
[arXiv:2404.16821; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151655,
    qkv_bias=True, rope_theta=1_000_000.0, attn_query_chunk=1024,
    frontend="vision_stub",
    frontend_len=256,
    notes="frontend stub: input_specs() provides 256 patch embeddings")
