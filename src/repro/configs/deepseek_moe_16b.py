"""DeepSeekMoE-16B: fine-grained 64 routed top-6 + 2 shared [arXiv:2401.06066; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=102400,
    rope_theta=10_000.0, num_experts=64, num_shared_experts=2, top_k=6,
    moe_dispatch="grouped", attn_query_chunk=1024,
    notes="fine-grained experts; shared experts bypass the router")
