"""GLM4-9B: RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=151552,
    rope_theta=10_000.0, attn_query_chunk=1024,
    notes="kv_heads=2 < TP width: decode shards the KV sequence axis")
