"""H2O-Danube3-4B: llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    num_heads=32, num_kv_heads=8, d_ff=10240, vocab_size=32000,
    head_dim=120, rope_theta=10_000.0, sliding_window=4096,
    attn_query_chunk=1024, swa_banded=True,
    notes="SWA window 4096 bounds the decode cache -> long_500k runs")
