"""RWKV6 (Finch) 3B: attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=0, num_kv_heads=0, d_ff=8960, vocab_size=65536,
    rwkv_head_dim=64, ssm_chunk=64,
    notes="constant-size state -> long_500k runs; chunked 3-pass WKV")
