"""OLMoE-1B-7B: 64-expert top-8 MoE [arXiv:2409.02060; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1024, vocab_size=50304,
    rope_theta=10_000.0, num_experts=64, top_k=8, moe_dispatch="grouped",
    attn_query_chunk=1024,
    notes="fully MoE FFN; d_ff is the per-expert width")
