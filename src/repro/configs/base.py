"""Config system: model architecture + input-shape cases + registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    activation: str = "silu_glu"    # silu_glu | sq_relu | gelu
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "capacity"  # capacity | sorted
    # SSM / hybrid
    ssm_state: int = 0
    rwkv_head_dim: int = 64
    ssm_chunk: int = 64
    # modality frontend (stubbed: precomputed embeddings)
    frontend: Optional[str] = None  # vision_stub | audio_stub
    frontend_len: int = 0           # prefix positions fed as embeddings
    # training-time structure
    scan_layers: bool = True
    remat: bool = True
    attn_query_chunk: Optional[int] = None  # flash-style score blocking
    swa_banded: bool = False        # banded SWA: only compute window band
    seq_sharded_activations: bool = False   # Megatron-SP saved activations
    loss_seq_chunk: Optional[int] = None    # chunked cross-entropy
    # roofline-unit builds only: python-unroll inner chunk loops so
    # cost_analysis counts every iteration (lax.scan bodies count once)
    unroll_inner_scans: bool = False
    moe_ep_pins: bool = False       # pin MoE expert buffers to the EP axis
    grad_accum_bf16: bool = False   # bf16 grad accumulation (halves the
    # accumulator + per-microbatch reduce-wire; Adam runs on the f32 cast)
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables padded to 256 (= 16 data x 16 model) so the
        vocab axis shards evenly on the production mesh (internvl2's 151655
        and hymba's 32001 are not 16-divisible).  Loss masks the pad."""
        return -(-self.vocab_size // 256) * 256

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-scale config of the same family (for CPU tests)."""
        kv_ratio = max(self.num_heads // max(self.num_kv_heads, 1), 1)
        num_heads = 4
        num_kv_heads = max(num_heads // min(kv_ratio, 4), 1)
        base = dict(
            name=self.name + "-reduced", family=self.family, num_layers=2,
            d_model=64, num_heads=0 if self.num_heads == 0 else num_heads,
            num_kv_heads=0 if self.num_heads == 0 else num_kv_heads,
            d_ff=96, vocab_size=256, head_dim=16, qkv_bias=self.qkv_bias,
            activation=self.activation, rope_theta=self.rope_theta,
            sliding_window=None if self.sliding_window is None else 8,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            num_shared_experts=min(self.num_shared_experts, 2),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=self.capacity_factor,
            moe_dispatch=self.moe_dispatch,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            rwkv_head_dim=16, ssm_chunk=8, frontend=self.frontend,
            frontend_len=4 if self.frontend else 0,
            scan_layers=self.scan_layers, remat=False, notes="reduced",
        )
        base.update(overrides)
        return ModelConfig(**base)


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode
    # decode shapes lower serve_step: one new token, KV cache of seq_len.


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}

# Archs whose long_500k cell runs (sub-quadratic / bounded-state decode).
SUBQUADRATIC = {"rwkv6-3b", "hymba-1.5b", "h2o-danube-3-4b"}

ARCH_IDS: List[str] = [
    "olmoe_1b_7b", "deepseek_moe_16b", "h2o_danube3_4b", "qwen15_05b",
    "nemotron4_340b", "glm4_9b", "rwkv6_3b", "internvl2_1b",
    "musicgen_large", "hymba_15b",
]


def get_config(arch: str) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py`` (dashes normalized)."""
    mod_name = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> List[ModelConfig]:
    return [get_config(a) for a in ARCH_IDS]


def cells_for(cfg: ModelConfig) -> List[Tuple[str, ShapeCase]]:
    """The (shape) cells assigned to an arch, honoring the long_500k and
    encoder-only skip rules (all assigned archs are decoder LMs)."""
    out = []
    for name, case in SHAPES.items():
        if name == "long_500k" and cfg.name not in SUBQUADRATIC:
            continue  # pure full-attention: no sub-quadratic 500k path
        out.append((name, case))
    return out
