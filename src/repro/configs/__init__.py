"""Per-architecture configs; ``get_config(arch_id)`` loads by module name."""
from repro.configs.base import (ARCH_IDS, SHAPES, SUBQUADRATIC, ModelConfig,
                                ShapeCase, all_configs, cells_for, get_config)

__all__ = ["ARCH_IDS", "SHAPES", "SUBQUADRATIC", "ModelConfig", "ShapeCase",
           "all_configs", "cells_for", "get_config"]
