"""MusicGen-large: decoder-only over EnCodec tokens [arXiv:2306.05284; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048,
    activation="gelu", attn_query_chunk=1024,
    frontend="audio_stub", frontend_len=64,
    notes="EnCodec frontend stubbed: conditioning frames as embeddings")
