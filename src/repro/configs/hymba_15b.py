"""Hymba-1.5B: parallel attention + mamba heads per layer
[arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", num_layers=32, d_model=1600,
    num_heads=25, num_kv_heads=5, d_ff=5504, vocab_size=32001,
    head_dim=64, ssm_state=16, sliding_window=1024, ssm_chunk=64,
    attn_query_chunk=1024, swa_banded=True,
    notes="attn branch uses SWA; mamba branch bounded state -> 500k runs")
