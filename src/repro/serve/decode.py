"""Serving: jitted one-token decode step with sharded KV caches + sampling.

Decode sharding policy (see DESIGN.md):

* batch over the data-parallel axes when divisible (decode_32k: B=128 over
  16 data shards);
* KV/state *sequence* axis over the model axis — essential when
  ``kv_heads < model_axis`` (glm4-9b has 2 KV heads on a 16-wide TP axis).
  Softmax over a sequence-sharded axis makes GSPMD emit the partial-max /
  partial-sum reductions — the flash-decode combine — on its own;
* long_500k (B=1): batch replicated, cache sharded over ``model`` only.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import use_ambient_mesh
from repro.configs.base import ModelConfig
from repro.models import cache_shape, decode_step


def _data_axes(mesh: Mesh, batch: int):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and batch % size == 0 and batch >= size:
        return tuple(axes)
    return ()


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int
                 ) -> Dict[str, P]:
    """Partition specs per cache leaf: [L, B, S, Hkv, hd] etc."""
    daxes = _data_axes(mesh, batch)
    b_ax = daxes if daxes else None
    tp = "model" if "model" in mesh.axis_names else None
    specs: Dict[str, P] = {}
    if cfg.family in ("dense", "vlm", "audio", "moe", "hybrid"):
        # prefer sharding KV heads over TP (local ring updates); fall back
        # to the sequence axis when kv_heads < TP width (e.g. glm4's kv=2)
        tp_width = mesh.shape.get("model", 1) if tp else 1
        if tp and cfg.num_kv_heads % tp_width == 0:
            specs["k"] = P(None, b_ax, None, tp, None)
            specs["v"] = P(None, b_ax, None, tp, None)
        else:
            specs["k"] = P(None, b_ax, tp, None, None)
            specs["v"] = P(None, b_ax, tp, None, None)
    if cfg.family == "ssm":
        # [L, B, H, K, V]: H (e.g. 40) need not divide TP; shard K instead
        specs["wkv"] = P(None, b_ax, None, tp, None)
        specs["xprev_t"] = P(None, b_ax, None, None)
        specs["xprev_c"] = P(None, b_ax, None, None)
    if cfg.family == "hybrid":
        specs["h"] = P(None, b_ax, tp, None)             # d_inner over TP
    return specs


def make_serve_step(cfg: ModelConfig, mesh: Mesh, *, batch: int,
                    seq_len: int, dtype=jnp.bfloat16):
    """Returns (jitted_step, param_sh, cache_sh, input_sds).

    ``jitted_step(params, tokens [B,1], pos, cache) -> (logits, new_cache)``
    with the cache donated (in-place ring update on device).
    """
    from repro.train.step import param_specs, shardings_for

    param_sh = shardings_for(mesh, param_specs(cfg))
    cache_sh = shardings_for(mesh, cache_pspecs(cfg, mesh, batch))
    daxes = _data_axes(mesh, batch)
    tok_sh = NamedSharding(mesh, P(daxes if daxes else None, None))

    def step_fn(params, tokens, pos, cache):
        with use_ambient_mesh(mesh):
            return decode_step(params, cfg, tokens, pos, cache, dtype=dtype)

    step = jax.jit(step_fn,
                   in_shardings=(param_sh, tok_sh, None, cache_sh),
                   out_shardings=(None, cache_sh),
                   donate_argnums=(3,))
    cache_sds = jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        cache_shape(cfg, batch, seq_len, dtype), cache_sh)
    tok_sds = jax.ShapeDtypeStruct((batch, 1), jnp.int32, sharding=tok_sh)
    return step, param_sh, cache_sh, {"tokens": tok_sds, "cache": cache_sds}


def sample_logits(key, logits: jax.Array, temperature: float = 1.0,
                  vocab_size: int | None = None) -> jax.Array:
    """Greedy (T=0) or temperature sampling. logits: [B, 1, V] -> [B, 1].

    ``vocab_size`` masks the vocab-padding columns (``padded_vocab`` rounds
    the head up to a lane multiple) to ``-inf`` so neither argmax nor
    categorical can ever emit an out-of-vocab token id.
    """
    last = logits[:, -1]
    if vocab_size is not None and vocab_size < last.shape[-1]:
        keep = jnp.arange(last.shape[-1]) < vocab_size
        last = jnp.where(keep, last, jnp.float32(-jnp.inf))
    if temperature == 0.0:
        return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, last / temperature, axis=-1)[:, None].astype(jnp.int32)


def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             cache, key=None, temperature: float = 0.0,
             dtype=jnp.float32) -> Tuple[jax.Array, Any]:
    """Simple autoregressive loop (prefill via repeated decode) for tests
    and the serving example; production uses make_serve_step."""
    b, plen = prompt.shape
    key = key if key is not None else jax.random.PRNGKey(0)
    out = []
    tok = prompt[:, :1]
    for t in range(plen + steps - 1):
        logits, cache = decode_step(params, cfg, tok, jnp.int32(t), cache,
                                    dtype=dtype)
        if t + 1 < plen:
            tok = prompt[:, t + 1:t + 2]
        else:
            key, sub = jax.random.split(key)
            tok = sample_logits(sub, logits, temperature,
                                vocab_size=cfg.vocab_size)
            out.append(tok)
    if not out:  # steps == 0: nothing sampled, [B, 0] keeps callers total
        return jnp.zeros((b, 0), jnp.int32), cache
    return jnp.concatenate(out, axis=1), cache
