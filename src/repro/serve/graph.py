"""Continuous-batching traversal serving over a shared ``AdvancePlan`` pair.

``bfs_multi`` vmaps *identical* queries; real traffic is a continuous
stream of heterogeneous ones — mixed BFS / SSSP / PageRank, arbitrary
sources, staggered arrival and completion.  This module is the serving
tier that sits on top of the load-balancing layer (the ROADMAP's
millions-of-users scenario): one :class:`GraphServer` holds a single plan
pair built once per graph, a :class:`QueryBatch` of fixed lane width ``W``
carries per-lane traversal state, and one jitted step advances every live
lane together.  Converged lanes retire and queued queries backfill the
freed lanes **without re-tracing** — lane lifecycle is data (masks and
selects), never shape.

Design (the espnet ``batch_beam_search_online`` pattern, applied to
traversal):

* **Unified lane state.**  BFS is unit-weight Bellman–Ford, so BFS and
  SSSP lanes share one min-combiner relax whose per-atom weight is a
  per-lane select between ``1.0`` and the plan's edge weight — one vmapped
  advance serves both kinds at no extra cost.  PageRank lanes ride a
  separate sum-combiner advance (the driver's power-iteration body) that
  runs under a *scalar* ``lax.cond`` — a stream with no live PageRank lane
  never pays it (and vice versa for the relax).  Each lane's ``[V]`` value
  row is its tentative distances (BFS/SSSP) or rank vector (PageRank).
* **Driver-exact recurrences.**  Each lane replays the exact loop body of
  its single-query driver (:func:`repro.sparse.graph.bfs` / ``sssp`` /
  ``pagerank``) over the same plan, so a retired lane's answer is
  **bitwise-identical** to the single-query result — the per-query drivers
  are the ``W=1`` special case of this layer.
* **Per-lane direction choice** falls out of the existing measured-density
  carry: each lane carries its frontier's active out-edge count, compared
  against the plan's modeled threshold.  Under vmap the direction
  ``lax.cond`` lowers to a both-branch select (the :func:`bfs_multi`
  caveat), so the server defaults to ``direction="pull"`` for throughput;
  ``"auto"`` stays available where per-lane adaptivity matters more than
  the double advance.
* **No-retrace contract.**  The step and admit functions are traced
  exactly once per server (pinned by :attr:`GraphServer.step_traces` /
  :attr:`GraphServer.admit_traces`); admission, retirement and backfill
  only change array *contents*.

See docs/serving.md for the lane lifecycle and the throughput-vs-latency
tradeoffs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ExecutionPath, Schedule
from repro.sparse.advance import (AdvancePlan, advance, advance_push,
                                  build_advance)
from repro.sparse.graph import (Graph, INF, _active_edge_count, _directed,
                                _pagerank_share, _pagerank_update,
                                _validate_sources)

__all__ = ["KIND_BFS", "KIND_SSSP", "KIND_PAGERANK", "QueryBatch",
           "ServedResult", "GraphServer"]

KIND_BFS = 0
KIND_SSSP = 1
KIND_PAGERANK = 2

_KIND_CODES = {"bfs": KIND_BFS, "sssp": KIND_SSSP, "pagerank": KIND_PAGERANK}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}


class QueryBatch(NamedTuple):
    """Fixed-width lane state: every field is ``[W]`` or ``[W, V]``.

    A NamedTuple pytree so the whole batch flows through one jitted step.
    ``value`` is the unified per-lane answer row — tentative distances for
    BFS/SSSP lanes (``inf`` = unreached; BFS depths are the integer-valued
    distances of the unit-weight relax), the rank vector for PageRank.
    ``active`` marks occupied lanes, ``done`` marks converged lanes
    awaiting host retirement (their rows are frozen by the step's
    liveness select).  ``active_edges`` is the measured frontier out-edge
    count — the same carry the single-query drivers thread for the
    ``"auto"`` direction switch.  ``delta`` is the PageRank L1 step
    change; ``pushes`` counts push-direction advances per lane (the
    direction-statistics evidence, as in the drivers).
    """

    kind: jax.Array          # [W] int32 — KIND_BFS / KIND_SSSP / KIND_PAGERANK
    source: jax.Array        # [W] int32 (ignored by PageRank lanes)
    qid: jax.Array           # [W] int32 (-1 = free lane)
    active: jax.Array        # [W] bool
    done: jax.Array          # [W] bool
    iters: jax.Array         # [W] int32
    value: jax.Array         # [W, V] float32
    frontier: jax.Array      # [W, V] bool (BFS/SSSP lanes)
    active_edges: jax.Array  # [W] int32 — measured-density carry
    delta: jax.Array         # [W] float32 — PageRank L1 step change
    pushes: jax.Array        # [W] int32 — push-direction advance count


@dataclasses.dataclass(frozen=True)
class ServedResult:
    """One retired query: the answer plus serving metadata."""

    qid: int
    kind: str                # "bfs" | "sssp" | "pagerank"
    source: int
    value: np.ndarray        # bfs: int32 depths; sssp/pagerank: float32 [V]
    iterations: int          # traversal iterations the lane ran
    pushes: int              # push-direction advances the lane ran
    submitted_at: float      # perf_counter timestamps
    admitted_at: float
    completed_at: float

    @property
    def latency(self) -> float:
        """Submit-to-completion wall-clock seconds (queueing included)."""
        return self.completed_at - self.submitted_at


@dataclasses.dataclass
class _Pending:
    kind_code: int
    source: int
    submitted_at: float
    admitted_at: float = 0.0


class GraphServer:
    """Continuous-batching server for graph queries over one plan pair.

    Parameters mirror the single-query drivers: ``schedule="auto"`` routes
    the plan choice through the autotuner's ``"advance_serve"`` workload
    family (its own cache namespace; pass ``measure=`` under
    ``REPRO_AUTOTUNE_MEASURE=1`` for measured-mode selection on the
    serving relax), ``direction`` picks the advance orientation for
    BFS/SSSP lanes (``"pull"`` default — see the module docstring),
    ``max_iters``/``damping``/``num_iters``/``tol`` pin the per-kind
    convergence rules (defaults match the drivers: ``max_iters=V``,
    PageRank ``damping=0.85, num_iters=50, tol=0.0``).

    Host API::

        srv = GraphServer(graph, lanes=8)
        qid = srv.submit("bfs", source=3)
        results = srv.drain()          # or: srv.tick() per arrival slot

    ``submit`` may be called at any time — including between ticks while
    earlier queries are in flight — which is the continuous-batching
    contract.
    """

    def __init__(self, graph: Graph, *, lanes: int = 8,
                 plan: Optional[AdvancePlan] = None,
                 schedule: Schedule | str = "auto",
                 num_blocks: Optional[int] = None,
                 path: ExecutionPath | str = ExecutionPath.AUTO,
                 direction: str = "pull",
                 max_iters: Optional[int] = None,
                 damping: float = 0.85, num_iters: int = 50,
                 tol: float = 0.0,
                 measure=None,
                 interpret: bool = True):
        if graph.num_vertices == 0:
            raise ValueError("GraphServer needs a non-empty graph "
                             "(no valid query sources on 0 vertices)")
        if lanes < 1:
            raise ValueError(f"lane width must be >= 1, got {lanes}")
        if direction not in ("pull", "push", "auto"):
            raise ValueError(f"unknown direction: {direction!r} "
                             f"(expected 'pull', 'push' or 'auto')")
        self.graph = graph
        self.lanes = int(lanes)
        self.direction = direction
        self.plan = plan if plan is not None else build_advance(
            graph, schedule=schedule, num_blocks=num_blocks, path=path,
            workload="advance_serve", measure=measure, interpret=interpret)
        V = graph.num_vertices
        self._V = V
        self.max_iters = V if max_iters is None else int(max_iters)
        self.damping = float(damping)
        self.num_iters = int(num_iters)
        self.tol = float(tol)

        # -- host bookkeeping ---------------------------------------------
        self._queue: Deque[int] = deque()          # qids awaiting a lane
        self._pending: Dict[int, _Pending] = {}    # qid -> submit metadata
        self._lane_qid = np.full(self.lanes, -1, np.int64)  # host mirror
        self._next_qid = 0
        self.steps = 0            # serving steps executed
        self.served = 0           # queries retired
        self._step_traces: List[float] = []   # appended at trace time
        self._admit_traces: List[float] = []

        self.batch = self._empty_batch()
        self._jstep = jax.jit(self._make_step())
        self._jadmit = jax.jit(self._make_admit())

    # -- construction helpers ---------------------------------------------

    def _empty_batch(self) -> QueryBatch:
        W, V = self.lanes, self._V
        return QueryBatch(
            kind=jnp.zeros((W,), jnp.int32),
            source=jnp.zeros((W,), jnp.int32),
            qid=jnp.full((W,), -1, jnp.int32),
            active=jnp.zeros((W,), bool),
            done=jnp.zeros((W,), bool),
            iters=jnp.zeros((W,), jnp.int32),
            value=jnp.zeros((W, V), jnp.float32),
            frontier=jnp.zeros((W, V), bool),
            active_edges=jnp.zeros((W,), jnp.int32),
            delta=jnp.full((W,), INF, jnp.float32),
            pushes=jnp.zeros((W,), jnp.int32))

    def _make_step(self):
        plan, W, V = self.plan, self.lanes, self._V
        direction = self.direction
        max_iters, num_iters = self.max_iters, self.num_iters
        damping, tol = self.damping, self.tol
        outdeg = plan.out_degrees.astype(jnp.float32)
        src, psrc = plan.src, plan.push_src
        w_pull, w_push = plan.weight, plan.push_weight

        def lane_relax(value, frontier, unit, active_edges):
            # One BFS/SSSP lane: the drivers' `_relax_directed` body with a
            # per-lane unit-weight select (BFS == unit-weight Bellman-Ford,
            # so SSSP lanes see exactly `value[src[e]] + weight[e]` — the
            # same two f32 operands, same rounding, as advance_relax_min).
            wl = jnp.where(unit, jnp.float32(1.0), w_pull)
            wp = jnp.where(unit, jnp.float32(1.0), w_push)
            cand, used_push = _directed(
                plan, direction, active_edges,
                lambda: advance_push(plan, frontier,
                                     lambda e: value[psrc[e]] + wp[e],
                                     combiner="min"),
                lambda: advance(plan, frontier,
                                lambda e: value[src[e]] + wl[e],
                                combiner="min"))
            new_value = jnp.minimum(value, cand)
            return new_value, new_value < value, used_push

        def lane_pagerank(pr):
            # One PageRank lane: the driver's power-iteration body, pull
            # direction (the driver's "auto" resolution on a full
            # frontier), bit-for-bit.  The shared helpers pin per-op
            # rounding behind optimization barriers — without them XLA
            # fuses the update differently in the vmapped serving step
            # than in the driver's while_loop and the bits drift.
            share = _pagerank_share(pr, outdeg)
            contrib = advance(plan, None, lambda e: share[src[e]],
                              combiner="sum")
            dangling = jnp.sum(jnp.where(outdeg > 0, 0.0, pr))
            new_pr = _pagerank_update(contrib, dangling, damping, V)
            return new_pr, jnp.abs(new_pr - pr).sum()

        def step(b: QueryBatch) -> QueryBatch:
            self._step_traces.append(time.perf_counter())
            live = jnp.logical_and(b.active, ~b.done)
            is_pr = b.kind == KIND_PAGERANK
            dist_live = jnp.logical_and(live, ~is_pr)
            pr_live = jnp.logical_and(live, is_pr)
            unit = b.kind == KIND_BFS

            # BFS/SSSP relax — scalar-guarded: a PageRank-only step never
            # pays the vmapped min-advance (and vice versa below).  The
            # frontier mask already zeroes non-dist lanes, so masked lanes
            # relax against the min identity and stay put.
            f_eff = jnp.logical_and(b.frontier, dist_live[:, None])

            def run_dist(_):
                return jax.vmap(lane_relax)(b.value, f_eff, unit,
                                            b.active_edges)

            def skip_dist(_):
                return (b.value, jnp.zeros((W, V), bool),
                        jnp.zeros((W,), bool))

            d_value, d_frontier, used_push = jax.lax.cond(
                dist_live.any(), run_dist, skip_dist, operand=None)

            # PageRank power iteration — non-PR rows masked to zero so the
            # (discarded) lanes never mix distances (inf) into the sums.
            pr_in = jnp.where(pr_live[:, None], b.value, 0.0)

            def run_pr(_):
                return jax.vmap(lane_pagerank)(pr_in)

            def skip_pr(_):
                return b.value, b.delta

            p_value, p_delta = jax.lax.cond(pr_live.any(), run_pr, skip_pr,
                                            operand=None)

            # Merge per kind; freeze every non-live lane bit-for-bit.
            stepped = jnp.where(is_pr[:, None], p_value, d_value)
            new_value = jnp.where(live[:, None], stepped, b.value)
            new_frontier = jnp.where(dist_live[:, None], d_frontier,
                                     b.frontier)
            new_delta = jnp.where(pr_live, p_delta, b.delta)
            new_iters = b.iters + live.astype(jnp.int32)
            # the measured-density carry feeds the per-lane push/pull
            # switch; a static direction never reads it, so skip the
            # per-lane masked O(E) reduction (the drivers do the same)
            if direction == "auto":
                counts = jax.vmap(
                    lambda f: _active_edge_count(plan, f))(new_frontier)
                new_edges = jnp.where(dist_live, counts, b.active_edges)
            else:
                new_edges = b.active_edges

            # Convergence — exactly the drivers' while-loop negations:
            # BFS/SSSP run while (i < max_iters) & frontier.any();
            # PageRank while (i < num_iters) & (delta > tol).
            dist_done = jnp.logical_and(
                dist_live,
                jnp.logical_or(~d_frontier.any(axis=1),
                               new_iters >= max_iters))
            pr_done = jnp.logical_and(
                pr_live,
                jnp.logical_or(p_delta <= tol, new_iters >= num_iters))
            new_done = b.done | dist_done | pr_done
            new_pushes = b.pushes + jnp.logical_and(
                used_push, dist_live).astype(jnp.int32)
            return b._replace(done=new_done, iters=new_iters,
                              value=new_value, frontier=new_frontier,
                              active_edges=new_edges, delta=new_delta,
                              pushes=new_pushes)

        return step

    def _make_admit(self):
        plan, V = self.plan, self._V

        def admit(b: QueryBatch, clear, take, kind, source, qid
                  ) -> QueryBatch:
            # clear: [W] bool — retired lanes to free; take: [W] bool —
            # lanes to (re)initialize from kind/source/qid.  Pure content
            # writes: the batch's shapes never change, so the serving step
            # never re-traces across retire/backfill boundaries.
            self._admit_traces.append(time.perf_counter())
            ids = jnp.arange(V, dtype=jnp.int32)
            is_pr = kind == KIND_PAGERANK
            f0 = jnp.logical_and(ids[None, :] == source[:, None],
                                 ~is_pr[:, None])
            dist0 = jnp.where(f0, 0.0, INF)
            pr0 = jnp.full((self.lanes, V), 1.0 / V, jnp.float32)
            value0 = jnp.where(is_pr[:, None], pr0, dist0)
            if self.direction == "auto":
                counts0 = jax.vmap(
                    lambda f: _active_edge_count(plan, f))(f0)
            else:    # static direction: the density carry is never read
                counts0 = jnp.zeros((self.lanes,), jnp.int32)

            sel = lambda m, new, old: jnp.where(m, new, old)
            selv = lambda m, new, old: jnp.where(m[:, None], new, old)
            zero = jnp.zeros((self.lanes,), jnp.int32)
            return QueryBatch(
                kind=sel(take, kind, b.kind),
                source=sel(take, source, b.source),
                qid=sel(take, qid, sel(clear, -1, b.qid)),
                active=jnp.logical_or(
                    jnp.logical_and(b.active, ~clear), take),
                done=jnp.logical_and(b.done, ~(clear | take)),
                iters=sel(take, zero, b.iters),
                value=selv(take, value0, b.value),
                frontier=selv(take, f0, b.frontier),
                active_edges=sel(take, counts0, b.active_edges),
                delta=sel(take, jnp.full_like(b.delta, INF), b.delta),
                pushes=sel(take, zero, b.pushes))

        return admit

    # -- trace counters (the no-retrace contract) --------------------------

    @property
    def step_traces(self) -> int:
        """Times the serving step has been traced (must stay 1)."""
        return len(self._step_traces)

    @property
    def admit_traces(self) -> int:
        """Times the admit function has been traced (must stay 1)."""
        return len(self._admit_traces)

    # -- host-side serving loop -------------------------------------------

    @property
    def queued(self) -> int:
        """Queries waiting for a lane."""
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Queries occupying lanes (running or awaiting retirement)."""
        return int((self._lane_qid >= 0).sum())

    def submit(self, kind: str, source: int = 0) -> int:
        """Enqueue one query; returns its qid.  Callable at any time."""
        if kind not in _KIND_CODES:
            raise ValueError(f"unknown query kind: {kind!r} "
                             f"(expected one of {sorted(_KIND_CODES)})")
        if kind != "pagerank":
            _validate_sources(source, self._V,
                              what=f"{kind} query source")
        qid = self._next_qid
        self._next_qid += 1
        self._pending[qid] = _Pending(_KIND_CODES[kind], int(source),
                                      time.perf_counter())
        self._queue.append(qid)
        return qid

    def _retire(self) -> List[ServedResult]:
        """Read converged lanes off the device and free them (host side)."""
        occupied = self._lane_qid >= 0
        if not occupied.any():
            return []
        done = np.asarray(self.batch.done) & occupied
        if not done.any():
            return []
        values = np.asarray(self.batch.value)
        iters = np.asarray(self.batch.iters)
        pushes = np.asarray(self.batch.pushes)
        now = time.perf_counter()
        results = []
        for lane in np.nonzero(done)[0]:
            qid = int(self._lane_qid[lane])
            meta = self._pending.pop(qid)
            row = values[lane]
            if meta.kind_code == KIND_BFS:
                # integer-valued unit-weight distances -> the drivers'
                # int32 depth labels (-1 = unreached); exact below 2**24
                out = np.where(np.isfinite(row), row, -1.0).astype(np.int32)
            else:
                out = row.copy()
            results.append(ServedResult(
                qid=qid, kind=_KIND_NAMES[meta.kind_code],
                source=meta.source, value=out, iterations=int(iters[lane]),
                pushes=int(pushes[lane]), submitted_at=meta.submitted_at,
                admitted_at=meta.admitted_at, completed_at=now))
            self._lane_qid[lane] = -1
        self.served += len(results)
        self._retired_lanes = done   # handed to the next admit as `clear`
        return results

    def tick(self) -> List[ServedResult]:
        """One serving slot: retire converged lanes, backfill from the
        queue, advance every live lane one iteration.  Returns the queries
        retired this tick."""
        results = self._retire()
        clear = getattr(self, "_retired_lanes", None)
        if clear is None:
            clear = np.zeros(self.lanes, bool)
        self._retired_lanes = None

        free = np.nonzero(self._lane_qid < 0)[0]
        take = np.zeros(self.lanes, bool)
        kind = np.zeros(self.lanes, np.int32)
        source = np.zeros(self.lanes, np.int32)
        qid = np.zeros(self.lanes, np.int32)
        now = time.perf_counter()
        for lane in free:
            if not self._queue:
                break
            q = self._queue.popleft()
            meta = self._pending[q]
            meta.admitted_at = now
            take[lane] = True
            kind[lane] = meta.kind_code
            source[lane] = meta.source
            qid[lane] = q
            self._lane_qid[lane] = q

        if clear.any() or take.any():
            self.batch = self._jadmit(self.batch, jnp.asarray(clear),
                                      jnp.asarray(take), jnp.asarray(kind),
                                      jnp.asarray(source), jnp.asarray(qid))
        if (self._lane_qid >= 0).any():
            self.batch = self._jstep(self.batch)
            self.steps += 1
        return results

    def drain(self) -> List[ServedResult]:
        """Tick until the queue and every lane are empty; returns all
        queries retired during the drain, in retirement order."""
        results: List[ServedResult] = []
        while self._queue or (self._lane_qid >= 0).any():
            results.extend(self.tick())
        return results

    def serve(self, queries) -> Dict[int, ServedResult]:
        """Convenience one-shot: submit ``(kind, source)`` pairs (source
        optional for ``"pagerank"``), drain, return results by qid."""
        for q in queries:
            if isinstance(q, str):
                self.submit(q)
            else:
                self.submit(*q)
        return {r.qid: r for r in self.drain()}
