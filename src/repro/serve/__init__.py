"""repro.serve substrate."""
