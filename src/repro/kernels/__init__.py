"""Pallas TPU kernels for the compute hot spots the paper optimizes.

Each kernel ships as a subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (jitted public wrapper doing the load-balancing
setup), ``ref.py`` (pure-jnp oracle used by the allclose test sweeps).
Kernels are validated with ``interpret=True`` on CPU; pass
``interpret=False`` on real TPU.
"""
