"""Segmented (grouped) matmul — the MoE expert GEMM, load-balanced.

The irregular workload: after top-k routing, expert ``e`` owns a *variable*
number of tokens.  In the paper's vocabulary the routed (token, expert) pairs
are **atoms**, experts are **tiles**, and the batch is the **tile set**; the
schedule must hand equal-size chunks to the compute units even though tile
sizes are wildly skewed (router collapse, domain shift).

TPU-native schedule (megablocks-style, built from our abstraction):
tokens are sorted by expert and each expert's segment padded up to a multiple
of the M-block; every grid block then owns exactly ``(bm, bn, bk)`` of work —
a *perfectly balanced* block-diagonal GEMM.  The only irregular object left
is the ``block -> expert`` map, an int32 vector computed by
``WorkSpec.from_segment_sizes`` + one searchsorted (the group-mapped
schedule's prefix-sum binning, lifted to the chip level), delivered to the
kernel via scalar prefetch so the right expert weight tile is DMA'd per
block.

Grid: ``(m_blocks, n_blocks, k_blocks)``, k innermost/sequential for
accumulation.  VMEM per block at (128, 128, 512): lhs 256 KB + rhs 256 KB +
acc 64 KB (f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _segmm_kernel(block_expert_ref, lhs_ref, rhs_ref, out_ref):
    del block_expert_ref  # consumed by the index maps only
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(lhs_ref[...].astype(jnp.float32),
                            rhs_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def segmented_matmul(lhs_padded: jax.Array, rhs: jax.Array,
                     block_expert: jax.Array, *, bm: int = 128,
                     bn: int = 128, bk: int = 512,
                     interpret: bool = True) -> jax.Array:
    """``out[i*bm:(i+1)*bm] = lhs[i*bm:(i+1)*bm] @ rhs[block_expert[i]]``.

    ``lhs_padded``: ``[M_pad, K]`` tokens sorted by expert, group-padded so
    every M-block maps to exactly one expert.  ``rhs``: ``[E, K, N]``.
    ``block_expert``: int32 ``[M_pad // bm]``.
    """
    m_pad, k_dim = lhs_padded.shape
    _, _, n_dim = rhs.shape
    assert m_pad % bm == 0
    bk = min(bk, k_dim)
    bn = min(bn, n_dim)
    assert k_dim % bk == 0 and n_dim % bn == 0
    grid = (m_pad // bm, n_dim // bn, k_dim // bk)

    return pl.pallas_call(
        _segmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, be: (i, k)),
                pl.BlockSpec((1, bk, bn), lambda i, j, k, be: (be[i], k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, be: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_dim), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_expert, lhs_padded, rhs)


# ---------------------------------------------------------------------------
# Native chunk-walking variant (dynamic schedules on-device).
# ---------------------------------------------------------------------------

def _segmm_chunk_kernel(block_expert_ref, chunks_ref, counts_ref,
                        lhs_ref, rhs_ref, out_ref, *, bm: int,
                        max_chunks: int):
    """One physical block drains its queue of M-blocks inside the kernel.

    The queue discipline (round-robin / LPT-ordered pops, see
    ``repro.kernels.segmm.ops``) arrives as the scalar-prefetched
    ``chunks_ref`` row; each pop DMAs the chunk's LHS window (dynamic slice,
    static ``bm`` size), looks up its expert, and accumulates into the
    chunk's own output rows — no host-side block permutation and no
    un-permute gather, unlike the fallback path.
    """
    p = pl.program_id(1)
    k = pl.program_id(2)
    count = counts_ref[p]

    def pop(i, carry):
        @pl.when(i < count)
        def _process():
            c = chunks_ref[p * max_chunks + i]
            e = block_expert_ref[c]

            @pl.when(k == 0)
            def _zero():
                out_ref[pl.ds(c * bm, bm), :] = jnp.zeros(
                    (bm, out_ref.shape[1]), jnp.float32)

            lhs = lhs_ref[pl.ds(c * bm, bm), :].astype(jnp.float32)
            rhs = rhs_ref[pl.ds(e, 1), :, :][0].astype(jnp.float32)
            out_ref[pl.ds(c * bm, bm), :] += jnp.dot(
                lhs, rhs, preferred_element_type=jnp.float32)
        return carry

    jax.lax.fori_loop(0, max_chunks, pop, 0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "max_chunks",
                                             "interpret"))
def segmented_matmul_chunked(lhs_padded: jax.Array, rhs: jax.Array,
                             block_expert: jax.Array,
                             block_chunks_flat: jax.Array,
                             chunk_counts: jax.Array, *, bm: int = 128,
                             bn: int = 128, bk: int = 512,
                             max_chunks: int = 1,
                             interpret: bool = True) -> jax.Array:
    """Chunk-walking segmented matmul over ``P`` physical blocks.

    Same contract as :func:`segmented_matmul` plus the queue:
    ``block_chunks_flat`` int32 ``[P * max_chunks]`` lists each physical
    block's M-block chunks in pop order, ``chunk_counts`` int32 ``[P]`` the
    true queue lengths.  Every M-block appears in exactly one queue, so each
    output row block is written exactly once per (j, k) wave.  Output is in
    *original* (unpermuted) M-block order — bit-identical to
    :func:`segmented_matmul` on the identity queue.
    """
    m_pad, k_dim = lhs_padded.shape
    e_dim, _, n_dim = rhs.shape
    assert m_pad % bm == 0
    bk = min(bk, k_dim)
    bn = min(bn, n_dim)
    assert k_dim % bk == 0 and n_dim % bn == 0
    num_physical = int(chunk_counts.shape[0])
    # j outermost so each output block's visits are consecutive; p then k so
    # every queue finishes its k-accumulation before the next output wave.
    grid = (n_dim // bn, num_physical, k_dim // bk)

    return pl.pallas_call(
        functools.partial(_segmm_chunk_kernel, bm=bm, max_chunks=max_chunks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((m_pad, bk), lambda j, p, k, *_: (0, k)),
                pl.BlockSpec((e_dim, bk, bn), lambda j, p, k, *_: (0, k, j)),
            ],
            out_specs=pl.BlockSpec((m_pad, bn), lambda j, p, k, *_: (0, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_dim), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_expert, block_chunks_flat, chunk_counts, lhs_padded, rhs)
