"""Pure-jnp oracle for the segmented matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_matmul_ref(lhs_padded: jax.Array, rhs: jax.Array,
                         block_expert: jax.Array, *, bm: int) -> jax.Array:
    """Row-level oracle: every row multiplies its block's expert matrix."""
    m_pad, _ = lhs_padded.shape
    row_expert = jnp.repeat(block_expert, bm, total_repeat_length=m_pad)
    gathered = rhs[row_expert]                      # [M_pad, K, N]
    return jnp.einsum("mk,mkn->mn", lhs_padded.astype(jnp.float32),
                      gathered.astype(jnp.float32))


def grouped_matmul_ref(tokens: jax.Array, expert_of_token: jax.Array,
                       rhs: jax.Array) -> jax.Array:
    """End-to-end oracle: out[t] = tokens[t] @ rhs[expert_of_token[t]]."""
    return jnp.einsum("tk,tkn->tn", tokens.astype(jnp.float32),
                      rhs[expert_of_token].astype(jnp.float32))
