"""Jitted public wrapper: unsorted routed tokens in, expert outputs out.

This is the "work definition" stage for the MoE workload: atoms = routed
tokens, tiles = experts.  The wrapper builds the sorted, group-padded layout
and the block->expert map (the schedule), then invokes the balanced Pallas
GEMM.  All shapes are static: the padded capacity is the worst case
``T + E * (bm - 1)`` rounded up, so the same compiled kernel serves every
routing outcome — a requirement for TPU serving.

Schedule policies (the dynamic-scheduling hook): the chunk -> block queue
discipline of :mod:`repro.core.dynamic` shows up here over the M-blocks.
``"group_mapped"`` keeps expert order; ``"chunked_rr"`` deals M-blocks
round-robin across a pool of physical blocks (Atos queue with round-robin
pops); ``"chunked_lpt"`` deals them heaviest-expert-first (greedy LPT).
All policies are algebraically identical — tests assert bit-equality —
which is exactly the paper's schedule/execution separation.

Execution paths (see :class:`repro.core.execute.ExecutionPath`): the
chunked policies execute **natively** by default — the queue per physical
block is scalar-prefetched into the chunk-walking Pallas kernel
(:func:`repro.kernels.segmm.kernel.segmented_matmul_chunked`), which walks
its M-blocks *inside* the kernel with no host-side permutation.  The
``"pure"`` path realizes the same queue as a host-side block permutation
feeding the plain kernel (PR-1 behavior, kept as the executable spec the
native path is tested against).  ``"auto"`` consults the cost-model
autotuner when the routing is concrete (eager inspector) and falls back to
``"group_mapped"`` under tracing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.execute import ExecutionPath, resolve_execution_path
from repro.kernels.segmm import kernel as _kernel

SCHEDULE_POLICIES = ("group_mapped", "chunked_rr", "chunked_lpt")

#: Physical-block pool the chunked policies drain their M-block queues with.
NUM_QUEUES = 8


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def resolve_schedule(expert_of_token, num_experts: int,
                     num_blocks: int = 64, *, measure=None) -> str:
    """Map the autotuner's choice onto a segmm block-order policy.

    Inspector step: needs concrete routing.  Under tracing (inside a jitted
    train step) returns the static default.

    ``measure`` is the measured-cost feedback knob (docs/autotune.md): a
    callable ``(plan) -> median_us`` timing one candidate plan on the
    caller's actual GEMM.  Forwarded to
    :func:`repro.core.autotune.select_plan` — consulted only when
    ``REPRO_AUTOTUNE_MEASURE`` is on, in which case the choice is re-ranked
    by measurement (the routing histogram's cache record then carries v2
    measured medians).  ``None`` keeps the model-only schedule-level
    selection of PR 2.
    """
    if isinstance(expert_of_token, jax.core.Tracer):
        return "group_mapped"
    from repro.core.autotune import (measurement_enabled, select_plan,
                                     select_schedule)
    from repro.core.schedules import Schedule
    from repro.core.work import WorkSpec

    counts = np.bincount(np.asarray(expert_of_token),
                         minlength=num_experts)[:num_experts]
    spec = WorkSpec.from_segment_sizes(jnp.asarray(counts, jnp.int32),
                                       num_atoms=int(counts.sum()))
    if measure is not None and measurement_enabled():
        chosen = select_plan(spec, num_blocks, measure=measure).schedule
    else:
        chosen = select_schedule(spec, num_blocks)
    return "chunked_lpt" if chosen == Schedule.CHUNKED else "group_mapped"


def plan_policy(plan) -> tuple[str, str]:
    """(schedule policy, path) a core autotuner plan maps onto for segmm.

    Only the chunked schedule has a native queue discipline here; every
    other core schedule executes as the group-mapped baseline.  Shared by
    the default measured-mode closure and tests.
    """
    policy = ("chunked_lpt" if str(plan.schedule) == "chunked"
              else "group_mapped")
    path = "native" if (policy != "group_mapped"
                        and str(plan.path) == "native") else "pure"
    return policy, path


def level_grouped_matmul(tokens: jax.Array, op_of_token: jax.Array,
                         rhs: jax.Array, *, num_ops: int, plan=None,
                         schedule: str | None = None,
                         path: str | None = None, bm: int = 8,
                         bn: int = 128, bk: int = 512,
                         interpret: bool = True) -> jax.Array:
    """Per-level dense evaluation entry for the wavefront scheduler.

    A DAG level is the MoE routing problem with ops for experts: atoms =
    nodes awaiting evaluation this level, tiles = per-node operator types,
    and the whole level runs as ONE balanced segmented matmul instead of
    per-node recursion.  ``plan`` is a core (schedule, path) object — e.g.
    the wavefront dependency :class:`~repro.sparse.advance.AdvancePlan` —
    whose choice is mapped onto the segmm block-order policies via
    :func:`plan_policy`, so the level GEMM rides the same schedule decision
    as the dependency advance; explicit ``schedule``/``path`` strings
    override.  Every output row depends only on its own token row, so the
    result is bitwise-invariant across all policies and paths — the
    property the wavefront conformance matrix leans on.  Called from
    inside a ``lax.while_loop`` body: all shape logic is traceable and the
    M-block default is sized for node counts, not token batches.
    """
    if plan is not None:
        p_sched, p_path = plan_policy(plan)
        schedule = schedule or p_sched
        path = path or p_path
    return _grouped_matmul(tokens, op_of_token, rhs, num_experts=num_ops,
                           bm=bm, bn=bn, bk=bk,
                           schedule=schedule or "group_mapped",
                           path=path or "pure", interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_experts", "bm", "bn", "bk",
                                             "schedule", "path", "interpret"))
def _grouped_matmul(tokens: jax.Array, expert_of_token: jax.Array,
                    rhs: jax.Array, *, num_experts: int, bm: int,
                    bn: int, bk: int, schedule: str, path: str,
                    interpret: bool) -> jax.Array:
    t_dim, k_dim = tokens.shape
    e_dim = num_experts
    m_pad = _round_up(t_dim + e_dim * (bm - 1), bm)

    # --- schedule construction (group-mapped prefix-sum binning) ----------
    order = jnp.argsort(expert_of_token)                     # sort atoms
    sorted_e = expert_of_token[order]
    sizes = jnp.bincount(expert_of_token, length=e_dim)
    offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                               jnp.cumsum(sizes)])
    padded_sizes = ((sizes + bm - 1) // bm) * bm
    padded_offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                                      jnp.cumsum(padded_sizes)])
    rank = jnp.arange(t_dim) - offsets[sorted_e]             # rank in group
    pos_sorted = (padded_offsets[sorted_e] + rank).astype(jnp.int32)

    lhs_padded = jnp.zeros((m_pad, k_dim), tokens.dtype)
    lhs_padded = lhs_padded.at[pos_sorted].set(tokens[order])

    nblk = m_pad // bm
    block_start = jnp.arange(nblk, dtype=jnp.int32) * bm
    block_expert = (jnp.searchsorted(padded_offsets, block_start,
                                     side="right").astype(jnp.int32) - 1)
    block_expert = jnp.clip(block_expert, 0, e_dim - 1)

    # --- queue discipline: M-block pop order -------------------------------
    if schedule == "chunked_rr":
        # round-robin pops: deal blocks across the queues in index order
        pop_order = jnp.arange(nblk, dtype=jnp.int32)
    elif schedule == "chunked_lpt":
        # greedy LPT: heaviest experts' blocks dealt first (stable, traceable)
        pop_order = jnp.argsort(-sizes[block_expert],
                                stable=True).astype(jnp.int32)
    elif schedule == "group_mapped":
        pop_order = jnp.arange(nblk, dtype=jnp.int32)
    else:
        raise ValueError(f"unknown segmm schedule: {schedule}")

    if path == "native" and schedule in ("chunked_rr", "chunked_lpt"):
        # --- native chunk walk: deal the pop order round-robin onto the
        # physical pool; each block walks its queue inside the kernel.  The
        # queue view has static shape, so this works under jit too (the
        # scalar-prefetch operands may be traced *values*).
        phys = min(NUM_QUEUES, nblk)
        cmax = -(-nblk // phys)
        rank = (np.arange(phys)[:, None]
                + np.arange(cmax)[None, :] * phys)          # [P, cmax]
        counts = jnp.asarray((rank < nblk).sum(1).astype(np.int32))
        chunks = pop_order[jnp.minimum(
            jnp.asarray(rank.reshape(-1), jnp.int32), nblk - 1)]
        out_padded = _kernel.segmented_matmul_chunked(
            lhs_padded, rhs, block_expert, chunks, counts,
            bm=bm, bn=bn, bk=bk, max_chunks=cmax, interpret=interpret)
    else:
        # --- pure/fallback: realize the queue as a host-side block
        # permutation feeding the plain kernel (one M-block per grid step).
        if schedule == "chunked_rr":
            lanes = min(NUM_QUEUES, nblk)
            perm = jnp.argsort(jnp.arange(nblk, dtype=jnp.int32) % lanes,
                               stable=True).astype(jnp.int32)
        else:
            perm = pop_order
        lhs_exec = lhs_padded.reshape(nblk, bm, k_dim)[perm].reshape(
            m_pad, k_dim)
        be_exec = block_expert[perm]
        out_exec = _kernel.segmented_matmul(lhs_exec, rhs, be_exec,
                                            bm=bm, bn=bn, bk=bk,
                                            interpret=interpret)
        # un-permute blocks, then unsort (gather each token's padded row)
        inv = jnp.zeros((nblk,), jnp.int32).at[perm].set(
            jnp.arange(nblk, dtype=jnp.int32))
        out_padded = out_exec.reshape(nblk, bm, -1)[inv].reshape(m_pad, -1)
    pos_orig = jnp.zeros((t_dim,), jnp.int32).at[order].set(pos_sorted)
    return out_padded[pos_orig]


def grouped_matmul(tokens: jax.Array, expert_of_token: jax.Array,
                   rhs: jax.Array, *, num_experts: int, bm: int = 128,
                   bn: int = 128, bk: int = 512,
                   schedule: str = "group_mapped",
                   execution_path: ExecutionPath | str = ExecutionPath.AUTO,
                   measure=None,
                   interpret: bool = True) -> jax.Array:
    """``out[t] = tokens[t] @ rhs[expert_of_token[t]]`` for ragged groups.

    ``tokens``: ``[T, K]``; ``expert_of_token``: int32 ``[T]`` in
    ``[0, num_experts)``; ``rhs``: ``[num_experts, K, N]``.  ``schedule``:
    one of ``SCHEDULE_POLICIES`` or ``"auto"``; ``execution_path``: native
    chunk-walking kernel vs permuted-grid fallback for the chunked policies
    (see module docstring).  ``measure`` is the measured-cost feedback knob
    for ``schedule="auto"`` (docs/autotune.md): ``None`` times candidates
    on this very GEMM when ``REPRO_AUTOTUNE_MEASURE=1``, ``False``
    disables, a callable ``(plan) -> median_us`` overrides.
    """
    if schedule == "auto":
        m = measure
        if m is None and not isinstance(expert_of_token, jax.core.Tracer):
            from repro.core.autotune import measurement_enabled
            if measurement_enabled():
                from repro.core.measure import time_fn

                def m(plan):
                    policy, p = plan_policy(plan)
                    f = functools.partial(
                        _grouped_matmul, num_experts=num_experts, bm=bm,
                        bn=bn, bk=bk, schedule=policy, path=p,
                        interpret=interpret)
                    return time_fn(f, tokens, expert_of_token, rhs,
                                   warmup=1, iters=3)
        schedule = resolve_schedule(expert_of_token, num_experts,
                                    measure=None if m is False else m)
    # every policy has a device-side form: the plain scalar-prefetch kernel
    # for group_mapped (block == chunk), the chunk-walking kernel for the
    # chunked queues (which works under jit too — the queue view has static
    # shape).  "pure" forces the host-permuted fallback.
    path = resolve_execution_path(execution_path, native_supported=True)
    return _grouped_matmul(tokens, expert_of_token, rhs,
                           num_experts=num_experts, bm=bm, bn=bn, bk=bk,
                           schedule=schedule, path=str(path),
                           interpret=interpret)
