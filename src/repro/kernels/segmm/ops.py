"""Jitted public wrapper: unsorted routed tokens in, expert outputs out.

This is the "work definition" stage for the MoE workload: atoms = routed
tokens, tiles = experts.  The wrapper builds the sorted, group-padded layout
and the block->expert map (the schedule), then invokes the balanced Pallas
GEMM.  All shapes are static: the padded capacity is the worst case
``T + E * (bm - 1)`` rounded up, so the same compiled kernel serves every
routing outcome — a requirement for TPU serving.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segmm import kernel as _kernel


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("num_experts", "bm", "bn", "bk",
                                             "interpret"))
def grouped_matmul(tokens: jax.Array, expert_of_token: jax.Array,
                   rhs: jax.Array, *, num_experts: int, bm: int = 128,
                   bn: int = 128, bk: int = 512,
                   interpret: bool = True) -> jax.Array:
    """``out[t] = tokens[t] @ rhs[expert_of_token[t]]`` for ragged groups.

    ``tokens``: ``[T, K]``; ``expert_of_token``: int32 ``[T]`` in
    ``[0, num_experts)``; ``rhs``: ``[num_experts, K, N]``.
    """
    t_dim, k_dim = tokens.shape
    e_dim = num_experts
    m_pad = _round_up(t_dim + e_dim * (bm - 1), bm)

    # --- schedule construction (group-mapped prefix-sum binning) ----------
    order = jnp.argsort(expert_of_token)                     # sort atoms
    sorted_e = expert_of_token[order]
    sizes = jnp.bincount(expert_of_token, length=e_dim)
    offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                               jnp.cumsum(sizes)])
    padded_sizes = ((sizes + bm - 1) // bm) * bm
    padded_offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                                      jnp.cumsum(padded_sizes)])
    rank = jnp.arange(t_dim) - offsets[sorted_e]             # rank in group
    pos_sorted = (padded_offsets[sorted_e] + rank).astype(jnp.int32)

    lhs_padded = jnp.zeros((m_pad, k_dim), tokens.dtype)
    lhs_padded = lhs_padded.at[pos_sorted].set(tokens[order])

    block_start = jnp.arange(m_pad // bm, dtype=jnp.int32) * bm
    block_expert = (jnp.searchsorted(padded_offsets, block_start,
                                     side="right").astype(jnp.int32) - 1)
    block_expert = jnp.clip(block_expert, 0, e_dim - 1)

    # --- balanced execution ------------------------------------------------
    out_padded = _kernel.segmented_matmul(lhs_padded, rhs, block_expert,
                                          bm=bm, bn=bn, bk=bk,
                                          interpret=interpret)

    # --- unsort (gather each original token's padded row) ------------------
    pos_orig = jnp.zeros((t_dim,), jnp.int32).at[order].set(pos_sorted)
    return out_padded[pos_orig]
