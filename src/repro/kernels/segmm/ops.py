"""Jitted public wrapper: unsorted routed tokens in, expert outputs out.

This is the "work definition" stage for the MoE workload: atoms = routed
tokens, tiles = experts.  The wrapper builds the sorted, group-padded layout
and the block->expert map (the schedule), then invokes the balanced Pallas
GEMM.  All shapes are static: the padded capacity is the worst case
``T + E * (bm - 1)`` rounded up, so the same compiled kernel serves every
routing outcome — a requirement for TPU serving.

Schedule policies (the dynamic-scheduling hook): the Pallas grid walks
M-blocks sequentially, so the chunk -> block queue discipline of
:mod:`repro.core.dynamic` shows up here as the *processing order* of the
M-blocks.  ``"group_mapped"`` keeps expert order; ``"chunked_rr"``
round-robins blocks across the grid (Atos queue with round-robin pops);
``"chunked_lpt"`` processes the heaviest experts' blocks first (greedy LPT).
All orders are algebraically identical — the output is un-permuted — which
is exactly the paper's schedule/execution separation: tests assert
bit-equality across policies.  ``"auto"`` consults the cost-model autotuner
when the routing is concrete (eager inspector) and falls back to
``"group_mapped"`` under tracing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segmm import kernel as _kernel

SCHEDULE_POLICIES = ("group_mapped", "chunked_rr", "chunked_lpt")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def resolve_schedule(expert_of_token, num_experts: int,
                     num_blocks: int = 64) -> str:
    """Map the autotuner's choice onto a segmm block-order policy.

    Inspector step: needs concrete routing.  Under tracing (inside a jitted
    train step) returns the static default.
    """
    if isinstance(expert_of_token, jax.core.Tracer):
        return "group_mapped"
    from repro.core.autotune import select_schedule
    from repro.core.schedules import Schedule
    from repro.core.work import WorkSpec

    counts = np.bincount(np.asarray(expert_of_token),
                         minlength=num_experts)[:num_experts]
    spec = WorkSpec.from_segment_sizes(jnp.asarray(counts, jnp.int32),
                                       num_atoms=int(counts.sum()))
    chosen = select_schedule(spec, num_blocks)
    return "chunked_lpt" if chosen == Schedule.CHUNKED else "group_mapped"


@functools.partial(jax.jit, static_argnames=("num_experts", "bm", "bn", "bk",
                                             "schedule", "interpret"))
def _grouped_matmul(tokens: jax.Array, expert_of_token: jax.Array,
                    rhs: jax.Array, *, num_experts: int, bm: int,
                    bn: int, bk: int, schedule: str,
                    interpret: bool) -> jax.Array:
    t_dim, k_dim = tokens.shape
    e_dim = num_experts
    m_pad = _round_up(t_dim + e_dim * (bm - 1), bm)

    # --- schedule construction (group-mapped prefix-sum binning) ----------
    order = jnp.argsort(expert_of_token)                     # sort atoms
    sorted_e = expert_of_token[order]
    sizes = jnp.bincount(expert_of_token, length=e_dim)
    offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                               jnp.cumsum(sizes)])
    padded_sizes = ((sizes + bm - 1) // bm) * bm
    padded_offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                                      jnp.cumsum(padded_sizes)])
    rank = jnp.arange(t_dim) - offsets[sorted_e]             # rank in group
    pos_sorted = (padded_offsets[sorted_e] + rank).astype(jnp.int32)

    lhs_padded = jnp.zeros((m_pad, k_dim), tokens.dtype)
    lhs_padded = lhs_padded.at[pos_sorted].set(tokens[order])

    nblk = m_pad // bm
    block_start = jnp.arange(nblk, dtype=jnp.int32) * bm
    block_expert = (jnp.searchsorted(padded_offsets, block_start,
                                     side="right").astype(jnp.int32) - 1)
    block_expert = jnp.clip(block_expert, 0, e_dim - 1)

    # --- queue discipline: M-block processing order ------------------------
    if schedule == "chunked_rr":
        # round-robin pops: deal blocks across 8 queues (stable sort by
        # residue class is always a permutation, any nblk)
        lanes = min(8, nblk)
        perm = jnp.argsort(jnp.arange(nblk, dtype=jnp.int32) % lanes,
                           stable=True).astype(jnp.int32)
    elif schedule == "chunked_lpt":
        # greedy LPT: heaviest experts' blocks first (stable, traceable)
        perm = jnp.argsort(-sizes[block_expert],
                           stable=True).astype(jnp.int32)
    elif schedule == "group_mapped":
        perm = jnp.arange(nblk, dtype=jnp.int32)
    else:
        raise ValueError(f"unknown segmm schedule: {schedule}")

    lhs_exec = lhs_padded.reshape(nblk, bm, k_dim)[perm].reshape(m_pad, k_dim)
    be_exec = block_expert[perm]

    # --- balanced execution ------------------------------------------------
    out_exec = _kernel.segmented_matmul(lhs_exec, rhs, be_exec,
                                        bm=bm, bn=bn, bk=bk,
                                        interpret=interpret)

    # un-permute blocks, then unsort (gather each token's padded row)
    inv = jnp.zeros((nblk,), jnp.int32).at[perm].set(
        jnp.arange(nblk, dtype=jnp.int32))
    out_padded = out_exec.reshape(nblk, bm, -1)[inv].reshape(m_pad, -1)
    pos_orig = jnp.zeros((t_dim,), jnp.int32).at[order].set(pos_sorted)
    return out_padded[pos_orig]


def grouped_matmul(tokens: jax.Array, expert_of_token: jax.Array,
                   rhs: jax.Array, *, num_experts: int, bm: int = 128,
                   bn: int = 128, bk: int = 512,
                   schedule: str = "group_mapped",
                   interpret: bool = True) -> jax.Array:
    """``out[t] = tokens[t] @ rhs[expert_of_token[t]]`` for ragged groups.

    ``tokens``: ``[T, K]``; ``expert_of_token``: int32 ``[T]`` in
    ``[0, num_experts)``; ``rhs``: ``[num_experts, K, N]``.  ``schedule``:
    one of ``SCHEDULE_POLICIES`` or ``"auto"`` (see module docstring).
    """
    if schedule == "auto":
        schedule = resolve_schedule(expert_of_token, num_experts)
    return _grouped_matmul(tokens, expert_of_token, rhs,
                           num_experts=num_experts, bm=bm, bn=bn, bk=bk,
                           schedule=schedule, interpret=interpret)
