"""Pure-jnp oracle for the merge-path SpMV kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ref(row_offsets: jax.Array, col_indices: jax.Array,
             values: jax.Array, x: jax.Array, num_rows: int) -> jax.Array:
    """y = A @ x via one global segmented reduction (no blocking)."""
    nnz = values.shape[0]
    atoms = jnp.arange(nnz, dtype=jnp.int32)
    row_ids = (jnp.searchsorted(row_offsets, atoms, side="right")
               .astype(jnp.int32) - 1)
    prods = values.astype(jnp.float32) * x[col_indices].astype(jnp.float32)
    return jax.ops.segment_sum(prods, row_ids, num_segments=num_rows)


def merge_stream_ref(row_offsets, col_indices, values, x, num_rows, nnz,
                     padded_total):
    """Reference construction of the merged work-item stream (numpy-clear).

    Returns (stream_vals, stream_rows): atom ``a`` at position ``a + row(a)``
    with value ``vals[a] * x[col[a]]``; row ``r``'s end marker at
    ``row_offsets[r+1] + r`` with value 0.  Padding rows = ``num_rows``.
    """
    atoms = jnp.arange(nnz, dtype=jnp.int32)
    row_ids = (jnp.searchsorted(row_offsets, atoms, side="right")
               .astype(jnp.int32) - 1)
    prods = values.astype(jnp.float32) * x[col_indices].astype(jnp.float32)

    stream_vals = jnp.zeros((padded_total,), jnp.float32)
    stream_rows = jnp.full((padded_total,), num_rows, jnp.int32)

    atom_pos = atoms + row_ids
    stream_vals = stream_vals.at[atom_pos].set(prods)
    stream_rows = stream_rows.at[atom_pos].set(row_ids)

    rows = jnp.arange(num_rows, dtype=jnp.int32)
    marker_pos = row_offsets[1:].astype(jnp.int32) + rows
    stream_rows = stream_rows.at[marker_pos].set(rows)
    return stream_vals, stream_rows
