"""Merge-path SpMV as a Pallas TPU kernel.

TPU reformulation of Merrill & Garland merge-path (paper §5.2.1)
----------------------------------------------------------------
The CUDA kernel gives each thread an equal share of ``rows + nnz`` work items
and lets each thread binary-search its (row, nnz) start coordinate.  TPU grid
blocks need *static* VMEM windows, so we make the merge decomposition
explicit instead of searched:

1.  Build the **merged work-item stream** of length ``rows + nnz`` in XLA:
    atom ``a`` (one non-zero) sits at stream position ``a + row(a)``; the
    end-marker of row ``r`` sits at ``row_offsets[r+1] + r``.  This is
    exactly the merge path — a bijection onto ``[0, rows + nnz)`` — realized
    as one scatter.  Atom positions carry ``vals[a] * x[col[a]]``; markers
    carry ``0``.  Every position carries its global row id.
2.  Each Pallas grid block consumes a **static** window of ``block_items``
    stream items — the uniform diagonal split, so every block does identical
    work (the merge-path guarantee: a block touches at most
    ``block_items + 1`` rows, no matter how skewed the matrix).
3.  Inside the block, the per-row reduction is a one-hot contraction
    ``dot(values[W], onehot[W, R_LOC])`` on the **MXU** — the TPU analogue of
    the warp-cooperative segmented reduction.
4.  Rows crossing block boundaries are resolved by a scatter-add **fixup**
    over the per-block partials (Merrill's "segmented fixup" pass; TPU grid
    blocks must not order-depend, so the fixup is a separate tiny reduction).

VMEM per block: ``block_items``(f32+i32) + ``block_items x R_LOC`` one-hot
(f32, transient) + ``R_LOC`` partials — ~1.7 MB at the default
``block_items=512`` (R_LOC=640), comfortably inside the ~16 MB v5e VMEM
budget, and MXU-aligned (512 and 640 are multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _spmv_block_kernel(row_base_ref, vals_ref, rows_ref, out_ref, *,
                       r_loc: int):
    """One merge-path block: masked one-hot MXU contraction."""
    b = pl.program_id(0)
    base = row_base_ref[b]
    local = rows_ref[...].astype(jnp.int32) - base            # [W]
    vals = vals_ref[...].astype(jnp.float32)                  # [W]
    # Rows outside [0, r_loc) (markers/padding carry value 0 anyway) simply
    # match no one-hot column — no explicit mask needed.
    onehot = (local[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, r_loc), 1))
    out_ref[0, :] = jnp.dot(vals, onehot.astype(jnp.float32),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_rows", "block_items",
                                             "interpret"))
def spmv_merge_stream(stream_vals: jax.Array, stream_rows: jax.Array,
                      row_base: jax.Array, *, num_rows: int,
                      block_items: int = 512,
                      interpret: bool = True) -> jax.Array:
    """Run the blocked kernel over a pre-built merge stream.

    ``stream_vals`` f32 ``[G * block_items]`` (zero at markers/padding),
    ``stream_rows`` int32 ``[G * block_items]`` (global row per item),
    ``row_base`` int32 ``[G]`` (first row touched by each block).
    Returns dense ``y`` of shape ``[num_rows]``.
    """
    total = stream_vals.shape[0]
    assert total % block_items == 0
    grid = total // block_items
    r_loc = _round_up(block_items + 1, 128)

    partials = pl.pallas_call(
        functools.partial(_spmv_block_kernel, r_loc=r_loc),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((block_items,), lambda b, rb: (b,)),
                pl.BlockSpec((block_items,), lambda b, rb: (b,)),
            ],
            out_specs=pl.BlockSpec((1, r_loc), lambda b, rb: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((grid, r_loc), jnp.float32),
        interpret=interpret,
    )(row_base, stream_vals, stream_rows)

    # Fixup: combine cross-block partial rows (scatter-add over partials).
    gids = row_base[:, None] + jnp.arange(r_loc, dtype=jnp.int32)[None, :]
    gids = jnp.where(gids < num_rows, gids, num_rows)
    y = jax.ops.segment_sum(partials.reshape(-1), gids.reshape(-1),
                            num_segments=num_rows + 1)
    return y[:-1]


# ---------------------------------------------------------------------------
# Native chunk-walking executor (dynamic schedules on-device).
# ---------------------------------------------------------------------------

#: Identity element per combiner, mirrored from
#: ``repro.core.execute.COMBINER_IDENTITY`` (kept literal here so the
#: kernel module stays import-light).
_IDENTITY = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _chunk_walk_kernel(atom_starts_ref, tile_starts_ref, chunks_ref,
                       counts_ref, *refs,
                       window: int, local_tiles: int, max_chunks: int,
                       combiner: str, has_mask: bool, emit: str):
    """One physical block drains its chunk queue inside the kernel.

    The queue discipline of :mod:`repro.core.dynamic` is delivered as the
    scalar-prefetched ``chunks_ref`` row (the inverted, padded view of
    ``Partition.block_map``).  Each pop processes a static ``window`` of
    atoms starting at the chunk's ``atom_starts`` boundary (masked past its
    end) and, for ``emit="tiles"``, reduces into ``local_tiles`` local bins:
    a one-hot MXU contraction for ``sum`` (same as the merge-path kernel), a
    masked elementwise reduce for ``min``/``max`` (the graph advance's
    scatter-min / scatter-or).  ``window``/``local_tiles`` come from the
    partition's ``atom_span``/``tile_span`` hints — sizing the tile window
    from the atom count alone would undercount chunks spanning empty tiles
    (the PR-1 ``blocked_tile_reduce`` hazard), so the hints are mandatory
    here.

    ``emit="atoms"`` skips the local binning and writes the masked value
    window itself — the push-direction graph advance, whose outputs are
    combined by edge *destination* (an id unrelated to the walked tile
    structure) in a host-side segmented scatter.  The chunk walk, the
    frontier-mask operand, and the window discipline are identical; only
    the output row semantics change (per-atom values instead of per-tile
    partials).

    ``emit="compact"`` is the gather-compacted sibling of ``"atoms"``: the
    chunk boundaries cover a *compacted active-atom index list* (an extra
    int32 operand), not the full atom set, and each window slot gathers its
    value through that indirection — ``vals[idx[slot]]`` — so the kernel
    streams only the frontier's out-edges instead of masking full windows.
    No mask operand is needed (the compaction already applied it); padded
    index slots point at the values array's identity padding.  Note for a
    real-TPU port: the per-slot gather is the one new Mosaic demand of this
    mode (see docs/graph.md, "Compacted frontier windows").

    With ``has_mask`` an extra int32 operand rides next to the values: the
    per-atom frontier mask of a graph advance.  Masked atoms behave exactly
    like atoms past the chunk's end (identity value, OOB local bin).  In
    ``emit="atoms"``/``"compact"`` modes no tile-id operand is streamed at
    all — the binning it feeds never happens.
    """
    tids_ref = mask_ref = idx_ref = None
    if emit == "compact":
        vals_ref, idx_ref, out_ref = refs
    elif emit == "atoms":
        if has_mask:
            vals_ref, mask_ref, out_ref = refs
        else:
            vals_ref, out_ref = refs
    elif has_mask:
        vals_ref, tids_ref, mask_ref, out_ref = refs
    else:
        vals_ref, tids_ref, out_ref = refs
    identity = _IDENTITY[combiner]
    p = pl.program_id(0)
    count = counts_ref[p]

    def pop(i, carry):
        @pl.when(i < count)
        def _process():
            c = chunks_ref[p * max_chunks + i]
            base = atom_starts_ref[c]
            end = atom_starts_ref[c + 1]
            tbase = tile_starts_ref[c]
            idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, window), 1)
            ok = (idx < end)[0]                                   # [W]
            if mask_ref is not None:
                ok = jnp.logical_and(
                    ok, mask_ref[pl.ds(base, window)] != 0)
            if emit == "compact":
                # gather through the compacted index list: padded slots
                # point past the atom set, into the values array's
                # identity padding (and are masked besides)
                gathered = idx_ref[pl.ds(base, window)].astype(jnp.int32)
                vals = vals_ref[...].astype(jnp.float32)[gathered]
            else:
                vals = vals_ref[pl.ds(base, window)].astype(jnp.float32)
            vals = jnp.where(ok, vals, identity)                  # [W]
            if emit in ("atoms", "compact"):
                out_ref[pl.ds(c, 1), :] = vals[None, :]
                return
            local = tids_ref[pl.ds(base, window)].astype(jnp.int32) - tbase
            local = jnp.where(ok, local, local_tiles)             # [W]
            onehot = (local[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (1, local_tiles), 1))                  # [W, L]
            if combiner == "sum":
                out_ref[pl.ds(c, 1), :] = jnp.dot(
                    vals[None, :], onehot.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
            else:
                contrib = jnp.where(onehot, vals[:, None],
                                    jnp.float32(identity))        # [W, L]
                red = (contrib.min(axis=0) if combiner == "min"
                       else contrib.max(axis=0))
                out_ref[pl.ds(c, 1), :] = red[None, :]
        return carry

    jax.lax.fori_loop(0, max_chunks, pop, 0)


@functools.partial(jax.jit, static_argnames=("window", "local_tiles",
                                             "max_chunks", "combiner",
                                             "interpret", "emit"))
def chunk_walk_reduce(vals_padded: jax.Array,
                      tids_padded: jax.Array | None,
                      atom_starts: jax.Array, tile_starts: jax.Array,
                      block_chunks_flat: jax.Array, chunk_counts: jax.Array,
                      mask_padded: jax.Array | None = None,
                      idx_padded: jax.Array | None = None,
                      *, window: int, local_tiles: int, max_chunks: int,
                      combiner: str = "sum", emit: str = "tiles",
                      interpret: bool = True) -> jax.Array:
    """Per-chunk partial tile reductions via the chunk-walking Pallas kernel.

    ``vals_padded`` f32 ``[A + window]`` (per-atom values, identity-padded),
    ``tids_padded`` int32 ``[A + window]`` (owning tile per atom, padding
    maps past ``local_tiles``), ``atom_starts``/``tile_starts`` int32
    ``[C + 1]`` chunk boundaries, ``block_chunks_flat`` int32
    ``[P * max_chunks]`` (row ``p`` = physical block ``p``'s queue), and
    ``chunk_counts`` int32 ``[P]``.  ``mask_padded`` (optional int32
    ``[A + window]``, zero-padded) is the frontier-mask operand: atoms with
    mask 0 contribute the combiner's identity.  Grid = ``P`` physical
    blocks; every chunk row of the ``[C, local_tiles]`` result is written by
    exactly the block that owns it.  The caller resolves cross-chunk partial
    tiles with the shared fixup (see
    :func:`repro.core.execute.fixup_partials`).

    ``emit="atoms"`` returns ``[C, window]`` masked value windows instead of
    per-tile partials (the push-direction advance; the caller combines by
    per-atom destination ids — see
    :func:`repro.core.execute.scatter_value_windows`).  ``tids_padded``
    is unused (pass ``None``): the kernel streams no tile-id operand.

    ``emit="compact"`` additionally takes ``idx_padded`` (int32
    ``[capacity + window]``, the compacted active-atom ids, padded past
    ``capacity`` with indices into ``vals_padded``'s identity padding);
    ``atom_starts`` then holds chunk boundaries over ``[0, capacity]`` and
    each window slot gathers ``vals_padded[idx_padded[slot]]`` — the
    frontier-compacted window mode (no ``mask_padded``: compaction already
    applied the mask).  Output is ``[C, window]`` windows of *compacted*
    values; the caller combines them with
    :func:`repro.core.execute.scatter_compact_windows`.
    """
    if combiner not in _IDENTITY:
        raise ValueError(f"unknown combiner: {combiner!r}")
    if emit not in ("tiles", "atoms", "compact"):
        raise ValueError(f"unknown emit mode: {emit!r}")
    if emit == "compact" and (idx_padded is None or mask_padded is not None):
        raise ValueError("emit='compact' needs idx_padded and no "
                         "mask_padded (compaction already applied the mask)")
    num_chunks = int(atom_starts.shape[0]) - 1
    num_physical = int(chunk_counts.shape[0])
    a_pad = int(vals_padded.shape[0])
    has_mask = mask_padded is not None
    out_cols = local_tiles if emit == "tiles" else window

    in_specs = [pl.BlockSpec((a_pad,), lambda p, *_: (0,))]
    operands = [vals_padded]
    if emit == "tiles":
        in_specs.append(pl.BlockSpec((a_pad,), lambda p, *_: (0,)))
        operands.append(tids_padded)
    if emit == "compact":
        i_pad = int(idx_padded.shape[0])
        in_specs.append(pl.BlockSpec((i_pad,), lambda p, *_: (0,)))
        operands.append(idx_padded)
    if has_mask:
        in_specs.append(pl.BlockSpec((a_pad,), lambda p, *_: (0,)))
        operands.append(mask_padded)

    return pl.pallas_call(
        functools.partial(_chunk_walk_kernel, window=window,
                          local_tiles=local_tiles, max_chunks=max_chunks,
                          combiner=combiner, has_mask=has_mask, emit=emit),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(num_physical,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((num_chunks, out_cols),
                                   lambda p, *_: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_chunks, out_cols),
                                       jnp.float32),
        interpret=interpret,
    )(atom_starts, tile_starts, block_chunks_flat, chunk_counts,
      *operands)
