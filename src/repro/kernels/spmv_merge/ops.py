"""Jitted public wrapper: CSR in, dense y out, merge-path balanced."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.execute import ExecutionPath, choose_execution_path
from repro.core.schedules import Schedule
from repro.kernels.spmv_merge import kernel as _kernel
from repro.kernels.spmv_merge import ref as _ref

#: Grid the autotuner scores against when no explicit num_blocks is given
#: (matches the benchmark harness's processor count).
DEFAULT_NUM_BLOCKS = 64

#: Accepted ``schedule=`` spellings for the dynamic queue policies.
_CHUNK_POLICIES = {"chunked": "lpt", "chunked_lpt": "lpt",
                   "chunked_rr": "round_robin"}


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("num_rows", "nnz", "block_items",
                                             "interpret"))
def _spmv_merge_path(row_offsets, col_indices, values, x, *, num_rows: int,
                     nnz: int, block_items: int, interpret: bool):
    total = _round_up(max(num_rows + nnz, 1), block_items)
    stream_vals, stream_rows = _ref.merge_stream_ref(
        row_offsets, col_indices, values, x, num_rows, nnz, total)
    grid = total // block_items
    row_base = stream_rows[jnp.arange(grid, dtype=jnp.int32) * block_items]
    # A block may begin on padding (row == num_rows); clamp its base so the
    # one-hot window stays in range (its values are all zero regardless).
    row_base = jnp.minimum(row_base, max(num_rows - 1, 0))
    return _kernel.spmv_merge_stream(stream_vals, stream_rows, row_base,
                                     num_rows=num_rows,
                                     block_items=block_items,
                                     interpret=interpret)


def _spmv_measure(A, x, nb: int, interpret: bool):
    """Measured-mode timing closure: one candidate plan on this very SpMV."""
    from repro.core.execute import execute_tile_reduce
    from repro.core.measure import time_fn
    from repro.core.schedules import make_partition
    spec = A.workspec()
    vals, cols = A.values, A.col_indices

    def run(plan) -> float:
        part = make_partition(spec, plan.schedule, nb)

        @jax.jit
        def f(xv):
            return execute_tile_reduce(spec, part,
                                       lambda nz: vals[nz] * xv[cols[nz]],
                                       path=plan.path, interpret=interpret)

        return time_fn(f, x, warmup=1, iters=3)
    return run


def spmv_merge_path(A, x, *, num_blocks: int | None = None,
                    block_items: int = 512,
                    schedule: Schedule | str | None = None,
                    execution_path: ExecutionPath | str = ExecutionPath.AUTO,
                    measure=None,
                    interpret: bool = True) -> jax.Array:
    """Merge-path SpMV ``y = A @ x`` for a :class:`repro.sparse.CSR` matrix.

    ``num_blocks`` (if given) overrides ``block_items`` to target a specific
    grid, mirroring the paper's processor-count parameterization.

    ``schedule`` (if given) sets the execution from a :class:`Partition`
    instead: ``"auto"`` asks the cost-model autotuner
    (:mod:`repro.core.autotune`) for a (schedule, path) plan; the dynamic
    spellings ``"chunked"``/``"chunked_lpt"``/``"chunked_rr"``/``"adaptive"``
    build the corresponding dynamic Partition and hand it to the
    :mod:`repro.core.execute` dispatcher.  With ``execution_path="auto"``
    (or ``"native"``) dynamic partitions run on the chunk-walking Pallas
    kernel — each physical block scalar-prefetches its chunk queue and walks
    it in-kernel; ``"pure"`` keeps the PR-1 fallbacks (chunk-granular merge
    stream for chunked, one merge stream per block otherwise).  Requires
    concrete (non-traced) ``A.row_offsets``.  The container is CPU-only, so
    ``interpret=True`` is the validated default; on real TPU pass
    ``interpret=False``.

    ``measure`` is the measured-cost feedback knob (docs/autotune.md):
    with ``schedule="auto"`` and ``REPRO_AUTOTUNE_MEASURE=1`` the
    autotuner times its top model-ranked candidates on *this* matrix and
    vector and re-ranks by measurement.  ``None`` builds the default
    timing closure when the env gate is on; ``False`` keeps selection
    model-only regardless; a callable ``(plan) -> median_us`` supplies
    custom timings.
    """
    num_rows = A.shape[0]
    if schedule is not None:
        policy = _CHUNK_POLICIES.get(str(schedule))
        sched = Schedule.CHUNKED if policy else Schedule(schedule)
        nb = num_blocks or DEFAULT_NUM_BLOCKS
        if sched == Schedule.AUTO:
            from repro.core.autotune import measurement_enabled, select_plan
            if callable(measure):
                m = measure
            elif measure is not False and measurement_enabled():
                m = _spmv_measure(A, x, nb, interpret)
            else:
                m = None
            plan = select_plan(A.workspec(), nb, measure=m)
            sched = plan.schedule
            policy = "lpt" if sched == Schedule.CHUNKED else None
            if ExecutionPath(execution_path) == ExecutionPath.AUTO:
                execution_path = plan.path
        if sched in (Schedule.CHUNKED, Schedule.ADAPTIVE):
            from repro.core.execute import execute_tile_reduce
            from repro.core.schedules import make_partition
            # an explicit "pure" request never consults the partition, so
            # skip the inspector (LPT assignment + queue inversion) entirely
            if ExecutionPath(execution_path) == ExecutionPath.PURE:
                path = ExecutionPath.PURE
            else:
                spec = A.workspec()
                part = make_partition(spec, sched, nb,
                                      chunk_policy=policy or "lpt")
                path = choose_execution_path(part, execution_path)
            if path == ExecutionPath.NATIVE:
                vals, cols = A.values, A.col_indices
                atom_fn = lambda nz: vals[nz] * x[cols[nz]]
                return execute_tile_reduce(spec, part, atom_fn, path=path,
                                           interpret=interpret)
            # pure fallback keeps PR-1 behavior: the kernel consumes a 1-D
            # merge stream; a chunked choice oversplits it into the
            # chunk-level grid (only the block granularity changes)
            if sched == Schedule.CHUNKED:
                from repro.core.dynamic import DEFAULT_CHUNK_FACTOR
                num_blocks = min(DEFAULT_CHUNK_FACTOR * nb, max(A.nnz, 1))
            else:
                num_blocks = nb
        else:
            num_blocks = nb
    if num_blocks is not None:
        block_items = max(_round_up(-(-(num_rows + A.nnz) // num_blocks), 128),
                          128)
    return _spmv_merge_path(A.row_offsets, A.col_indices, A.values, x,
                            num_rows=num_rows, nnz=A.nnz,
                            block_items=block_items, interpret=interpret)
