"""Pure-jnp oracle for the banded SWA flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention_ref(q, k, v, *, window: int) -> jax.Array:
    """Full-materialization causal SWA. q/k/v: [B, S, H, hd]."""
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = (j <= i) & (j > i - window)
    logits = jnp.where(mask[None, None], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
