"""Banded sliding-window flash attention as a Pallas TPU kernel.

The SWA archs (danube window=4096, hymba window=1024) only ever attend to a
``window``-wide band, but a naive kernel materializes [S, S] scores.  This
kernel fuses the banded schedule into the grid:

* grid = (batch, heads, S/qc, window/qc + 1) — a query tile visits ONLY the
  KV tiles inside its causal window band (the O(S * window) schedule);
* the KV index map walks ``j`` tiles back from the query tile, clamped at
  the sequence start; clamped (out-of-band) tiles are fully masked so they
  contribute exp(-inf) = 0;
* classic online-softmax accumulation across the innermost (sequential) KV
  dimension in VMEM scratch: running max ``m``, normalizer ``l`` and the
  unnormalized accumulator — numerics identical to full softmax (tested).

Per-block VMEM at (qc=256, hd=128): q/k/v tiles 3 x 64 KB + scores 256 KB
fp32 + acc 128 KB — well inside v5e VMEM.  FLOPs and HBM traffic drop from
O(S^2) to O(S * (window + qc)): 6.4x for danube's prefill_32k shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30  # plain float: jnp scalars would be captured as consts


def _flash_swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      qc: int, window: int, wb: int, scale: float):
    i = pl.program_id(2)          # query tile
    j = pl.program_id(3)          # band tile (0 = oldest in window)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)              # [qc, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [qc, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [qc, qc]

    # absolute positions from the UNclamped tile index: clamped tiles load
    # tile 0's data but their masked scores contribute nothing.
    kblk = i - wb + j
    qpos = i * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, qc), 0)
    kpos = kblk * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, qc), 1)
    mask = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - window)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                                    # [qc, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                            # [qc, qc]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == wb)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_ref[...]
                             / jnp.maximum(l_ref[...], 1e-30)
                             ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "qc", "interpret"))
def flash_swa(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
              qc: int = 256, interpret: bool = True) -> jax.Array:
    """Causal sliding-window attention.  q/k/v: [B, S, H, hd] (same head
    count — see ops.flash_swa_gqa for GQA); positions 0..S-1; ``window``
    and S must be multiples of ``qc``."""
    b, s, h, hd = q.shape
    assert s % qc == 0 and window % qc == 0, (s, window, qc)
    nq = s // qc
    wb = window // qc
    scale = hd ** -0.5

    def q_index(bi, hi, i, j):
        return (bi, i, hi, 0)

    def kv_index(bi, hi, i, j):
        return (bi, jnp.maximum(i - wb + j, 0), hi, 0)

    return pl.pallas_call(
        functools.partial(_flash_swa_kernel, qc=qc, window=window, wb=wb,
                          scale=scale),
        grid=(b, h, nq, wb + 1),
        in_specs=[
            pl.BlockSpec((1, qc, 1, hd), q_index),
            pl.BlockSpec((1, qc, 1, hd), kv_index),
            pl.BlockSpec((1, qc, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, qc, 1, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc, 1), jnp.float32),    # running max
            pltpu.VMEM((qc, 1), jnp.float32),    # running normalizer
            pltpu.VMEM((qc, hd), jnp.float32),   # unnormalized accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
