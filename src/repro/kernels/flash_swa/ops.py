"""Public wrappers: GQA-aware banded SWA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_swa import kernel as _kernel


def flash_swa(q, k, v, *, window: int, qc: int = 256,
              interpret: bool = True) -> jax.Array:
    return _kernel.flash_swa(q, k, v, window=window, qc=qc,
                             interpret=interpret)


def flash_swa_gqa(q, k, v, *, window: int, qc: int = 256,
                  interpret: bool = True) -> jax.Array:
    """GQA: q [B,S,H,hd], k/v [B,S,Hkv,hd] with H % Hkv == 0.  The repeat is
    a broadcast-reshape (no copy under XLA) before the kernel."""
    h, hkv = q.shape[2], k.shape[2]
    groups = h // hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    return flash_swa(q, k, v, window=window, qc=qc, interpret=interpret)
