"""repro.data substrate."""
