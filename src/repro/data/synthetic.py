"""Deterministic synthetic LM data — batches as a pure function of step.

``batch_at(step)`` derives everything from ``fold_in(seed, step)``: a
restarted (or replaced) host regenerates exactly the batch it would have
seen, which is what makes checkpoint/restart and elastic re-membership
stateless (no iterator state to migrate).  Token stream: Zipf-distributed
ids over document spans with power-law lengths, packed by the load-balanced
packer (repro.data.packing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 512
    global_batch: int = 8
    mean_doc_len: int = 256
    zipf_alpha: float = 1.1


def _zipf_tokens(key, shape, vocab: int, alpha: float) -> jax.Array:
    """Zipf-ish ids via inverse-CDF on uniform samples (vectorized)."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    ids = jnp.floor(jnp.exp(jnp.log(u) / (1.0 - alpha))) - 1.0
    return jnp.clip(ids, 0, vocab - 1).astype(jnp.int32)


def batch_at(cfg: DataConfig, step: int,
             model_cfg: Optional[ModelConfig] = None
             ) -> Dict[str, jax.Array]:
    """Batch for ``step``: tokens/labels [B, S] (+ frontend stub embeds)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k_tok, k_doc, k_front = jax.random.split(key, 3)
    b, s = cfg.global_batch, cfg.seq_len
    tokens = _zipf_tokens(k_tok, (b, s), cfg.vocab_size, cfg.zipf_alpha)

    # document boundaries (power-law lengths): mask loss across them
    boundary = jax.random.uniform(k_doc, (b, s)) < (1.0 / cfg.mean_doc_len)
    labels = jnp.where(boundary[:, 1:], -1, tokens[:, 1:])
    labels = jnp.concatenate([labels, -jnp.ones((b, 1), jnp.int32)], axis=1)

    batch = {"tokens": tokens, "labels": labels}
    if model_cfg is not None and model_cfg.frontend is not None:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            k_front, (b, model_cfg.frontend_len, model_cfg.d_model),
            jnp.float32)
    return batch


def for_model(model_cfg: ModelConfig, *, seq_len: int, global_batch: int,
              seed: int = 0) -> DataConfig:
    return DataConfig(seed=seed, vocab_size=model_cfg.vocab_size,
                      seq_len=seq_len, global_batch=global_batch)
