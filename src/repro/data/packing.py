"""Load-balanced document packing — the paper's abstraction applied to the
data pipeline.

Packing documents of wildly varying length into fixed ``seq_len`` rows IS a
load-balancing problem: atoms = tokens, tiles = documents, processors =
batch rows.  ``merge_path_partition`` splits ``tokens + documents`` work
exactly evenly across rows, so every packed row carries the same token count
(+-1 document boundary) — no ragged tail batches, no padding-FLOP waste.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import WorkSpec, merge_path_partition


def pack_documents(doc_lengths: jax.Array, num_rows: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Partition documents into ``num_rows`` balanced rows.

    Returns ``(row_token_starts [R+1], row_doc_starts [R+1])`` — row ``r``
    carries tokens ``[row_token_starts[r], row_token_starts[r+1])`` of the
    concatenated token stream (documents crossing a row boundary are split,
    the usual packing semantics).
    """
    doc_lengths = jnp.asarray(doc_lengths, jnp.int32)
    total = int(jnp.sum(doc_lengths)) if not isinstance(
        doc_lengths, jax.core.Tracer) else None
    spec = WorkSpec.from_segment_sizes(
        doc_lengths, num_atoms=int(doc_lengths.sum()) if total is None
        else total)
    part = merge_path_partition(spec, num_rows)
    return part.atom_starts, part.tile_starts


def packing_efficiency(doc_lengths: np.ndarray, num_rows: int) -> dict:
    """Compare balanced packing vs naive one-doc-per-row padding."""
    doc_lengths = np.asarray(doc_lengths)
    total = int(doc_lengths.sum())
    starts, _ = pack_documents(jnp.asarray(doc_lengths), num_rows)
    per_row = np.diff(np.asarray(starts))
    balanced_cost = int(per_row.max()) * num_rows
    naive_rows = len(doc_lengths)
    naive_cost = int(doc_lengths.max()) * naive_rows
    return {
        "tokens": total,
        "balanced_padded": balanced_cost,
        "balanced_efficiency": total / max(balanced_cost, 1),
        "naive_padded": naive_cost,
        "naive_efficiency": total / max(naive_cost, 1),
    }
