"""Load-balanced document packing — the paper's abstraction applied to the
data pipeline.

Packing documents of wildly varying length into fixed ``seq_len`` rows IS a
load-balancing problem: atoms = tokens, tiles = documents, processors =
batch rows.  ``merge_path_partition`` splits ``tokens + documents`` work
exactly evenly across rows, so every packed row carries the same token count
(+-1 document boundary) — no ragged tail batches, no padding-FLOP waste.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import WorkSpec, merge_path_partition


def _validate_lengths(doc_lengths, num_rows: int,
                      row_capacity: Optional[int]) -> None:
    """Reject malformed packing inputs with a clean error at build time.

    Packing is an inspector step (lengths are concrete), and the merge
    path silently mis-packs every malformed input it is fed: an empty or
    all-zero document set packs into ``num_rows`` empty rows that look
    like a successful batch, negative lengths break the prefix-sum
    monotonicity the diagonal search assumes (rows overlap), and
    ``num_rows < 1`` indexes nothing.  Wavefront forest batching
    (:func:`repro.sparse.wavefront.pack_forest`) feeds this exact surface
    — empty levels, zero-node trees, single-node trees — so each case
    raises here instead.  Traced lengths pass through unchecked, as any
    jit argument must.
    """
    if num_rows < 1:
        raise ValueError(f"num_rows must be >= 1, got {num_rows}")
    if isinstance(doc_lengths, jax.core.Tracer):
        return
    arr = np.asarray(doc_lengths)
    if arr.size == 0:
        raise ValueError("pack_documents needs at least one document "
                         "(got empty doc_lengths)")
    if (arr < 0).any():
        bad = np.flatnonzero(arr < 0)
        raise ValueError(
            f"negative document lengths at indices {bad[:8].tolist()} "
            f"(e.g. {int(arr[bad[0]])}); lengths must be >= 0")
    if (arr == 0).any():
        bad = np.flatnonzero(arr == 0)
        raise ValueError(
            f"zero-length documents at indices {bad[:8].tolist()}; drop "
            f"empty entries before packing (an empty document would "
            f"silently vanish into a row boundary)")
    if row_capacity is not None:
        if row_capacity < 1:
            raise ValueError(f"row_capacity must be >= 1 or None, "
                             f"got {row_capacity}")
        total = int(arr.sum())
        if total > num_rows * row_capacity:
            raise ValueError(
                f"{total} tokens cannot fit {num_rows} rows of capacity "
                f"{row_capacity} ({num_rows * row_capacity} slots); "
                f"raise num_rows or row_capacity")


def pack_documents(doc_lengths: jax.Array, num_rows: int, *,
                   row_capacity: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Partition documents into ``num_rows`` balanced rows.

    Returns ``(row_token_starts [R+1], row_doc_starts [R+1])`` — row ``r``
    carries tokens ``[row_token_starts[r], row_token_starts[r+1])`` of the
    concatenated token stream (documents crossing a row boundary are split,
    the usual packing semantics).

    Malformed inputs (empty document set, zero or negative lengths,
    ``num_rows < 1``) raise :class:`ValueError` instead of silently
    mis-packing; ``row_capacity`` optionally bounds the per-row token
    count (the fixed ``seq_len`` case) and over-capacity inputs raise
    too — the merge-path split is within +-1 document boundary of
    ``total / num_rows``, so the post-pack check below can only fire on
    genuinely unpackable inputs, never on balance noise.
    """
    _validate_lengths(doc_lengths, num_rows, row_capacity)
    doc_lengths = jnp.asarray(doc_lengths, jnp.int32)
    total = int(jnp.sum(doc_lengths)) if not isinstance(
        doc_lengths, jax.core.Tracer) else None
    spec = WorkSpec.from_segment_sizes(
        doc_lengths, num_atoms=int(doc_lengths.sum()) if total is None
        else total)
    part = merge_path_partition(spec, num_rows)
    if row_capacity is not None and total is not None:
        per_row = np.diff(np.asarray(part.atom_starts))
        if per_row.size and int(per_row.max()) > row_capacity:
            worst = int(np.argmax(per_row))
            raise ValueError(
                f"balanced packing puts {int(per_row[worst])} tokens in "
                f"row {worst}, over row_capacity={row_capacity}; raise "
                f"num_rows or row_capacity")
    return part.atom_starts, part.tile_starts


def packing_efficiency(doc_lengths: np.ndarray, num_rows: int) -> dict:
    """Compare balanced packing vs naive one-doc-per-row padding."""
    doc_lengths = np.asarray(doc_lengths)
    total = int(doc_lengths.sum())
    starts, _ = pack_documents(jnp.asarray(doc_lengths), num_rows)
    per_row = np.diff(np.asarray(starts))
    balanced_cost = int(per_row.max()) * num_rows
    naive_rows = len(doc_lengths)
    naive_cost = int(doc_lengths.max()) * naive_rows
    return {
        "tokens": total,
        "balanced_padded": balanced_cost,
        "balanced_efficiency": total / max(balanced_cost, 1),
        "naive_padded": naive_cost,
        "naive_efficiency": total / max(naive_cost, 1),
    }
