"""AdamW + clipping + LR schedules, dependency-free (no optax offline).

Optimizer state is fp32 and inherits the *parameter* partition specs
(ZeRO-style: m/v live wherever the param shard lives, so FSDP sharding of
params automatically shards optimizer memory 1:1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["mu"])
    flat_v = jax.tree.leaves(opt_state["nu"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_params = treedef.unflatten([t[0] for t in new])
    new_state = {"mu": treedef.unflatten([t[1] for t in new]),
                 "nu": treedef.unflatten([t[2] for t in new]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
