"""Int8 error-feedback gradient compression for the cross-pod reduction.

At multi-pod scale the gradient all-reduce crosses the (slow) inter-pod
links; compressing those bytes 4x is a standard distributed-optimization
trick.  Implementation: per-tensor-chunk symmetric int8 quantization with an
**error-feedback** residual (the quantization error is carried into the next
step, which keeps SGD/Adam convergence — Karimireddy et al., 2019).

The quantize -> (wire) -> dequantize pair is expressed inside the jitted
step so XLA sees int8 tensors at the reduction point; on hardware the
cross-pod collective then moves 1/4 of the bytes.  The error state rides in
the optimizer-state pytree like any other leaf.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

CHUNK = 2048  # quantization group size


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(chunks / jnp.maximum(scale, 1e-12)), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


def compress_roundtrip(g: jax.Array) -> jax.Array:
    """quantize -> dequantize (the wire format both pods agree on)."""
    q, s = _quantize(g.astype(jnp.float32))
    return _dequantize(q, s, g.shape)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, error_state):
    """Error-feedback compression: returns (compressed_grads, new_error).

    compressed = Q(g + e);  e' = (g + e) - compressed.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        sent = compress_roundtrip(corrected)
        return sent, corrected - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))
