"""Distributed train step: FSDP x TP sharding, microbatch accumulation, remat.

``make_train_step`` builds a jitted step:

* **params/optimizer sharding**: from the model's partition specs — matrices
  FSDP-sharded over ``data`` and TP-sharded over ``model`` (GSPMD inserts the
  per-layer weight all-gathers and gradient reduce-scatters; with a ``pod``
  axis the gradient reduction becomes hierarchical automatically).
* **microbatching**: ``lax.scan`` over ``num_microbatches`` slices with fp32
  grad accumulation — this is what fits 340B training activations in 16 GB
  chips (saved activations scale with the microbatch, not the global batch).
* **remat**: per-layer ``jax.checkpoint`` inside the model (cfg.remat).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import use_ambient_mesh
from repro.configs.base import ModelConfig
from repro.models import init_params, lm_loss
from repro.train.optimizer import OptConfig, adamw_update

BATCH_AXES = ("pod", "data")  # batch shards over every data-parallel axis


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def param_specs(cfg: ModelConfig):
    """Partition specs without materializing full-size params.

    Specs depend only on the *structure* of the param tree (family, bias
    flags, expert counts), never on dimensions — so they are built from the
    reduced structural twin, which is cheap to init for any config.
    """
    _, specs = init_params(cfg.reduced(), jax.random.PRNGKey(0))
    return specs


def shardings_for(mesh: Mesh, specs) -> Any:
    """PartitionSpec tree -> NamedSharding tree, dropping axes the mesh does
    not have (so the same specs serve single- and multi-pod meshes)."""
    def fix(spec: P) -> NamedSharding:
        cleaned = []
        for a in spec:
            if a is None:
                cleaned.append(None)
            elif isinstance(a, tuple):
                keep = tuple(x for x in a if x in mesh.axis_names)
                cleaned.append(keep if keep else None)
            else:
                cleaned.append(a if a in mesh.axis_names else None)
        return NamedSharding(mesh, P(*cleaned))
    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, P))


def opt_shardings(mesh: Mesh, param_sh) -> Dict[str, Any]:
    return {"mu": param_sh, "nu": param_sh,
            "step": NamedSharding(mesh, P())}


def _constrain_batch(batch, mesh: Optional[Mesh]):
    """Re-pin the batch dim sharding — GSPMD loses it after the microbatch
    reshape/slice, which would leave attention logits batch-replicated
    (a ~15x per-device memory blowup measured on qwen train_4k)."""
    if mesh is None:
        return batch
    spec = batch_pspec(mesh)
    if spec == P():
        return batch

    def pin(x):
        full = P(*(tuple(spec) + (None,) * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, full))
    return jax.tree.map(pin, batch)


def loss_and_grads(params, cfg: ModelConfig, batch, num_microbatches: int,
                   dtype=jnp.bfloat16, mesh: Optional[Mesh] = None):
    """Grad accumulation over microbatches via lax.scan."""
    if num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, cfg, _constrain_batch(batch, mesh),
                                   dtype=dtype)
        return loss, {"loss": metrics["loss"],
                      "ntokens": metrics["ntokens"]}, grads

    def reshape(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape((num_microbatches, b // num_microbatches)
                         + x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    acc_dtype = jnp.bfloat16 if cfg.grad_accum_bf16 else jnp.float32
    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              params)

    def body(carry, mb):
        acc, loss_acc, ntok = carry
        mb = _constrain_batch(mb, mesh)
        (loss, metrics), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, cfg, mb, dtype=dtype)
        acc = jax.tree.map(lambda a, g: a + g.astype(acc_dtype), acc, grads)
        return (acc, loss_acc + loss, ntok + metrics["ntokens"]), None

    (grads, loss_sum, ntok), _ = jax.lax.scan(
        body, (zero_grads, jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), micro)
    inv = 1.0 / num_microbatches
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
    return loss_sum * inv, {"loss": loss_sum * inv, "ntokens": ntok}, grads


METRIC_KEYS = ("loss", "ntokens", "grad_norm", "lr")


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh: Mesh, *,
                    num_microbatches: int = 1, dtype=jnp.bfloat16,
                    grad_compress: Optional[Callable] = None):
    """Returns (jitted_step, param_shardings, opt_shardings).

    ``jitted_step(params, opt_state, batch) -> (params, opt_state, metrics)``.
    ``grad_compress`` (see repro.train.compress) is applied to accumulated
    grads before the optimizer — int8 error-feedback cross-pod reduction.
    """
    specs = param_specs(cfg)
    param_sh = shardings_for(mesh, specs)
    opt_sh = opt_shardings(mesh, param_sh)
    scalar_sh = NamedSharding(mesh, P())

    def step_fn(params, opt_state, batch):
        # the abstract mesh is active while this traces -> maybe_constrain
        # pins activation shardings against it.
        with use_ambient_mesh(mesh):
            loss, metrics, grads = loss_and_grads(params, cfg, batch,
                                                  num_microbatches, dtype,
                                                  mesh=mesh)
            if grad_compress is not None:
                grads = grad_compress(grads)
            new_params, new_opt, opt_metrics = adamw_update(params, grads,
                                                            opt_state,
                                                            opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    step = jax.jit(
        step_fn,
        in_shardings=(param_sh, opt_sh, None),
        out_shardings=(param_sh, opt_sh,
                       {k: scalar_sh for k in METRIC_KEYS}),
        donate_argnums=(0, 1))
    return step, param_sh, opt_sh
