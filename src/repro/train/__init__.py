"""repro.train substrate."""
