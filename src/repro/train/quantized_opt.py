"""Int8 blockwise-quantized Adam state (bitsandbytes-style, for 340B fits).

On 256 chips, fp32 Adam m/v for nemotron-4-340b cost 10.7 GiB/chip — alone
forcing the 512-chip mesh.  Blockwise int8 state brings m+v to ~2.7 GiB:

* ``m``: signed linear quantization per 256-element block (absmax scale);
* ``v``: non-negative with a huge dynamic range — quantized as a per-block
  affine int8 code over ``log(v)``, giving uniform *relative* error, which
  is what the ``1/sqrt(v)`` the update consumes actually needs (linear or
  sqrt-space codes collapse small-v entries within a block — measured 100%+
  rsqrt error; log-space holds it to a few percent).

The quantize/dequantize pair lives inside the jitted step; state rides the
optimizer pytree as ``{"q": int8, "s": f32 scales}`` leaves, sharded like
the parameter.  Convergence is validated in tests (quadratic + tiny LM) —
the standard result that blockwise 8-bit Adam tracks fp32 Adam closely.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, global_norm, lr_at

BLOCK = 256


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


_LOG_TINY = -27.6  # log(1e-12): the "v == 0" codepoint


def quantize_blockwise(x: jax.Array, log_space: bool = False
                       ) -> Dict[str, jax.Array]:
    """``linear``: signed absmax int8 per block (for m).  ``log_space``:
    per-block affine int8 over log(x) (for v) — uniform *relative* error,
    which is what the Adam rsqrt consumes."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = _pad_len(flat.shape[0]) - flat.shape[0]
    if not log_space:
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-20)), -127, 127
                     ).astype(jnp.int8)
        return {"q": q, "s": scale[:, 0], "m": jnp.zeros_like(scale[:, 0])}
    y = jnp.log(jnp.maximum(flat, 1e-12))
    blocks = jnp.pad(y, (0, pad), constant_values=_LOG_TINY).reshape(
        -1, BLOCK)
    lo = jnp.min(blocks, axis=1, keepdims=True)
    hi = jnp.max(blocks, axis=1, keepdims=True)
    mid = (hi + lo) / 2.0
    scale = jnp.maximum((hi - lo) / 2.0 / 127.0, 1e-8)
    q = jnp.clip(jnp.round((blocks - mid) / scale), -127, 127).astype(
        jnp.int8)
    return {"q": q, "s": scale[:, 0], "m": mid[:, 0]}


def dequantize_blockwise(state: Dict[str, jax.Array], shape,
                         log_space: bool = False) -> jax.Array:
    size = 1
    for d in shape:
        size *= d
    if not log_space:
        flat = (state["q"].astype(jnp.float32)
                * state["s"][:, None]).reshape(-1)
        return flat[:size].reshape(shape)
    y = (state["q"].astype(jnp.float32) * state["s"][:, None]
         + state["m"][:, None]).reshape(-1)[:size]
    out = jnp.exp(y)
    return jnp.where(y <= _LOG_TINY + 1e-3, 0.0, out).reshape(shape)


def init_opt_state_int8(params) -> Dict[str, Any]:
    def zq(p):
        n_blocks = _pad_len(p.size) // BLOCK
        return {"q": jnp.zeros((n_blocks, BLOCK), jnp.int8),
                "s": jnp.zeros((n_blocks,), jnp.float32),
                "m": jnp.full((n_blocks,), _LOG_TINY, jnp.float32)}
    return {"mu": jax.tree.map(zq, params), "nu": jax.tree.map(zq, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update_int8(params, grads, opt_state, cfg: OptConfig
                      ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """AdamW with int8 blockwise m/v.  Same contract as adamw_update."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mq, vq):
        g = g.astype(jnp.float32) * scale
        m = dequantize_blockwise(mq, p.shape)
        v = dequantize_blockwise(vq, p.shape, log_space=True)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, quantize_blockwise(m), quantize_blockwise(
            v, log_space=True)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    mu_leaves = treedef.flatten_up_to(opt_state["mu"])
    nu_leaves = treedef.flatten_up_to(opt_state["nu"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, mu_leaves,
                                                 nu_leaves)]
    return (treedef.unflatten([t[0] for t in new]),
            {"mu": treedef.unflatten([t[1] for t in new]),
             "nu": treedef.unflatten([t[2] for t in new]),
             "step": step},
            {"grad_norm": gnorm, "lr": lr})


def state_bytes(params, int8: bool) -> int:
    """Optimizer-state bytes (for the memory table)."""
    total = 0
    for p in jax.tree.leaves(params):
        if int8:
            nb = _pad_len(p.size) // BLOCK
            total += 2 * (nb * BLOCK + 2 * nb * 4)  # q + scale/mid, m and v
        else:
            total += 2 * p.size * 4
    return total
