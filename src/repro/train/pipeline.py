"""Pipeline parallelism over the pod axis (GPipe schedule).

At multi-pod scale the cross-pod links are the slowest tier, so instead of
data-parallel gradient sync over ``pod`` the framework can run the layer
stack as P pipeline stages (one per pod): activations stream stage-to-stage
over point-to-point ``ppermute`` (cheap on the pod interconnect), and only
microbatch activations — never weights or gradients — cross pods.

Schedule: classic GPipe.  T = num_micro + P - 1 ticks; at tick ``t`` stage
``s`` computes microbatch ``t - s`` (bubble ticks compute masked garbage —
the standard utilization cost ``(P-1)/T``).  All stages run one SPMD program
under ``shard_map``; the inter-stage hop is a single ``ppermute``.  The
whole schedule is differentiable (``ppermute`` transposes to the reverse
permute), so ``jax.grad`` through it yields pipeline-parallel training
without a hand-written backward schedule.

Stage weights live only on their pod (``P('pod', ...)`` on the stacked
stage dim) — pipeline parallelism is also the memory play that lets a
340B-class model drop the FSDP all-gather traffic entirely.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

AXIS = "pod"


def split_stages(layer_params, num_stages: int):
    """Reshape stacked layer params [L, ...] -> [P, L//P, ...]."""
    def r(x):
        l = x.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return x.reshape((num_stages, l // num_stages) + x.shape[1:])
    return jax.tree.map(r, layer_params)


def make_pipeline_apply(stage_fn: Callable, mesh: Mesh, num_stages: int,
                        num_micro: int):
    """Build ``apply(stage_params, xs) -> ys``.

    ``stage_fn(params_stage, x) -> y`` applies one stage's layers to one
    microbatch activation ``[mb, ...]``.  ``xs``: ``[num_micro, mb, ...]``
    microbatched inputs (replicated across pods); returns ``ys`` of the same
    shape from the last stage.
    """
    assert num_micro >= 1 and num_stages >= 1
    ticks = num_micro + num_stages - 1
    perm = [(i, i + 1) for i in range(num_stages - 1)]

    def per_pod(stage_params, xs):
        # shapes inside shard_map: stage_params [1, L/P, ...]; xs full
        # (replicated).  Drop the leading stage dim.
        stage_params_local = jax.tree.map(lambda p: p[0], stage_params)
        stage = jax.lax.axis_index(AXIS)
        mb_shape = xs.shape[1:]

        def tick(act_in, t):
            mb_idx = jnp.clip(t - stage, 0, num_micro - 1)
            x_t = jnp.where(stage == 0, xs[jnp.clip(t, 0, num_micro - 1)],
                            act_in)
            y = stage_fn(stage_params_local, x_t)
            act_next = jax.lax.ppermute(y, AXIS, perm) if perm else y
            return act_next, y

        act0 = jnp.zeros(mb_shape, xs.dtype)
        _, ys = jax.lax.scan(tick, act0, jnp.arange(ticks))
        # keep only this stage's outputs; callers read the last stage's.
        return ys[None]  # [1, T, mb, ...] -> stacked over pods by out_spec

    sharded = shard_map(
        per_pod, mesh=mesh,
        in_specs=(P(AXIS), P()),        # stage params by pod; inputs repl.
        out_specs=P(AXIS),              # [P, T, mb, ...]
        check=False)

    def apply(stage_params, xs):
        ys_all = sharded(stage_params, xs)                  # [P, T, mb, ...]
        # valid outputs of the LAST stage are ticks P-1 .. P-1+num_micro
        return ys_all[num_stages - 1, num_stages - 1:
                      num_stages - 1 + num_micro]
    return apply


def reference_apply(stage_fn, stage_params, xs, num_stages: int):
    """Sequential oracle: run every stage in order on each microbatch."""
    def one_micro(x):
        for s in range(num_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x
    return jax.vmap(one_micro)(xs)
