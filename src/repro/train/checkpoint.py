"""Fault-tolerant checkpointing: atomic, keep-k, elastic resharding.

Design for 1000+-node operation:

* **Canonical mesh-free layout**: checkpoints store full (unsharded) arrays
  keyed by tree path.  Restore targets *any* mesh shape — ``restore`` device-
  puts each array with the shardings of the new mesh, so a job can come back
  elastically on 256, 512 or 4096 chips (or a different DP/TP split) without
  a conversion step.  (At true 340B scale one would write per-shard files +
  an index; the layout here keeps the same API surface while staying
  runnable in this container.)
* **Atomicity**: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a
  preempted save can never shadow a valid checkpoint.
* **keep-k GC + latest-valid discovery**: a corrupt/partial newest checkpoint
  (node died mid-save before rename) is invisible by construction;
  ``latest_step`` simply picks the newest committed one, giving
  checkpoint/restart fault tolerance.
* **Stateless data resumption**: the loader (repro.data) computes batches as
  a pure function of (seed, step), so restoring params+opt_state+step fully
  restores the run — no data-iterator state to hand between replaced hosts.
* **Straggler/elasticity posture**: save cadence is cheap (async thread
  optional); on a detected straggler or membership change the controller
  checkpoints, re-forms the mesh with the survivors, and restores —
  the elastic-resharding test exercises exactly that path.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, params, opt_state, *,
         extra: Optional[Dict[str, Any]] = None, keep: int = 3,
         async_save: bool = False) -> threading.Thread | None:
    """Atomically write ``<ckpt_dir>/step_<step>``; GC to ``keep`` newest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # Gather to host BEFORE the (optional) thread: device buffers may be
    # donated away by the next step.
    host = {f"p/{k}": np.asarray(v) for k, v in _flatten(params).items()}
    host.update({f"o/{k}": np.asarray(v)
                 for k, v in _flatten(opt_state).items()})
    meta = {"step": int(step), "format": 1}
    meta.update(extra or {})

    def _write():
        tmp = os.path.join(ckpt_dir, f"tmp.{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, params_like, opt_like, *,
            param_sh=None, opt_sh=None) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore onto (possibly different) shardings — elastic resharding.

    ``params_like``/``opt_like``: pytrees (arrays or ShapeDtypeStructs) fixing
    the tree structure; ``param_sh``/``opt_sh``: optional NamedSharding trees
    for the *new* mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    def rebuild(prefix, like, sh):
        flat_keys = list(_flatten(like).keys())
        treedef = jax.tree.structure(like)
        sh_leaves = (jax.tree.leaves(sh) if sh is not None
                     else [None] * len(flat_keys))
        leaves = []
        for key, s in zip(flat_keys, sh_leaves):
            arr = arrays[f"{prefix}/{key}"]
            leaves.append(jax.device_put(arr, s) if s is not None
                          else jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, leaves)

    params = rebuild("p", params_like, param_sh)
    opt_state = rebuild("o", opt_like, opt_sh)
    return params, opt_state, meta


def restore_latest(ckpt_dir: str, params_like, opt_like, **kw):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return restore(ckpt_dir, step, params_like, opt_like, **kw)
