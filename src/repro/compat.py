"""Version-compat shims for the jax APIs this repo straddles.

The codebase targets the current jax sharding API (``jax.sharding.AxisType``,
``use_abstract_mesh``/``get_abstract_mesh``, ``jax.make_mesh(axis_types=...)``)
but must also run on jax 0.4.x where none of those exist publicly.  Every
version probe lives here; callers import the uniform surface:

* :data:`AxisType` — the real enum when available, a stand-in otherwise.
* :func:`make_mesh` — ``jax.make_mesh`` that silently drops ``axis_types``
  on versions whose signature predates it.
* :func:`use_ambient_mesh` — context manager taking the *physical* mesh and
  making it the ambient mesh for :func:`get_ambient_mesh` during tracing.
  New jax: the mesh's abstract twin via ``use_abstract_mesh``.  Old jax: the
  physical ``Mesh`` context manager (which is what feeds
  ``with_sharding_constraint(x, PartitionSpec(...))`` there).
* :func:`get_ambient_mesh` — the mesh ``maybe_constrain`` should resolve
  axis names against, or ``None`` when sharding pins must no-op.
"""
from __future__ import annotations

import contextlib
import enum
import inspect
from typing import Optional, Sequence

import jax

# --------------------------------------------------------------------------
# AxisType
# --------------------------------------------------------------------------
try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPE = True
except ImportError:  # pragma: no cover - exercised on old jax only
    HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on jax < 0.5.

        Only carries the names; old jax has a single (Auto) axis semantics,
        so the value is accepted and dropped by :func:`make_mesh`.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Sequence] = None, **kwargs):
    """``jax.make_mesh`` across versions; drops ``axis_types`` if unknown."""
    if axis_types is None:
        axis_types = tuple(AxisType.Auto for _ in axis_names)
    if _MAKE_MESH_TAKES_AXIS_TYPES:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=tuple(axis_types), **kwargs)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


# --------------------------------------------------------------------------
# shard_map (moved from jax.experimental to jax.*; check_rep -> check_vma)
# --------------------------------------------------------------------------
_shard_map_impl = getattr(jax, "shard_map", None)
if _shard_map_impl is None:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_CHECK_KW = next(
    (kw for kw in ("check_vma", "check_rep")
     if kw in inspect.signature(_shard_map_impl).parameters), None)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across versions.

    ``check`` maps onto whichever replication/varying-manual-axes checker
    kwarg the installed jax spells (``check_rep`` on 0.4.x, ``check_vma``
    now).  The sharded traversal bodies squeeze stacked per-shard plan
    leaves and run data-dependent collectives, so callers pass ``False``.
    """
    kwargs = {}
    if _SHARD_MAP_CHECK_KW is not None:
        kwargs[_SHARD_MAP_CHECK_KW] = check
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


# --------------------------------------------------------------------------
# optimization_barrier (no batching rule on jax 0.4.x)
# --------------------------------------------------------------------------
# ``lax.optimization_barrier`` is how fusion-sensitive numerics (the PageRank
# update, see sparse/graph.py) pin per-op rounding so eager, jit, while_loop
# and vmapped-lane contexts all produce identical bits.  On jax 0.4.x the
# primitive exists but has no batching rule, so vmapping a barrier-protected
# body raises NotImplementedError.  The rule is trivially dimension-preserving
# (the barrier is an identity on each operand); register it when absent.
try:
    from jax.interpreters import batching as _batching
    from jax._src.lax.lax import (  # type: ignore[attr-defined]
        optimization_barrier_p as _opt_barrier_p)
except ImportError:  # pragma: no cover - internals moved; newer jax has rule
    _opt_barrier_p = None

if _opt_barrier_p is not None and _opt_barrier_p not in _batching.primitive_batchers:
    def _opt_barrier_batcher(batched_args, batch_dims, **params):
        return _opt_barrier_p.bind(*batched_args, **params), batch_dims

    _batching.primitive_batchers[_opt_barrier_p] = _opt_barrier_batcher


def opt_barrier(x):
    """``lax.optimization_barrier`` with a vmap rule guaranteed registered."""
    return jax.lax.optimization_barrier(x)


# --------------------------------------------------------------------------
# Pallas TPU compiler params (renamed TPUCompilerParams -> CompilerParams)
# --------------------------------------------------------------------------
def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across the rename from ``TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


# --------------------------------------------------------------------------
# Ambient (abstract) mesh context
# --------------------------------------------------------------------------
_use_abstract_mesh = getattr(jax.sharding, "use_abstract_mesh", None)
_get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)

if _get_abstract_mesh is None:  # old jax: internal equivalents
    try:
        from jax._src import mesh as _mesh_internal
    except ImportError:  # pragma: no cover - defensive
        _mesh_internal = None


def use_ambient_mesh(mesh) -> contextlib.AbstractContextManager:
    """Make ``mesh`` (a physical ``jax.sharding.Mesh``) ambient.

    Inside the context, ``maybe_constrain``-style code can resolve
    ``PartitionSpec`` axis names via :func:`get_ambient_mesh` and call
    ``with_sharding_constraint`` with bare specs.
    """
    if mesh is None:
        return contextlib.nullcontext()
    if _use_abstract_mesh is not None:
        return _use_abstract_mesh(mesh.abstract_mesh)
    # Old jax: the physical mesh context manager provides the mesh that
    # with_sharding_constraint(P(...)) resolves against.
    return mesh


def get_ambient_mesh():
    """The ambient mesh for axis-name resolution, or ``None``.

    Returns an object with ``.empty`` and ``.axis_names`` (an
    ``AbstractMesh`` on new jax; on old jax, whichever of the abstract or
    physical mesh contexts is active).
    """
    if _get_abstract_mesh is not None:
        mesh = _get_abstract_mesh()
        if mesh is None or mesh.empty:
            return None
        return mesh
    if _mesh_internal is not None:
        # the internal context manager's default value is a raw sentinel
        # (not a mesh) on 0.4.x — only trust a real AbstractMesh
        mesh = _mesh_internal.get_abstract_mesh()
        if isinstance(mesh, _mesh_internal.AbstractMesh) and not mesh.empty:
            return mesh
        physical = _mesh_internal.thread_resources.env.physical_mesh
        if physical is not None and not physical.empty:
            return physical
    return None
