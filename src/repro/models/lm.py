"""Decoder LM assembly: init, train forward, decode step — all 10 families.

Structure: embedding -> ``num_layers`` blocks (scan-over-layers with
per-layer remat) -> final norm -> untied LM head.  Block internals are
family-dispatched:

* ``dense`` / ``vlm`` / ``audio``: GQA attention + MLP variant
* ``moe``: GQA attention + routed experts (+ shared experts)
* ``ssm``: RWKV6 time-mix + RWKV channel-mix
* ``hybrid``: parallel attention (SWA) + mamba heads, then MLP

``vlm``/``audio`` accept precomputed frontend embeddings (the stub) that are
projected and prepended to the token embeddings.

Parameters are stacked ``[L, ...]`` so XLA compiles ONE layer body
regardless of depth — essential for the 512-device dry-run compile times and
for O(1) HLO size on the 96-layer 340B config.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    params: Params = {}
    specs: Params = {}
    ks = jax.random.split(key, 6)
    hd = cfg.resolved_head_dim

    params["ln1"], specs["ln1"] = L.rmsnorm_init(cfg.d_model)
    params["ln2"], specs["ln2"] = L.rmsnorm_init(cfg.d_model)

    if cfg.family in ("dense", "vlm", "audio", "moe", "hybrid"):
        params["attn"], specs["attn"] = L.attention_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd,
            cfg.qkv_bias)
    if cfg.family in ("dense", "vlm", "audio", "hybrid"):
        params["mlp"], specs["mlp"] = L.mlp_init(ks[1], cfg.d_model,
                                                 cfg.d_ff, cfg.activation)
    if cfg.family == "moe":
        params["moe"], specs["moe"] = M.moe_init(
            ks[2], cfg.d_model, cfg.d_ff, cfg.num_experts,
            cfg.num_shared_experts, cfg.activation)
    if cfg.family == "ssm":
        params["tmix"], specs["tmix"] = S.rwkv6_init(
            ks[3], cfg.d_model, cfg.rwkv_num_heads, cfg.rwkv_head_dim)
        params["cmix"], specs["cmix"] = S.rwkv_cmix_init(
            ks[4], cfg.d_model, cfg.d_ff)
    if cfg.family == "hybrid":
        params["mamba"], specs["mamba"] = S.mamba_init(
            ks[5], cfg.d_model, cfg.num_heads * hd, cfg.ssm_state)
    return params, specs


def init_params(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    """Returns (params, partition_specs); layer params stacked [L, ...]."""
    k_embed, k_head, k_layers, k_front = jax.random.split(key, 4)
    scale = (3.0 / cfg.d_model) ** 0.5
    params: Params = {
        "embed": L._uniform(k_embed, (cfg.padded_vocab, cfg.d_model), scale),
        "lm_head": L._uniform(k_head, (cfg.d_model, cfg.padded_vocab), scale),
    }
    specs: Params = {
        "embed": P("model", "data"),      # vocab-sharded (row-parallel)
        "lm_head": P("data", "model"),
    }
    params["ln_f"], specs["ln_f"] = L.rmsnorm_init(cfg.d_model)

    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layer_params = jax.vmap(lambda k: _layer_init(cfg, k)[0])(layer_keys)
    _, layer_specs = _layer_init(cfg, layer_keys[0])
    params["layers"] = layer_params
    specs["layers"] = jax.tree.map(
        lambda spec: P(*((None,) + tuple(spec))), layer_specs,
        is_leaf=lambda x: isinstance(x, P))

    if cfg.frontend is not None:
        params["frontend_proj"] = L._uniform(
            k_front, (cfg.d_model, cfg.d_model), scale)
        specs["frontend_proj"] = P("data", "model")
    return params, specs


def param_shapes(cfg: ModelConfig):
    """Abstract init (no allocation) — used for counts and checkpoints."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k)[0], key)


def param_count(cfg: ModelConfig) -> int:
    import math
    shapes = param_shapes(cfg)
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: routed experts count at top_k/E; everything else fully."""
    shapes = param_shapes(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= int(d)
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if "/moe/w" in keys:  # routed expert tensors [L, E, ...]
            n = n * cfg.top_k // max(cfg.num_experts, 1)
        total += n
    return total


# ---------------------------------------------------------------------------
# train-time block + forward
# ---------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, params: Params, x: jax.Array,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decoder block; returns (x, aux_loss)."""
    hd = cfg.resolved_head_dim
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(params["ln1"], x)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        x = x + L.attention(params["attn"], h, positions,
                            num_heads=cfg.num_heads,
                            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                            rope_theta=cfg.rope_theta,
                            sliding_window=cfg.sliding_window,
                            query_chunk=cfg.attn_query_chunk,
                            swa_banded=cfg.swa_banded,
                            unroll_chunks=cfg.unroll_inner_scans)
    elif cfg.family == "ssm":
        x = x + S.rwkv6_block(params["tmix"], h,
                              num_heads=cfg.rwkv_num_heads,
                              head_dim=cfg.rwkv_head_dim,
                              chunk=cfg.ssm_chunk)
    elif cfg.family == "hybrid":
        attn_out = L.attention(params["attn"], h, positions,
                               num_heads=cfg.num_heads,
                               num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                               rope_theta=cfg.rope_theta,
                               sliding_window=cfg.sliding_window,
                               query_chunk=cfg.attn_query_chunk,
                               swa_banded=cfg.swa_banded,
                               unroll_chunks=cfg.unroll_inner_scans)
        mamba_out = S.mamba_block(params["mamba"], h, chunk=cfg.ssm_chunk)
        x = x + 0.5 * (attn_out + mamba_out)   # parallel heads, mean-fused
    else:
        raise ValueError(cfg.family)

    h2 = L.rmsnorm(params["ln2"], x)
    if cfg.family == "moe":
        out, aux = M.moe(params["moe"], h2, num_experts=cfg.num_experts,
                         top_k=cfg.top_k, num_shared=cfg.num_shared_experts,
                         dispatch=cfg.moe_dispatch,
                         capacity_factor=cfg.capacity_factor,
                         ep_pins=cfg.moe_ep_pins)
        x = x + out
    elif cfg.family == "ssm":
        x = x + S.rwkv_cmix(params["cmix"], h2)
    else:
        x = x + L.mlp(params["mlp"], h2, cfg.activation)
    return x, aux


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jax.Array,
                   prefix_embeds: Optional[jax.Array] = None,
                   dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Backbone forward: returns (final-norm hidden [B,S,D], aux loss)."""
    x = params["embed"].astype(dtype)[tokens]
    if cfg.frontend is not None:
        assert prefix_embeds is not None, f"{cfg.name} needs frontend stub"
        pre = prefix_embeds.astype(dtype) @ params["frontend_proj"].astype(
            dtype)
        x = jnp.concatenate([pre, x], axis=1)
    b, s, _ = x.shape
    # Megatron-SP option: keep saved activations sequence-sharded over TP —
    # shrinks the per-layer remat carries 16x at the cost of per-layer
    # gather/scatter collectives (the nemotron §Perf lever).
    act_spec = ((L.BATCH, L.TP, None) if cfg.seq_sharded_activations
                else (L.BATCH, None, None))
    x = L.maybe_constrain(x, *act_spec)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    positions = L.maybe_constrain(positions, L.BATCH, None)

    def body(carry, layer_params):
        xx, aux = carry
        xx, a = _block_train(cfg, layer_params, xx, positions)
        xx = L.maybe_constrain(xx, *act_spec)
        return (xx, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda p: p[i], params["layers"])
            (x, aux), _ = body((x, aux), layer)

    return L.rmsnorm(params["ln_f"], x), aux


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill forward. tokens: [B, S_tok]; returns (logits, aux)."""
    x, aux = forward_hidden(params, cfg, tokens, prefix_embeds, dtype)
    logits = x @ params["lm_head"].astype(dtype)
    return logits, aux


def _ce_terms(cfg: ModelConfig, head: jax.Array, hidden: jax.Array,
              labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(sum NLL, token count) for one hidden chunk [B, s, D]."""
    logits = (hidden @ head).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab padding columns
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.float32(-1e30))
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    return ((logz - gold) * mask).sum(), mask.sum()


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (+0.01 aux).  batch: tokens [B,S], labels [B,S]
    (-1 = masked), optional prefix_embeds [B,Lf,D].

    ``cfg.loss_seq_chunk``: the [B, S, V] fp32 logits tensor is never
    materialized — CE runs per sequence chunk under remat (logits are
    recomputed in the backward), the big-vocab §Perf lever."""
    hidden, aux = forward_hidden(params, cfg, batch["tokens"],
                                 batch.get("prefix_embeds"), dtype=dtype)
    if cfg.frontend is not None:   # prefix positions predict nothing
        hidden = hidden[:, cfg.frontend_len:]
    labels = batch["labels"]
    head = params["lm_head"].astype(dtype)

    ck = cfg.loss_seq_chunk
    s = hidden.shape[1]
    if ck and s % ck == 0 and s > ck:
        nc = s // ck
        h_c = hidden.reshape(hidden.shape[0], nc, ck, -1).swapaxes(0, 1)
        l_c = labels.reshape(labels.shape[0], nc, ck).swapaxes(0, 1)

        @jax.checkpoint
        def piece(carry, inp):
            h, l = inp
            nll, cnt = _ce_terms(cfg, head, h, l)
            return (carry[0] + nll, carry[1] + cnt), None

        if cfg.unroll_inner_scans:  # roofline units: count all chunks
            carry = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
            for i in range(nc):
                carry, _ = piece(carry, (h_c[i], l_c[i]))
            nll_sum, count = carry
        else:
            (nll_sum, count), _ = jax.lax.scan(
                piece,
                (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                (h_c, l_c))
    else:
        nll_sum, count = _ce_terms(cfg, head, hidden, labels)

    loss = nll_sum / jnp.maximum(count, 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux, "ntokens": count}


# ---------------------------------------------------------------------------
# prefill: forward + cache emission (inference-prefill shape cells)
# ---------------------------------------------------------------------------

def _emit_kv_cache(k: jax.Array, cache_len: int) -> jax.Array:
    """Ring-align prefill K (or V) [B, S, H, hd] into a [B, cache_len, ...]
    decode cache: position p lives at slot p % cache_len."""
    b, s = k.shape[:2]
    if cache_len >= s:  # identity slots, zero-pad the unwritten tail
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, cache_len - s)
        return jnp.pad(k, pad)
    tail = k[:, s - cache_len:]          # positions s-cache_len .. s-1
    return jnp.roll(tail, s % cache_len, axis=1)


def _block_prefill(cfg: ModelConfig, params: Params, x: jax.Array,
                   positions: jax.Array, cache_len: int):
    """Like _block_train but also emits this layer's decode cache."""
    hd = cfg.resolved_head_dim
    cache = {}
    h = L.rmsnorm(params["ln1"], x)
    if cfg.family in ("dense", "vlm", "audio", "moe", "hybrid"):
        attn_out, k, v = L.attention(
            params["attn"], h, positions, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window,
            query_chunk=cfg.attn_query_chunk, swa_banded=cfg.swa_banded,
            unroll_chunks=cfg.unroll_inner_scans, return_kv=True)
        win = min(cache_len, cfg.sliding_window or cache_len)
        cache["k"] = _emit_kv_cache(k, win)
        cache["v"] = _emit_kv_cache(v, win)
    if cfg.family == "ssm":
        tout, (xp, wkv) = S.rwkv6_block(
            params["tmix"], h, num_heads=cfg.rwkv_num_heads,
            head_dim=cfg.rwkv_head_dim, chunk=cfg.ssm_chunk,
            return_state=True)
        cache["wkv"], cache["xprev_t"] = wkv, xp
        x = x + tout
    elif cfg.family == "hybrid":
        mout, hstate = S.mamba_block(params["mamba"], h, chunk=cfg.ssm_chunk,
                                     return_state=True)
        cache["h"] = hstate
        x = x + 0.5 * (attn_out + mout)
    else:
        x = x + attn_out

    h2 = L.rmsnorm(params["ln2"], x)
    if cfg.family == "moe":
        out, _ = M.moe(params["moe"], h2, num_experts=cfg.num_experts,
                       top_k=cfg.top_k, num_shared=cfg.num_shared_experts,
                       dispatch=cfg.moe_dispatch,
                       capacity_factor=cfg.capacity_factor,
                       ep_pins=cfg.moe_ep_pins)
        x = x + out
    elif cfg.family == "ssm":
        cout, xpc = S.rwkv_cmix(params["cmix"], h2, return_state=True)
        cache["xprev_c"] = xpc
        x = x + cout
    else:
        x = x + L.mlp(params["mlp"], h2, cfg.activation)
    return x, cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None, dtype=jnp.bfloat16,
            cache_len: Optional[int] = None):
    """Inference prefill: consume the prompt, return (last-position logits
    [B, 1, V], stacked decode caches sized for ``cache_len`` total
    positions).  Only the final position's logits are materialized — never
    the [B, S, V] tensor."""
    x = params["embed"].astype(dtype)[tokens]
    if cfg.frontend is not None:
        assert prefix_embeds is not None
        pre = prefix_embeds.astype(dtype) @ params["frontend_proj"].astype(
            dtype)
        x = jnp.concatenate([pre, x], axis=1)
    b, s, _ = x.shape
    if cache_len is None:
        cache_len = s
    x = L.maybe_constrain(x, L.BATCH, None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    positions = L.maybe_constrain(positions, L.BATCH, None)

    def body(x, layer_params):
        x, cache = _block_prefill(cfg, layer_params, x, positions, cache_len)
        x = L.maybe_constrain(x, L.BATCH, None, None)
        cache = jax.tree.map(
            lambda c: c if c.dtype == jnp.float32 else c.astype(dtype), cache)
        return x, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:  # unrolled (used by the roofline unit compiles)
        cache_list = []
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda p: p[i], params["layers"])
            x, c = body(x, layer)
            cache_list.append(c)
        caches = jax.tree.map(lambda *cs: jnp.stack(cs), *cache_list)
    x = L.rmsnorm(params["ln_f"], x[:, -1:])
    logits = x @ params["lm_head"].astype(dtype)
    return logits, caches


# ---------------------------------------------------------------------------
# decode: cache init + one-token step
# ---------------------------------------------------------------------------

def cache_shape(cfg: ModelConfig, batch: int, seq_len: int,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract KV/state cache for ``input_specs`` (no allocation)."""
    hd = cfg.resolved_head_dim
    ca: Dict[str, Any] = {}
    lcfg = cfg.num_layers
    if cfg.family in ("dense", "vlm", "audio", "moe", "hybrid"):
        s_cache = min(seq_len, cfg.sliding_window or seq_len)
        ca["k"] = jax.ShapeDtypeStruct(
            (lcfg, batch, s_cache, cfg.num_kv_heads, hd), dtype)
        ca["v"] = jax.ShapeDtypeStruct(
            (lcfg, batch, s_cache, cfg.num_kv_heads, hd), dtype)
    if cfg.family == "ssm":
        h, k = cfg.rwkv_num_heads, cfg.rwkv_head_dim
        ca["wkv"] = jax.ShapeDtypeStruct((lcfg, batch, h, k, k), jnp.float32)
        ca["xprev_t"] = jax.ShapeDtypeStruct((lcfg, batch, 1, cfg.d_model),
                                             dtype)
        ca["xprev_c"] = jax.ShapeDtypeStruct((lcfg, batch, 1, cfg.d_model),
                                             dtype)
    if cfg.family == "hybrid":
        ca["h"] = jax.ShapeDtypeStruct(
            (lcfg, batch, cfg.num_heads * hd, cfg.ssm_state), jnp.float32)
    return ca


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_shape(cfg, batch, seq_len, dtype))


def _block_decode(cfg: ModelConfig, params: Params, x: jax.Array,
                  pos: jax.Array, cache: Dict[str, jax.Array]):
    hd = cfg.resolved_head_dim
    new_cache = {}
    h = L.rmsnorm(params["ln1"], x)
    if cfg.family in ("dense", "vlm", "audio", "moe", "hybrid"):
        attn_out, nk, nv = L.attention_decode(
            params["attn"], h, pos, cache["k"], cache["v"],
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=hd, rope_theta=cfg.rope_theta,
            sliding_window=cfg.sliding_window)
        new_cache["k"], new_cache["v"] = nk, nv
    if cfg.family == "ssm":
        tout, (xp, wkv) = S.rwkv6_block(
            params["tmix"], h, num_heads=cfg.rwkv_num_heads,
            head_dim=cfg.rwkv_head_dim, use_chunked=False,
            x_prev=cache["xprev_t"], state=cache["wkv"], return_state=True)
        new_cache["wkv"], new_cache["xprev_t"] = wkv, xp.astype(
            cache["xprev_t"].dtype)
        x = x + tout
    elif cfg.family == "hybrid":
        mout, hstate = S.mamba_block(params["mamba"], h, use_chunked=False,
                                     state=cache["h"], return_state=True)
        new_cache["h"] = hstate
        x = x + 0.5 * (attn_out + mout)
    else:
        x = x + attn_out

    h2 = L.rmsnorm(params["ln2"], x)
    if cfg.family == "moe":
        out, _ = M.moe(params["moe"], h2, num_experts=cfg.num_experts,
                       top_k=cfg.top_k, num_shared=cfg.num_shared_experts,
                       dispatch=cfg.moe_dispatch,
                       capacity_factor=cfg.capacity_factor,
                       ep_pins=cfg.moe_ep_pins)
        x = x + out
    elif cfg.family == "ssm":
        cout, xpc = S.rwkv_cmix(params["cmix"], h2, x_prev=cache["xprev_c"],
                                return_state=True)
        new_cache["xprev_c"] = xpc.astype(cache["xprev_c"].dtype)
        x = x + cout
    else:
        x = x + L.mlp(params["mlp"], h2, cfg.activation)
    return x, new_cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                pos: jax.Array, cache: Dict[str, jax.Array],
                dtype=jnp.bfloat16):
    """One-token decode. tokens: [B, 1]; pos: scalar int32 (batch-synced).
    Returns (logits [B, 1, V], new_cache)."""
    x = params["embed"].astype(dtype)[tokens]

    def body(x, inp):
        layer_params, layer_cache = inp
        x, new_cache = _block_decode(cfg, layer_params, x, pos, layer_cache)
        return x, new_cache

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:  # unrolled (used by the roofline unit compiles)
        caches = []
        for i in range(cfg.num_layers):
            inp = jax.tree.map(lambda p: p[i], (params["layers"], cache))
            x, c = body(x, inp)
            caches.append(c)
        new_cache = jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
    x = L.rmsnorm(params["ln_f"], x)
    logits = x @ params["lm_head"].astype(dtype)
    return logits, new_cache
