"""TreeLSTM-lite: recursive tree evaluation as wavefront-scheduled GEMMs.

The classic TreeLSTM evaluates a parse tree by per-node recursion —
each node waits for its children, then runs a small dense cell.  That
recursion is exactly the workload :mod:`repro.sparse.wavefront`
schedules: children point at their parent in the dependency CSR, every
tree level is a frontier, and the whole forest's cells at one level run
as ONE balanced segmented matmul (grouped by operator).  This module is
the deliberately small reference model wired to that scheduler — a
gated-combine cell, not the full four-gate LSTM, because the point is
the scheduling contract, not SOTA parsing:

    h[v] = tanh((x[v] + sum of h[children]) @ W[op[v]] + b[op[v]])

Ops distinguish node types (e.g. leaf token vs internal composition, or
per-syntactic-category weights); widths stay square so composition
feeds back through the same combine.  Ragged forests batch with
:func:`repro.sparse.wavefront.pack_forest` — one padded DAG, one
wavefront, every tree in the batch advancing together.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.graph import Graph
from repro.sparse.wavefront import (PackedForest, WavefrontPlan,
                                    build_wavefront, pack_forest,
                                    wavefront_eval)


def init_treelstm(key: jax.Array, feat: int, num_ops: int = 2) -> dict:
    """Per-op square weight stack + bias; scaled for tanh stability."""
    wkey, bkey = jax.random.split(key)
    scale = 1.0 / np.sqrt(feat)
    return {
        "w": jax.random.normal(wkey, (num_ops, feat, feat),
                               jnp.float32) * scale,
        "b": jax.random.normal(bkey, (num_ops, feat), jnp.float32) * 0.1,
    }


def treelstm_embed(params: dict, wplan: WavefrontPlan, x: jax.Array,
                   op_of_node: jax.Array, *,
                   activation="tanh") -> jax.Array:
    """Every node's embedding, children-before-parents, level-batched.

    Thin wrapper over :func:`~repro.sparse.wavefront.wavefront_eval`:
    the dependency combine and the level GEMM both ride ``wplan``'s
    schedule choice.  ``activation`` is swappable so the conformance
    tests can pin an exact activation while the model default stays
    ``tanh``.
    """
    return wavefront_eval(wplan, x, op_of_node, params["w"],
                          bias=params["b"], activation=activation)


def tree_roots(wplan: WavefrontPlan) -> np.ndarray:
    """Node ids with no outgoing dependency edge — the per-tree results
    (for child->parent trees, each tree's root; host-side, like every
    inspector product)."""
    out_deg = np.asarray(wplan.plan.out_degrees)
    return np.flatnonzero(out_deg == 0)


def treelstm_forest(params: dict,
                    trees: Sequence[Union[Graph, "object"]],
                    x: jax.Array, op_of_node: jax.Array, *,
                    schedule="auto", num_rows: Optional[int] = None,
                    activation="tanh"):
    """Embed a ragged forest in one wavefront: pack, inspect, evaluate.

    ``x``/``op_of_node`` are concatenated over the forest in
    ``pack_forest``'s node order.  Returns ``(root_embeddings [T, F],
    packed)`` — one embedding per tree, plus the :class:`PackedForest`
    for callers that want per-node states or the row split.
    """
    packed = pack_forest(trees, num_rows=num_rows)
    wplan = build_wavefront(packed.dag, schedule=schedule)
    h = treelstm_embed(params, wplan, x, op_of_node,
                       activation=activation)
    roots = tree_roots(wplan)
    # one root per tree for child->parent trees; guard loudly otherwise
    if roots.size != packed.num_trees:
        raise ValueError(
            f"forest has {roots.size} dependency sinks for "
            f"{packed.num_trees} trees; treelstm_forest expects "
            f"child->parent trees (exactly one root each)")
    return h[jnp.asarray(roots)], packed
