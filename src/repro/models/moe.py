"""Mixture-of-Experts with load-balanced dispatch.

Routing is the canonical irregular workload inside an LM: after top-k, the
(token, expert) pairs are **atoms** and experts are **tiles** of wildly
different sizes.  Two dispatch executors, same router:

* ``dispatch="capacity"`` — dense one-hot/einsum dispatch with a capacity
  factor (Shazeer-style).  Fully static, shards over the mesh (experts on the
  TP axis -> GSPMD emits the expert-parallel all_to_all).  This is the path
  the multi-pod dry-run lowers.
* ``dispatch="sorted"`` — the paper's schedule: sort atoms by tile, pad
  groups to M-blocks, run the balanced Pallas segmented GEMM
  (:mod:`repro.kernels.segmm`).  No token dropping, perfectly balanced
  blocks; validated against the capacity path at capacity -> inf.

Aux losses: standard load-balancing loss (mean gate fraction x mean route
fraction) + router z-loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (BATCH, FSDP, TP, _uniform, gather_in,
                                 gather_out, maybe_constrain)

Params = Dict[str, Any]

# Expert-parallel axis: experts live on the TP axis of the mesh.
EP = TP


def moe_init(key, d_model: int, d_ff: int, num_experts: int,
             num_shared: int, activation: str):
    ks = jax.random.split(key, 7)
    scale = (3.0 / d_model) ** 0.5
    fscale = (3.0 / d_ff) ** 0.5
    params: Params = {
        "router": _uniform(ks[0], (d_model, num_experts), scale),
        "w1": _uniform(ks[1], (num_experts, d_model, d_ff), scale),
        "w3": _uniform(ks[2], (num_experts, d_model, d_ff), scale),
        "w2": _uniform(ks[3], (num_experts, d_ff, d_model), fscale),
    }
    specs = {
        "router": P(None, None),
        "w1": P(EP, FSDP, None), "w3": P(EP, FSDP, None),
        "w2": P(EP, None, FSDP),
    }
    if num_shared > 0:
        params.update({
            "sw1": _uniform(ks[4], (d_model, num_shared * d_ff), scale),
            "sw3": _uniform(ks[5], (d_model, num_shared * d_ff), scale),
            "sw2": _uniform(ks[6], (num_shared * d_ff, d_model), fscale),
        })
        specs.update({"sw1": P(FSDP, TP), "sw3": P(FSDP, TP),
                      "sw2": P(TP, FSDP)})
    del activation  # experts are silu_glu in both assigned MoE archs
    return params, specs


def _router(params: Params, x2d: jax.Array, num_experts: int, top_k: int):
    """Returns (topk_idx [T,k], topk_w [T,k], aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)

    # load-balance loss (Switch): E * sum_e f_e * p_e
    route_frac = jnp.mean(
        jax.nn.one_hot(topk_idx, num_experts, dtype=jnp.float32), axis=(0, 1))
    gate_frac = jnp.mean(probs, axis=0)
    lb_loss = num_experts * jnp.sum(route_frac * gate_frac)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return topk_idx, topk_w, lb_loss + 1e-3 * z_loss


def _expert_ffn(w1, w3, w2, h):
    return (jax.nn.silu(h @ w1) * (h @ w3)) @ w2


def moe_capacity_einsum(params: Params, x: jax.Array, *, num_experts: int,
                        top_k: int, capacity_factor: float = 1.25,
                        ) -> Tuple[jax.Array, jax.Array]:
    """Dense one-hot/einsum dispatch (Shazeer-style reference).

    O(T * E * C) memory — only viable at smoke scale; kept as the executable
    specification that the production sort-based dispatch is tested against.
    """
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    topk_idx, topk_w, aux = _router(params, x2d, num_experts, top_k)

    capacity = max(int(capacity_factor * t * top_k / num_experts), 1)
    # position of each (token, k) atom within its expert's queue
    onehot = jax.nn.one_hot(topk_idx, num_experts, dtype=jnp.int32)  # [T,k,E]
    flat = onehot.reshape(t * top_k, num_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(
        t, top_k, num_experts)
    within = pos_in_expert < capacity
    # dispatch tensor [T, E, C] (bool -> dtype); combine with router weights
    pos_oh = jax.nn.one_hot(jnp.sum(pos_in_expert * onehot, -1), capacity,
                            dtype=x.dtype)                     # [T, k, C]
    keep = (jnp.sum(onehot * within, -1) > 0).astype(x.dtype)  # [T, k]
    disp = jnp.einsum("tke,tkc,tk->tec", onehot.astype(x.dtype), pos_oh, keep)
    comb = jnp.einsum("tke,tkc,tk,tk->tec", onehot.astype(x.dtype), pos_oh,
                      keep, topk_w.astype(x.dtype))

    xe = jnp.einsum("td,tec->ecd", x2d, disp)                  # [E, C, D]
    he = jax.vmap(_expert_ffn)(params["w1"].astype(x.dtype),
                               params["w3"].astype(x.dtype),
                               params["w2"].astype(x.dtype), xe)
    out = jnp.einsum("ecd,tec->td", he, comb)
    return out.reshape(b, s, d), aux


def moe_capacity(params: Params, x: jax.Array, *, num_experts: int,
                 top_k: int, capacity_factor: float = 1.25,
                 ep_pins: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch — the production/distributed path.

    The paper's schedule vocabulary at chip granularity: atoms = routed
    (token, k) pairs, tiles = experts.  Atoms are *sorted by tile* (one
    argsort), each atom's rank within its tile computed from the tile
    offsets (group-mapped prefix-sum binning), then scattered into the
    static ``[E, C, D]`` expert buffer; rank >= C drops (capacity).  Memory
    is O(T*D + E*C*D) — no [T, E, C] one-hot — and with experts sharded over
    the ``model`` axis GSPMD turns the scatter/gather into the
    expert-parallel all_to_all.
    """
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    topk_idx, topk_w, aux = _router(params, x2d, num_experts, top_k)
    capacity = max(int(capacity_factor * t * top_k / num_experts), 1)

    ta = t * top_k
    atom_expert = topk_idx.reshape(ta)
    atom_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    atom_w = topk_w.reshape(ta)

    order = jnp.argsort(atom_expert)                    # sort atoms by tile
    sizes = jnp.bincount(atom_expert, length=num_experts)
    offsets = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                               jnp.cumsum(sizes)])
    sorted_e = atom_expert[order]
    rank = jnp.arange(ta, dtype=jnp.int32) - offsets[sorted_e].astype(
        jnp.int32)                                       # rank within expert
    kept = rank < capacity
    slot = jnp.where(kept, sorted_e * capacity + rank, num_experts * capacity)

    xe_flat = jnp.zeros((num_experts * capacity + 1, d), x.dtype)
    xe_flat = xe_flat.at[slot].set(x2d[atom_token[order]], mode="drop")
    xe = xe_flat[:-1].reshape(num_experts, capacity, d)
    if ep_pins:
        # pin the expert buffer to the EP axis (measured on the 16x16 mesh:
        # REGRESSION — GSPMD replicates the scatter source; kept switchable,
        # see EXPERIMENTS.md §Perf cell B iteration log)
        xe = maybe_constrain(xe, EP, None, None)

    he = jax.vmap(_expert_ffn)(params["w1"].astype(x.dtype),
                               params["w3"].astype(x.dtype),
                               params["w2"].astype(x.dtype), xe)
    if ep_pins:
        he = maybe_constrain(he, EP, None, None)

    he_flat = jnp.concatenate(
        [he.reshape(num_experts * capacity, d),
         jnp.zeros((1, d), he.dtype)], axis=0)
    out_atoms = he_flat[slot] * (atom_w[order] * kept)[:, None].astype(
        he.dtype)
    out = jax.ops.segment_sum(out_atoms, atom_token[order], num_segments=t)
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_sorted(params: Params, x: jax.Array, *, num_experts: int, top_k: int,
               bm: int = 128, schedule: str = "group_mapped",
               execution_path: str = "auto",
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """The paper's load-balanced dispatch: sort atoms by tile, pad to
    M-blocks, balanced segmented GEMM.  Drop-free.

    ``schedule``: segmm block-order policy (``"group_mapped"``,
    ``"chunked_rr"``, ``"chunked_lpt"``) or ``"auto"`` — the cost-model
    autotuner inspects the concrete routing (atoms = routed pairs, tiles =
    experts) and picks; under jit the routing is traced, so ``"auto"``
    resolves to the static default (see ``repro.kernels.segmm.ops``).
    ``execution_path`` routes the chunked policies through the
    :mod:`repro.core.execute` dispatcher: ``"native"``/``"auto"`` walk the
    expert M-blocks inside the chunk-walking Pallas kernel, ``"pure"``
    keeps the host-permuted fallback.
    """
    from repro.kernels.segmm import ops as segmm_ops

    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    topk_idx, topk_w, aux = _router(params, x2d, num_experts, top_k)

    # atoms = (token, k) pairs
    atom_expert = topk_idx.reshape(t * top_k).astype(jnp.int32)
    atom_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    atoms_in = x2d[atom_token]                              # [T*k, D]

    if schedule == "auto":
        # one inspection serves all three GEMMs (same routing).  Measured
        # mode (REPRO_AUTOTUNE_MEASURE=1, docs/autotune.md) times the
        # candidate policies on the first GEMM's actual operands — the
        # other two share its routing, so one measured record covers all.
        measure = None
        if not isinstance(atom_expert, jax.core.Tracer):
            from repro.core.autotune import measurement_enabled
            if measurement_enabled():
                import functools

                from repro.core.measure import time_fn

                def measure(plan):
                    policy, p = segmm_ops.plan_policy(plan)
                    f = functools.partial(
                        segmm_ops.grouped_matmul, num_experts=num_experts,
                        bm=bm, schedule=policy, execution_path=p,
                        interpret=interpret)
                    return time_fn(f, atoms_in, atom_expert, params["w1"],
                                   warmup=1, iters=3)
        schedule = segmm_ops.resolve_schedule(atom_expert, num_experts,
                                              measure=measure)

    h1 = segmm_ops.grouped_matmul(atoms_in, atom_expert, params["w1"],
                                  num_experts=num_experts, bm=bm,
                                  schedule=schedule,
                                  execution_path=execution_path,
                                  interpret=interpret)
    h3 = segmm_ops.grouped_matmul(atoms_in, atom_expert, params["w3"],
                                  num_experts=num_experts, bm=bm,
                                  schedule=schedule,
                                  execution_path=execution_path,
                                  interpret=interpret)
    h = jax.nn.silu(h1) * h3
    out_atoms = segmm_ops.grouped_matmul(h.astype(x.dtype), atom_expert,
                                         params["w2"],
                                         num_experts=num_experts, bm=bm,
                                         schedule=schedule,
                                         execution_path=execution_path,
                                         interpret=interpret)
    weighted = out_atoms * topk_w.reshape(t * top_k, 1)
    out = jax.ops.segment_sum(weighted, atom_token, num_segments=t)
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_capacity_grouped(params: Params, x: jax.Array, *, num_experts: int,
                         top_k: int, capacity_factor: float = 1.25,
                         ) -> Tuple[jax.Array, jax.Array]:
    """Per-row (grouped) capacity dispatch — the distributed-scale schedule.

    The flat sort-based dispatch sorts ALL tokens globally; under GSPMD a
    batch-sharded global argsort becomes a distributed sort (measured:
    192 GiB/device of collective-permute traffic on olmoe train_4k).  The
    paper's locality lesson at chip granularity: partition the atoms by
    *row* (tiles = experts per row), sort each row locally — the sorts are
    vmapped over the batch dim, which is batch-sharded, so they never cross
    a chip — and let only the routed activations move when the expert einsum
    contracts against the expert-sharded weights.  Capacity is per row
    (ceil(cf * S * k / E)); drop-free at cf -> inf like the flat version.
    """
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    topk_idx, topk_w, aux = _router(params, x2d, num_experts, top_k)
    capacity = max(int(capacity_factor * s * top_k / num_experts), 1)

    sk = s * top_k
    atom_expert = topk_idx.reshape(b, sk)
    atom_w = topk_w.reshape(b, sk)
    atom_token = jnp.repeat(jnp.arange(s, dtype=jnp.int32), top_k)  # per row

    order = jnp.argsort(atom_expert, axis=1)               # local, vmapped
    sorted_e = jnp.take_along_axis(atom_expert, order, axis=1)
    sizes = jax.vmap(lambda e: jnp.bincount(e, length=num_experts)
                     )(atom_expert)                         # [B, E]
    offsets = jnp.concatenate(
        [jnp.zeros((b, 1), sizes.dtype), jnp.cumsum(sizes, axis=1)], axis=1)
    rank = (jnp.arange(sk, dtype=jnp.int32)[None]
            - jnp.take_along_axis(offsets, sorted_e, axis=1).astype(
                jnp.int32))
    kept = rank < capacity
    slot = jnp.where(kept, sorted_e * capacity + rank,
                     num_experts * capacity)                # [B, Sk]

    x3d = x2d.reshape(b, s, d)
    tok_sorted = jnp.take_along_axis(
        jnp.broadcast_to(atom_token[None], (b, sk)), order, axis=1)
    gathered = jnp.take_along_axis(x3d, tok_sorted[..., None],
                                   axis=1)                  # [B, Sk, D]

    def scatter_row(slots, vals):
        buf = jnp.zeros((num_experts * capacity + 1, d), vals.dtype)
        return buf.at[slots].set(vals, mode="drop")

    xe = jax.vmap(scatter_row)(slot, gathered)[:, :-1].reshape(
        b, num_experts, capacity, d)                        # [B, E, C, D]
    xe = maybe_constrain(xe, BATCH, EP, None, None)

    w1 = params["w1"].astype(x.dtype)
    w3 = params["w3"].astype(x.dtype)
    w2 = params["w2"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w1)) * jnp.einsum(
        "becd,edf->becf", xe, w3)
    he = jnp.einsum("becf,efd->becd", h, w2)                # [B, E, C, D]
    he = maybe_constrain(he, BATCH, EP, None, None)

    he_flat = jnp.concatenate(
        [he.reshape(b, num_experts * capacity, d),
         jnp.zeros((b, 1, d), he.dtype)], axis=1)
    out_atoms = jnp.take_along_axis(he_flat, slot[..., None], axis=1)
    w_sorted = jnp.take_along_axis(atom_w, order, axis=1)
    out_atoms = out_atoms * (w_sorted * kept)[..., None].astype(he.dtype)
    out = jax.vmap(lambda v, t: jax.ops.segment_sum(v, t, num_segments=s)
                   )(out_atoms, tok_sorted)
    return out.astype(x.dtype), aux


def moe_shared(params: Params, x: jax.Array) -> jax.Array:
    """Shared experts (DeepSeekMoE): a dense gated MLP every token visits."""
    h = jax.nn.silu(x @ gather_in(params["sw1"], x.dtype)) * (
        x @ gather_in(params["sw3"], x.dtype))
    return h @ gather_out(params["sw2"], x.dtype)


def moe(params: Params, x: jax.Array, *, num_experts: int, top_k: int,
        num_shared: int, dispatch: str = "capacity",
        capacity_factor: float = 1.25, schedule: str = "group_mapped",
        execution_path: str = "auto",
        ep_pins: bool = False) -> Tuple[jax.Array, jax.Array]:
    if dispatch == "capacity":
        out, aux = moe_capacity(params, x, num_experts=num_experts,
                                top_k=top_k, capacity_factor=capacity_factor,
                                ep_pins=ep_pins)
    elif dispatch == "grouped":
        out, aux = moe_capacity_grouped(params, x, num_experts=num_experts,
                                        top_k=top_k,
                                        capacity_factor=capacity_factor)
    elif dispatch == "sorted":
        out, aux = moe_sorted(params, x, num_experts=num_experts,
                              top_k=top_k, schedule=schedule,
                              execution_path=execution_path)
    else:
        raise ValueError(dispatch)
    if num_shared > 0:
        out = out + moe_shared(params, x)
    return out, aux
