"""Transformer building blocks: norms, RoPE, GQA attention, MLP variants.

Pure-function style: parameters are nested dicts of arrays, every block is
``apply(params, x, ...) -> y``.  Initializers return ``(params, specs)``
pairs where ``specs`` mirrors the param tree with ``PartitionSpec``s — the
distribution layer (``repro.train.step``) consumes them for FSDP x TP
sharding without the model code knowing about meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_ambient_mesh

Params = Dict[str, Any]

# Sharding axis names (see repro.launch.mesh): "data" = FSDP axis,
# "model" = tensor-parallel axis.  "pod" only shards the batch.
FSDP = "data"
TP = "model"
BATCH = ("pod", "data")


def maybe_constrain(x: jax.Array, *spec) -> jax.Array:
    """Sharding-constrain ``x`` against the ambient mesh (jax.set_mesh).

    No-op when no mesh is active (single-device tests).  Axis names absent
    from the ambient mesh are dropped, so the same annotations serve the
    (data, model) and (pod, data, model) production meshes.  These pins
    matter: GSPMD drops the batch sharding on mask/select chains built from
    iota (a measured 15x per-device blow-up of attention logits).
    """
    mesh = get_ambient_mesh()
    if mesh is None or mesh.empty:
        return x
    cleaned = []
    for a in spec:
        if isinstance(a, tuple):
            keep = tuple(x_ for x_ in a if x_ in mesh.axis_names)
            cleaned.append(keep if keep else None)
        else:
            cleaned.append(a if a is None or a in mesh.axis_names else None)
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": P(None)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (full / sliding-window, optional QKV bias, KV cache decode)
# ---------------------------------------------------------------------------

def _uniform(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def gather_in(w: jax.Array, dtype) -> jax.Array:
    """ZeRO-3 gather-at-use for a [in, out] matrix sharded P(FSDP, TP):
    all-gather the FSDP axis (in bf16) right before the matmul.  Without
    this pin GSPMD may instead partial-sum the *activations* over the data
    axis — measured 10 GiB/layer f32 all-reduces on danube prefill vs the
    ~0.04 GiB weight gather."""
    return maybe_constrain(w.astype(dtype), None, TP)


def gather_out(w: jax.Array, dtype) -> jax.Array:
    """Same for [in, out] matrices sharded P(TP, FSDP)."""
    return maybe_constrain(w.astype(dtype), TP, None)


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qkv_bias: bool):
    ks = jax.random.split(key, 4)
    scale = (3.0 / d_model) ** 0.5
    params = {
        "wq": _uniform(ks[0], (d_model, num_heads * head_dim), scale),
        "wk": _uniform(ks[1], (d_model, num_kv_heads * head_dim), scale),
        "wv": _uniform(ks[2], (d_model, num_kv_heads * head_dim), scale),
        "wo": _uniform(ks[3], (num_heads * head_dim, d_model), scale),
    }
    specs = {
        "wq": P(FSDP, TP), "wk": P(FSDP, TP), "wv": P(FSDP, TP),
        "wo": P(TP, FSDP),
    }
    if qkv_bias:
        params.update({
            "bq": jnp.zeros((num_heads * head_dim,), jnp.float32),
            "bk": jnp.zeros((num_kv_heads * head_dim,), jnp.float32),
            "bv": jnp.zeros((num_kv_heads * head_dim,), jnp.float32),
        })
        specs.update({"bq": P(TP), "bk": P(TP), "bv": P(TP)})
    return params, specs


def _qkv(params: Params, x: jax.Array, num_heads: int, num_kv_heads: int,
         head_dim: int):
    b, s, _ = x.shape
    q = x @ gather_in(params["wq"], x.dtype)
    k = x @ gather_in(params["wk"], x.dtype)
    v = x @ gather_in(params["wv"], x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv_heads, head_dim)
    v = v.reshape(b, s, num_kv_heads, head_dim)
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, groups, axis=2)


def _attend(q, k, v, qpos, kpos, scale, sliding_window):
    """Masked softmax attention core. q:[B,Sq,H,hd], k/v:[B,Sk,H,hd]."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = maybe_constrain(logits, BATCH, TP, None, None)
    i = qpos[:, None, :, None]
    j = kpos[:, None, None, :]
    mask = j <= i
    if sliding_window is not None:
        mask = jnp.logical_and(mask, j > i - sliding_window)
    logits = jnp.where(mask, logits, jnp.float32(-1e30))
    logits = maybe_constrain(logits, BATCH, TP, None, None)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention(params: Params, x: jax.Array, positions: jax.Array, *,
              num_heads: int, num_kv_heads: int, head_dim: int,
              rope_theta: float, sliding_window: Optional[int] = None,
              query_chunk: Optional[int] = None, swa_banded: bool = False,
              unroll_chunks: bool = False, return_kv: bool = False):
    """Training/prefill causal self-attention. x: [B, S, D].

    ``query_chunk``: flash-style blocking — scores are materialized one
    ``[B, H, qc, S]`` block at a time under ``lax.scan`` instead of the full
    ``[B, H, S, S]``, bounding the transient memory at long context
    (the §Perf "chunked attention" lever).

    ``swa_banded`` (+``query_chunk`` +``sliding_window``): each query chunk
    attends only to its ``[chunk_start - window, chunk_end)`` KV band —
    compute AND memory drop from O(S^2) to O(S * (window + qc)), the banded
    sliding-window schedule (§Perf lever for the SWA archs).

    ``return_kv`` additionally returns the roped (k, v) for prefill cache
    emission.
    """
    b, s, d_model = x.shape
    q, k, v = _qkv(params, x, num_heads, num_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    groups = num_heads // num_kv_heads
    kk = _repeat_kv(k, groups)
    vv = _repeat_kv(v, groups)
    scale = head_dim ** -0.5

    banded = (swa_banded and sliding_window is not None
              and query_chunk is not None
              and s > query_chunk + sliding_window)
    if query_chunk is None or s <= query_chunk:
        out = _attend(q, kk, vv, positions, positions, scale, sliding_window)
    else:
        assert s % query_chunk == 0, (s, query_chunk)
        nq = s // query_chunk
        q_blocks = q.reshape(b, nq, query_chunk, num_heads, head_dim
                             ).swapaxes(0, 1)
        p_blocks = positions.reshape(b, nq, query_chunk).swapaxes(0, 1)

        if banded:
            band = query_chunk + sliding_window

            def blk(_, inp):
                qb, pb, i = inp
                start = jnp.clip(i * query_chunk - sliding_window, 0,
                                 s - band)
                kb = jax.lax.dynamic_slice_in_dim(kk, start, band, axis=1)
                vb = jax.lax.dynamic_slice_in_dim(vv, start, band, axis=1)
                pkb = jax.lax.dynamic_slice_in_dim(positions, start, band,
                                                   axis=1)
                return None, _attend(qb, kb, vb, pb, pkb, scale,
                                     sliding_window)

            xs = (q_blocks, p_blocks, jnp.arange(nq, dtype=jnp.int32))
        else:
            def blk(_, inp):
                qb, pb = inp
                return None, _attend(qb, kk, vv, pb, positions, scale,
                                     sliding_window)

            xs = (q_blocks, p_blocks)
        if unroll_chunks:  # roofline units: count every chunk's flops
            outs = [blk(None, jax.tree.map(lambda a: a[i], xs))[1]
                    for i in range(nq)]
            out_blocks = jnp.stack(outs)
        else:
            _, out_blocks = jax.lax.scan(blk, None, xs)
        out = out_blocks.swapaxes(0, 1).reshape(b, s, num_heads, head_dim)

    out = out.reshape(b, s, num_heads * head_dim) @ gather_out(
        params["wo"], x.dtype)
    if return_kv:
        return out, k, v
    return out


def attention_decode(params: Params, x: jax.Array, pos: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array, *,
                     num_heads: int, num_kv_heads: int, head_dim: int,
                     rope_theta: float, sliding_window: Optional[int] = None,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode step with a static-length KV cache.

    x: [B, 1, D]; pos: scalar int32 (current position, same for the batch);
    cache_k/v: [B, S_cache, Hkv, hd].  With ``sliding_window`` the cache is a
    ring buffer of length ``min(S_cache, window)`` indexed by ``pos % len``.
    Returns (out [B, 1, D], new_cache_k, new_cache_v).
    """
    b, _, _ = x.shape
    s_cache = cache_k.shape[1]
    q, k, v = _qkv(params, x, num_heads, num_kv_heads, head_dim)
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    # Ring-buffer slot; for full attention the caller sizes the cache to the
    # max sequence length so the ring never wraps.
    slot = pos % s_cache
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))

    groups = num_heads // num_kv_heads
    kk = _repeat_kv(cache_k.astype(x.dtype), groups)   # [B, Sc, H, hd]
    vv = _repeat_kv(cache_v.astype(x.dtype), groups)

    scale = head_dim ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    # Validity: ring slot j holds absolute position p(j) = the largest
    # p <= pos with p % s_cache == j; valid iff p(j) >= 0 (written yet) and,
    # for SWA, p(j) > pos - window (always true when cache len == window).
    jslots = jnp.arange(s_cache, dtype=jnp.int32)
    wrap = (pos - jslots + s_cache) % s_cache
    abs_pos = pos - wrap
    valid = abs_pos >= 0
    logits = jnp.where(valid[None, None, None, :], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = out.reshape(b, 1, num_heads * head_dim) @ gather_out(
        params["wo"], x.dtype)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, activation: str):
    ks = jax.random.split(key, 3)
    scale = (3.0 / d_model) ** 0.5
    if activation == "silu_glu":
        params = {"w1": _uniform(ks[0], (d_model, d_ff), scale),
                  "w3": _uniform(ks[1], (d_model, d_ff), scale),
                  "w2": _uniform(ks[2], (d_ff, d_model),
                                 (3.0 / d_ff) ** 0.5)}
        specs = {"w1": P(FSDP, TP), "w3": P(FSDP, TP), "w2": P(TP, FSDP)}
    else:  # non-gated (squared-relu / gelu)
        params = {"w1": _uniform(ks[0], (d_model, d_ff), scale),
                  "w2": _uniform(ks[2], (d_ff, d_model),
                                 (3.0 / d_ff) ** 0.5)}
        specs = {"w1": P(FSDP, TP), "w2": P(TP, FSDP)}
    return params, specs


def mlp(params: Params, x: jax.Array, activation: str) -> jax.Array:
    if activation == "silu_glu":
        h = jax.nn.silu(x @ gather_in(params["w1"], x.dtype)) * (
            x @ gather_in(params["w3"], x.dtype))
    elif activation == "sq_relu":
        h = jnp.square(jax.nn.relu(x @ gather_in(params["w1"], x.dtype)))
    elif activation == "gelu":
        h = jax.nn.gelu(x @ gather_in(params["w1"], x.dtype))
    else:
        raise ValueError(activation)
    return h @ gather_out(params["w2"], x.dtype)
