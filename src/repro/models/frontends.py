"""Modality frontend STUBS (per assignment: the transformer backbone is the
deliverable; ``input_specs()`` provides precomputed frame/patch embeddings).

* ``vision_stub`` (internvl2-1b): stands in for InternViT — emits
  ``frontend_len`` patch embeddings at ``d_model``.
* ``audio_stub`` (musicgen-large): stands in for the EnCodec conditioning
  encoder — emits conditioning frame embeddings; the decoded stream itself
  is EnCodec *tokens* (vocab 2048) and goes through the normal embedding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int,
                         dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    assert cfg.frontend is not None
    return jax.ShapeDtypeStruct((batch, cfg.frontend_len, cfg.d_model), dtype)


def make_frontend_embeds(cfg: ModelConfig, batch: int, key,
                         dtype=jnp.float32) -> jax.Array:
    """Random stand-in for precomputed frontend activations (tests)."""
    sd = frontend_embed_shape(cfg, batch, dtype)
    return jax.random.normal(key, sd.shape, sd.dtype) * 0.02
