"""repro.models — composable model definitions for the assigned archs."""
from repro.models.lm import (active_param_count, cache_shape, decode_step,
                             forward, init_cache, init_params, lm_loss,
                             param_count, param_shapes)
from repro.models.frontends import frontend_embed_shape, make_frontend_embeds
from repro.models.treelstm import (init_treelstm, tree_roots,
                                   treelstm_embed, treelstm_forest)

__all__ = ["active_param_count", "cache_shape", "decode_step", "forward",
           "init_cache", "init_params", "lm_loss", "param_count",
           "param_shapes", "frontend_embed_shape", "make_frontend_embeds",
           "init_treelstm", "tree_roots", "treelstm_embed",
           "treelstm_forest"]
