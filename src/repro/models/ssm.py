"""Attention-free sequence mixers: RWKV6 ("Finch") and a Mamba-lite SSM.

Both are diagonal-decay linear recurrences over an outer-product state
``S_t = diag(w_t) S_{t-1} + k_t (x) v_t``; RWKV6's decay ``w_t`` is
*data-dependent* (the Finch contribution) and readout happens on the K side
with a per-channel bonus ``u``; Mamba reads out on the V (state) side.

Sequential-depth note (this is the load-balancing-adjacent perf story): a
naive ``lax.scan`` over S steps serializes 4k-512k iterations.  We implement
the **chunked 3-pass form** (cf. GLA/FLA): (A) per-chunk local state
contributions — embarrassingly parallel einsums with decay ratios that are
always <= 1 (computed as ``exp(negative)``, so no overflow); (B) a short scan
over ``S/C`` chunks propagating states; (C) per-chunk readout scans of length
``C``, vmapped over chunks.  Sequential depth drops from ``S`` to
``S/C + C``; everything else is MXU-shaped.  The plain scan is kept as the
oracle (`*_scan`) and the two are asserted allclose in tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (FSDP, TP, _uniform, gather_in,
                                 gather_out, rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Core recurrence: oracle scan + chunked 3-pass
# ---------------------------------------------------------------------------

def wkv_scan(r, k, v, logw, u, s0=None):
    """Oracle RWKV6 recurrence.

    r,k,logw: [B,S,H,K]; v: [B,S,H,V]; u: [H,K].
    out_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t);  S_t = diag(w_t) S_{t-1}
    + k_t (x) v_t.  Returns (out [B,S,H,V], S_final [B,H,K,V]).
    """
    b, s, h, kk = k.shape
    vv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, kk, vv), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         S + u[None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, out

    xs = (r.swapaxes(0, 1).astype(jnp.float32),
          k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32),
          logw.swapaxes(0, 1).astype(jnp.float32))
    # note: u enters via closure; kv bonus uses broadcast over V
    S, outs = jax.lax.scan(step, s0, xs)
    return outs.swapaxes(0, 1), S


def wkv_chunked(r, k, v, logw, u, s0=None, *, chunk: int = 64):
    """Chunked 3-pass RWKV6 recurrence; == wkv_scan (tested)."""
    b, s, h, kk = k.shape
    vv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, kk, vv), jnp.float32)
    if s % chunk != 0:
        chunk = 1 if s < chunk else [c for c in range(chunk, 0, -1)
                                     if s % c == 0][0]
    nc = s // chunk
    f32 = jnp.float32
    rc = r.reshape(b, nc, chunk, h, kk).astype(f32)
    kc = k.reshape(b, nc, chunk, h, kk).astype(f32)
    vc = v.reshape(b, nc, chunk, h, vv).astype(f32)
    lw = logw.reshape(b, nc, chunk, h, kk).astype(f32)

    # --- pass A: per-chunk totals (parallel over chunks) -------------------
    lw_cum = jnp.cumsum(lw, axis=2)                     # logW_{1..t}
    lw_tot = lw_cum[:, :, -1:]                          # logW_{1..C}
    decay_after = jnp.exp(lw_tot - lw_cum)              # prod_{u>s} w_u <= 1
    contrib = jnp.einsum("bnchk,bnchv->bnhkv", kc * decay_after, vc)
    w_total = jnp.exp(lw_tot[:, :, 0])                  # [B,NC,H,K]

    # --- pass B: propagate chunk-start states (scan over NC) ---------------
    def chunk_step(S, inp):
        wt, cb = inp
        return wt[..., None] * S + cb, S

    _, s_starts = jax.lax.scan(
        chunk_step, s0, (w_total.swapaxes(0, 1), contrib.swapaxes(0, 1)))
    s_starts = s_starts.swapaxes(0, 1)                  # [B,NC,H,K,V]

    # --- pass C: per-chunk readout (scan over C, vmapped over chunks) ------
    def readout(S, inp):
        r_t, k_t, v_t, lw_t = inp                       # [B,NC,H,*]
        kv = jnp.einsum("bnhk,bnhv->bnhkv", k_t, v_t)
        out = jnp.einsum("bnhk,bnhkv->bnhv", r_t,
                         S + u[None, None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., None] * S + kv
        return S, out

    xs = (rc.swapaxes(0, 2).swapaxes(1, 2),             # [C,B,NC,H,K]
          kc.swapaxes(0, 2).swapaxes(1, 2),
          vc.swapaxes(0, 2).swapaxes(1, 2),
          lw.swapaxes(0, 2).swapaxes(1, 2))
    s_final, outs = jax.lax.scan(readout, s_starts, xs)  # outs [C,B,NC,H,V]
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, s, h, vv)
    return out, s_final[:, -1]


def ssm_scan(a, bx, c, h0=None):
    """Oracle Mamba-style recurrence.

    a (decay, in (0,1]): [B,S,D,N]; bx (input): [B,S,D,N]; c: [B,S,N].
    h_t = a_t * h_{t-1} + bx_t ;  y_t = sum_n h_t[d,n] c_t[n].
    Returns (y [B,S,D], h_final [B,D,N]).
    """
    b, s, d, n = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, inp):
        a_t, bx_t, c_t = inp
        h = a_t * h + bx_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    h, ys = jax.lax.scan(step, h0, (a.swapaxes(0, 1).astype(jnp.float32),
                                    bx.swapaxes(0, 1).astype(jnp.float32),
                                    c.swapaxes(0, 1).astype(jnp.float32)))
    return ys.swapaxes(0, 1), h


def ssm_chunked(a, bx, c, h0=None, *, chunk: int = 64):
    """Chunked 3-pass Mamba recurrence; == ssm_scan (tested)."""
    b, s, d, n = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)
    if s % chunk != 0:
        chunk = 1 if s < chunk else [cc for cc in range(chunk, 0, -1)
                                     if s % cc == 0][0]
    nc = s // chunk
    f32 = jnp.float32
    la = jnp.log(jnp.maximum(a.reshape(b, nc, chunk, d, n).astype(f32),
                             1e-38))
    bxc = bx.reshape(b, nc, chunk, d, n).astype(f32)
    cc_ = c.reshape(b, nc, chunk, n).astype(f32)

    la_cum = jnp.cumsum(la, axis=2)
    la_tot = la_cum[:, :, -1:]
    decay_after = jnp.exp(la_tot - la_cum)
    contrib = jnp.sum(bxc * decay_after, axis=2)        # [B,NC,D,N]
    a_total = jnp.exp(la_tot[:, :, 0])

    def chunk_step(h, inp):
        at, cb = inp
        return at * h + cb, h

    _, h_starts = jax.lax.scan(
        chunk_step, h0, (a_total.swapaxes(0, 1), contrib.swapaxes(0, 1)))
    h_starts = h_starts.swapaxes(0, 1)

    def readout(h, inp):
        a_t, bx_t, c_t = inp
        h = a_t * h + bx_t                                  # [B,NC,D,N]
        return h, jnp.einsum("bcdn,bcn->bcd", h, c_t)

    xs = (jnp.exp(la).swapaxes(0, 2).swapaxes(1, 2),
          bxc.swapaxes(0, 2).swapaxes(1, 2),
          cc_.swapaxes(0, 2).swapaxes(1, 2))
    h_fin, ys = jax.lax.scan(readout, h_starts, xs)     # ys [C,B,NC,D]
    y = ys.transpose(1, 2, 0, 3).reshape(b, s, d)
    return y, h_fin[:, -1]


# ---------------------------------------------------------------------------
# RWKV6 block
# ---------------------------------------------------------------------------

def rwkv6_init(key, d_model: int, num_heads: int, head_dim: int):
    ks = jax.random.split(key, 8)
    scale = (3.0 / d_model) ** 0.5
    hk = num_heads * head_dim
    params = {
        "mu": _uniform(ks[0], (5, d_model), 0.5) + 0.5,   # token-shift lerps
        "wr": _uniform(ks[1], (d_model, hk), scale),
        "wk": _uniform(ks[2], (d_model, hk), scale),
        "wv": _uniform(ks[3], (d_model, hk), scale),
        "wg": _uniform(ks[4], (d_model, hk), scale),
        "wdecay": _uniform(ks[5], (d_model, hk), scale * 0.1),
        "decay_base": jnp.zeros((num_heads, head_dim), jnp.float32) - 0.5,
        "bonus_u": _uniform(ks[6], (num_heads, head_dim), 0.5),
        "wo": _uniform(ks[7], (hk, d_model), (3.0 / hk) ** 0.5),
        "ln_x": jnp.ones((hk,), jnp.float32),
    }
    specs = {
        "mu": P(None, None), "wr": P(FSDP, TP), "wk": P(FSDP, TP),
        "wv": P(FSDP, TP), "wg": P(FSDP, TP), "wdecay": P(FSDP, TP),
        # [H, hd] tensors: H (e.g. 40) need not divide the TP axis; they are
        # tiny, so replicate rather than shard unevenly.
        "decay_base": P(None, None), "bonus_u": P(None, None),
        "wo": P(TP, FSDP), "ln_x": P(TP),
    }
    return params, specs


def _rwkv6_inputs(params, x, x_prev, num_heads, head_dim):
    """Token-shift lerp + projections.  x: [B,S,D]; x_prev: [B,1,D] (the
    token before this window, zeros at sequence start)."""
    b, s, d = x.shape
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    mu = params["mu"].astype(x.dtype)
    mix = [x + (shifted - x) * mu[i] for i in range(5)]
    proj = lambda m, w: (m @ gather_in(params[w], x.dtype)).reshape(
        b, s, num_heads, head_dim)
    r = proj(mix[0], "wr")
    k = proj(mix[1], "wk")
    v = proj(mix[2], "wv")
    g = proj(mix[3], "wg")
    # Finch data-dependent decay: logw in (-inf, 0)
    wraw = (mix[4] @ params["wdecay"].astype(x.dtype)).reshape(
        b, s, num_heads, head_dim)
    logw = -jnp.exp(jnp.clip(params["decay_base"][None, None].astype(
        jnp.float32) + wraw.astype(jnp.float32), -8.0, 6.0))
    return r, k, v, g, logw


def rwkv6_block(params: Params, x: jax.Array, *, num_heads: int,
                head_dim: int, chunk: int = 64, use_chunked: bool = True,
                x_prev=None, state=None, return_state: bool = False):
    """RWKV6 time-mix block. x: [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    r, k, v, g, logw = _rwkv6_inputs(params, x, x_prev, num_heads, head_dim)
    u = params["bonus_u"].astype(jnp.float32)
    if use_chunked:
        out, s_fin = wkv_chunked(r, k, v, logw, u, s0=state, chunk=chunk)
    else:
        out, s_fin = wkv_scan(r, k, v, logw, u, s0=state)
    # per-head group norm + silu gate
    hk = num_heads * head_dim
    out = out.reshape(b, s, num_heads, head_dim)
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, s, hk) * params["ln_x"].astype(jnp.float32)
    out = (out.astype(x.dtype) * jax.nn.silu(g.reshape(b, s, hk)))
    y = out @ gather_out(params["wo"], x.dtype)
    if return_state:
        return y, (x[:, -1:], s_fin)
    return y


def rwkv_cmix_init(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    scale = (3.0 / d_model) ** 0.5
    params = {
        "mu": _uniform(ks[0], (2, d_model), 0.5) + 0.5,
        "wr": _uniform(ks[1], (d_model, d_model), scale),
        "wk": _uniform(ks[2], (d_model, d_ff), scale),
        "wv": _uniform(jax.random.fold_in(key, 3), (d_ff, d_model),
                       (3.0 / d_ff) ** 0.5),
    }
    specs = {"mu": P(None, None), "wr": P(FSDP, TP), "wk": P(FSDP, TP),
             "wv": P(TP, FSDP)}
    return params, specs


def rwkv_cmix(params: Params, x: jax.Array, x_prev=None,
              return_state: bool = False):
    """RWKV6 channel-mix: token-shifted squared-ReLU gated MLP."""
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    mu = params["mu"].astype(x.dtype)
    xk = x + (shifted - x) * mu[0]
    xr = x + (shifted - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ gather_in(params["wk"], x.dtype)))
    out = jax.nn.sigmoid(xr @ gather_in(params["wr"], x.dtype)) * (
        k @ gather_out(params["wv"], x.dtype))
    if return_state:
        return out, x[:, -1:]
    return out


# ---------------------------------------------------------------------------
# Mamba-lite block (hymba's SSM heads)
# ---------------------------------------------------------------------------

def mamba_init(key, d_model: int, d_inner: int, d_state: int):
    ks = jax.random.split(key, 6)
    scale = (3.0 / d_model) ** 0.5
    params = {
        "win": _uniform(ks[0], (d_model, d_inner), scale),
        "wg": _uniform(ks[1], (d_model, d_inner), scale),
        "wdt": _uniform(ks[2], (d_model, d_inner), scale * 0.1),
        "wb": _uniform(ks[3], (d_model, d_state), scale),
        "wc": _uniform(ks[4], (d_model, d_state), scale),
        "a_log": jnp.log(jnp.linspace(1.0, float(d_state), d_state)
                         )[None, :] * jnp.ones((d_inner, 1), jnp.float32),
        "dskip": jnp.ones((d_inner,), jnp.float32),
        "wo": _uniform(ks[5], (d_inner, d_model), (3.0 / d_inner) ** 0.5),
    }
    specs = {
        "win": P(FSDP, TP), "wg": P(FSDP, TP), "wdt": P(FSDP, TP),
        "wb": P(FSDP, None), "wc": P(FSDP, None), "a_log": P(TP, None),
        "dskip": P(TP), "wo": P(TP, FSDP),
    }
    return params, specs


def mamba_block(params: Params, x: jax.Array, *, chunk: int = 64,
                use_chunked: bool = True, state=None,
                return_state: bool = False):
    """Selective-SSM block. x: [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    xin = x @ gather_in(params["win"], x.dtype)              # [B,S,Di]
    gate = jax.nn.silu(x @ gather_in(params["wg"], x.dtype))
    dt = jax.nn.softplus(x @ gather_in(params["wdt"], x.dtype)
                         ).astype(jnp.float32)               # [B,S,Di]
    bmat = (x @ params["wb"].astype(x.dtype)).astype(jnp.float32)  # [B,S,N]
    cmat = (x @ params["wc"].astype(x.dtype)).astype(jnp.float32)  # [B,S,N]
    a = jnp.exp(-jnp.exp(params["a_log"])[None, None]
                * dt[..., None])                             # [B,S,Di,N]
    bx = (dt * xin.astype(jnp.float32))[..., None] * bmat[:, :, None, :]
    core = ssm_chunked if use_chunked else ssm_scan
    if use_chunked:
        y, h_fin = core(a, bx, cmat, h0=state, chunk=chunk)
    else:
        y, h_fin = core(a, bx, cmat, h0=state)
    y = y.astype(x.dtype) + xin * params["dskip"].astype(x.dtype)
    y = (y * gate) @ gather_out(params["wo"], x.dtype)
    if return_state:
        return y, h_fin
    return y
