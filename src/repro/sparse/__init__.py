"""repro.sparse — formats, load-balanced linear algebra, graph primitives."""
from repro.sparse.formats import COO, CSC, CSR, random_csr, suite_like_corpus
from repro.sparse.ops import spmm, spmv, spmv_reference, spvv
from repro.sparse.graph import Graph, bfs, sssp

__all__ = ["COO", "CSC", "CSR", "random_csr", "suite_like_corpus",
           "spmm", "spmv", "spmv_reference", "spvv", "Graph", "bfs", "sssp"]
