"""repro.sparse — formats, load-balanced linear algebra, graph operators."""
from repro.sparse.formats import COO, CSC, CSR, random_csr, suite_like_corpus
from repro.sparse.ops import spmm, spmv, spmv_reference, spvv
from repro.sparse.advance import (AdvancePlan, advance, advance_frontier,
                                  advance_push, advance_relax_min,
                                  advance_src_argmin, build_advance,
                                  estimate_delta, frontier_filter)
from repro.sparse.graph import (Graph, bfs, bfs_multi, delta_stepping,
                                pagerank, sssp)
from repro.sparse.shard import (SHARD_SCHEDULES, ShardedAdvancePlan,
                                build_sharded_advance, shard_boundaries,
                                sharded_bfs, sharded_bfs_multi,
                                sharded_delta_stepping, sharded_pagerank,
                                sharded_sssp)
from repro.sparse.wavefront import (PackedForest, WavefrontPlan,
                                    build_wavefront, pack_forest,
                                    topological_levels, wavefront_eval)

__all__ = ["COO", "CSC", "CSR", "random_csr", "suite_like_corpus",
           "spmm", "spmv", "spmv_reference", "spvv",
           "AdvancePlan", "advance", "advance_frontier", "advance_push",
           "advance_relax_min", "advance_src_argmin", "build_advance",
           "estimate_delta", "frontier_filter",
           "Graph", "bfs", "bfs_multi", "delta_stepping", "pagerank",
           "sssp",
           "SHARD_SCHEDULES", "ShardedAdvancePlan", "build_sharded_advance",
           "shard_boundaries", "sharded_bfs", "sharded_bfs_multi",
           "sharded_delta_stepping", "sharded_pagerank", "sharded_sssp",
           "PackedForest", "WavefrontPlan", "build_wavefront",
           "pack_forest", "topological_levels", "wavefront_eval"]
