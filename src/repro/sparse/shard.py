"""Sharded graph advance: recursive load balancing across devices.

The paper's hierarchy balances atoms over tiles and tiles over blocks; this
module adds the next level of the same recursion — **vertices over shards**
(devices on a 1-axis ``"shard"`` mesh).  Each shard owns a contiguous vertex
range and holds *local* pull/push CSR views of exactly its own rows, built
by the very same view-level inspector the single-device plan pair uses
(:func:`repro.sparse.advance.build_advance_views`): chunks balance blocks,
blocks balance shards, one cost model and autotune family per level
(``workload="advance_sharded"``, see
:func:`repro.core.autotune.select_sharded_plan` and
:func:`repro.core.balance.modeled_sharded_cost`).

Where the split points fall is itself a pluggable schedule — the
*boundary* schedules in :data:`SHARD_SCHEDULES`, the shard-level analogue
of the block-level balancing schedules:

* ``"equal_width"`` — uniform ``ceil(V/S)`` ranges (the thread-mapped
  schedule one level up; the default and the bitwise-frozen baseline);
* ``"edge_balanced"`` — split points from the prefix sum of each vertex's
  in+out degree (nonzero_split / merge-path one level up);
* ``"lpt_contiguous"`` — greedy nudging of edge-balanced boundaries that
  minimizes the max-shard load (LPT's move-work-off-the-max discipline,
  constrained to contiguous ranges).

Boundaries are always contiguous — that is what preserves per-destination
atom order and with it the bitwise contract below — but shards are no
longer uniform width: every local view is padded to the *max* shard width
and each shard's real extent rides the plan (``shard_lo``/``shard_hi``).

Execution contract (what makes the sharded result **bit-identical** to the
single-device plan, asserted by ``tests/test_shard_advance.py``):

* Shards own contiguous vertex ranges, so each local view is a contiguous
  *slice* of the global CSR with rebased offsets — every destination's atom
  segment survives in the same order, and the per-tile reductions reduce
  the same operands in the same order as one device would.
* State inside ``shard_map`` lives in **padded-slot coordinates**: vertex
  ``v`` owned by shard ``s`` occupies slot ``s * shard_size + (v - lo_s)``.
  The plan's ``glob2pad``/``pad2glob`` permutation maps between the two
  layouts; all per-atom source/destination index arrays are pre-mapped to
  padded coordinates at build time, so the gathered halo is indexed
  directly and the push combine scatters directly — no per-iteration
  relayout.  The map is monotone in global id (contiguity again), so
  min-reductions over ids (BFS parents) pick the same winner in either
  coordinate system.  For ``equal_width`` the permutation is the
  identity, which is what keeps the default byte-identical to the
  pre-boundary-schedule layout.
* The **pull** direction is purely local: a shard's tiles (destinations)
  own all their in-edge atoms, so
  :func:`repro.core.execute.execute_sharded_tile_reduce` needs no
  collective.  The frontier/state *halo* arrives first, via one
  ``all_gather`` of the ``[shard_size]`` carries per iteration.
* The **push** direction scatters anywhere: each shard produces a full
  ``[V_pad]`` partial (identity at untouched destinations) and
  :func:`repro.core.execute.execute_sharded_scatter_reduce` combines the
  partials with the combiner's matching collective (exact for min/max,
  disjoint-support-exact for sum), then each shard keeps its own slice.
* Ragged local edge counts are padded to a common ``E_max`` per direction
  **before** partitioning, so every shard traces the same shapes; padding
  atoms live in a dedicated pad tile past the owned rows and are masked
  out of every advance (``pull_valid``/``push_valid`` ride the plan), and
  padding *slots* of the carry only ever receive combiner identities, so
  they stay inert without extra masking.
* Direction choice is *global*: the measured frontier out-edge count is a
  ``psum`` across shards, compared against the plan's one modeled
  threshold — shards never disagree about direction, which keeps the
  ``lax.cond`` predicate uniform across the mesh.

Termination predicates (``frontier.any()`` etc.) must not issue collectives
inside ``while_loop`` *cond* functions, so every driver threads the psum'd
scalars (frontier population, active out-edge count) through its carry and
conds read the carry only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import (ExecutionPath, Schedule, choose_execution_path,
                        estimate_compact_capacity,
                        estimate_direction_threshold,
                        execute_sharded_scatter_reduce,
                        execute_sharded_tile_reduce, make_partition)
from repro.core.autotune import (Plan, REGISTERED_PLANS, ShardedPlan,
                                 select_sharded_plan)
from repro.core.work import WorkSpec
from repro.launch.mesh import make_graph_mesh
from repro.sparse.advance import (DEFAULT_NUM_BLOCKS, AdvancePlan,
                                  _CHUNK_POLICIES, _combined_mask,
                                  build_advance_views, estimate_delta)
from repro.sparse.graph import (INF, _FAR_BUCKET, _SSSP_ALGORITHMS,
                                _bucket_of, _check_driver_direction,
                                _pagerank_share, _pagerank_update,
                                _validate_sources)

__all__ = ["SHARD_SCHEDULES", "ShardedAdvancePlan", "build_sharded_advance",
           "shard_boundaries", "sharded_bfs", "sharded_bfs_multi",
           "sharded_delta_stepping", "sharded_pagerank", "sharded_sssp"]


# ---------------------------------------------------------------------------
# Inspector: local views, uniform statics, stacking
# ---------------------------------------------------------------------------

def _local_csr_view(row_offsets, col_indices, values, lo: int, hi: int,
                    shard_size: int, e_max: int, *,
                    spread_pad: bool = False):
    """One shard's padded local view of a global CSR.

    Rows ``[lo, hi)`` of the global matrix become local tiles ``[0, hi-lo)``
    (trailing tiles up to ``shard_size`` are empty for a short final shard);
    tile ``shard_size`` is a dedicated *pad tile* holding the padding atoms
    ``[E_local, e_max)``.  Columns/values are the contiguous global slice —
    same per-row atom order as the global CSR, which is the bitwise
    contract.  Returns ``(offsets [shard_size+2], cols, vals, valid)``.

    ``spread_pad`` distributes the padding atoms evenly over the empty
    trailing slots *and* the pad tile instead of dumping them all into the
    pad tile.  Padding atoms are masked either way, so placement never
    changes results — but one huge pad segment inflates the blocked
    executor's static window/local-tile maxima (a merge-path block swallows
    the whole run of zero-atom slots, and another the monolithic pad
    segment), and the mesh-uniform statics impose that worst block shape
    on every shard.  Uneven boundary schedules (which create wide empty
    slot runs on their narrow shards) pay a multiple of the advance cost
    for it; ``equal_width`` keeps the legacy all-in-pad-tile layout
    byte-for-byte.
    """
    roff = np.asarray(row_offsets)
    lo = min(lo, hi)
    a0, a1 = int(roff[lo]), int(roff[hi])
    e_local = a1 - a0
    counts = np.diff(roff[lo:hi + 1])
    if spread_pad:
        n_bins = shard_size - counts.size + 1
        base, rem = divmod(e_max - e_local, n_bins)
        pad_counts = np.full(n_bins, base, np.int64)
        pad_counts[:rem] += 1
        offs = np.concatenate(
            [[0], np.cumsum(np.concatenate([counts, pad_counts]))]
        ).astype(np.int32)
    else:
        counts = np.concatenate(
            [counts, np.zeros(shard_size - counts.size, np.int64)])
        offs = np.concatenate(
            [[0], np.cumsum(counts), [e_max]]).astype(np.int32)
    cols = np.zeros(e_max, np.int32)
    vals = np.zeros(e_max, np.float32)
    valid = np.zeros(e_max, bool)
    cols[:e_local] = np.asarray(col_indices)[a0:a1]
    vals[:e_local] = np.asarray(values)[a0:a1]
    valid[:e_local] = True
    return offs, cols, vals, valid


def _shard_ranges(num_vertices: int, num_shards: int, shard_size: int):
    los = [s * shard_size for s in range(num_shards)]
    his = [min(lo + shard_size, num_vertices) for lo in los]
    return [(min(lo, hi), hi) for lo, hi in zip(los, his)]


def _direction_e_max(row_offsets, ranges) -> int:
    roff = np.asarray(row_offsets)
    return max(1, max(int(roff[hi] - roff[lo]) for lo, hi in ranges))


# ---------------------------------------------------------------------------
# Boundary schedules: where the contiguous split points fall
# ---------------------------------------------------------------------------

def _vertex_loads(fwd_row_offsets, rev_row_offsets):
    """Per-vertex work measure the degree-aware schedules balance.

    In + out degree (each edge is relaxed once per direction a traversal
    might take) plus 1 — the merge-path measure one level down counts a
    tile *and* its atoms, and the +1 keeps long edgeless stretches from
    collapsing into a single shard's range.
    """
    fdeg = np.diff(np.asarray(fwd_row_offsets).astype(np.int64))
    rdeg = np.diff(np.asarray(rev_row_offsets).astype(np.int64))
    return fdeg + rdeg + 1


def _equal_width_boundaries(loads, num_vertices, num_shards):
    width = max(-(-num_vertices // num_shards) if num_vertices else 1, 1)
    return np.minimum(
        np.arange(num_shards + 1, dtype=np.int64) * width, num_vertices)


def _edge_balanced_boundaries(loads, num_vertices, num_shards):
    # nonzero_split one level up: boundary k lands where the cumulative
    # load first reaches k/S of the total — searchsorted on the prefix sum,
    # exactly the merge-path diagonal intersection over (vertices, work).
    cum = np.concatenate([[0], np.cumsum(loads)])
    targets = cum[-1] * np.arange(1, num_shards, dtype=np.float64) / num_shards
    inner = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], inner, [num_vertices]]).astype(np.int64)
    return np.maximum.accumulate(np.minimum(bounds, num_vertices))


def _lpt_contiguous_boundaries(loads, num_vertices, num_shards):
    # LPT's move-work-off-the-max discipline under a contiguity constraint:
    # start from the edge-balanced split, then coordinate-descend each
    # interior boundary to the position minimizing max(left, right) load of
    # its two neighbours, sweeping until no boundary moves.
    bounds = _edge_balanced_boundaries(loads, num_vertices, num_shards)
    cum = np.concatenate([[0], np.cumsum(loads)])

    def seg(a, b):
        return cum[b] - cum[a]

    for _ in range(2 * num_shards):
        moved = False
        for k in range(1, num_shards):
            lo, hi = bounds[k - 1], bounds[k + 1]
            mid = (cum[lo] + cum[hi]) / 2.0
            x = int(np.clip(np.searchsorted(cum, mid, side="left"), lo, hi))
            best = bounds[k]
            best_cost = max(seg(lo, best), seg(best, hi))
            for cand in (x - 1, x, x + 1):
                if lo <= cand <= hi:
                    cost = max(seg(lo, cand), seg(cand, hi))
                    if cost < best_cost:
                        best, best_cost = cand, cost
            if best != bounds[k]:
                bounds[k] = best
                moved = True
        if not moved:
            break
    return bounds


#: The shard-level schedule registry — the analogue of the block-level
#: ``Schedule`` enum, one recursion up: each entry maps per-vertex loads to
#: the ``[S+1]`` contiguous boundary array ``build_sharded_advance`` splits
#: the vertex range on.  Order matters: auto-selection dedups identical
#: splits keeping the *first* name, so ``equal_width`` (the bitwise-frozen
#: baseline) wins ties.
SHARD_SCHEDULES = {
    "equal_width": _equal_width_boundaries,
    "edge_balanced": _edge_balanced_boundaries,
    "lpt_contiguous": _lpt_contiguous_boundaries,
}


def _validate_boundaries(bounds, num_vertices, num_shards, name):
    b = np.asarray(bounds, dtype=np.int64)
    if (b.shape != (num_shards + 1,) or b[0] != 0 or b[-1] != num_vertices
            or np.any(np.diff(b) < 0)):
        raise ValueError(
            f"shard schedule {name!r} produced invalid boundaries "
            f"{b.tolist()} for V={num_vertices}, S={num_shards}: need a "
            f"non-decreasing [S+1] split of [0, V]")
    return b


def _schedule_boundaries(fwd_csr, rev_csr, num_vertices, num_shards, name):
    if name not in SHARD_SCHEDULES:
        raise ValueError(f"unknown shard schedule {name!r} (expected one "
                         f"of {sorted(SHARD_SCHEDULES)})")
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_shards > max(num_vertices, 1) and name != "equal_width":
        raise ValueError(
            f"shard schedule {name!r} cannot split V={num_vertices} "
            f"vertices into S={num_shards} contiguous non-degenerate "
            f"shards; only 'equal_width' accepts a mesh larger than the "
            f"graph (its trailing shards are all-empty padding)")
    loads = _vertex_loads(fwd_csr.row_offsets, rev_csr.row_offsets)
    bounds = SHARD_SCHEDULES[name](loads, num_vertices, num_shards)
    return _validate_boundaries(bounds, num_vertices, num_shards, name)


def shard_boundaries(graph, num_shards: int,
                     shard_schedule: str = "equal_width"):
    """The ``[S+1]`` contiguous vertex boundaries a shard schedule yields.

    Public inspection hook for tests and benchmarks; the same computation
    :func:`build_sharded_advance` runs internally.
    """
    fwd = graph.csr
    return _schedule_boundaries(fwd, fwd.transpose(), graph.num_vertices,
                                int(num_shards), shard_schedule)


def _boundary_permutation(bounds, shard_size: int):
    """The global<->padded-slot bijection for a boundary array.

    Slot ``s * shard_size + j`` holds global vertex ``bounds[s] + j`` for
    ``j < width_s``; the remaining padding slots take the overflow ids
    ``[V, V_pad)`` in increasing order, making both maps full permutations
    of ``[0, V_pad)``.  For equal-width boundaries this is the identity —
    the property that keeps the default layout byte-identical to the
    pre-boundary-schedule one.
    """
    bounds = np.asarray(bounds, dtype=np.int64)
    num_shards = bounds.size - 1
    num_vertices = int(bounds[-1])
    v_pad = num_shards * shard_size
    pad2glob = np.empty(v_pad, dtype=np.int32)
    overflow = num_vertices
    for s in range(num_shards):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        base = s * shard_size
        pad2glob[base:base + (hi - lo)] = np.arange(lo, hi, dtype=np.int32)
        n_pad = shard_size - (hi - lo)
        pad2glob[base + (hi - lo):base + shard_size] = np.arange(
            overflow, overflow + n_pad, dtype=np.int32)
        overflow += n_pad
    glob2pad = np.empty(v_pad, dtype=np.int32)
    glob2pad[pad2glob] = np.arange(v_pad, dtype=np.int32)
    return glob2pad, pad2glob


def _pull_shard_specs(rev_csr, num_vertices: int, num_shards: int):
    """Per-shard padded pull work views for one candidate shard count —
    the inputs :func:`repro.core.autotune.select_sharded_plan` scores."""
    shard_size = max(-(-num_vertices // num_shards) if num_vertices else 1, 1)
    ranges = _shard_ranges(num_vertices, num_shards, shard_size)
    e_max = _direction_e_max(rev_csr.row_offsets, ranges)
    specs = []
    for lo, hi in ranges:
        offs, _, _, _ = _local_csr_view(rev_csr.row_offsets,
                                        rev_csr.col_indices, rev_csr.values,
                                        lo, hi, shard_size, e_max)
        specs.append(WorkSpec.from_segment_offsets(jnp.asarray(offs),
                                                   num_atoms=e_max))
    return specs


def _candidate_shard_counts(num_vertices: int):
    """Powers of two up to the smaller of device count and vertex count."""
    n = max(len(jax.devices()), 1)
    counts, c = [], 1
    while c <= n and c <= max(num_vertices, 1):
        counts.append(c)
        c *= 2
    return counts


def _uniform_partitions(parts):
    """Rewrite per-shard partitions to share one set of static hints.

    ``shard_map`` traces a single program, so the statics baked into the
    executors' shapes (window spans, per-block item bound, chunk-queue
    width, the tile-aligned flag) must agree across shards.  Every
    uniformization direction is mask-safe: larger windows only add masked
    slots, ``tile_aligned=False`` on an aligned partition just runs the
    (identity-combining) fixup path, and zero-padded chunk queue columns
    are past each block's chunk count.
    """
    def _max_opt(vals):
        return None if any(v is None for v in vals) else max(vals)

    aspan = _max_opt([p.atom_span for p in parts])
    tspan = _max_opt([p.tile_span for p in parts])
    items = _max_opt([p.items_per_block for p in parts])
    items = items if items is None else int(items)
    aligned = all(p.tile_aligned for p in parts)
    out = []
    for p in parts:
        bc = p.block_chunks
        if bc is not None:
            wmax = max(q.block_chunks.shape[1] for q in parts)
            bc = jnp.pad(bc, ((0, 0), (0, wmax - bc.shape[1])))
        out.append(dataclasses.replace(
            p, atom_span=aspan, tile_span=tspan, items_per_block=items,
            tile_aligned=aligned, block_chunks=bc))
    return out


def _stack_tree(objs):
    """Stack pytrees leaf-wise; asserts identical treedefs (= statics)."""
    flats = [jax.tree_util.tree_flatten(o) for o in objs]
    td0 = flats[0][1]
    for _, td in flats[1:]:
        if td != td0:
            raise ValueError(
                f"shard statics diverged after uniformization: {td} != {td0}")
    return tuple(jnp.stack(ls) for ls in zip(*(f[0] for f in flats))), td0


# ---------------------------------------------------------------------------
# The sharded plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedAdvancePlan:
    """Inspector output for the device-sharded advance: one
    :class:`~repro.sparse.advance.AdvancePlan` *per shard*, stored stacked.

    ``template`` is shard 0's plan carrying the (uniform) statics —
    schedule, paths, threshold, compaction capacity, padded shapes; the
    per-shard arrays and partition/work-view leaves are stacked along a
    leading ``[num_shards]`` axis and fed through ``shard_map`` with
    ``P("shard")`` specs, where each shard reconstructs its local plan
    (:func:`_local_plan`).  Built outside jit, like every inspector
    product.

    State arrays the drivers shard are length ``V_pad = num_shards *
    shard_size`` in **padded-slot layout** (shard ``s``'s owned window at
    ``[s * shard_size, s * shard_size + width_s)``, padding slots after);
    :meth:`to_global` reorders results to global vertex order and trims to
    ``[:num_vertices]`` on the way out — the identity + slice for
    ``equal_width`` boundaries.
    """

    mesh: Mesh
    axis: str
    num_shards: int
    num_vertices: int         # global V, pre-padding
    shard_size: int           # max shard width (uneven boundaries pad up)
    num_edges: int            # global edge count (NOT the padded E_max)
    template: AdvancePlan
    arrays: dict              # stacked [S, ...] per-shard plan arrays
    pull_part_leaves: tuple
    pull_part_treedef: object
    push_part_leaves: tuple
    push_part_treedef: object
    pull_spec_leaves: tuple
    pull_spec_treedef: object
    push_spec_leaves: tuple
    push_spec_treedef: object
    shard_schedule: str = "equal_width"
    boundaries: tuple = ()    # [S+1] contiguous vertex split points
    glob2pad: Optional[jax.Array] = None   # [V_pad] global id -> slot
    pad2glob: Optional[jax.Array] = None   # [V_pad] slot -> global id

    @property
    def padded_vertices(self) -> int:
        return self.num_shards * self.shard_size

    def to_global(self, padded: jax.Array) -> jax.Array:
        """Reorder a padded-layout ``[..., V_pad]`` result to global vertex
        order, trimmed to ``[..., V]``.  An identity gather + slice for
        ``equal_width`` boundaries."""
        return jnp.take(padded, self.glob2pad[:self.num_vertices], axis=-1)

    @property
    def direction_threshold(self) -> float:
        return self.template.direction_threshold

    @property
    def delta(self) -> Optional[float]:
        return self.template.delta

    @property
    def schedule(self) -> Schedule:
        return self.template.schedule

    @property
    def path(self) -> ExecutionPath:
        return self.template.path

    def edge_fraction(self, active_edge_count: jax.Array) -> jax.Array:
        """Measured *global* frontier density: psum'd active out-edge count
        over the true global edge count.  The template's own ``num_edges``
        is the padded per-shard ``E_max`` — never use it here."""
        return active_edge_count.astype(jnp.float32) / jnp.float32(
            max(self.num_edges, 1))

    def data(self) -> dict:
        """The stacked pytree a ``shard_map`` body consumes: per-shard
        leaves under ``P(axis)``, plus the replicated global<->padded
        permutation under ``"glob"`` (see :func:`_data_specs`)."""
        return {"arrays": dict(self.arrays),
                "pull_part": list(self.pull_part_leaves),
                "push_part": list(self.push_part_leaves),
                "pull_spec": list(self.pull_spec_leaves),
                "push_spec": list(self.push_spec_leaves),
                "glob": {"glob2pad": self.glob2pad,
                         "pad2glob": self.pad2glob}}

    def with_delta(self, delta: Optional[float] = None) -> "ShardedAdvancePlan":
        """Attach the light/heavy bucket split to every shard.

        Width ``None`` estimates from the *valid* (non-padding) push
        weights — identical to the single-device estimate, since the valid
        atoms are exactly the global edge set.  Per-shard light out-degrees
        count only valid light atoms, binned over owned rows.
        """
        push_w = np.asarray(self.arrays["push_weight"])
        push_v = np.asarray(self.arrays["push_valid"])
        if delta is None:
            delta = estimate_delta(push_w[push_v])
        delta = float(delta)
        if not delta > 0.0:
            raise ValueError(f"delta must be positive, got {delta}")
        thr = np.float32(delta)
        light = np.asarray(self.arrays["weight"]) <= thr
        push_light = push_w <= thr
        light_outs = []
        for s in range(self.num_shards):
            spec = jax.tree_util.tree_unflatten(
                self.push_spec_treedef, [l[s] for l in self.push_spec_leaves])
            tids = np.asarray(spec.atom_tile_ids())
            light_outs.append(np.bincount(
                tids, weights=(push_light[s] & push_v[s]).astype(np.int64),
                minlength=self.shard_size + 1)[:self.shard_size])
        arrays = dict(self.arrays)
        arrays["light_mask"] = jnp.asarray(light)
        arrays["push_light_mask"] = jnp.asarray(push_light)
        arrays["light_out_degrees"] = jnp.asarray(
            np.stack(light_outs).astype(np.int32))
        template = dataclasses.replace(
            self.template, delta=delta,
            light_mask=arrays["light_mask"][0],
            push_light_mask=arrays["push_light_mask"][0],
            light_out_degrees=arrays["light_out_degrees"][0])
        return dataclasses.replace(self, template=template, arrays=arrays)


def _local_plan(splan: ShardedAdvancePlan, data):
    """Reconstruct this shard's AdvancePlan inside a ``shard_map`` body.

    Every leaf arrives with a leading length-1 shard axis; squeeze it and
    re-hang the arrays on the template (whose statics are uniform by
    construction).  Returns ``(plan, pull_valid, push_valid)`` — the valid
    masks are ANDed into every advance's edge mask so padding atoms never
    contribute.
    """
    def sq(leaves, td):
        return jax.tree_util.tree_unflatten(td, [l[0] for l in leaves])

    a = {k: v[0] for k, v in data["arrays"].items()}
    t = splan.template
    delta_fields = {}
    if t.delta is not None:
        delta_fields = {"light_mask": a["light_mask"],
                        "push_light_mask": a["push_light_mask"],
                        "light_out_degrees": a["light_out_degrees"]}
    lp = dataclasses.replace(
        t,
        spec=sq(data["pull_spec"], splan.pull_spec_treedef),
        push_spec=sq(data["push_spec"], splan.push_spec_treedef),
        part=sq(data["pull_part"], splan.pull_part_treedef),
        push_part=sq(data["push_part"], splan.push_part_treedef),
        src=a["src"], weight=a["weight"], dst=a["dst"],
        push_weight=a["push_weight"], push_src=a["push_src"],
        out_degrees=a["out_degrees"], **delta_fields)
    return lp, a["pull_valid"], a["push_valid"]


def _data_specs(axis: str) -> dict:
    """``in_specs`` tree for :meth:`ShardedAdvancePlan.data`: per-shard
    leaves split over the mesh axis, the global<->padded permutation
    replicated (every shard indexes the whole map)."""
    return {"arrays": P(axis), "pull_part": P(axis), "push_part": P(axis),
            "pull_spec": P(axis), "push_spec": P(axis), "glob": P()}


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def _resolve_schedule_enum(schedule) -> tuple[Schedule, Optional[str]]:
    policy = _CHUNK_POLICIES.get(str(schedule))
    return (Schedule.CHUNKED if policy else Schedule(schedule)), policy


def build_sharded_advance(graph, num_shards=None, *,
                          schedule: Schedule | str = "auto",
                          num_blocks: Optional[int] = None,
                          path: ExecutionPath | str = ExecutionPath.AUTO,
                          workload: str = "advance",
                          shard_schedule: Optional[str] = None,
                          direction_threshold: Optional[float] = None,
                          delta: Optional[float | str] = None,
                          compact: Optional[bool | int | float] = None,
                          measure=None,
                          interpret: bool = True) -> ShardedAdvancePlan:
    """Inspect a graph into a :class:`ShardedAdvancePlan`.

    ``num_shards`` accepts an int (shards = devices on a fresh 1-axis graph
    mesh, :func:`repro.launch.mesh.make_graph_mesh`), an existing 1-axis
    :class:`~jax.sharding.Mesh`, or ``None``/``"auto"`` — which asks
    :func:`repro.core.autotune.select_sharded_plan` to pick the shard count
    jointly with schedule, path, and boundary schedule over power-of-two
    candidate counts (the ``workload="advance_sharded"`` family, its own
    cache namespace).  With an explicit count and ``schedule="auto"`` the
    same selector picks (schedule, path, boundary) for that count; fully
    explicit arguments skip the autotuner entirely.

    ``shard_schedule`` names a boundary schedule from
    :data:`SHARD_SCHEDULES` (where the contiguous split points fall);
    ``None`` defaults to ``"equal_width"`` when everything else is
    explicit, and to joint auto-selection over all registered boundary
    schedules whenever the autotuner runs anyway.  Pass
    ``shard_schedule="auto"`` to force boundary selection even with an
    explicit count and schedule.

    The direction threshold is computed **once from the global work views**
    (the same call the single-device inspector makes) and handed to every
    shard, so direction policy is a global constant; likewise ``delta`` (a
    static bucket width) is estimated from the global weight distribution.
    Per-shard inspection then runs the ordinary
    :func:`~repro.sparse.advance.build_advance_views` on each shard's
    rebased CSR slices with overridden ``push_src`` (padded-layout source
    ids) and ``out_degrees`` (owned vertices only).
    """
    num_blocks = DEFAULT_NUM_BLOCKS if num_blocks is None else num_blocks
    V = graph.num_vertices
    fwd = graph.csr
    rev = fwd.transpose()

    mesh = None
    if isinstance(num_shards, Mesh):
        mesh = num_shards
        if len(mesh.axis_names) != 1:
            raise ValueError(f"sharded advance needs a 1-axis mesh, got "
                             f"axes {mesh.axis_names}")
        S = int(np.prod(list(mesh.shape.values())))
    elif num_shards is None or num_shards == "auto":
        S = None
    else:
        S = int(num_shards)
        if S < 1:
            raise ValueError(f"num_shards must be >= 1, got {S}")

    if shard_schedule is not None and shard_schedule != "auto" \
            and shard_schedule not in SHARD_SCHEDULES:
        raise ValueError(
            f"unknown shard schedule {shard_schedule!r} (expected one of "
            f"{sorted(SHARD_SCHEDULES)} or 'auto')")
    auto_sched = (str(schedule) not in _CHUNK_POLICIES
                  and Schedule(schedule) == Schedule.AUTO)
    auto_boundary = shard_schedule in (None, "auto")
    if S is None or auto_sched or shard_schedule == "auto":
        counts = [S] if S is not None else _candidate_shard_counts(V)
        bnames = (tuple(SHARD_SCHEDULES) if auto_boundary
                  else (shard_schedule,))
        bounds_by_count = {}
        for c in counts:
            per, seen = {}, set()
            for bname in bnames:
                if c > max(V, 1) and bname != "equal_width":
                    continue  # degree-aware splits reject S > V
                arr = _schedule_boundaries(fwd, rev, V, c, bname)
                key = tuple(int(x) for x in arr)
                if key in seen:
                    continue  # identical split: first (default) name wins
                seen.add(key)
                per[bname] = arr
            bounds_by_count[c] = per
        plans = REGISTERED_PLANS
        if not auto_sched:
            sched_enum, _ = _resolve_schedule_enum(schedule)
            plans = (tuple(p for p in REGISTERED_PLANS
                           if p.schedule == sched_enum)
                     or (Plan(sched_enum),))
        if ExecutionPath(path) != ExecutionPath.AUTO:
            plans = (tuple(p for p in plans
                           if p.path == ExecutionPath(path)) or plans)
        sp: ShardedPlan = select_sharded_plan(
            rev.workspec(), bounds_by_count, num_blocks,
            push_spec=fwd.workspec(), plans=plans, measure=measure)
        if S is None:
            S = sp.num_shards
        if auto_boundary:
            shard_schedule = sp.boundary
        if auto_sched:
            schedule = sp.schedule
            if ExecutionPath(path) == ExecutionPath.AUTO:
                path = sp.path
    boundary_name = ("equal_width" if shard_schedule in (None, "auto")
                     else shard_schedule)

    if mesh is None:
        mesh = make_graph_mesh(S)
    axis = mesh.axis_names[0]

    bounds = _schedule_boundaries(fwd, rev, V, S, boundary_name)
    ranges = list(zip(bounds[:-1].tolist(), bounds[1:].tolist()))
    shard_size = max(max(hi - lo for lo, hi in ranges), 1)
    V_pad = S * shard_size
    glob2pad, pad2glob = _boundary_permutation(bounds, shard_size)
    e_pull = _direction_e_max(rev.row_offsets, ranges)
    e_push = _direction_e_max(fwd.row_offsets, ranges)

    # Global direction threshold: exactly the single-device inspector's
    # computation over the global work views, so S=1 matches unsharded
    # plans bit-for-bit and S>1 shards never disagree about direction.
    sched_enum, policy = _resolve_schedule_enum(schedule)
    if direction_threshold is None:
        pull_spec_g = rev.workspec()
        push_spec_g = fwd.workspec()
        pull_part_g = make_partition(pull_spec_g, sched_enum, num_blocks,
                                     chunk_policy=policy or "lpt")
        push_part_g = make_partition(push_spec_g, sched_enum, num_blocks,
                                     chunk_policy=policy or "lpt")
        direction_threshold = estimate_direction_threshold(
            pull_spec_g, push_spec_g, num_blocks,
            pull_schedule=sched_enum, push_schedule=sched_enum,
            pull_path=str(choose_execution_path(pull_part_g,
                                                ExecutionPath(path))),
            push_path=str(choose_execution_path(push_part_g,
                                                ExecutionPath(path))),
            pull_part=pull_part_g, push_part=push_part_g)

    # Mesh-global compaction capacity: resolve ``compact`` once from the
    # *global* edge count — the same resolution
    # :func:`~repro.sparse.advance.build_advance_views` applies to the
    # whole-graph push view — and hand every shard the concrete slot count.
    # Resolving per shard would size capacities from the padded local
    # ``E_max``: uniform across shards only incidentally (every shard pads
    # to the same width) and drifting from single-device semantics for
    # fractional ``compact=``.  A global bound keeps ``compact=`` composing
    # with ``mesh=`` on every driver and makes the statics-agreement
    # assertion below structural; executors clamp the capacity to their
    # local window count at run time, so a bound above a shard's padded
    # edge count stays correct.
    if compact is None or compact is False:
        compact_resolved: Optional[int] = None
    elif compact is True:
        compact_resolved = estimate_compact_capacity(
            graph.num_edges, float(direction_threshold))
    elif isinstance(compact, float):
        if not 0.0 < compact <= 1.0:
            raise ValueError(f"compact fraction must be in (0, 1], "
                             f"got {compact}")
        compact_resolved = max(int(np.ceil(graph.num_edges * compact)), 1)
    else:
        if int(compact) < 1:
            raise ValueError(f"compact capacity must be >= 1 (or None/"
                             f"False to disable), got {compact}")
        compact_resolved = int(compact)

    shard_plans, pull_valids, push_valids = [], [], []
    spread_pad = boundary_name != "equal_width"
    fwd_roff = np.asarray(fwd.row_offsets)
    for lo, hi in ranges:
        poffs, pcols, pvals, pvalid = _local_csr_view(
            rev.row_offsets, rev.col_indices, rev.values, lo, hi,
            shard_size, e_pull, spread_pad=spread_pad)
        qoffs, qcols, qvals, qvalid = _local_csr_view(
            fwd.row_offsets, fwd.col_indices, fwd.values, lo, hi,
            shard_size, e_push, spread_pad=spread_pad)
        pull_spec = WorkSpec.from_segment_offsets(jnp.asarray(poffs),
                                                  num_atoms=e_pull)
        push_spec = WorkSpec.from_segment_offsets(jnp.asarray(qoffs),
                                                  num_atoms=e_push)
        # owned vertices' real out-degrees, independent of where the
        # padding atoms were binned (spread_pad puts them in empty slots)
        out_deg = np.zeros(shard_size, np.int32)
        out_deg[:hi - lo] = np.diff(fwd_roff[lo:hi + 1]).astype(np.int32)
        tids = np.asarray(push_spec.atom_tile_ids())
        # pad atoms: source 0 (masked anyway), destination the dropped
        # overflow row V_pad; real atoms carry *padded-layout* ids so the
        # halo gather and the collective push combine index the gathered
        # padded state directly (identity mapping for equal_width).
        push_src = np.where(qvalid,
                            glob2pad[np.where(qvalid, lo + tids, 0)],
                            0).astype(np.int32)
        push_dst = np.where(qvalid,
                            glob2pad[np.where(qvalid, qcols, 0)],
                            V_pad).astype(np.int32)
        pull_src = glob2pad[pcols].astype(np.int32)
        plan = build_advance_views(
            pull_spec=pull_spec, pull_src=jnp.asarray(pull_src),
            pull_weight=jnp.asarray(pvals),
            push_spec=push_spec, push_dst=jnp.asarray(push_dst),
            push_weight=jnp.asarray(qvals),
            push_src=jnp.asarray(push_src),
            num_vertices=V_pad, schedule=schedule, num_blocks=num_blocks,
            path=path, workload=workload,
            direction_threshold=float(direction_threshold),
            compact=compact_resolved,
            out_degrees=jnp.asarray(out_deg), interpret=interpret)
        shard_plans.append(plan)
        pull_valids.append(jnp.asarray(pvalid))
        push_valids.append(jnp.asarray(qvalid))

    statics = [(p.schedule, p.path, p.push_schedule, p.push_path,
                p.direction_threshold, p.compact_capacity)
               for p in shard_plans]
    if any(s != statics[0] for s in statics[1:]):
        raise AssertionError(f"per-shard plan statics diverged: {statics}")

    pull_parts = _uniform_partitions([p.part for p in shard_plans])
    push_parts = _uniform_partitions([p.push_part for p in shard_plans])
    shard_plans = [dataclasses.replace(p, part=a, push_part=b)
                   for p, a, b in zip(shard_plans, pull_parts, push_parts)]

    pull_part_leaves, pull_part_td = _stack_tree(pull_parts)
    push_part_leaves, push_part_td = _stack_tree(push_parts)
    pull_spec_leaves, pull_spec_td = _stack_tree(
        [p.spec for p in shard_plans])
    push_spec_leaves, push_spec_td = _stack_tree(
        [p.push_spec for p in shard_plans])
    arrays = {f: jnp.stack([getattr(p, f) for p in shard_plans])
              for f in ("src", "weight", "dst", "push_weight", "push_src",
                        "out_degrees")}
    arrays["pull_valid"] = jnp.stack(pull_valids)
    arrays["push_valid"] = jnp.stack(push_valids)
    # each shard's real extent (uneven under degree-aware boundaries):
    # drivers read their own [1] slice to mask padding slots of the carry.
    arrays["shard_lo"] = jnp.asarray(bounds[:-1], jnp.int32)
    arrays["shard_hi"] = jnp.asarray(bounds[1:], jnp.int32)

    splan = ShardedAdvancePlan(
        mesh=mesh, axis=axis, num_shards=S, num_vertices=V,
        shard_size=shard_size, num_edges=graph.num_edges,
        template=shard_plans[0], arrays=arrays,
        pull_part_leaves=pull_part_leaves, pull_part_treedef=pull_part_td,
        push_part_leaves=push_part_leaves, push_part_treedef=push_part_td,
        pull_spec_leaves=pull_spec_leaves, pull_spec_treedef=pull_spec_td,
        push_spec_leaves=push_spec_leaves, push_spec_treedef=push_spec_td,
        shard_schedule=boundary_name,
        boundaries=tuple(int(b) for b in bounds),
        glob2pad=jnp.asarray(glob2pad), pad2glob=jnp.asarray(pad2glob))
    if delta is not None:
        splan = splan.with_delta(None if delta == "auto" else float(delta))
    return splan


# ---------------------------------------------------------------------------
# Shard-local advance ops (inside shard_map bodies)
# ---------------------------------------------------------------------------

def _pull_local(splan, lp, frontier_full, atom_fn, *, combiner, edge_mask):
    """Local pull advance -> this shard's [shard_size] owned slice."""
    atom_mask = _combined_mask(frontier_full, lp.src, edge_mask)
    out = execute_sharded_tile_reduce(
        lp.spec, lp.part, atom_fn, jnp.float32, axis_name=splan.axis,
        path=lp.path, combiner=combiner, atom_mask=atom_mask,
        interpret=lp.interpret)
    return out[:splan.shard_size]


def _push_local(splan, lp, frontier_full, atom_fn, *, combiner, edge_mask):
    """Local push advance + cross-shard combine -> owned [shard_size]."""
    atom_mask = _combined_mask(frontier_full, lp.push_src, edge_mask)
    full = execute_sharded_scatter_reduce(
        lp.push_spec, lp.push_part, atom_fn, lp.dst, lp.num_vertices,
        jnp.float32, axis_name=splan.axis, path=lp.push_path,
        combiner=combiner, atom_mask=atom_mask,
        compact_capacity=lp.compact_capacity, interpret=lp.interpret)
    lo = jax.lax.axis_index(splan.axis) * splan.shard_size
    return jax.lax.dynamic_slice(full, (lo,), (splan.shard_size,))


def _subset_mask(lp, direction: str, edges: str, valid):
    """Edge-subset mask ANDed with the shard's padding-validity mask."""
    em = lp.edge_set_mask(edges, direction)
    return valid if em is None else jnp.logical_and(valid, em)


def _directed_sharded(splan, direction: str, active_edges, push_fn, pull_fn):
    """Direction switch on *global* measured density (psum'd count)."""
    if direction == "push":
        return push_fn(), jnp.bool_(True)
    if direction == "pull":
        return pull_fn(), jnp.bool_(False)
    density = splan.edge_fraction(active_edges)
    use_push = density < jnp.float32(splan.direction_threshold)
    return (jax.lax.cond(use_push, lambda _: push_fn(), lambda _: pull_fn(),
                         operand=None), use_push)


def _relax_local(splan, lp, pvalid, qvalid, direction, dist_full,
                 frontier_full, active_edges, edges: str = "all"):
    """One direction-resolved local min-relax; returns (cand, used_push)."""
    def push():
        src, w = lp.push_src, lp.push_weight
        return _push_local(splan, lp, frontier_full,
                           lambda e: dist_full[src[e]] + w[e],
                           combiner="min",
                           edge_mask=_subset_mask(lp, "push", edges, qvalid))

    def pull():
        src, w = lp.src, lp.weight
        return _pull_local(splan, lp, frontier_full,
                           lambda e: dist_full[src[e]] + w[e],
                           combiner="min",
                           edge_mask=_subset_mask(lp, "pull", edges, pvalid))

    return _directed_sharded(splan, direction, active_edges, push, pull)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def _make_bfs_fn(splan: ShardedAdvancePlan, max_iters: int, direction: str,
                 return_parents: bool):
    """The shard_map'ed single-source BFS loop (vmap-able over source)."""
    n, axis = splan.shard_size, splan.axis

    def body_fn(data, src):
        lp, pvalid, qvalid = _local_plan(splan, data)
        slots = jax.lax.axis_index(axis) * n + jnp.arange(n, dtype=jnp.int32)
        frontier0 = slots == data["glob"]["glob2pad"][src]
        depth0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
        parent0 = jnp.full((n,), jnp.int32(-1))
        outdeg = lp.out_degrees

        def g_active(f_l):
            return jax.lax.psum(
                jnp.sum(jnp.where(f_l, outdeg, 0)).astype(jnp.int32), axis)

        def g_count(f_l):
            return jax.lax.psum(jnp.sum(f_l).astype(jnp.int32), axis)

        def cond(s):
            return jnp.logical_and(s[0] < max_iters, s[6] > 0)

        def body(s):
            i, depth, parent, frontier_l, active_edges, pushes, _ = s
            full_f = jax.lax.all_gather(frontier_l, axis, tiled=True)
            if return_parents:
                def push():
                    srcs = lp.push_src
                    return _push_local(
                        splan, lp, full_f,
                        lambda e: srcs[e].astype(jnp.float32),
                        combiner="min", edge_mask=qvalid)

                def pull():
                    srcs = lp.src
                    return _pull_local(
                        splan, lp, full_f,
                        lambda e: srcs[e].astype(jnp.float32),
                        combiner="min", edge_mask=pvalid)

                cand, used_push = _directed_sharded(
                    splan, direction, active_edges, push, pull)
                cand = jnp.where(jnp.isfinite(cand), cand,
                                 -1.0).astype(jnp.int32)
                newly = jnp.logical_and(cand >= 0, depth < 0)
                parent = jnp.where(newly, cand, parent)
            else:
                unit = lambda e: jnp.ones(e.shape, jnp.float32)

                def push():
                    return _push_local(splan, lp, full_f, unit,
                                       combiner="max", edge_mask=qvalid)

                def pull():
                    return _pull_local(splan, lp, full_f, unit,
                                       combiner="max", edge_mask=pvalid)

                reached, used_push = _directed_sharded(
                    splan, direction, active_edges, push, pull)
                newly = jnp.logical_and(reached > 0.0, depth < 0)
            depth = jnp.where(newly, i + 1, depth)
            return (i + 1, depth, parent, newly, g_active(newly),
                    pushes + used_push.astype(jnp.int32), g_count(newly))

        state = jax.lax.while_loop(
            cond, body,
            (0, depth0, parent0 if return_parents else jnp.int32(0),
             frontier0, g_active(frontier0), jnp.int32(0),
             g_count(frontier0)))
        iters, pushes = jnp.int32(state[0]), state[5]
        parent = state[2]
        if return_parents:
            # parents were min-reduced in padded-slot coordinates (monotone
            # in global id, so the winning edge is the same); hand the
            # caller global vertex ids.
            p2g = data["glob"]["pad2glob"]
            parent = jnp.where(parent >= 0,
                               p2g[jnp.maximum(parent, 0)], jnp.int32(-1))
        return state[1], parent, jnp.stack([pushes, iters - pushes])

    return shard_map(
        body_fn, mesh=splan.mesh, in_specs=(_data_specs(axis), P()),
        out_specs=(P(axis), P(axis) if return_parents else P(), P()),
        check=False)


def sharded_bfs(splan: ShardedAdvancePlan, source, *,
                max_iters: Optional[int] = None,
                return_parents: bool = False, direction: str = "auto",
                return_direction_counts: bool = False):
    """Sharded BFS; same contract (and bits) as :func:`repro.sparse.graph.bfs`."""
    _check_driver_direction(direction)
    V = splan.num_vertices
    _validate_sources(source, V)
    if return_parents and splan.padded_vertices >= (1 << 24):
        raise ValueError(
            f"sharded BFS parents reduce vertex ids as f32, exact only "
            f"below 2**24 padded vertices (got {splan.padded_vertices})")
    max_iters = V if max_iters is None else max_iters
    run = _make_bfs_fn(splan, max_iters, direction, return_parents)
    depth_pad, parent_pad, counts = run(splan.data(),
                                        jnp.asarray(source, jnp.int32))
    out = (splan.to_global(depth_pad),)
    if return_parents:
        out = out + (splan.to_global(parent_pad),)
    if return_direction_counts:
        out = out + (counts,)
    return out[0] if len(out) == 1 else out


def sharded_bfs_multi(splan: ShardedAdvancePlan, sources, *,
                      max_iters: Optional[int] = None,
                      direction: str = "pull") -> jax.Array:
    """Batched sharded BFS: ``jax.vmap`` over the shard_map'ed loop.

    Default direction pull, same rationale as the single-device driver —
    under vmap the direction ``lax.cond`` lowers to both-branch selects.
    """
    _check_driver_direction(direction)
    V = splan.num_vertices
    _validate_sources(sources, V, what="bfs_multi sources")
    max_iters = V if max_iters is None else max_iters
    run = _make_bfs_fn(splan, max_iters, direction, return_parents=False)
    data = splan.data()
    sources = jnp.asarray(sources, jnp.int32)
    depths = jax.vmap(lambda s: run(data, s)[0])(sources)
    return splan.to_global(depths)


def sharded_sssp(splan: ShardedAdvancePlan, source, *,
                 max_iters: Optional[int] = None, direction: str = "auto",
                 algorithm: str = "bellman_ford",
                 delta: Optional[float] = None,
                 return_direction_counts: bool = False):
    """Sharded SSSP; same contract (and bits) as :func:`repro.sparse.graph.sssp`."""
    _check_driver_direction(direction)
    if algorithm not in _SSSP_ALGORITHMS:
        raise ValueError(f"unknown algorithm: {algorithm!r} "
                         f"(expected one of {_SSSP_ALGORITHMS})")
    if algorithm == "delta":
        return sharded_delta_stepping(
            splan, source, delta=delta, max_iters=max_iters,
            direction=direction,
            return_direction_counts=return_direction_counts)
    V = splan.num_vertices
    _validate_sources(source, V)
    max_iters = V if max_iters is None else max_iters
    n, axis = splan.shard_size, splan.axis

    def body_fn(data, src):
        lp, pvalid, qvalid = _local_plan(splan, data)
        slots = jax.lax.axis_index(axis) * n + jnp.arange(n, dtype=jnp.int32)
        frontier0 = slots == data["glob"]["glob2pad"][src]
        dist0 = jnp.where(frontier0, 0.0, INF)
        outdeg = lp.out_degrees

        def g_active(f_l):
            return jax.lax.psum(
                jnp.sum(jnp.where(f_l, outdeg, 0)).astype(jnp.int32), axis)

        def g_count(f_l):
            return jax.lax.psum(jnp.sum(f_l).astype(jnp.int32), axis)

        def cond(s):
            return jnp.logical_and(s[0] < max_iters, s[5] > 0)

        def body(s):
            i, dist_l, frontier_l, active_edges, pushes, _ = s
            full_f = jax.lax.all_gather(frontier_l, axis, tiled=True)
            full_d = jax.lax.all_gather(dist_l, axis, tiled=True)
            cand, used_push = _relax_local(splan, lp, pvalid, qvalid,
                                           direction, full_d, full_f,
                                           active_edges)
            new_dist = jnp.minimum(dist_l, cand)
            new_frontier = new_dist < dist_l
            return (i + 1, new_dist, new_frontier, g_active(new_frontier),
                    pushes + used_push.astype(jnp.int32),
                    g_count(new_frontier))

        state = jax.lax.while_loop(
            cond, body, (0, dist0, frontier0, g_active(frontier0),
                         jnp.int32(0), g_count(frontier0)))
        iters, pushes = jnp.int32(state[0]), state[4]
        return state[1], jnp.stack([pushes, iters - pushes])

    run = shard_map(body_fn, mesh=splan.mesh,
                    in_specs=(_data_specs(axis), P()),
                    out_specs=(P(axis), P()), check=False)
    dist_pad, counts = run(splan.data(), jnp.asarray(source, jnp.int32))
    dist = splan.to_global(dist_pad)
    if return_direction_counts:
        return dist, counts
    return dist


def sharded_delta_stepping(splan: ShardedAdvancePlan, source, *,
                           delta: Optional[float] = None,
                           max_iters: Optional[int] = None,
                           direction: str = "auto",
                           return_direction_counts: bool = False):
    """Sharded delta-stepping; bit-identical to the single-device driver.

    Same nested-loop structure as :func:`repro.sparse.graph.delta_stepping`
    (light inner loop, one heavy relax per settled bucket, Bellman-Ford
    mop-up backstop), with every termination/bucket scalar made global:
    the active bucket is a ``pmin`` over shards, the in-bucket and
    needs-relaxing populations are psum'd counts threaded through the
    carries so the ``while_loop`` conds stay collective-free.
    """
    _check_driver_direction(direction)
    V = splan.num_vertices
    _validate_sources(source, V)
    if splan.delta is None or (delta is not None
                               and float(delta) != splan.delta):
        splan = splan.with_delta(delta)
    width = splan.delta
    max_outer = (V + 2) if max_iters is None else max_iters
    inner_cap = V + 1
    n, axis = splan.shard_size, splan.axis

    def body_fn(data, src):
        lp, pvalid, qvalid = _local_plan(splan, data)
        slots = jax.lax.axis_index(axis) * n + jnp.arange(n, dtype=jnp.int32)
        needs0 = slots == data["glob"]["glob2pad"][src]
        dist0 = jnp.where(needs0, 0.0, INF)
        light_out = lp.light_out_degrees
        heavy_out = lp.out_degrees - light_out

        def g_active(mask_l, deg_l):
            return jax.lax.psum(
                jnp.sum(jnp.where(mask_l, deg_l, 0)).astype(jnp.int32), axis)

        def g_count(mask_l):
            return jax.lax.psum(jnp.sum(mask_l).astype(jnp.int32), axis)

        def relax(dist_l, frontier_l, active, edges):
            full_f = jax.lax.all_gather(frontier_l, axis, tiled=True)
            full_d = jax.lax.all_gather(dist_l, axis, tiled=True)
            cand, used_push = _relax_local(splan, lp, pvalid, qvalid,
                                           direction, full_d, full_f,
                                           active, edges=edges)
            return jnp.minimum(dist_l, cand), used_push

        def outer_cond(s):
            return jnp.logical_and(s[0] < max_outer, s[4] > 0)

        def outer_body(s):
            i, dist_l, needs_l, counts, _ = s
            bucket = jax.lax.pmin(
                jnp.min(jnp.where(needs_l, _bucket_of(dist_l, width),
                                  _FAR_BUCKET)), axis)

            def inner_cond(t):
                return jnp.logical_and(t[0] < inner_cap, t[5] > 0)

            def inner_body(t):
                j, dist_l, needs_l, settled_l, counts, _ = t
                frontier_l = jnp.logical_and(
                    needs_l, _bucket_of(dist_l, width) == bucket)
                new_dist, used_push = relax(
                    dist_l, frontier_l, g_active(frontier_l, light_out),
                    "light")
                improved = new_dist < dist_l
                needs_l = jnp.logical_or(
                    jnp.logical_and(needs_l, ~frontier_l), improved)
                nxt = jnp.logical_and(needs_l,
                                      _bucket_of(new_dist, width) == bucket)
                return (j + 1, new_dist, needs_l,
                        jnp.logical_or(settled_l, frontier_l),
                        counts.at[jnp.where(used_push, 0, 1)].add(1),
                        g_count(nxt))

            in0 = jnp.logical_and(needs_l,
                                  _bucket_of(dist_l, width) == bucket)
            _, dist_l, needs_l, settled_l, counts, _ = jax.lax.while_loop(
                inner_cond, inner_body,
                (0, dist_l, needs_l, jnp.zeros((n,), bool), counts,
                 g_count(in0)))

            # heavy phase: unconditional — an empty settled frontier makes
            # the relax a no-op (identity everywhere), and skipping the
            # single-device driver's lax.cond keeps all collectives on the
            # unconditionally-traced path of the SPMD program.
            active_heavy = g_active(settled_l, heavy_out)
            new_dist, used_push = relax(dist_l, settled_l, active_heavy,
                                        "heavy")
            counts = jnp.where(
                active_heavy > 0,
                counts.at[jnp.where(used_push, 0, 1)].add(1), counts)
            needs_l = jnp.logical_or(needs_l, new_dist < dist_l)
            return (i + 1, new_dist, needs_l, counts, g_count(needs_l))

        _, dist_l, needs_l, counts, nneeds = jax.lax.while_loop(
            outer_cond, outer_body,
            (0, dist0, needs0, jnp.zeros((2,), jnp.int32),
             g_count(needs0)))

        def mop_cond(s):
            return jnp.logical_and(s[0] < V, s[4] > 0)

        def mop_body(s):
            j, dist_l, needs_l, counts, _ = s
            new_dist, used_push = relax(
                dist_l, needs_l, g_active(needs_l, lp.out_degrees), "all")
            new_needs = new_dist < dist_l
            return (j + 1, new_dist, new_needs,
                    counts.at[jnp.where(used_push, 0, 1)].add(1),
                    g_count(new_needs))

        _, dist_l, _, counts, _ = jax.lax.while_loop(
            mop_cond, mop_body, (0, dist_l, needs_l, counts, nneeds))
        return dist_l, counts

    run = shard_map(body_fn, mesh=splan.mesh,
                    in_specs=(_data_specs(axis), P()),
                    out_specs=(P(axis), P()), check=False)
    dist_pad, counts = run(splan.data(), jnp.asarray(source, jnp.int32))
    dist = splan.to_global(dist_pad)
    if return_direction_counts:
        return dist, counts
    return dist


def sharded_pagerank(splan: ShardedAdvancePlan, *, damping: float = 0.85,
                     num_iters: int = 50, tol: float = 0.0,
                     direction: str = "auto") -> jax.Array:
    """Sharded PageRank; matches :func:`repro.sparse.graph.pagerank`.

    Pull contributions are per-destination reductions over the same rebased
    atom segments as single-device, so pull results are bit-identical
    whenever the sums themselves are exactly representable; the dangling
    term is a psum of per-shard partial sums (order differs from a single
    device's one-pass sum, so general float graphs agree to tolerance, and
    dyadic constructions agree bitwise).  Padding rows are pinned to rank 0
    every iteration — they would otherwise absorb base/dangling mass and
    corrupt the real rows' next iteration.
    """
    _check_driver_direction(direction)
    direction = "pull" if direction == "auto" else direction
    V = splan.num_vertices
    if V == 0:
        return jnp.zeros((0,), jnp.float32)
    n, axis = splan.shard_size, splan.axis

    def body_fn(data):
        lp, pvalid, qvalid = _local_plan(splan, data)
        width = data["arrays"]["shard_hi"][0] - data["arrays"]["shard_lo"][0]
        is_real = jnp.arange(n, dtype=jnp.int32) < width
        outdeg = lp.out_degrees.astype(jnp.float32)
        pr0 = jnp.where(is_real, 1.0 / V, 0.0).astype(jnp.float32)

        def cond(s):
            return jnp.logical_and(s[0] < num_iters, s[2] > tol)

        def body(s):
            i, pr_l, _ = s
            share_l = _pagerank_share(pr_l, outdeg)
            full_share = jax.lax.all_gather(share_l, axis, tiled=True)
            if direction == "push":
                srcs = lp.push_src
                contrib = _push_local(splan, lp, None,
                                      lambda e: full_share[srcs[e]],
                                      combiner="sum", edge_mask=qvalid)
            else:
                srcs = lp.src
                contrib = _pull_local(splan, lp, None,
                                      lambda e: full_share[srcs[e]],
                                      combiner="sum", edge_mask=pvalid)
            dangling = jax.lax.psum(
                jnp.sum(jnp.where(outdeg > 0, 0.0, pr_l)), axis)
            new_pr = _pagerank_update(contrib, dangling, damping, V)
            new_pr = jnp.where(is_real, new_pr, 0.0)
            step = jax.lax.psum(jnp.abs(new_pr - pr_l).sum(), axis)
            return i + 1, new_pr, step

        _, pr_l, _ = jax.lax.while_loop(cond, body,
                                        (0, pr0, jnp.float32(jnp.inf)))
        return pr_l

    run = shard_map(body_fn, mesh=splan.mesh, in_specs=(_data_specs(axis),),
                    out_specs=P(axis), check=False)
    return splan.to_global(run(splan.data()))
