"""Data-centric graph primitives on the load-balancing abstraction (§5.3).

BFS / SSSP are frontier-based *advance* operations: atoms = edges of the
graph, tiles = source vertices — the same WorkSpec vocabulary as SpMV.  The
paper's Listing 5 loops over assigned edges, finds each edge's source tile
via ``get_tile(edge)``, and relaxes with ``atomicMin``.

TPU adaptation: per-iteration dynamic frontiers would force dynamic shapes,
so the advance processes the full static edge set with a frontier *mask*
(a standard direction-free dense advance — the linear-algebra view the paper
cites from GraphBLAST) and relaxes with a vectorized scatter-min
(``.at[].min``), JAX's deterministic ``atomicMin``.  Iterations run under
``lax.while_loop`` — the host-side analogue of persistent-kernel mode
(paper §5.1 ``infinite_range``), since Pallas has no device-wide sync.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sparse.formats import CSR

INF = jnp.float32(jnp.inf)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph as CSR adjacency; ``weights`` parallel to edges."""

    csr: CSR

    def tree_flatten(self):
        return ((self.csr,), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        (csr,) = children
        return cls(csr)

    @property
    def num_vertices(self) -> int:
        return self.csr.shape[0]

    @property
    def num_edges(self) -> int:
        return self.csr.nnz

    def edge_sources(self) -> jax.Array:
        """tile-of-atom: the paper's ``get_tile(edge)`` for every edge."""
        return self.csr.workspec().atom_tile_ids()


def sssp(graph: Graph, source: int, *, max_iters: int | None = None
         ) -> jax.Array:
    """Single-source shortest path; returns distances [V] (inf = unreached)."""
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    src_ids = graph.edge_sources()                     # [E]
    dst_ids = graph.csr.col_indices                    # [E]
    weights = graph.csr.values                         # [E]

    dist0 = jnp.full((V,), INF).at[source].set(0.0)
    frontier0 = jnp.zeros((V,), bool).at[source].set(True)

    def cond(state):
        i, _, frontier = state
        return jnp.logical_and(i < max_iters, frontier.any())

    def body(state):
        i, dist, frontier = state
        # Paper Listing 5 body, vectorized over every edge atom:
        active = frontier[src_ids]
        cand = jnp.where(active, dist[src_ids] + weights, INF)
        new_dist = dist.at[dst_ids].min(cand)
        new_frontier = new_dist < dist
        return i + 1, new_dist, new_frontier

    _, dist, _ = jax.lax.while_loop(cond, body, (0, dist0, frontier0))
    return dist


def bfs(graph: Graph, source: int, *, max_iters: int | None = None
        ) -> jax.Array:
    """BFS depth labels [V] (-1 = unreached); same advance, unit weights."""
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    src_ids = graph.edge_sources()
    dst_ids = graph.csr.col_indices

    depth0 = jnp.full((V,), jnp.int32(-1)).at[source].set(0)
    frontier0 = jnp.zeros((V,), bool).at[source].set(True)

    def cond(state):
        i, _, frontier = state
        return jnp.logical_and(i < max_iters, frontier.any())

    def body(state):
        i, depth, frontier = state
        active = frontier[src_ids]
        reached = jnp.zeros((V,), bool).at[dst_ids].max(active)
        newly = jnp.logical_and(reached, depth < 0)
        depth = jnp.where(newly, i + 1, depth)
        return i + 1, depth, newly

    _, depth, _ = jax.lax.while_loop(cond, body, (0, depth0, frontier0))
    return depth
