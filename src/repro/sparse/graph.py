"""Data-centric graph algorithms on the load-balancing abstraction (§5.3).

BFS / SSSP / PageRank are frontier-based *advance* operations: atoms = edges
of the graph, tiles = vertices — the same WorkSpec vocabulary as SpMV.  The
paper's Listing 5 loops over assigned edges, finds each edge's tile via
``get_tile(edge)``, and relaxes with ``atomicMin``.

All three drivers here are thin iteration loops around
:mod:`repro.sparse.advance`: the graph topology is inspected **once** into
an :class:`~repro.sparse.advance.AdvancePlan` (a pull/push plan *pair*),
then every iteration runs the balanced advance through
``repro.core.execute`` — any registered schedule (static, chunked queue,
adaptive, or cost-model ``"auto"``), either execution path (pure blocked
executor or the native chunk-walking Pallas kernel), selected by argument.
Iterations run under ``lax.while_loop`` — the host-side analogue of
persistent-kernel mode (paper §5.1 ``infinite_range``), since Pallas has no
device-wide sync.

**Direction optimization** (Beamer's push/pull switch, the §5.3 traversal
regime): with ``direction="auto"`` (the default) BFS and SSSP measure the
frontier's out-edge fraction — a masked sum threaded through the while-loop
carry — and run the *push* advance (only frontier out-edges do work) while
the frontier is sparse, switching to *pull* (stream all in-edges, no
scatter) once the measured density crosses the plan's modeled
``direction_threshold``.  Both directions produce identical bits for the
exact min/max combiners, so switching never changes results — only cost.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ExecutionPath, Schedule
from repro.sparse.advance import (AdvancePlan, advance, advance_frontier,
                                  advance_push, advance_relax_min,
                                  advance_src_argmin, build_advance)
from repro.sparse.formats import CSR

INF = jnp.float32(jnp.inf)

#: Accepted ``direction=`` spellings for the traversal drivers.
_DRIVER_DIRECTIONS = ("auto", "pull", "push")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph as CSR adjacency; ``weights`` parallel to edges."""

    csr: CSR

    def tree_flatten(self):
        return ((self.csr,), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        (csr,) = children
        return cls(csr)

    @property
    def num_vertices(self) -> int:
        return self.csr.shape[0]

    @property
    def num_edges(self) -> int:
        return self.csr.nnz

    def edge_sources(self) -> jax.Array:
        """tile-of-atom: the paper's ``get_tile(edge)`` for every edge."""
        return self.csr.workspec().atom_tile_ids()

    def out_degrees(self) -> jax.Array:
        return self.csr.workspec().atoms_per_tile()

    def advance_plan(self, *, schedule: Schedule | str = "auto",
                     num_blocks: Optional[int] = None,
                     path: ExecutionPath | str = ExecutionPath.AUTO,
                     workload: str = "advance",
                     direction_threshold: Optional[float] = None,
                     interpret: bool = True) -> AdvancePlan:
        """One-time inspector: see :func:`repro.sparse.advance.build_advance`."""
        return build_advance(self, schedule=schedule, num_blocks=num_blocks,
                             path=path, workload=workload,
                             direction_threshold=direction_threshold,
                             interpret=interpret)


def _resolve_plan(graph: Graph, plan: Optional[AdvancePlan],
                  schedule, num_blocks, path, interpret,
                  workload: str = "advance") -> AdvancePlan:
    if plan is not None:
        return plan
    return build_advance(graph, schedule=schedule, num_blocks=num_blocks,
                         path=path, workload=workload, interpret=interpret)


def _check_driver_direction(direction: str) -> str:
    if direction not in _DRIVER_DIRECTIONS:
        raise ValueError(f"unknown direction: {direction!r} "
                         f"(expected one of {_DRIVER_DIRECTIONS})")
    return direction


def _active_edge_count(plan: AdvancePlan, frontier: jax.Array) -> jax.Array:
    """Out-edges leaving the frontier — the measured-density carry term."""
    return jnp.sum(jnp.where(frontier, plan.out_degrees, 0)).astype(jnp.int32)


def _directed(plan: AdvancePlan, direction: str, active_edges: jax.Array,
              push_fn, pull_fn):
    """Run one advance in the requested / measured-density direction.

    ``direction`` is static; for ``"auto"`` the switch is a traced
    ``lax.cond`` on the carried active-out-edge count against the plan's
    modeled threshold, so only the chosen branch executes at runtime.
    Returns ``(result, used_push)``.
    """
    if direction == "push":
        return push_fn(), jnp.bool_(True)
    if direction == "pull":
        return pull_fn(), jnp.bool_(False)
    density = plan.edge_fraction(active_edges)
    use_push = density < jnp.float32(plan.direction_threshold)
    return (jax.lax.cond(use_push, lambda _: push_fn(), lambda _: pull_fn(),
                         operand=None), use_push)


def sssp(graph: Graph, source: int, *, max_iters: Optional[int] = None,
         schedule: Schedule | str = "auto",
         num_blocks: Optional[int] = None,
         path: ExecutionPath | str = ExecutionPath.AUTO,
         plan: Optional[AdvancePlan] = None,
         direction: str = "auto",
         interpret: bool = True) -> jax.Array:
    """Single-source shortest path; returns distances [V] (inf = unreached).

    Frontier-driven Bellman-Ford: each iteration relaxes every edge whose
    source improved last round (Listing 5's advance, min-combiner), then the
    frontier filter keeps only the vertices whose distance just dropped.
    ``direction`` picks the advance orientation per iteration (``"auto"``:
    measured density vs. the plan threshold); min is exact, so every
    direction policy returns identical bits.
    """
    _check_driver_direction(direction)
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)

    dist0 = jnp.full((V,), INF).at[source].set(0.0)
    frontier0 = jnp.zeros((V,), bool).at[source].set(True)

    def cond(state):
        i, _, frontier, _ = state
        return jnp.logical_and(i < max_iters, frontier.any())

    def body(state):
        i, dist, frontier, active_edges = state
        cand, _ = _directed(
            aplan, direction, active_edges,
            lambda: advance_relax_min(aplan, dist, frontier,
                                      direction="push"),
            lambda: advance_relax_min(aplan, dist, frontier,
                                      direction="pull"))
        new_dist = jnp.minimum(dist, cand)
        new_frontier = new_dist < dist
        return (i + 1, new_dist, new_frontier,
                _active_edge_count(aplan, new_frontier))

    _, dist, _, _ = jax.lax.while_loop(
        cond, body, (0, dist0, frontier0, _active_edge_count(aplan,
                                                             frontier0)))
    return dist


def _bfs_loop(aplan: AdvancePlan, source: jax.Array, max_iters: int,
              direction: str, return_parents: bool):
    """Shared BFS while-loop (single-source; vmap-able over ``source``).

    The carry threads ``(iteration, depth, [parent], frontier,
    active_out_edges, push_iterations)`` — the active-edge count is the
    measured frontier density the ``"auto"`` direction switches on, and the
    push counter is what the drivers report as direction statistics.
    """
    V = aplan.num_vertices
    ids = jnp.arange(V, dtype=jnp.int32)
    source = jnp.asarray(source, jnp.int32)
    frontier0 = ids == source
    depth0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    parent0 = jnp.full((V,), jnp.int32(-1))

    def cond(state):
        return jnp.logical_and(state[0] < max_iters, state[3].any())

    def body(state):
        # parent rides the carry only when requested (a dead [V] buffer
        # per vmap lane otherwise); slot 2 is a scalar placeholder then
        i, depth, parent, frontier, active_edges, pushes = state
        if return_parents:
            # one advance does both jobs: cand >= 0 iff the destination has
            # an active in-edge, so the scatter-or sweep is redundant here
            cand, used_push = _directed(
                aplan, direction, active_edges,
                lambda: advance_src_argmin(aplan, frontier,
                                           direction="push"),
                lambda: advance_src_argmin(aplan, frontier,
                                           direction="pull"))
            newly = jnp.logical_and(cand >= 0, depth < 0)
            parent = jnp.where(newly, cand, parent)
        else:
            reached, used_push = _directed(
                aplan, direction, active_edges,
                lambda: advance_frontier(aplan, frontier, direction="push"),
                lambda: advance_frontier(aplan, frontier, direction="pull"))
            newly = jnp.logical_and(reached, depth < 0)
        depth = jnp.where(newly, i + 1, depth)
        return (i + 1, depth, parent, newly,
                _active_edge_count(aplan, newly),
                pushes + used_push.astype(jnp.int32))

    state = jax.lax.while_loop(
        cond, body, (0, depth0, parent0 if return_parents else jnp.int32(0),
                     frontier0, _active_edge_count(aplan, frontier0),
                     jnp.int32(0)))
    iters, depth = state[0], state[1]
    parent = state[2] if return_parents else parent0
    pushes = state[5]
    return depth, parent, jnp.stack([pushes,
                                     jnp.int32(iters) - pushes])


def bfs(graph: Graph, source: int, *, max_iters: Optional[int] = None,
        schedule: Schedule | str = "auto",
        num_blocks: Optional[int] = None,
        path: ExecutionPath | str = ExecutionPath.AUTO,
        plan: Optional[AdvancePlan] = None,
        return_parents: bool = False,
        direction: str = "auto",
        return_direction_counts: bool = False,
        interpret: bool = True):
    """BFS depth labels [V] (-1 = unreached); same advance, unit weights.

    ``return_parents=True`` additionally returns parent pointers [V]
    (-1 at the source and unreached vertices): each newly reached vertex's
    parent is its smallest frontier in-neighbour — deterministic, unlike
    the GPU's atomic race, and checkable (``depth[parent[v]] ==
    depth[v] - 1``) — in either direction (min over the same id multiset).

    ``direction="auto"`` (default) is direction-optimizing: push while the
    measured frontier out-edge fraction is below the plan's threshold, pull
    above.  ``return_direction_counts=True`` appends an int32 ``[2]`` array
    ``(push_iterations, pull_iterations)`` to the result tuple — the
    benchmark/CI evidence that the switch actually exercised both
    directions.
    """
    _check_driver_direction(direction)
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)

    depth, parent, counts = _bfs_loop(aplan, source, max_iters, direction,
                                      return_parents)
    out = (depth,)
    if return_parents:
        out = out + (parent,)
    if return_direction_counts:
        out = out + (counts,)
    return out[0] if len(out) == 1 else out


def bfs_multi(graph: Graph, sources, *, max_iters: Optional[int] = None,
              schedule: Schedule | str = "auto",
              num_blocks: Optional[int] = None,
              path: ExecutionPath | str = ExecutionPath.AUTO,
              plan: Optional[AdvancePlan] = None,
              direction: str = "pull",
              interpret: bool = True) -> jax.Array:
    """Batched multi-source BFS: depth labels ``[S, V]`` for ``sources[s]``.

    One plan pair serves the whole batch — the inspector runs once and
    ``jax.vmap`` maps the shared while-loop over per-source carries.  This
    is the multi-source traversal the plan-pair design exists for:
    topology inspection is per *graph*, not per source.

    Default direction is ``"pull"``, not ``"auto"``: under vmap the
    direction ``lax.cond`` lowers to a select that executes *both*
    branches for every batch lane, so measured-density switching costs
    push + pull per iteration — strictly worse than either fixed
    direction.  ``"auto"`` stays available for batch sizes small enough
    that result-identical semantics matter more than the double advance.
    """
    _check_driver_direction(direction)
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)
    sources = jnp.asarray(sources, jnp.int32)

    def run(src):
        depth, _, _ = _bfs_loop(aplan, src, max_iters, direction,
                                return_parents=False)
        return depth

    return jax.vmap(run)(sources)


def pagerank(graph: Graph, *, damping: float = 0.85, num_iters: int = 50,
             tol: float = 0.0,
             schedule: Schedule | str = "auto",
             num_blocks: Optional[int] = None,
             path: ExecutionPath | str = ExecutionPath.AUTO,
             plan: Optional[AdvancePlan] = None,
             direction: str = "auto",
             interpret: bool = True) -> jax.Array:
    """Power-iteration PageRank [V] through the balanced advance.

    The per-iteration kernel is a full (unmasked) sum-combiner advance —
    structurally a pull-SpMV of the degree-normalized adjacency, which is
    exactly the paper's point: graph analytics and sparse linear algebra
    share one load-balancing abstraction.  Dangling mass (zero out-degree
    vertices) is redistributed uniformly; stops early when the L1 step
    change drops to ``tol``.

    The frontier is always full (density 1.0), so ``direction="auto"``
    resolves to pull at build time — no per-iteration switch to pay for.
    ``direction="push"`` runs the scatter form instead (summation order
    differs, so expect ulp-level float differences, not bit-identity).
    """
    _check_driver_direction(direction)
    direction = "pull" if direction == "auto" else direction
    V = graph.num_vertices
    if V == 0:
        return jnp.zeros((0,), jnp.float32)
    # full-frontier sum-advance: no mask load/select per atom, so "auto"
    # scores the plain "reduce" cost family, not the masked-advance one
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret,
                          workload="reduce")
    outdeg = graph.out_degrees().astype(jnp.float32)
    src = aplan.push_src if direction == "push" else aplan.src

    pr0 = jnp.full((V,), 1.0 / V, jnp.float32)

    def cond(state):
        i, _, delta = state
        return jnp.logical_and(i < num_iters, delta > tol)

    def body(state):
        i, pr, _ = state
        share = jnp.where(outdeg > 0, pr / jnp.maximum(outdeg, 1.0), 0.0)
        atom_fn = lambda e: share[src[e]]
        if direction == "push":
            contrib = advance_push(aplan, None, atom_fn, combiner="sum")
        else:
            contrib = advance(aplan, None, atom_fn, combiner="sum")
        dangling = jnp.sum(jnp.where(outdeg > 0, 0.0, pr))
        new_pr = (1.0 - damping) / V + damping * (contrib + dangling / V)
        return i + 1, new_pr, jnp.abs(new_pr - pr).sum()

    _, pr, _ = jax.lax.while_loop(cond, body, (0, pr0, jnp.float32(jnp.inf)))
    return pr
