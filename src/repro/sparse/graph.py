"""Data-centric graph algorithms on the load-balancing abstraction (§5.3).

BFS / SSSP / PageRank are frontier-based *advance* operations: atoms = edges
of the graph, tiles = vertices — the same WorkSpec vocabulary as SpMV.  The
paper's Listing 5 loops over assigned edges, finds each edge's tile via
``get_tile(edge)``, and relaxes with ``atomicMin``.

All three drivers here are thin iteration loops around
:mod:`repro.sparse.advance`: the graph topology is inspected **once** into
an :class:`~repro.sparse.advance.AdvancePlan` (transpose CSR + Partition),
then every iteration runs the balanced advance through
``repro.core.execute.execute_tile_reduce`` — any registered schedule
(static, chunked queue, adaptive, or cost-model ``"auto"``), either
execution path (pure blocked executor or the native chunk-walking Pallas
kernel), selected by argument.  Iterations run under ``lax.while_loop`` —
the host-side analogue of persistent-kernel mode (paper §5.1
``infinite_range``), since Pallas has no device-wide sync.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ExecutionPath, Schedule
from repro.sparse.advance import (AdvancePlan, advance, advance_frontier,
                                  advance_relax_min, advance_src_argmin,
                                  build_advance)
from repro.sparse.formats import CSR

INF = jnp.float32(jnp.inf)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph as CSR adjacency; ``weights`` parallel to edges."""

    csr: CSR

    def tree_flatten(self):
        return ((self.csr,), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        (csr,) = children
        return cls(csr)

    @property
    def num_vertices(self) -> int:
        return self.csr.shape[0]

    @property
    def num_edges(self) -> int:
        return self.csr.nnz

    def edge_sources(self) -> jax.Array:
        """tile-of-atom: the paper's ``get_tile(edge)`` for every edge."""
        return self.csr.workspec().atom_tile_ids()

    def out_degrees(self) -> jax.Array:
        return self.csr.workspec().atoms_per_tile()

    def advance_plan(self, *, schedule: Schedule | str = "auto",
                     num_blocks: Optional[int] = None,
                     path: ExecutionPath | str = ExecutionPath.AUTO,
                     workload: str = "advance",
                     interpret: bool = True) -> AdvancePlan:
        """One-time inspector: see :func:`repro.sparse.advance.build_advance`."""
        return build_advance(self, schedule=schedule, num_blocks=num_blocks,
                             path=path, workload=workload,
                             interpret=interpret)


def _resolve_plan(graph: Graph, plan: Optional[AdvancePlan],
                  schedule, num_blocks, path, interpret,
                  workload: str = "advance") -> AdvancePlan:
    if plan is not None:
        return plan
    return build_advance(graph, schedule=schedule, num_blocks=num_blocks,
                         path=path, workload=workload, interpret=interpret)


def sssp(graph: Graph, source: int, *, max_iters: Optional[int] = None,
         schedule: Schedule | str = "auto",
         num_blocks: Optional[int] = None,
         path: ExecutionPath | str = ExecutionPath.AUTO,
         plan: Optional[AdvancePlan] = None,
         interpret: bool = True) -> jax.Array:
    """Single-source shortest path; returns distances [V] (inf = unreached).

    Frontier-driven Bellman-Ford: each iteration relaxes every edge whose
    source improved last round (Listing 5's advance, min-combiner), then the
    frontier filter keeps only the vertices whose distance just dropped.
    """
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)

    dist0 = jnp.full((V,), INF).at[source].set(0.0)
    frontier0 = jnp.zeros((V,), bool).at[source].set(True)

    def cond(state):
        i, _, frontier = state
        return jnp.logical_and(i < max_iters, frontier.any())

    def body(state):
        i, dist, frontier = state
        cand = advance_relax_min(aplan, dist, frontier)
        new_dist = jnp.minimum(dist, cand)
        new_frontier = new_dist < dist
        return i + 1, new_dist, new_frontier

    _, dist, _ = jax.lax.while_loop(cond, body, (0, dist0, frontier0))
    return dist


def bfs(graph: Graph, source: int, *, max_iters: Optional[int] = None,
        schedule: Schedule | str = "auto",
        num_blocks: Optional[int] = None,
        path: ExecutionPath | str = ExecutionPath.AUTO,
        plan: Optional[AdvancePlan] = None,
        return_parents: bool = False,
        interpret: bool = True):
    """BFS depth labels [V] (-1 = unreached); same advance, unit weights.

    ``return_parents=True`` additionally returns parent pointers [V]
    (-1 at the source and unreached vertices): each newly reached vertex's
    parent is its smallest frontier in-neighbour — deterministic, unlike
    the GPU's atomic race, and checkable (``depth[parent[v]] ==
    depth[v] - 1``).
    """
    V = graph.num_vertices
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)

    depth0 = jnp.full((V,), jnp.int32(-1)).at[source].set(0)
    parent0 = jnp.full((V,), jnp.int32(-1))
    frontier0 = jnp.zeros((V,), bool).at[source].set(True)

    def cond(state):
        i = state[0]
        frontier = state[-1]
        return jnp.logical_and(i < max_iters, frontier.any())

    def body(state):
        if return_parents:
            i, depth, parent, frontier = state
        else:
            i, depth, frontier = state
        if return_parents:
            # one advance does both jobs: cand >= 0 iff the destination has
            # an active in-edge, so the scatter-or sweep is redundant here
            cand = advance_src_argmin(aplan, frontier)
            newly = jnp.logical_and(cand >= 0, depth < 0)
            depth = jnp.where(newly, i + 1, depth)
            parent = jnp.where(newly, cand, parent)
            return i + 1, depth, parent, newly
        reached = advance_frontier(aplan, frontier)
        newly = jnp.logical_and(reached, depth < 0)
        depth = jnp.where(newly, i + 1, depth)
        return i + 1, depth, newly

    if return_parents:
        state = jax.lax.while_loop(cond, body,
                                   (0, depth0, parent0, frontier0))
        return state[1], state[2]
    _, depth, _ = jax.lax.while_loop(cond, body, (0, depth0, frontier0))
    return depth


def pagerank(graph: Graph, *, damping: float = 0.85, num_iters: int = 50,
             tol: float = 0.0,
             schedule: Schedule | str = "auto",
             num_blocks: Optional[int] = None,
             path: ExecutionPath | str = ExecutionPath.AUTO,
             plan: Optional[AdvancePlan] = None,
             interpret: bool = True) -> jax.Array:
    """Power-iteration PageRank [V] through the balanced advance.

    The per-iteration kernel is a full (unmasked) sum-combiner advance —
    structurally a pull-SpMV of the degree-normalized adjacency, which is
    exactly the paper's point: graph analytics and sparse linear algebra
    share one load-balancing abstraction.  Dangling mass (zero out-degree
    vertices) is redistributed uniformly; stops early when the L1 step
    change drops to ``tol``.
    """
    V = graph.num_vertices
    if V == 0:
        return jnp.zeros((0,), jnp.float32)
    # full-frontier sum-advance: no mask load/select per atom, so "auto"
    # scores the plain "reduce" cost family, not the masked-advance one
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret,
                          workload="reduce")
    outdeg = graph.out_degrees().astype(jnp.float32)
    src = aplan.src

    pr0 = jnp.full((V,), 1.0 / V, jnp.float32)

    def cond(state):
        i, _, delta = state
        return jnp.logical_and(i < num_iters, delta > tol)

    def body(state):
        i, pr, _ = state
        share = jnp.where(outdeg > 0, pr / jnp.maximum(outdeg, 1.0), 0.0)
        contrib = advance(aplan, None, lambda e: share[src[e]],
                          combiner="sum")
        dangling = jnp.sum(jnp.where(outdeg > 0, 0.0, pr))
        new_pr = (1.0 - damping) / V + damping * (contrib + dangling / V)
        return i + 1, new_pr, jnp.abs(new_pr - pr).sum()

    _, pr, _ = jax.lax.while_loop(cond, body, (0, pr0, jnp.float32(jnp.inf)))
    return pr
