"""Data-centric graph algorithms on the load-balancing abstraction (§5.3).

BFS / SSSP / PageRank are frontier-based *advance* operations: atoms = edges
of the graph, tiles = vertices — the same WorkSpec vocabulary as SpMV.  The
paper's Listing 5 loops over assigned edges, finds each edge's tile via
``get_tile(edge)``, and relaxes with ``atomicMin``.

All three drivers here are thin iteration loops around
:mod:`repro.sparse.advance`: the graph topology is inspected **once** into
an :class:`~repro.sparse.advance.AdvancePlan` (a pull/push plan *pair*),
then every iteration runs the balanced advance through
``repro.core.execute`` — any registered schedule (static, chunked queue,
adaptive, or cost-model ``"auto"``), either execution path (pure blocked
executor or the native chunk-walking Pallas kernel), selected by argument.
Iterations run under ``lax.while_loop`` — the host-side analogue of
persistent-kernel mode (paper §5.1 ``infinite_range``), since Pallas has no
device-wide sync.

**Direction optimization** (Beamer's push/pull switch, the §5.3 traversal
regime): with ``direction="auto"`` (the default) BFS and SSSP measure the
frontier's out-edge fraction — a masked sum threaded through the while-loop
carry — and run the *push* advance (only frontier out-edges do work) while
the frontier is sparse, switching to *pull* (stream all in-edges, no
scatter) once the measured density crosses the plan's modeled
``direction_threshold``.  Both directions produce identical bits for the
exact min/max combiners, so switching never changes results — only cost.

**Bucketed traversal** (this PR): :func:`delta_stepping` (also
``sssp(algorithm="delta")``) runs Meyer & Sanders' delta-stepping as
nested while-loops of light/heavy-restricted advances over the same plan
pair — bit-identical to Bellman-Ford for every bucket width, because both
run f32 relaxation to the same fixed point.  Concrete out-of-range sources
raise at build time in every driver (under jit they would silently clamp
into wrong-but-plausible results).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import opt_barrier
from repro.core import ExecutionPath, Schedule
from repro.sparse.advance import (AdvancePlan, advance, advance_frontier,
                                  advance_push, advance_relax_min,
                                  advance_src_argmin, build_advance,
                                  estimate_delta)
from repro.sparse.formats import CSR

INF = jnp.float32(jnp.inf)

#: Accepted ``direction=`` spellings for the traversal drivers.
_DRIVER_DIRECTIONS = ("auto", "pull", "push")

#: Accepted ``algorithm=`` spellings for :func:`sssp`.
_SSSP_ALGORITHMS = ("bellman_ford", "delta")

#: Bucket index standing in for +inf distances (far above any reachable
#: bucket: distances are clamped into int32 range before the floor).
_FAR_BUCKET = jnp.int32(2 ** 30)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph as CSR adjacency; ``weights`` parallel to edges."""

    csr: CSR

    def tree_flatten(self):
        return ((self.csr,), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        (csr,) = children
        return cls(csr)

    @property
    def num_vertices(self) -> int:
        return self.csr.shape[0]

    @property
    def num_edges(self) -> int:
        return self.csr.nnz

    def edge_sources(self) -> jax.Array:
        """tile-of-atom: the paper's ``get_tile(edge)`` for every edge."""
        return self.csr.workspec().atom_tile_ids()

    def out_degrees(self) -> jax.Array:
        return self.csr.workspec().atoms_per_tile()

    def advance_plan(self, *, schedule: Schedule | str = "auto",
                     num_blocks: Optional[int] = None,
                     path: ExecutionPath | str = ExecutionPath.AUTO,
                     workload: str = "advance",
                     direction_threshold: Optional[float] = None,
                     interpret: bool = True) -> AdvancePlan:
        """One-time inspector: see :func:`repro.sparse.advance.build_advance`."""
        return build_advance(self, schedule=schedule, num_blocks=num_blocks,
                             path=path, workload=workload,
                             direction_threshold=direction_threshold,
                             interpret=interpret)


def _resolve_plan(graph: Graph, plan: Optional[AdvancePlan],
                  schedule, num_blocks, path, interpret,
                  workload: str = "advance", delta=None,
                  compact=None) -> AdvancePlan:
    if plan is not None:
        return plan
    return build_advance(graph, schedule=schedule, num_blocks=num_blocks,
                         path=path, workload=workload, delta=delta,
                         compact=compact, interpret=interpret)


def _wants_sharded(plan, mesh) -> bool:
    """Route to the device-sharded drivers?  Either an explicit ``mesh=``
    request or a prebuilt :class:`~repro.sparse.shard.ShardedAdvancePlan`
    (the one plan type that is not an :class:`AdvancePlan`)."""
    return mesh is not None or (plan is not None
                                and not isinstance(plan, AdvancePlan))


def _resolve_sharded_plan(graph: Graph, plan, mesh, schedule, num_blocks,
                          path, interpret, workload: str = "advance",
                          delta=None, compact=None, shard_schedule=None):
    """The sharded sibling of :func:`_resolve_plan` (lazy import: the shard
    module pulls in mesh/collective machinery single-device users never
    touch)."""
    from repro.sparse import shard as _shard
    if plan is not None:
        if not isinstance(plan, _shard.ShardedAdvancePlan):
            raise TypeError(
                f"mesh= traversal needs a ShardedAdvancePlan (from "
                f"build_sharded_advance), got {type(plan).__name__}")
        return _shard, plan
    return _shard, _shard.build_sharded_advance(
        graph, mesh, schedule=schedule, num_blocks=num_blocks, path=path,
        workload=workload, shard_schedule=shard_schedule, delta=delta,
        compact=compact, interpret=interpret)


def _check_driver_direction(direction: str) -> str:
    if direction not in _DRIVER_DIRECTIONS:
        raise ValueError(f"unknown direction: {direction!r} "
                         f"(expected one of {_DRIVER_DIRECTIONS})")
    return direction


def _validate_sources(sources, num_vertices: int, *,
                      what: str = "source") -> None:
    """Reject out-of-range traversal sources at build time.

    Under jit, ``dist0.at[source].set(0.0)`` and ``ids == source`` silently
    clamp/drop out-of-range indices and negative sources wrap Python-style,
    so a bad source returns wrong-but-plausible labels instead of failing.
    The drivers run this host-side check on every *concrete* source (the
    common case — sources are inspector-time inputs, like the plan);
    traced sources pass through unchecked, as any shape-polymorphic jit
    argument must.
    """
    if isinstance(sources, jax.core.Tracer):
        return
    arr = np.asarray(sources)
    if arr.size == 0:
        return
    if not np.issubdtype(arr.dtype, np.integer):
        arr = arr.astype(np.int64)
    lo, hi = int(arr.min()), int(arr.max())
    if lo < 0 or hi >= num_vertices:
        bad = arr[(arr < 0) | (arr >= num_vertices)]
        raise ValueError(
            f"{what} out of range for graph with {num_vertices} "
            f"vertices: {bad.reshape(-1)[:8].tolist()} (valid range "
            f"[0, {num_vertices - 1}])" if num_vertices else
            f"{what} {bad.reshape(-1)[:8].tolist()} on an empty graph "
            f"(no valid sources)")


def _active_edge_count(plan: AdvancePlan, frontier: jax.Array) -> jax.Array:
    """Out-edges leaving the frontier — the measured-density carry term."""
    return jnp.sum(jnp.where(frontier, plan.out_degrees, 0)).astype(jnp.int32)


def _directed(plan: AdvancePlan, direction: str, active_edges: jax.Array,
              push_fn, pull_fn):
    """Run one advance in the requested / measured-density direction.

    ``direction`` is static; for ``"auto"`` the switch is a traced
    ``lax.cond`` on the carried active-out-edge count against the plan's
    modeled threshold, so only the chosen branch executes at runtime.
    Returns ``(result, used_push)``.
    """
    if direction == "push":
        return push_fn(), jnp.bool_(True)
    if direction == "pull":
        return pull_fn(), jnp.bool_(False)
    density = plan.edge_fraction(active_edges)
    use_push = density < jnp.float32(plan.direction_threshold)
    return (jax.lax.cond(use_push, lambda _: push_fn(), lambda _: pull_fn(),
                         operand=None), use_push)


def _relax_directed(aplan: AdvancePlan, direction: str, dist: jax.Array,
                    frontier: jax.Array, active_edges: jax.Array,
                    edges: str = "all"):
    """One direction-resolved min-relax; returns (new_dist, used_push)."""
    cand, used_push = _directed(
        aplan, direction, active_edges,
        lambda: advance_relax_min(aplan, dist, frontier, direction="push",
                                  edges=edges),
        lambda: advance_relax_min(aplan, dist, frontier, direction="pull",
                                  edges=edges))
    return jnp.minimum(dist, cand), used_push


def sssp(graph: Graph, source: int, *, max_iters: Optional[int] = None,
         schedule: Schedule | str = "auto",
         num_blocks: Optional[int] = None,
         path: ExecutionPath | str = ExecutionPath.AUTO,
         plan: Optional[AdvancePlan] = None,
         mesh=None,
         shard_schedule: Optional[str] = None,
         direction: str = "auto",
         algorithm: str = "bellman_ford",
         delta: Optional[float] = None,
         return_direction_counts: bool = False,
         interpret: bool = True):
    """Single-source shortest path; returns distances [V] (inf = unreached).

    ``algorithm="bellman_ford"`` (default) is the frontier-driven
    Bellman-Ford of PR 3/4: each iteration relaxes every edge whose source
    improved last round (Listing 5's advance, min-combiner), then the
    frontier filter keeps only the vertices whose distance just dropped.
    ``algorithm="delta"`` routes to :func:`delta_stepping` (bucketed
    traversal over the same plan pair; ``delta`` pins the bucket width).
    Both algorithms run every edge relaxation to quiescence with the exact
    min combiner, so their distances are **bit-identical** for every delta,
    schedule, path, and direction policy.

    ``direction`` picks the advance orientation per iteration (``"auto"``:
    measured density vs. the plan threshold); min is exact, so every
    direction policy returns identical bits.
    ``return_direction_counts=True`` appends an int32 ``[2]``
    ``(push_iterations, pull_iterations)`` array, exactly like
    :func:`bfs` — the evidence the SSSP direction switch actually moves.

    ``mesh`` (shard count, 1-axis :class:`~jax.sharding.Mesh`, or
    ``"auto"``) runs the traversal device-sharded — see
    :mod:`repro.sparse.shard`; distances stay bit-identical for every
    boundary schedule (``shard_schedule`` from
    :data:`repro.sparse.shard.SHARD_SCHEDULES`, default equal-width).
    """
    _check_driver_direction(direction)
    if algorithm not in _SSSP_ALGORITHMS:
        raise ValueError(f"unknown algorithm: {algorithm!r} "
                         f"(expected one of {_SSSP_ALGORITHMS})")
    if algorithm == "delta":
        return delta_stepping(graph, source, delta=delta,
                              max_iters=max_iters, schedule=schedule,
                              num_blocks=num_blocks, path=path, plan=plan,
                              mesh=mesh, shard_schedule=shard_schedule,
                              direction=direction,
                              return_direction_counts=return_direction_counts,
                              interpret=interpret)
    if _wants_sharded(plan, mesh):
        _shard, splan = _resolve_sharded_plan(graph, plan, mesh, schedule,
                                              num_blocks, path, interpret,
                                              shard_schedule=shard_schedule)
        return _shard.sharded_sssp(
            splan, source, max_iters=max_iters, direction=direction,
            return_direction_counts=return_direction_counts)
    V = graph.num_vertices
    _validate_sources(source, V)
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)

    dist0 = jnp.full((V,), INF).at[source].set(0.0)
    frontier0 = jnp.zeros((V,), bool).at[source].set(True)

    def cond(state):
        i, _, frontier, _, _ = state
        return jnp.logical_and(i < max_iters, frontier.any())

    def body(state):
        i, dist, frontier, active_edges, pushes = state
        new_dist, used_push = _relax_directed(aplan, direction, dist,
                                              frontier, active_edges)
        new_frontier = new_dist < dist
        return (i + 1, new_dist, new_frontier,
                _active_edge_count(aplan, new_frontier),
                pushes + used_push.astype(jnp.int32))

    iters, dist, _, _, pushes = jax.lax.while_loop(
        cond, body, (0, dist0, frontier0,
                     _active_edge_count(aplan, frontier0), jnp.int32(0)))
    if return_direction_counts:
        return dist, jnp.stack([pushes, jnp.int32(iters) - pushes])
    return dist


def _bucket_of(dist: jax.Array, delta: float) -> jax.Array:
    """floor(dist / delta) as int32; +inf (unreached) maps far away."""
    b = jnp.floor(dist / jnp.float32(delta))
    b = jnp.minimum(b, jnp.float32(_FAR_BUCKET - 1))
    return jnp.where(jnp.isfinite(dist), b.astype(jnp.int32), _FAR_BUCKET)


def delta_stepping(graph: Graph, source: int, *,
                   delta: Optional[float] = None,
                   max_iters: Optional[int] = None,
                   schedule: Schedule | str = "auto",
                   num_blocks: Optional[int] = None,
                   path: ExecutionPath | str = ExecutionPath.AUTO,
                   plan: Optional[AdvancePlan] = None,
                   mesh=None,
                   shard_schedule: Optional[str] = None,
                   direction: str = "auto",
                   compact: Optional[bool | int | float] = True,
                   return_direction_counts: bool = False,
                   interpret: bool = True):
    """Delta-stepping SSSP (Meyer & Sanders) on the advance plan pair.

    Distances are partitioned into buckets of width ``delta``
    (:func:`repro.sparse.advance.estimate_delta` from the plan's weight
    distribution when unset).  The outer loop processes the lowest bucket
    holding a vertex that still *needs relaxing*; the inner loop repeatedly
    relaxes only the **light** edges (weight <= delta) leaving that bucket
    until it stops changing — light chains can re-enter the current bucket,
    heavy ones cannot — then the **heavy** edges of everything the bucket
    settled are relaxed once.  Both loops are ``lax.while_loop``s over the
    same plan pair as Bellman-Ford: every relaxation is an ordinary
    direction-optimized advance restricted by the plan's delta split
    (``edges="light"``/``"heavy"``), so all six schedules, both execution
    paths and all three direction policies apply unchanged, and the
    measured-density push/pull switch runs *per bucket phase* (light
    phases measure light-out-edge density, heavy phases heavy density).

    The driver tracks "needs relaxing" explicitly (a vertex re-enters
    whenever its distance improves) and terminates only when no vertex
    does, so it reaches the exact same relaxation fixed point as
    Bellman-Ford — distances are **bit-identical** to :func:`sssp` for
    every ``delta``, even when f32 bucket arithmetic mis-bins a boundary
    distance (mis-binning costs a round, never a bit).  Requires positive
    weights, like every delta-stepping.

    ``compact=True`` (default) builds the plan with gather-compacted push
    windows sized from the direction threshold — the sparse bucket
    frontiers are exactly the regime frontier compaction exists for.
    Like ``schedule``/``num_blocks``/``path``, ``compact`` is an
    *inspector* parameter: with a prebuilt ``plan=`` the plan's own
    ``compact_capacity`` governs (rebuild or pass ``build_advance(...,
    compact=)`` to change it); only ``delta`` — a per-call algorithm
    parameter, not an inspector product — is reconciled onto a prebuilt
    plan via :meth:`~repro.sparse.advance.AdvancePlan.with_delta`.
    ``max_iters`` caps *outer* rounds (default ``V + 2``: a round settles
    its bucket, and the slack absorbs boundary-rounding re-entries); if
    the cap is ever exhausted with work remaining, a plain Bellman-Ford
    backstop loop finishes the leftover relaxations, so the bit-identity
    contract holds unconditionally — a bad cap costs rounds, never bits.
    ``return_direction_counts=True`` appends (push, pull) advance counts
    across all bucket phases, as in :func:`bfs`/:func:`sssp`.
    """
    _check_driver_direction(direction)
    if _wants_sharded(plan, mesh):
        _shard, splan = _resolve_sharded_plan(
            graph, plan, mesh, schedule, num_blocks, path, interpret,
            workload="advance_delta",
            delta=delta if delta is not None else "auto", compact=compact,
            shard_schedule=shard_schedule)
        return _shard.sharded_delta_stepping(
            splan, source, delta=delta, max_iters=max_iters,
            direction=direction,
            return_direction_counts=return_direction_counts)
    V = graph.num_vertices
    _validate_sources(source, V)
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret,
                          workload="advance_delta",
                          delta=delta if delta is not None else "auto",
                          compact=compact)
    if aplan.delta is None or (delta is not None
                               and float(delta) != aplan.delta):
        aplan = aplan.with_delta(delta)
    width = aplan.delta
    max_outer = (V + 2) if max_iters is None else max_iters
    inner_cap = V + 1

    light_out = aplan.light_out_degrees
    heavy_out = aplan.out_degrees - light_out

    # Per-phase compaction capacity: a light-bucket advance can never
    # activate more atoms than the light edge set holds (that count is the
    # ceiling of the measured light density the carry tracks), so each
    # phase's static capacity is clamped to its own edge subset and sparse
    # bucket frontiers stream tighter gather-compacted windows.  The
    # executor's measured-count ``lax.cond`` still arbitrates per advance,
    # so a mis-sized capacity costs streamed volume, never bits.
    light_plan = heavy_plan = aplan
    if aplan.compact_capacity is not None and aplan.num_edges:
        # numpy on the plan's own (concrete, inspector-built) degree array:
        # the whole driver may be wrapped in jax.jit, where a jnp.sum here
        # would become a tracer and could not size a static capacity
        light_edges = int(np.asarray(aplan.light_out_degrees).sum())
        heavy_edges = aplan.num_edges - light_edges
        light_plan = aplan.with_compact_capacity(
            min(aplan.compact_capacity, max(light_edges, 1)))
        heavy_plan = aplan.with_compact_capacity(
            min(aplan.compact_capacity, max(heavy_edges, 1)))

    def _active(mask, out_deg):
        return jnp.sum(jnp.where(mask, out_deg, 0)).astype(jnp.int32)

    dist0 = jnp.full((V,), INF).at[source].set(0.0)
    needs0 = jnp.zeros((V,), bool).at[source].set(True)

    def outer_cond(state):
        i, _, needs, _ = state
        return jnp.logical_and(i < max_outer, needs.any())

    def outer_body(state):
        i, dist, needs, counts = state
        bucket = jnp.min(jnp.where(needs, _bucket_of(dist, width),
                                   _FAR_BUCKET))

        def inner_cond(s):
            j, dist, needs, _, _ = s
            in_bucket = jnp.logical_and(needs,
                                        _bucket_of(dist, width) == bucket)
            return jnp.logical_and(j < inner_cap, in_bucket.any())

        def inner_body(s):
            j, dist, needs, settled, counts = s
            frontier = jnp.logical_and(needs,
                                       _bucket_of(dist, width) == bucket)
            new_dist, used_push = _relax_directed(
                light_plan, direction, dist, frontier,
                _active(frontier, light_out), edges="light")
            improved = new_dist < dist
            needs = jnp.logical_or(jnp.logical_and(needs, ~frontier),
                                   improved)
            return (j + 1, new_dist, needs,
                    jnp.logical_or(settled, frontier),
                    counts.at[jnp.where(used_push, 0, 1)].add(1))

        _, dist, needs, settled, counts = jax.lax.while_loop(
            inner_cond, inner_body,
            (0, dist, needs, jnp.zeros((V,), bool), counts))

        # heavy phase: every vertex the bucket settled relaxes its heavy
        # out-edges once, with its final in-bucket distance.  Skipped
        # outright when the settled set has no heavy out-edges (e.g. a
        # width past the max weight — the Delta -> inf Bellman-Ford
        # degeneration must not pay a no-op advance per bucket).
        active_heavy = _active(settled, heavy_out)

        def heavy_phase(_):
            new_dist, used_push = _relax_directed(
                heavy_plan, direction, dist, settled, active_heavy,
                edges="heavy")
            return new_dist, counts.at[jnp.where(used_push, 0, 1)].add(1)

        new_dist, counts = jax.lax.cond(
            active_heavy > 0, heavy_phase, lambda _: (dist, counts),
            operand=None)
        needs = jnp.logical_or(needs, new_dist < dist)
        return (i + 1, new_dist, needs, counts)

    _, dist, needs, counts = jax.lax.while_loop(
        outer_cond, outer_body,
        (0, dist0, needs0, jnp.zeros((2,), jnp.int32)))

    # Convergence backstop: if the outer cap was exhausted with work left
    # (pathological f32 bucket re-entries can cost more rounds than the
    # slack), finish with plain frontier Bellman-Ford over ALL edges from
    # the leftover needs set — from any upper-bound state it reaches the
    # same fixed point in <= V rounds, so the bit-identity contract holds
    # *unconditionally*, never silently truncated.  In the normal case
    # needs is empty and this loop costs one predicate evaluation.
    def mop_cond(state):
        j, _, needs, _ = state
        return jnp.logical_and(j < V, needs.any())

    def mop_body(state):
        j, dist, needs, counts = state
        new_dist, used_push = _relax_directed(
            aplan, direction, dist, needs,
            _active(needs, aplan.out_degrees))
        return (j + 1, new_dist, new_dist < dist,
                counts.at[jnp.where(used_push, 0, 1)].add(1))

    _, dist, _, counts = jax.lax.while_loop(
        mop_cond, mop_body, (0, dist, needs, counts))
    if return_direction_counts:
        return dist, counts
    return dist


def _bfs_loop(aplan: AdvancePlan, source: jax.Array, max_iters: int,
              direction: str, return_parents: bool):
    """Shared BFS while-loop (single-source; vmap-able over ``source``).

    The carry threads ``(iteration, depth, [parent], frontier,
    active_out_edges, push_iterations)`` — the active-edge count is the
    measured frontier density the ``"auto"`` direction switches on, and the
    push counter is what the drivers report as direction statistics.
    """
    V = aplan.num_vertices
    ids = jnp.arange(V, dtype=jnp.int32)
    source = jnp.asarray(source, jnp.int32)
    frontier0 = ids == source
    depth0 = jnp.where(frontier0, 0, -1).astype(jnp.int32)
    parent0 = jnp.full((V,), jnp.int32(-1))

    def cond(state):
        return jnp.logical_and(state[0] < max_iters, state[3].any())

    def body(state):
        # parent rides the carry only when requested (a dead [V] buffer
        # per vmap lane otherwise); slot 2 is a scalar placeholder then
        i, depth, parent, frontier, active_edges, pushes = state
        if return_parents:
            # one advance does both jobs: cand >= 0 iff the destination has
            # an active in-edge, so the scatter-or sweep is redundant here
            cand, used_push = _directed(
                aplan, direction, active_edges,
                lambda: advance_src_argmin(aplan, frontier,
                                           direction="push"),
                lambda: advance_src_argmin(aplan, frontier,
                                           direction="pull"))
            newly = jnp.logical_and(cand >= 0, depth < 0)
            parent = jnp.where(newly, cand, parent)
        else:
            reached, used_push = _directed(
                aplan, direction, active_edges,
                lambda: advance_frontier(aplan, frontier, direction="push"),
                lambda: advance_frontier(aplan, frontier, direction="pull"))
            newly = jnp.logical_and(reached, depth < 0)
        depth = jnp.where(newly, i + 1, depth)
        return (i + 1, depth, parent, newly,
                _active_edge_count(aplan, newly),
                pushes + used_push.astype(jnp.int32))

    state = jax.lax.while_loop(
        cond, body, (0, depth0, parent0 if return_parents else jnp.int32(0),
                     frontier0, _active_edge_count(aplan, frontier0),
                     jnp.int32(0)))
    iters, depth = state[0], state[1]
    parent = state[2] if return_parents else parent0
    pushes = state[5]
    return depth, parent, jnp.stack([pushes,
                                     jnp.int32(iters) - pushes])


def bfs(graph: Graph, source: int, *, max_iters: Optional[int] = None,
        schedule: Schedule | str = "auto",
        num_blocks: Optional[int] = None,
        path: ExecutionPath | str = ExecutionPath.AUTO,
        plan: Optional[AdvancePlan] = None,
        mesh=None,
        shard_schedule: Optional[str] = None,
        return_parents: bool = False,
        direction: str = "auto",
        return_direction_counts: bool = False,
        interpret: bool = True):
    """BFS depth labels [V] (-1 = unreached); same advance, unit weights.

    ``return_parents=True`` additionally returns parent pointers [V]
    (-1 at the source and unreached vertices): each newly reached vertex's
    parent is its smallest frontier in-neighbour — deterministic, unlike
    the GPU's atomic race, and checkable (``depth[parent[v]] ==
    depth[v] - 1``) — in either direction (min over the same id multiset).

    ``direction="auto"`` (default) is direction-optimizing: push while the
    measured frontier out-edge fraction is below the plan's threshold, pull
    above.  ``return_direction_counts=True`` appends an int32 ``[2]`` array
    ``(push_iterations, pull_iterations)`` to the result tuple — the
    benchmark/CI evidence that the switch actually exercised both
    directions.

    ``mesh`` (shard count, 1-axis :class:`~jax.sharding.Mesh`, or
    ``"auto"``) runs the traversal device-sharded — see
    :mod:`repro.sparse.shard`; depths and parents stay bit-identical for
    every boundary schedule (``shard_schedule``).
    """
    _check_driver_direction(direction)
    if _wants_sharded(plan, mesh):
        _shard, splan = _resolve_sharded_plan(graph, plan, mesh, schedule,
                                              num_blocks, path, interpret,
                                              shard_schedule=shard_schedule)
        return _shard.sharded_bfs(
            splan, source, max_iters=max_iters,
            return_parents=return_parents, direction=direction,
            return_direction_counts=return_direction_counts)
    V = graph.num_vertices
    _validate_sources(source, V)
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)

    depth, parent, counts = _bfs_loop(aplan, source, max_iters, direction,
                                      return_parents)
    out = (depth,)
    if return_parents:
        out = out + (parent,)
    if return_direction_counts:
        out = out + (counts,)
    return out[0] if len(out) == 1 else out


def bfs_multi(graph: Graph, sources, *, max_iters: Optional[int] = None,
              schedule: Schedule | str = "auto",
              num_blocks: Optional[int] = None,
              path: ExecutionPath | str = ExecutionPath.AUTO,
              plan: Optional[AdvancePlan] = None,
              mesh=None,
              shard_schedule: Optional[str] = None,
              direction: str = "pull",
              interpret: bool = True) -> jax.Array:
    """Batched multi-source BFS: depth labels ``[S, V]`` for ``sources[s]``.

    One plan pair serves the whole batch — the inspector runs once and
    ``jax.vmap`` maps the shared while-loop over per-source carries.  This
    is the multi-source traversal the plan-pair design exists for:
    topology inspection is per *graph*, not per source.

    Default direction is ``"pull"``, not ``"auto"``: under vmap the
    direction ``lax.cond`` lowers to a select that executes *both*
    branches for every batch lane, so measured-density switching costs
    push + pull per iteration — strictly worse than either fixed
    direction.  ``"auto"`` stays available for batch sizes small enough
    that result-identical semantics matter more than the double advance.

    ``mesh`` runs each lane device-sharded (``jax.vmap`` over the
    ``shard_map``-ed loop — the batch axis composes with the mesh axis).
    """
    _check_driver_direction(direction)
    if _wants_sharded(plan, mesh):
        _shard, splan = _resolve_sharded_plan(graph, plan, mesh, schedule,
                                              num_blocks, path, interpret,
                                              shard_schedule=shard_schedule)
        return _shard.sharded_bfs_multi(splan, sources, max_iters=max_iters,
                                        direction=direction)
    V = graph.num_vertices
    _validate_sources(sources, V, what="bfs_multi sources")
    max_iters = V if max_iters is None else max_iters
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret)
    sources = jnp.asarray(sources, jnp.int32)

    def run(src):
        depth, _, _ = _bfs_loop(aplan, src, max_iters, direction,
                                return_parents=False)
        return depth

    return jax.vmap(run)(sources)


def _pagerank_share(pr: jax.Array, outdeg: jax.Array) -> jax.Array:
    """Degree-normalized contribution vector (dangling rows emit zero)."""
    return opt_barrier(
        jnp.where(outdeg > 0, pr / jnp.maximum(outdeg, 1.0), 0.0))


def _pagerank_update(contrib: jax.Array, dangling: jax.Array,
                     damping: float, V: int) -> jax.Array:
    """New rank vector from advance output, with rounding pinned per op.

    The naive one-liner ``(1-d)/V + d*(contrib + dangling/V)`` is
    fusion-sensitive: XLA forms FMAs differently depending on the
    surrounding compilation unit (eager op-by-op, a jitted body, a
    ``while_loop`` body, a vmapped lane inside a jitted serving step), so
    the same inputs round to ulp-different bits per context.  Every driver
    and the serving layer must agree bitwise, so each intermediate is
    pinned behind an ``optimization_barrier`` — forcing one individually
    rounded op sequence everywhere.  :func:`_pagerank_share` pins the
    share vector for the same reason.
    """
    contrib, dangling = opt_barrier((contrib, dangling))
    total = opt_barrier(contrib + dangling / V)
    scaled = opt_barrier(damping * total)
    return (1.0 - damping) / V + scaled


def pagerank(graph: Graph, *, damping: float = 0.85, num_iters: int = 50,
             tol: float = 0.0,
             schedule: Schedule | str = "auto",
             num_blocks: Optional[int] = None,
             path: ExecutionPath | str = ExecutionPath.AUTO,
             plan: Optional[AdvancePlan] = None,
             mesh=None,
             shard_schedule: Optional[str] = None,
             direction: str = "auto",
             interpret: bool = True) -> jax.Array:
    """Power-iteration PageRank [V] through the balanced advance.

    The per-iteration kernel is a full (unmasked) sum-combiner advance —
    structurally a pull-SpMV of the degree-normalized adjacency, which is
    exactly the paper's point: graph analytics and sparse linear algebra
    share one load-balancing abstraction.  Dangling mass (zero out-degree
    vertices) is redistributed uniformly; stops early when the L1 step
    change drops to ``tol``.

    The frontier is always full (density 1.0), so ``direction="auto"``
    resolves to pull at build time — no per-iteration switch to pay for.
    ``direction="push"`` runs the scatter form instead (summation order
    differs, so expect ulp-level float differences, not bit-identity).

    ``mesh`` runs the iteration device-sharded (pull contributions stay
    per-destination reductions over the same atom segments; the dangling
    sum becomes a psum of per-shard partials).
    """
    _check_driver_direction(direction)
    direction = "pull" if direction == "auto" else direction
    if _wants_sharded(plan, mesh):
        _shard, splan = _resolve_sharded_plan(graph, plan, mesh, schedule,
                                              num_blocks, path, interpret,
                                              workload="reduce",
                                              shard_schedule=shard_schedule)
        return _shard.sharded_pagerank(splan, damping=damping,
                                       num_iters=num_iters, tol=tol,
                                       direction=direction)
    V = graph.num_vertices
    if V == 0:
        return jnp.zeros((0,), jnp.float32)
    # full-frontier sum-advance: no mask load/select per atom, so "auto"
    # scores the plain "reduce" cost family, not the masked-advance one
    aplan = _resolve_plan(graph, plan, schedule, num_blocks, path, interpret,
                          workload="reduce")
    outdeg = graph.out_degrees().astype(jnp.float32)
    src = aplan.push_src if direction == "push" else aplan.src

    pr0 = jnp.full((V,), 1.0 / V, jnp.float32)

    def cond(state):
        i, _, delta = state
        return jnp.logical_and(i < num_iters, delta > tol)

    def body(state):
        i, pr, _ = state
        share = _pagerank_share(pr, outdeg)
        atom_fn = lambda e: share[src[e]]
        if direction == "push":
            contrib = advance_push(aplan, None, atom_fn, combiner="sum")
        else:
            contrib = advance(aplan, None, atom_fn, combiner="sum")
        dangling = jnp.sum(jnp.where(outdeg > 0, 0.0, pr))
        new_pr = _pagerank_update(contrib, dangling, damping, V)
        return i + 1, new_pr, jnp.abs(new_pr - pr).sum()

    # The loop runs under jit, not eagerly: XLA lowers the sum-advance's
    # reduction differently for an eagerly dispatched while_loop than for
    # a jit-compiled one (even with the barrier-pinned update), and the
    # serving layer's jitted step must reproduce driver bits exactly.
    # Compiling here puts both in the same regime (see serve/graph.py).
    run = jax.jit(lambda p0: jax.lax.while_loop(
        cond, body, (0, p0, jnp.float32(jnp.inf))))
    _, pr, _ = run(pr0)
    return pr
