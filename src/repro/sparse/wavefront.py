"""Topological wavefront scheduling: DAG/tree evaluation as a balanced
frontier workload.

Nothing in the frontier machinery requires graph *traversal*: dependency-
ordered computation over trees and DAGs (TreeLSTM-style recursive
evaluation, expression forests, task graphs) is the same abstraction with
the roles recast — **tiles = nodes, atoms = dependency in-edges**.  A
wavefront level is a frontier; the per-node work is a dense kernel
(:func:`repro.kernels.segmm.ops.level_grouped_matmul`) instead of a scalar
relax.  Atos (arXiv 2112.00132) drives exactly this wavefront-style
task-parallel dependency execution with the chunked-queue machinery this
repo already ships.

The scheduler generalizes delta-stepping's bucket loop: a node enters the
ready bucket when its **in-degree counter** — decremented by an ordinary
``advance`` over the dependency edges resolved each level — reaches zero.
Concretely, per iteration of a ``lax.while_loop`` shaped like the drivers
in :mod:`repro.sparse.graph`:

1. ``ready = (indeg == 0) & ~resolved`` — the current wavefront level;
2. the **dependency combine**: a pull advance (frontier = the resolved
   set) sums each node's already-evaluated predecessor states, one
   balanced advance per feature column under ``jax.vmap`` — any of the
   six schedules, either execution path, all bitwise-identical;
3. the **level GEMM**: every ready node's combined state hits its
   operator's weight matrix in ONE segmented matmul
   (:func:`~repro.kernels.segmm.ops.level_grouped_matmul`, grouped by
   op), committed under the ready mask — TreeLSTM-style recursion
   becomes one balanced GEMM per level instead of per-node calls;
4. the **counter decrement**: a unit-valued advance over the out-edges of
   the nodes that just resolved lowers the remaining in-degrees — next
   level's ready set emerges with no host round-trip.

The dependency CSR is inspected **once** by the ordinary
:func:`~repro.sparse.advance.build_advance` (``schedule="auto"`` routes
through the ``workload="wavefront"`` autotune family, its own cache
namespace and cost constants); acyclicity and the level count are
validated host-side at build time, so the device loop needs no cycle
guard.  Ragged forests batch through :mod:`repro.data.packing` into one
block-diagonal DAG (:func:`pack_forest`) — every tree's levels advance in
the same wavefront, which is the whole batching win.

Edge orientation: an edge ``u -> v`` in the dependency CSR means *u must
be evaluated before v* (for trees: children point at their parent).
Nodes with no in-edges are the wavefront's sources (level 0); a node's
in-degree is its dependency fan-in — the skew the schedules balance.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExecutionPath, Schedule
from repro.kernels.segmm.ops import level_grouped_matmul
from repro.sparse.advance import AdvancePlan, advance, build_advance
from repro.sparse.formats import CSR
from repro.sparse.graph import Graph

#: Named activations (string spellings resolve here; callables pass
#: through).  ``relu`` and ``identity`` are exact in every backend — the
#: bitwise conformance matrix uses them (and bounded ``clip`` callables);
#: ``tanh`` is the model-quality choice and matches NumPy only to ULP.
ACTIVATIONS = {
    "relu": lambda z: jnp.maximum(z, 0.0),
    "tanh": jnp.tanh,
    "identity": lambda z: z,
}


def _resolve_activation(activation) -> Callable[[jax.Array], jax.Array]:
    if callable(activation):
        return activation
    try:
        return ACTIVATIONS[activation]
    except KeyError:
        raise ValueError(
            f"unknown activation: {activation!r} (expected a callable or "
            f"one of {sorted(ACTIVATIONS)})") from None


def topological_levels(row_offsets: np.ndarray, col_indices: np.ndarray,
                       num_nodes: int) -> np.ndarray:
    """Kahn-style level assignment over a dependency CSR (host-side).

    ``level_of[v]`` = length of the longest dependency chain ending at
    ``v`` (sources are level 0).  Raises :class:`ValueError` on cycles —
    the nodes whose counters never reach zero.  This is the inspector
    half of the wavefront contract: the device loop below replays exactly
    these levels from the in-degree counters, so the host result doubles
    as the oracle the property tests check the driver against.
    """
    row_offsets = np.asarray(row_offsets, np.int64)
    col_indices = np.asarray(col_indices, np.int64)
    indeg = np.zeros(num_nodes, np.int64)
    np.add.at(indeg, col_indices, 1)
    level_of = np.full(num_nodes, -1, np.int32)
    frontier = np.flatnonzero(indeg == 0)
    level = 0
    placed = 0
    while frontier.size:
        level_of[frontier] = level
        placed += frontier.size
        nxt = np.concatenate(
            [col_indices[row_offsets[u]:row_offsets[u + 1]]
             for u in frontier]) if frontier.size else col_indices[:0]
        np.subtract.at(indeg, nxt, 1)
        # a successor enters the next level when its LAST in-edge resolves;
        # restrict to successors of this level so each node appears once
        cand = np.unique(nxt)
        frontier = cand[indeg[cand] == 0]
        level += 1
    if placed != num_nodes:
        stuck = np.flatnonzero(level_of < 0)
        raise ValueError(
            f"dependency graph has a cycle: {stuck.size} of {num_nodes} "
            f"nodes can never become ready (e.g. nodes "
            f"{stuck[:8].tolist()}); wavefront scheduling needs a DAG")
    return level_of


@dataclasses.dataclass(frozen=True)
class WavefrontPlan:
    """One-time inspector product for a dependency DAG.

    ``plan`` is the ordinary :class:`~repro.sparse.advance.AdvancePlan`
    pair over the dependency CSR (pull view: tiles = nodes, atoms =
    in-edges — the mapping the whole module rests on).  ``level_of`` /
    ``num_levels`` / ``level_counts`` are the host-side Kahn products:
    build-time cycle validation, the while-loop's iteration bound, and
    the per-level node histogram the benchmarks report.
    """

    plan: AdvancePlan
    num_levels: int
    level_of: np.ndarray      # [V] int32 host-side (inspector product)
    level_counts: np.ndarray  # [num_levels] int64 nodes per level

    @property
    def num_nodes(self) -> int:
        return self.plan.num_vertices

    @property
    def num_dependencies(self) -> int:
        return self.plan.num_edges

    def in_degrees(self) -> jax.Array:
        """Dependency fan-in per node — the wavefront's ready counters
        (the pull view's atoms-per-tile array, by construction)."""
        return self.plan.spec.atoms_per_tile().astype(jnp.int32)


def build_wavefront(dag: Graph, *,
                    schedule: Schedule | str = "auto",
                    num_blocks: Optional[int] = None,
                    path: ExecutionPath | str = ExecutionPath.AUTO,
                    workload: str = "wavefront",
                    measure=None,
                    interpret: bool = True) -> WavefrontPlan:
    """Inspect a dependency DAG into a :class:`WavefrontPlan`.

    One call validates acyclicity (host-side Kahn leveling — a cycle
    raises here, at build time, never silently inside the device loop)
    and builds the dependency CSR's :class:`AdvancePlan` pair through the
    ordinary :func:`~repro.sparse.advance.build_advance` inspector.
    ``schedule="auto"`` scores the ``workload="wavefront"`` family (its
    push sibling ``"wavefront_push"`` prices the forward view), so the
    dependency combine's schedule is chosen by the same cost model as
    every other workload in the repo.
    """
    level_of = topological_levels(dag.csr.row_offsets, dag.csr.col_indices,
                                  dag.num_vertices)
    num_levels = int(level_of.max()) + 1 if level_of.size else 0
    plan = build_advance(dag, schedule=schedule, num_blocks=num_blocks,
                         path=path, workload=workload, measure=measure,
                         interpret=interpret)
    counts = np.bincount(level_of, minlength=max(num_levels, 1)) \
        if level_of.size else np.zeros(0, np.int64)
    return WavefrontPlan(plan=plan, num_levels=num_levels,
                         level_of=level_of,
                         level_counts=counts[:num_levels].astype(np.int64))


def _validate_ops(op_of_node, num_ops: int, num_nodes: int) -> None:
    """Reject out-of-range operator ids at build time (concrete inputs
    only, like :func:`repro.sparse.graph._validate_sources`): under jit
    the level GEMM's block->op map clips silently, so a bad id would
    evaluate the wrong operator instead of failing."""
    if isinstance(op_of_node, jax.core.Tracer):
        return
    arr = np.asarray(op_of_node)
    if arr.shape != (num_nodes,):
        raise ValueError(f"op_of_node must have shape ({num_nodes},), "
                         f"got {arr.shape}")
    if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= num_ops):
        bad = arr[(arr < 0) | (arr >= num_ops)]
        raise ValueError(
            f"op_of_node out of range for {num_ops} operators: "
            f"{bad.reshape(-1)[:8].tolist()} (valid range "
            f"[0, {num_ops - 1}])")


def wavefront_eval(wplan: WavefrontPlan, x: jax.Array,
                   op_of_node: jax.Array, weights: jax.Array, *,
                   bias: Optional[jax.Array] = None,
                   activation="relu",
                   bm: int = 8, bn: int = 128, bk: int = 512,
                   segmm_schedule: Optional[str] = None,
                   segmm_path: Optional[str] = None,
                   return_levels: bool = False):
    """Evaluate every node of the DAG in dependency order, level by level.

    Per node ``v`` with operator ``o = op_of_node[v]``::

        h[v] = act((x[v] + sum of h[u] over dependency edges u -> v)
                   @ weights[o] + bias[o])

    ``x``: ``[V, K]`` per-node inputs; ``weights``: ``[O, K, K]`` (square:
    the recursion feeds node outputs back through the same combine, so
    output width must equal input width); ``bias``: optional ``[O, K]``;
    ``activation``: a name from :data:`ACTIVATIONS` or any jnp callable.
    Returns ``[V, K]`` f32 (with the level count actually run when
    ``return_levels=True`` — equal to ``wplan.num_levels`` by the
    build-time validation).

    The loop body runs the three balanced pieces described in the module
    docstring; the dependency combine rides ``wplan.plan``'s (schedule,
    path) and the level GEMM maps the same plan onto the segmm policies
    via :func:`~repro.kernels.segmm.ops.plan_policy` (override with
    ``segmm_schedule``/``segmm_path``).  Every per-node result is
    committed at exactly one level, after all its predecessors — with
    exactly-summable data (integer-valued f32, exact activations) the
    result is **bitwise identical** across all six schedules and both
    execution paths, and to the sequential per-node NumPy oracle
    (``tests/_conformance.py::np_wavefront``).
    """
    plan = wplan.plan
    V = plan.num_vertices
    x = jnp.asarray(x, jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    if x.ndim != 2 or x.shape[0] != V:
        raise ValueError(f"x must be [num_nodes={V}, K], got {x.shape}")
    if weights.ndim != 3 or weights.shape[1] != weights.shape[2]:
        raise ValueError(
            f"weights must be [num_ops, K, K] (square per-op matrices: "
            f"node outputs feed back through the combine), got "
            f"{weights.shape}")
    K = x.shape[1]
    num_ops = weights.shape[0]
    if weights.shape[1] != K:
        raise ValueError(f"weights feature width {weights.shape[1]} != "
                         f"input width {K}")
    if bias is not None:
        bias = jnp.asarray(bias, jnp.float32)
        if bias.shape != (num_ops, K):
            raise ValueError(f"bias must be [num_ops={num_ops}, K={K}], "
                             f"got {bias.shape}")
    _validate_ops(op_of_node, num_ops, V)
    op_of_node = jnp.asarray(op_of_node, jnp.int32)
    act = _resolve_activation(activation)
    if V == 0:
        h = jnp.zeros((0, K), jnp.float32)
        return (h, jnp.int32(0)) if return_levels else h

    src = plan.src
    unit = lambda e: jnp.ones(e.shape, jnp.float32)

    def combine(h, resolved):
        # one balanced advance per feature column: [V, K] -> [K, V] -> back
        col_adv = lambda col: advance(plan, resolved,
                                      lambda e: col[src[e]], combiner="sum")
        return jax.vmap(col_adv)(h.T).T

    def body(state):
        level, h, indeg, resolved = state
        ready = jnp.logical_and(indeg == 0, jnp.logical_not(resolved))
        combined = x + combine(h, resolved)
        z = level_grouped_matmul(combined, op_of_node, weights,
                                 num_ops=num_ops, plan=plan,
                                 schedule=segmm_schedule, path=segmm_path,
                                 bm=bm, bn=bn, bk=bk,
                                 interpret=plan.interpret)
        if bias is not None:
            z = z + bias[op_of_node]
        # each output row depends only on its own combined row, so the
        # masked commit keeps non-ready rows' (discarded) work from ever
        # touching the result — the bitwise-stability argument
        h = jnp.where(ready[:, None], act(z), h)
        resolved = jnp.logical_or(resolved, ready)
        # the generalized bucket loop: decrement each successor's counter
        # once per resolved in-edge (unit-valued advance over the edges
        # leaving this level)
        dec = advance(plan, ready, unit, combiner="sum")
        indeg = indeg - dec.astype(jnp.int32)
        return level + 1, h, indeg, resolved

    def cond(state):
        level, _, _, resolved = state
        # the level bound is host-validated (acyclic => exactly
        # num_levels iterations); the all-resolved check mirrors the
        # graph drivers' empty-frontier termination
        return jnp.logical_and(level < wplan.num_levels,
                               jnp.logical_not(jnp.all(resolved)))

    state0 = (jnp.int32(0), jnp.zeros((V, K), jnp.float32),
              wplan.in_degrees(), jnp.zeros((V,), bool))
    levels_run, h, _, _ = jax.lax.while_loop(cond, body, state0)
    return (h, levels_run) if return_levels else h


# ---------------------------------------------------------------------------
# Ragged-forest batching (data/packing.py applied to trees).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedForest:
    """A ragged forest packed into one block-diagonal dependency DAG.

    ``dag`` unions every tree (node ids offset by ``node_offsets``); its
    wavefront levels advance all trees simultaneously — level ``l`` holds
    level-``l`` nodes of *every* tree, which is what turns a forest of
    ragged recursions into one segmented matmul per level.  ``row_*`` are
    the balanced batch-row boundaries from
    :func:`repro.data.packing.pack_documents` (atoms = nodes, tiles =
    trees, processors = rows): row ``r`` owns nodes
    ``[row_node_starts[r], row_node_starts[r+1])`` of the concatenated
    node stream.
    """

    dag: Graph
    node_offsets: np.ndarray    # [T+1] node id base of each tree
    row_node_starts: jax.Array  # [R+1] balanced node split across rows
    row_tree_starts: jax.Array  # [R+1] tree split across rows
    num_rows: int

    @property
    def num_trees(self) -> int:
        return len(self.node_offsets) - 1

    def tree_slice(self, t: int) -> slice:
        """Node-id range of tree ``t`` inside the packed DAG."""
        return slice(int(self.node_offsets[t]), int(self.node_offsets[t + 1]))


def pack_forest(trees: Sequence[Union[Graph, CSR]],
                num_rows: Optional[int] = None) -> PackedForest:
    """Batch a ragged forest of dependency DAGs into one padded DAG.

    Node counts vary wildly across trees — the load-balancing problem
    :mod:`repro.data.packing` already solves for documents — so the row
    split reuses :func:`~repro.data.packing.pack_documents` verbatim
    (which also supplies the guards: an empty forest or a zero-node tree
    raises a clean :class:`ValueError` there instead of silently
    mis-packing; single-node trees are legal and common).  The returned
    block-diagonal union is an ordinary :class:`~repro.sparse.graph.Graph`
    — feed it straight to :func:`build_wavefront`.
    """
    from repro.data.packing import pack_documents
    trees = list(trees)
    if not trees:
        raise ValueError("pack_forest needs at least one tree "
                         "(got an empty forest)")
    csrs = [t.csr if isinstance(t, Graph) else t for t in trees]
    counts = np.asarray([c.shape[0] for c in csrs], np.int64)
    if num_rows is None:
        num_rows = min(len(trees), 8)
    # the packing guards vet counts/num_rows (zero-node trees, bad rows)
    node_starts, tree_starts = pack_documents(
        jnp.asarray(counts, jnp.int32), num_rows)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    total_nodes = int(offsets[-1])
    row_offsets = [np.zeros(1, np.int64)]
    cols, vals = [], []
    edge_base = 0
    for t, c in enumerate(csrs):
        ro = np.asarray(c.row_offsets, np.int64)
        row_offsets.append(ro[1:] + edge_base)
        cols.append(np.asarray(c.col_indices, np.int64) + offsets[t])
        vals.append(np.asarray(c.values, np.float32))
        edge_base += int(ro[-1])
    dag = Graph(CSR(jnp.asarray(np.concatenate(row_offsets), jnp.int32),
                    jnp.asarray(np.concatenate(cols), jnp.int32),
                    jnp.asarray(np.concatenate(vals), jnp.float32),
                    (total_nodes, total_nodes), edge_base))
    return PackedForest(dag=dag, node_offsets=offsets.astype(np.int64),
                        row_node_starts=node_starts,
                        row_tree_starts=tree_starts, num_rows=int(num_rows))
