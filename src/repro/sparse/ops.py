"""Load-balanced sparse linear algebra (paper Listings 3-4, §5.3).

``spmv``/``spmm`` are the paper's benchmark computations.  The *computation*
is 4-5 lines (the atom transform + the per-tile reduction); everything else —
which schedule partitions the work, whether the blocked executor or the
Pallas kernel consumes it — is selected by arguments, never rewritten.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import (Schedule, blocked_tile_reduce, choose_schedule,
                        execute_tile_reduce, make_partition, tile_reduce)
from repro.sparse.formats import CSR

DEFAULT_BLOCKS = 128  # grid blocks used by the blocked executors


def spmv_reference(A: CSR, x: jax.Array) -> jax.Array:
    """Oracle: one global segmented reduction (schedule-free)."""
    spec = A.workspec()
    # The paper's entire SpMV computation (Listing 3, lines 17-18):
    atom_fn = lambda nz: A.values[nz] * x[A.col_indices[nz]]
    return tile_reduce(spec, atom_fn)


def spmv(A: CSR, x: jax.Array, *, schedule: Optional[Schedule | str] = None,
         num_blocks: int = DEFAULT_BLOCKS, impl: str = "blocked") -> jax.Array:
    """Load-balanced SpMV: ``y = A @ x``.

    ``schedule=None`` applies the paper's §6.2 heuristic.  ``impl`` selects
    the executor: ``"blocked"`` (pure-JAX faithful blocked execution),
    ``"pallas"`` (the merge-path TPU kernel, see :mod:`repro.kernels`), or
    ``"reference"``.
    """
    rows, _ = A.shape
    if schedule is None:
        schedule = choose_schedule(rows, A.nnz)
    schedule = Schedule(schedule)
    if impl == "reference":
        return spmv_reference(A, x)
    if impl == "pallas":
        from repro.kernels.spmv_merge import ops as kops
        return kops.spmv_merge_path(A, x, num_blocks=num_blocks)
    spec = A.workspec()
    part = make_partition(spec, schedule, num_blocks)
    atom_fn = lambda nz: A.values[nz] * x[A.col_indices[nz]]
    return blocked_tile_reduce(spec, part, atom_fn)


def spmm(A: CSR, B: jax.Array, *, schedule: Optional[Schedule | str] = None,
         num_blocks: int = DEFAULT_BLOCKS) -> jax.Array:
    """SpMM ``C = A @ B`` — the paper's Listing 4: *one extra loop* over the
    columns of B around the unchanged SpMV computation.

    The partition is the per-*matrix* inspector output, so it is built
    exactly once per call and shared by every column; only the atom
    transform is batched (a vmap over B's columns — the per-atom gather of
    ``A``'s structure is column-invariant and hoisted by vmap).  Routing
    each column back through :func:`spmv` would re-enter schedule selection
    and partition construction per columned call path instead — the
    one-build invariant is pinned by a regression test against
    ``repro.core.schedules.partition_build_count``.
    """
    if schedule is None:
        schedule = choose_schedule(A.shape[0], A.nnz)
    spec = A.workspec()
    part = make_partition(spec, schedule, num_blocks)   # once per spmm call
    vals, cols = A.values, A.col_indices

    def one_col(b_col: jax.Array) -> jax.Array:
        # path="pure": the blocked executor vmaps cleanly (the native Pallas
        # kernel would re-launch per column instead of batching).
        return execute_tile_reduce(spec, part,
                                   lambda nz: vals[nz] * b_col[cols[nz]],
                                   path="pure")

    return jax.vmap(one_col, in_axes=1, out_axes=1)(B)


def spvv(x_sparse_vals: jax.Array, x_sparse_idx: jax.Array,
         y_dense: jax.Array) -> jax.Array:
    """Sparse-vector x dense-vector dot — the perfectly balanced case CUB
    special-cases with a thread-mapped kernel (paper Fig. 2 discussion)."""
    return jnp.dot(x_sparse_vals, y_dense[x_sparse_idx])
