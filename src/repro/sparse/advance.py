"""Load-balanced graph frontier operators (paper §5.3, Listing 5).

The paper's graph evaluation drives BFS/SSSP through a balanced ``advance``:
every edge leaving the frontier is one work atom, and the per-edge relax
(``atomicMin(dist[dst], dist[src] + w)``) is load-balanced exactly like a
SpMV's multiply — that is the point of the abstraction.  Atos (arXiv
2112.00132) builds the same discipline around a chunked work queue, which is
what :mod:`repro.core.dynamic` reproduces.

Two *directions* of the same advance are provided, behind one inspector:

* **Pull** (PR 3): tiles = destination vertices, atoms = in-edges of the
  transpose CSR; the relax is a per-tile ``min``-reduce over in-edges under
  a frontier mask (``frontier[src(e)]``).  Touches every edge per
  iteration — the right direction when the frontier is dense.
* **Push** (this PR): tiles = *source* vertices, atoms = out-edges of the
  forward CSR — the paper's original Listing 5 orientation.  The balanced
  executors produce frontier-compacted per-source value windows (masked to
  edges whose source tile is in the frontier) and the results are combined
  by edge *destination* through the same segmented machinery the tile
  reduces use (:func:`repro.core.execute.execute_scatter_reduce`) — the
  deterministic stand-in for ``atomicMin``'s scatter.  Only the frontier's
  out-edges carry non-identity values, which is why the cost model charges
  push by frontier density (:func:`repro.core.balance.modeled_advance_cost`)
  and why direction choice dominates sparse-frontier iterations (the §5.3 /
  Atos observation, Beamer's direction-optimizing BFS).

Because the graph's topology is static across iterations, both directions
are one-time inspector products (:func:`build_advance` returns a *plan
pair* in one call): BFS/SSSP/PageRank pay schedule construction once per
direction and re-run the balanced advance every iteration under
``lax.while_loop`` — any of the six registered schedules, either execution
path, selected by argument or by the cost-model autotuner
(``schedule="auto"`` scores the ``workload="advance"`` family for pull and
``workload="advance_push"`` for push, each under its own cache namespace).
The drivers in :mod:`repro.sparse.graph` switch directions per iteration
from the *measured* frontier out-edge count threaded through the while-loop
carry, against the plan's modeled ``direction_threshold``.

Two refinements ride the same plan pair (this PR):

* a **delta split** (:meth:`AdvancePlan.with_delta` / ``build_advance(...,
  delta=)``): per-direction light/heavy edge masks at a bucket width chosen
  from the weight distribution, which is all the delta-stepping SSSP driver
  needs — its bucket loops are ordinary advances restricted by
  ``edges="light"``/``"heavy"``; and
* **frontier compaction** (``build_advance(..., compact=)``): the push
  direction's masked windows are gather-compacted to a static capacity
  (:func:`repro.core.execute.execute_scatter_reduce`), so sparse frontiers
  stream only their own out-edges — with a masked fallback past capacity,
  results never change, only streamed volume.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import (ExecutionPath, Partition, Schedule,
                        choose_execution_path, estimate_compact_capacity,
                        estimate_direction_threshold,
                        execute_scatter_reduce, execute_tile_reduce,
                        make_partition)
from repro.core.work import WorkSpec

#: Default physical blocks for graph advance (graphs in this repo's tests
#: and benchmarks are modest; ops-layer callers can always override).
DEFAULT_NUM_BLOCKS = 32

#: Accepted ``schedule=`` spellings for the dynamic queue policies, same
#: contract as ``kernels/spmv_merge/ops.py``.
_CHUNK_POLICIES = {"chunked": "lpt", "chunked_lpt": "lpt",
                   "chunked_rr": "round_robin"}

#: Directions an advance can run in (see module docstring).
DIRECTIONS = ("pull", "push")

#: Edge subsets an advance can restrict itself to: the whole edge set, or —
#: on a plan carrying a ``delta`` split — only the light (weight <= delta)
#: or heavy (weight > delta) edges.  The delta-stepping SSSP buckets are
#: built from exactly these two restricted advances.
EDGE_SETS = ("all", "light", "heavy")


def estimate_delta(weights) -> float:
    """Bucket width for delta-stepping, from the weight distribution.

    The mean positive weight: it splits the edge set roughly in half
    (light edges drive the inner bucket loop, heavy edges are relaxed once
    per bucket) and bounds the bucket count by ``max_dist / mean_weight`` —
    the practical middle of Meyer & Sanders' Delta range (Delta -> 0 is
    Dijkstra, Delta -> inf is Bellman-Ford).  Deterministic, so plans built
    from the same graph always agree.  Edgeless graphs get 1.0 (any
    positive width: there is nothing to bucket).
    """
    w = np.asarray(weights, np.float32)
    w = w[np.isfinite(w) & (w > 0)]
    if w.size == 0:
        return 1.0
    return float(max(np.float32(w.mean()), w.min()))


@dataclasses.dataclass(frozen=True)
class AdvancePlan:
    """One-time inspector output for a graph's advance operator — a *pair*
    of direction plans sharing one inspection pass.

    The pull fields (``spec``/``src``/``weight``/``part``/``schedule``/
    ``path``) keep their PR-3 names: tiles = destination vertices, atoms =
    in-edges of the transpose CSR.  The ``push_*`` fields hold the forward
    view: tiles = source vertices, atoms = out-edges; ``dst`` is each
    out-edge atom's destination (the scatter id), ``push_src`` its source
    tile (the frontier-mask gather, materialized once).  Built outside jit
    (partitioning is a pre-launch inspector); consumed freely inside
    ``lax.while_loop`` bodies, where its arrays become trace constants.

    ``direction_threshold`` is the modeled frontier (out-edge) density at
    which pull becomes cheaper than push
    (:func:`repro.core.balance.estimate_direction_threshold`); the
    direction-optimizing drivers compare the measured density against it
    every iteration.  ``out_degrees`` rides along so that measurement is
    one masked sum in the carry.
    """

    # -- pull direction (PR-3 field names kept) -----------------------------
    spec: WorkSpec            # pull view: tiles = destinations
    src: jax.Array            # [E] int32 source vertex of each in-edge atom
    weight: jax.Array         # [E] f32 weight of each in-edge atom
    part: Partition
    schedule: Schedule
    path: ExecutionPath
    # -- push direction -----------------------------------------------------
    push_spec: WorkSpec       # push view: tiles = sources
    dst: jax.Array            # [E] int32 destination of each out-edge atom
    push_weight: jax.Array    # [E] f32 weight of each out-edge atom
    push_src: jax.Array       # [E] int32 source tile of each out-edge atom
    push_part: Partition
    push_schedule: Schedule
    push_path: ExecutionPath
    # -- shared -------------------------------------------------------------
    num_vertices: int
    out_degrees: jax.Array    # [V] int32 (measured-density term)
    direction_threshold: float
    interpret: bool = True
    # -- bucketed (delta-stepping) view: set by with_delta/build_advance ----
    delta: Optional[float] = None
    light_mask: Optional[jax.Array] = None       # [E] bool, pull edge order
    push_light_mask: Optional[jax.Array] = None  # [E] bool, push edge order
    light_out_degrees: Optional[jax.Array] = None  # [V] int32
    # -- frontier compaction: static capacity of the gather-compacted push
    #    windows (None = masked full windows, the PR-4 behaviour) ----------
    compact_capacity: Optional[int] = None

    @property
    def num_edges(self) -> int:
        return self.push_spec.num_atoms

    def with_compact_capacity(self,
                              capacity: Optional[int]) -> "AdvancePlan":
        """Same plan pair, different static push-compaction capacity.

        Pure bookkeeping (no re-inspection): the capacity only sizes the
        gather-compacted window mode of
        :func:`repro.core.execute.execute_scatter_reduce`, whose runtime
        ``lax.cond`` falls back to masked full windows whenever the
        measured active count exceeds it — so any capacity is correct.
        The delta-stepping driver uses this to hand its light bucket
        phases a capacity clamped to the light edge-set size (the largest
        measured light density any bucket can reach), keeping sparse
        bucket frontiers on the compact path without rebuilding the
        partitions.  ``None`` disables compaction on the returned plan.
        """
        if capacity is not None:
            capacity = int(capacity)
            if capacity < 1:
                raise ValueError(f"compact capacity must be >= 1 or None, "
                                 f"got {capacity}")
        return dataclasses.replace(self, compact_capacity=capacity)

    def with_delta(self, delta: Optional[float] = None) -> "AdvancePlan":
        """Attach a light/heavy edge split (bucket width ``delta``).

        Materializes the per-direction light masks (pull and push edge
        orders differ, so both are stored) and the light out-degree array
        the drivers measure light-frontier density with.  ``None`` picks
        :func:`estimate_delta` from this plan's weight distribution.  Pure
        bookkeeping over arrays the plan already owns — no re-inspection.
        """
        if delta is None:
            delta = estimate_delta(self.push_weight)
        delta = float(delta)
        if not delta > 0.0:
            raise ValueError(f"delta must be positive, got {delta}")
        light_mask, push_light, light_out = _delta_edge_split(
            delta, self.weight, self.push_weight, self.push_src,
            self.num_vertices)
        return dataclasses.replace(
            self, delta=delta, light_mask=light_mask,
            push_light_mask=push_light, light_out_degrees=light_out)

    def edge_set_mask(self, edges: str, direction: str) -> Optional[jax.Array]:
        """The requested edge subset as a per-atom mask in ``direction``'s
        own edge order (``None`` for the full set)."""
        if edges not in EDGE_SETS:
            raise ValueError(f"unknown edge set: {edges!r} "
                             f"(expected one of {EDGE_SETS})")
        if edges == "all":
            return None
        if self.delta is None:
            raise ValueError(
                f"edges={edges!r} needs a delta split on the plan; build "
                f"with delta= or call plan.with_delta()")
        light = (self.push_light_mask if direction == "push"
                 else self.light_mask)
        return light if edges == "light" else jnp.logical_not(light)

    def edge_fraction(self, active_edge_count: jax.Array) -> jax.Array:
        """Fraction of the edge set a given active out-edge count covers —
        the one definition of measured density the drivers and tests share
        (compared against ``direction_threshold``)."""
        return active_edge_count.astype(jnp.float32) / jnp.float32(
            max(self.num_edges, 1))

    def frontier_edge_fraction(self, frontier: jax.Array) -> jax.Array:
        """Measured frontier density: fraction of edges leaving ``frontier``.

        One masked sum over the static out-degree array — cheap enough to
        thread through a ``while_loop`` carry every iteration, which is
        what makes the direction switch *measured* rather than guessed.
        """
        return self.edge_fraction(
            jnp.sum(jnp.where(frontier, self.out_degrees, 0)))


def _delta_edge_split(delta: float, pull_weight: jax.Array,
                      push_weight: jax.Array, push_src: jax.Array,
                      num_vertices: int):
    """Light/heavy edge split at bucket width ``delta``, both directions in
    one pass.

    The threshold compare runs once per distinct weight array and the light
    out-degree segment sum runs once total (over the push view, which owns
    the out-edges) — previously each direction recomputed its own degree
    term.  Shared by :meth:`AdvancePlan.with_delta` and the per-shard local
    views in :mod:`repro.sparse.shard`.  Returns ``(light_mask,
    push_light_mask, light_out_degrees)``.
    """
    thr = jnp.float32(delta)
    push_light = push_weight <= thr
    light_out = (jax.ops.segment_sum(push_light.astype(jnp.int32), push_src,
                                     num_segments=num_vertices)
                 if num_vertices else jnp.zeros((0,), jnp.int32))
    return pull_weight <= thr, push_light, light_out


def _resolve_direction_plan(spec: WorkSpec, schedule, path, num_blocks: int,
                            workload: str, measure=None):
    """(schedule, policy, path, Partition) for one direction's work view."""
    policy = _CHUNK_POLICIES.get(str(schedule))
    sched = Schedule.CHUNKED if policy else Schedule(schedule)
    req_path = ExecutionPath(path)
    if sched == Schedule.AUTO:
        from repro.core.autotune import select_plan
        plan = select_plan(spec, num_blocks, workload=workload,
                           measure=measure)
        sched = plan.schedule
        policy = "lpt" if sched == Schedule.CHUNKED else None
        if req_path == ExecutionPath.AUTO:
            req_path = plan.path
    part = make_partition(spec, sched, num_blocks,
                          chunk_policy=policy or "lpt")
    return sched, choose_execution_path(part, req_path), part


def _direction_measure(spec: WorkSpec, gather: jax.Array, num_blocks: int,
                       direction: str, weight: jax.Array,
                       num_vertices: int, dst: Optional[jax.Array],
                       interpret: bool):
    """Default measured-mode timing closure for one direction's candidates.

    Times each candidate (schedule, path) plan on this graph's *actual*
    relax workload (min-combine of ``potentials[src] + w`` under a
    representative ~30% frontier — between the sparse and dense regimes
    the direction threshold separates) via
    :func:`repro.core.measure.time_fn`.  Only consulted when
    ``REPRO_AUTOTUNE_MEASURE`` is on; the measured medians land in the v2
    autotune cache under the direction's own workload namespace.
    """
    from repro.core.measure import time_fn
    rng = np.random.default_rng(0)
    frontier = jnp.asarray(rng.random(max(num_vertices, 1)) < 0.3)
    potentials = jnp.zeros((max(num_vertices, 1),), jnp.float32)
    w = weight.astype(jnp.float32)

    def run(plan) -> float:
        part = make_partition(spec, plan.schedule, num_blocks,
                              chunk_policy="lpt")
        mask = frontier[gather]
        atom_fn = lambda e, p: p[gather[e]] + w[e]
        if direction == "push":
            @jax.jit
            def f(p):
                return execute_scatter_reduce(
                    spec, part, lambda e: atom_fn(e, p), dst, num_vertices,
                    jnp.float32, path=plan.path, combiner="min",
                    atom_mask=mask, interpret=interpret)
        else:
            @jax.jit
            def f(p):
                return execute_tile_reduce(
                    spec, part, lambda e: atom_fn(e, p), jnp.float32,
                    path=plan.path, combiner="min", atom_mask=mask,
                    interpret=interpret)
        return time_fn(f, potentials, warmup=1, iters=3)
    return run


#: Push-direction sibling of each frontier-masked workload family; other
#: families (e.g. "reduce" for PageRank's unmasked full sweeps) apply to
#: both directions as-is.
_PUSH_WORKLOADS = {"advance": "advance_push",
                   "advance_delta": "advance_delta_push",
                   "advance_serve": "advance_serve_push",
                   "wavefront": "wavefront_push"}


def build_advance(graph, *, schedule: Schedule | str = "auto",
                  num_blocks: Optional[int] = None,
                  path: ExecutionPath | str = ExecutionPath.AUTO,
                  workload: str = "advance",
                  direction_threshold: Optional[float] = None,
                  delta: Optional[float | str] = None,
                  compact: Optional[bool | int | float] = None,
                  measure=None,
                  interpret: bool = True) -> AdvancePlan:
    """Inspect a :class:`~repro.sparse.graph.Graph` into an AdvancePlan pair.

    One inspector call builds *both* directions: the pull partition over the
    transpose CSR and the push partition over the forward CSR.  ``schedule``
    accepts every registered schedule, the dynamic queue spellings
    (``"chunked"``/``"chunked_lpt"``/``"chunked_rr"``), or ``"auto"`` —
    which asks :func:`repro.core.autotune.select_plan` for a (schedule,
    path) plan per direction: the ``workload`` cost family (default
    ``"advance"``; ``"reduce"`` for unmasked full sweeps like PageRank) for
    pull, and the ``"advance_push"`` family — its own cache namespace —
    for push, so schedule and direction are selected jointly from the same
    cost model.  ``path`` resolves against each built partition exactly
    like the SpMV ops wrapper.

    ``direction_threshold`` overrides the modeled push->pull switch density
    (:func:`repro.core.balance.estimate_direction_threshold`); pass ``0.0``
    to force pull-only or ``1.0`` push-only behaviour in the
    direction-optimizing drivers without rebuilding anything.

    ``delta`` attaches the light/heavy bucket split for delta-stepping
    (``"auto"`` estimates the width from the weight distribution — see
    :func:`estimate_delta`; a float pins it).  ``compact`` enables the
    gather-compacted push window mode (ROADMAP's frontier compaction):
    ``True`` sizes the static capacity from the direction threshold
    (:func:`repro.core.balance.estimate_compact_capacity`), a float in
    (0, 1] is a fraction of the edge set, an int >= 1 an exact slot count.
    Overflowing frontiers fall back to masked full windows inside the
    executor, so compaction never changes results — only streamed volume.

    ``measure`` is the measured-cost feedback knob (docs/autotune.md): with
    ``REPRO_AUTOTUNE_MEASURE=1`` and ``schedule="auto"``, each direction's
    candidate plans are *timed on this graph's own relax workload* (see
    :func:`_direction_measure`) and the autotuner re-ranks by measurement.
    ``None`` builds the default per-direction timing closures when the env
    gate is on; ``False`` keeps selection model-only regardless; a callable
    ``(direction, plan) -> median_us`` supplies custom timings.
    """
    num_blocks = DEFAULT_NUM_BLOCKS if num_blocks is None else num_blocks
    pull = graph.csr.transpose()          # CSR of A^T: rows = destinations
    spec = pull.workspec()
    push_spec = graph.csr.workspec()      # forward CSR: rows = sources
    push_ids = push_spec.atom_tile_ids()  # once: measure closure + plan
    pull_measure = push_measure = None
    if measure is not False and str(schedule) not in _CHUNK_POLICIES \
            and Schedule(schedule) == Schedule.AUTO:
        from repro.core.autotune import measurement_enabled
        if callable(measure):
            pull_measure = lambda p: measure("pull", p)
            push_measure = lambda p: measure("push", p)
        elif measurement_enabled():
            pull_measure = _direction_measure(
                spec, pull.col_indices, num_blocks, "pull",
                pull.values, graph.num_vertices, None, interpret)
            push_measure = _direction_measure(
                push_spec, push_ids, num_blocks, "push",
                graph.csr.values, graph.num_vertices,
                graph.csr.col_indices, interpret)
    return build_advance_views(
        pull_spec=spec, pull_src=pull.col_indices, pull_weight=pull.values,
        push_spec=push_spec, push_dst=graph.csr.col_indices,
        push_weight=graph.csr.values, push_src=push_ids,
        num_vertices=graph.num_vertices,
        schedule=schedule, num_blocks=num_blocks, path=path,
        workload=workload, direction_threshold=direction_threshold,
        delta=delta, compact=compact,
        pull_measure=pull_measure, push_measure=push_measure,
        interpret=interpret)


def build_advance_views(*, pull_spec: WorkSpec, pull_src: jax.Array,
                        pull_weight: jax.Array, push_spec: WorkSpec,
                        push_dst: jax.Array, push_weight: jax.Array,
                        push_src: Optional[jax.Array] = None,
                        num_vertices: int,
                        schedule: Schedule | str = "auto",
                        num_blocks: Optional[int] = None,
                        path: ExecutionPath | str = ExecutionPath.AUTO,
                        workload: str = "advance",
                        direction_threshold: Optional[float] = None,
                        delta: Optional[float | str] = None,
                        compact: Optional[bool | int | float] = None,
                        pull_measure=None, push_measure=None,
                        out_degrees: Optional[jax.Array] = None,
                        interpret: bool = True) -> AdvancePlan:
    """The view-level inspector core behind :func:`build_advance`.

    Takes the two work views directly (pull: tiles = destinations over
    ``pull_spec`` with per-atom ``pull_src``/``pull_weight``; push: tiles =
    sources over ``push_spec`` with per-atom ``push_dst``/``push_weight``)
    instead of a :class:`~repro.sparse.graph.Graph`, so the same
    partitioning/threshold/compaction logic serves both the whole-graph
    build and the per-shard local views of
    :func:`repro.sparse.shard.build_sharded_advance` — where the views are
    *slices* of the global CSRs rebased to a shard's vertex range and the
    caller overrides ``push_src`` (global source ids, not local tile ids)
    and ``out_degrees`` (owned vertices only, pad tiles excluded).

    ``pull_measure``/``push_measure`` are pre-built per-direction timing
    closures (or ``None``); everything else matches :func:`build_advance`.
    """
    num_blocks = DEFAULT_NUM_BLOCKS if num_blocks is None else num_blocks
    sched, resolved, part = _resolve_direction_plan(
        pull_spec, schedule, path, num_blocks, workload,
        measure=pull_measure)
    push_workload = _PUSH_WORKLOADS.get(workload, workload)
    push_sched, push_resolved, push_part = _resolve_direction_plan(
        push_spec, schedule, path, num_blocks, push_workload,
        measure=push_measure)
    if direction_threshold is None:
        direction_threshold = estimate_direction_threshold(
            pull_spec, push_spec, num_blocks,
            pull_schedule=sched, push_schedule=push_sched,
            pull_path=str(resolved), push_path=str(push_resolved),
            pull_part=part, push_part=push_part)
    num_edges = push_spec.num_atoms
    if compact is None or compact is False:
        capacity = None
    elif compact is True:
        capacity = estimate_compact_capacity(num_edges,
                                             float(direction_threshold))
    elif isinstance(compact, float):
        if not 0.0 < compact <= 1.0:
            raise ValueError(f"compact fraction must be in (0, 1], "
                             f"got {compact}")
        capacity = max(int(np.ceil(num_edges * compact)), 1)
    else:
        if int(compact) < 1:
            raise ValueError(f"compact capacity must be >= 1 (or None/"
                             f"False to disable), got {compact}")
        capacity = int(compact)
    if push_src is None:
        push_src = push_spec.atom_tile_ids()
    if out_degrees is None:
        out_degrees = push_spec.atoms_per_tile()
    plan = AdvancePlan(
        spec=pull_spec, src=pull_src,
        weight=pull_weight.astype(jnp.float32), part=part,
        schedule=sched, path=resolved,
        push_spec=push_spec, dst=push_dst,
        push_weight=push_weight.astype(jnp.float32),
        push_src=push_src, push_part=push_part,
        push_schedule=push_sched, push_path=push_resolved,
        num_vertices=num_vertices,
        out_degrees=out_degrees.astype(jnp.int32),
        direction_threshold=float(direction_threshold),
        compact_capacity=capacity,
        interpret=interpret)
    if delta is not None:
        plan = plan.with_delta(None if delta == "auto" else delta)
    return plan


def _combined_mask(vertex_mask: Optional[jax.Array], gather: jax.Array,
                   edge_mask: Optional[jax.Array]) -> Optional[jax.Array]:
    """frontier-gather AND edge-subset mask (either may be absent)."""
    atom_mask = None if vertex_mask is None else vertex_mask[gather]
    if edge_mask is None:
        return atom_mask
    return edge_mask if atom_mask is None else jnp.logical_and(atom_mask,
                                                               edge_mask)


def advance(plan: AdvancePlan, frontier: Optional[jax.Array],
            atom_fn: Callable[[jax.Array], jax.Array], *,
            combiner: str = "sum",
            edge_mask: Optional[jax.Array] = None) -> jax.Array:
    """The pull-direction balanced advance: per-destination ``combiner``-
    reduce over in-edge atoms, masked to edges whose *source* is in the
    frontier.

    ``frontier`` is a bool ``[V]`` vertex mask (``None`` = all active);
    ``atom_fn`` maps **in-edge atom ids** (pull order) to f32 candidate
    values (Listing 5's loop body).  ``edge_mask`` (bool ``[E]``, pull edge
    order) further restricts the atom set — the delta-stepping light/heavy
    split (:meth:`AdvancePlan.edge_set_mask`).  Returns ``[V]`` f32;
    destinations with no active in-edge carry the combiner's identity.
    Routed through :func:`repro.core.execute.execute_tile_reduce`, so every
    schedule and both execution paths produce identical bits.
    """
    atom_mask = _combined_mask(frontier, plan.src, edge_mask)
    return execute_tile_reduce(plan.spec, plan.part, atom_fn, jnp.float32,
                               path=plan.path, combiner=combiner,
                               atom_mask=atom_mask, interpret=plan.interpret)


def advance_push(plan: AdvancePlan, frontier: Optional[jax.Array],
                 atom_fn: Callable[[jax.Array], jax.Array], *,
                 combiner: str = "sum",
                 edge_mask: Optional[jax.Array] = None) -> jax.Array:
    """The push-direction balanced advance (Listing 5's own orientation).

    ``atom_fn`` maps **out-edge atom ids** (push/forward order) to f32
    candidate values; ``edge_mask`` (bool ``[E]``, push edge order) is the
    delta-stepping light/heavy restriction.  The balanced executors walk
    the push partition (tiles = source vertices) producing
    frontier-compacted per-source value windows;
    :func:`repro.core.execute.scatter_value_windows` then combines them by
    each edge's destination — the same segmented machinery as the tile
    reduces, so every schedule and both execution paths produce identical
    bits, and (for the exact min/max combiners or exactly summable values)
    the same bits as the pull advance over the same edge multiset.

    On a plan built with ``compact=...`` the masked atoms are additionally
    *gather-compacted* before streaming (``compact_capacity`` slots, with
    an in-executor masked fallback past capacity) — sparse frontiers stream
    only their own out-edges instead of masking full windows, without
    changing a single result bit.
    """
    atom_mask = _combined_mask(frontier, plan.push_src, edge_mask)
    return execute_scatter_reduce(plan.push_spec, plan.push_part, atom_fn,
                                  plan.dst, plan.num_vertices, jnp.float32,
                                  path=plan.push_path, combiner=combiner,
                                  atom_mask=atom_mask,
                                  compact_capacity=plan.compact_capacity,
                                  interpret=plan.interpret)


def _check_direction(direction: str) -> str:
    if direction not in DIRECTIONS:
        raise ValueError(f"unknown direction: {direction!r} "
                         f"(expected one of {DIRECTIONS})")
    return direction


def advance_relax_min(plan: AdvancePlan, potentials: jax.Array,
                      frontier: Optional[jax.Array], *,
                      direction: str = "pull",
                      edges: str = "all") -> jax.Array:
    """SSSP relax (Listing 5): ``cand[v] = min over edges (u, v) of
    potentials[u] + w(u, v)``.

    ``direction="pull"`` is the segmented form of ``atomicMin``;
    ``"push"`` computes the identical candidate per edge (same two f32
    operands, same rounding) on the forward view and scatters by
    destination — min is exact, so both directions return identical bits.
    ``edges="light"``/``"heavy"`` restricts the relax to one side of the
    plan's delta split (the delta-stepping bucket loops); the restriction
    is a mask over the same candidate multiset, so direction equivalence
    holds per subset too.
    """
    edge_mask = plan.edge_set_mask(edges, _check_direction(direction))
    if direction == "push":
        src, w = plan.push_src, plan.push_weight
        return advance_push(plan, frontier,
                            lambda e: potentials[src[e]] + w[e],
                            combiner="min", edge_mask=edge_mask)
    src, w = plan.src, plan.weight
    return advance(plan, frontier, lambda e: potentials[src[e]] + w[e],
                   combiner="min", edge_mask=edge_mask)


def advance_frontier(plan: AdvancePlan, frontier: jax.Array, *,
                     direction: str = "pull") -> jax.Array:
    """Scatter-or: which destinations have at least one active edge.

    The max-combiner over unit values; identity ``-inf`` at untouched
    destinations, so the threshold test recovers the bool mask in either
    direction.
    """
    unit = lambda e: jnp.ones(e.shape, jnp.float32)
    if _check_direction(direction) == "push":
        reached = advance_push(plan, frontier, unit, combiner="max")
    else:
        reached = advance(plan, frontier, unit, combiner="max")
    return reached > 0.0


def advance_src_argmin(plan: AdvancePlan, frontier: jax.Array, *,
                       direction: str = "pull") -> jax.Array:
    """Smallest active in-neighbour per destination (BFS parent pointers).

    Vertex ids reduce exactly as f32 up to 2**24 vertices (enforced loudly:
    beyond that the min-combiner could return a rounded, wrong parent);
    destinations with no active in-edge come back as ``-1``.  Min over the
    same id multiset — directions agree bitwise.
    """
    if plan.num_vertices >= (1 << 24):
        raise ValueError(
            f"advance_src_argmin: vertex ids are reduced as f32, exact only "
            f"below 2**24 vertices (got {plan.num_vertices})")
    if _check_direction(direction) == "push":
        src = plan.push_src
        cand = advance_push(plan, frontier,
                            lambda e: src[e].astype(jnp.float32),
                            combiner="min")
    else:
        src = plan.src
        cand = advance(plan, frontier, lambda e: src[e].astype(jnp.float32),
                       combiner="min")
    return jnp.where(jnp.isfinite(cand), cand, -1.0).astype(jnp.int32)


def frontier_filter(plan: AdvancePlan, frontier: jax.Array,
                    keep: Optional[jax.Array] = None, *,
                    direction: str = "pull") -> jax.Array:
    """The paper's ``filter``: next frontier = unique destinations of active
    edges, minus those failing ``keep``.

    The expensive half of a GPU filter — deduplicating the scattered
    destination list — *is* the max-combiner reduce above (each destination
    collapses its active edges to one bit, in either direction); under TPU
    static shapes the compaction half degenerates to a mask-and, which is
    exactly what downstream advances consume.
    """
    nxt = advance_frontier(plan, frontier, direction=direction)
    if keep is not None:
        nxt = jnp.logical_and(nxt, keep)
    return nxt
