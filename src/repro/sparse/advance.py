"""Load-balanced graph frontier operators (paper §5.3, Listing 5).

The paper's graph evaluation drives BFS/SSSP through a balanced ``advance``:
every edge leaving the frontier is one work atom, and the per-edge relax
(``atomicMin(dist[dst], dist[src] + w)``) is load-balanced exactly like a
SpMV's multiply — that is the point of the abstraction.  Atos (arXiv
2112.00132) builds the same discipline around a chunked work queue, which is
what :mod:`repro.core.dynamic` reproduces.

TPU adaptation (two deliberate departures from the CUDA formulation):

* **Pull direction.**  ``atomicMin`` scatters by edge *destination*; TPU
  grid blocks must not collide on output tiles, so the advance runs over the
  transpose CSR — tiles = destination vertices, atoms = incoming edges — and
  the relax becomes a per-tile ``min``-reduce over in-edges.  This is the
  standard push->pull direction flip of linear-algebra graph frameworks
  (GraphBLAST, which the paper cites): scatter-min turns into segmented min,
  scatter-or (frontier expansion) into segmented max over {0, 1}.
* **Frontier mask, not frontier queue.**  Per-iteration compacted frontiers
  would force dynamic shapes; instead the full static edge set is processed
  under a per-atom *mask* (``frontier[src(e)]``), which rides into the
  native chunk-walking kernel as its own operand
  (:func:`repro.core.execute.native_chunk_tile_reduce`).  Masked atoms
  contribute the combiner's identity — the moral equivalent of not being in
  the queue, at the cost of touching every edge per iteration (the dense
  direction-free advance; the cost model charges it via
  :data:`repro.core.balance.ADVANCE_ATOM_WORK`).

Because the graph's topology is static across iterations, the partition is
a one-time inspector product (:func:`build_advance`): BFS/SSSP/PageRank pay
schedule construction once and re-run the balanced advance every iteration
under ``lax.while_loop`` — any of the six registered schedules, either
execution path, selected by argument or by the cost-model autotuner
(``schedule="auto"`` scores the ``workload="advance"`` plan family).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import (ExecutionPath, Partition, Schedule,
                        choose_execution_path, execute_tile_reduce,
                        make_partition)
from repro.core.work import WorkSpec

#: Default physical blocks for graph advance (graphs in this repo's tests
#: and benchmarks are modest; ops-layer callers can always override).
DEFAULT_NUM_BLOCKS = 32

#: Accepted ``schedule=`` spellings for the dynamic queue policies, same
#: contract as ``kernels/spmv_merge/ops.py``.
_CHUNK_POLICIES = {"chunked": "lpt", "chunked_lpt": "lpt",
                   "chunked_rr": "round_robin"}


@dataclasses.dataclass(frozen=True)
class AdvancePlan:
    """One-time inspector output for a graph's advance operator.

    Holds the pull-direction work definition (tiles = destination vertices,
    atoms = incoming edges), the edge gather arrays, and the schedule's
    Partition — everything that is iteration-invariant.  Built outside jit
    (partitioning is a pre-launch inspector); consumed freely inside
    ``lax.while_loop`` bodies, where its arrays become trace constants.
    """

    spec: WorkSpec            # pull view of the graph
    src: jax.Array            # [E] int32 source vertex of each in-edge atom
    weight: jax.Array         # [E] f32 weight of each in-edge atom
    part: Partition
    schedule: Schedule
    path: ExecutionPath
    num_vertices: int
    interpret: bool = True


def build_advance(graph, *, schedule: Schedule | str = "auto",
                  num_blocks: Optional[int] = None,
                  path: ExecutionPath | str = ExecutionPath.AUTO,
                  workload: str = "advance",
                  interpret: bool = True) -> AdvancePlan:
    """Inspect a :class:`~repro.sparse.graph.Graph` into an AdvancePlan.

    ``schedule`` accepts every registered schedule, the dynamic queue
    spellings (``"chunked"``/``"chunked_lpt"``/``"chunked_rr"``), or
    ``"auto"`` — which asks :func:`repro.core.autotune.select_plan` for a
    (schedule, path) plan under the ``workload`` cost family: ``"advance"``
    (default — frontier-masked, heavier per-atom cost, separate cache
    namespace) or ``"reduce"`` for unmasked full sweeps like PageRank.
    ``path`` resolves against the built partition exactly like the SpMV
    ops wrapper.
    """
    num_blocks = DEFAULT_NUM_BLOCKS if num_blocks is None else num_blocks
    pull = graph.csr.transpose()          # CSR of A^T: rows = destinations
    spec = pull.workspec()
    policy = _CHUNK_POLICIES.get(str(schedule))
    sched = Schedule.CHUNKED if policy else Schedule(schedule)
    req_path = ExecutionPath(path)
    if sched == Schedule.AUTO:
        from repro.core.autotune import select_plan
        plan = select_plan(spec, num_blocks, workload=workload)
        sched = plan.schedule
        policy = "lpt" if sched == Schedule.CHUNKED else None
        if req_path == ExecutionPath.AUTO:
            req_path = plan.path
    part = make_partition(spec, sched, num_blocks,
                          chunk_policy=policy or "lpt")
    resolved = choose_execution_path(part, req_path)
    return AdvancePlan(spec=spec, src=pull.col_indices,
                       weight=pull.values.astype(jnp.float32), part=part,
                       schedule=sched, path=resolved,
                       num_vertices=graph.num_vertices, interpret=interpret)


def advance(plan: AdvancePlan, frontier: Optional[jax.Array],
            atom_fn: Callable[[jax.Array], jax.Array], *,
            combiner: str = "sum") -> jax.Array:
    """The balanced advance: per-destination ``combiner``-reduce over
    in-edge atoms, masked to edges whose *source* is in the frontier.

    ``frontier`` is a bool ``[V]`` vertex mask (``None`` = all active);
    ``atom_fn`` maps in-edge atom ids to f32 candidate values (Listing 5's
    loop body).  Returns ``[V]`` f32; destinations with no active in-edge
    carry the combiner's identity.  Routed through
    :func:`repro.core.execute.execute_tile_reduce`, so every schedule and
    both execution paths produce identical bits.
    """
    atom_mask = None if frontier is None else frontier[plan.src]
    return execute_tile_reduce(plan.spec, plan.part, atom_fn, jnp.float32,
                               path=plan.path, combiner=combiner,
                               atom_mask=atom_mask, interpret=plan.interpret)


def advance_relax_min(plan: AdvancePlan, potentials: jax.Array,
                      frontier: Optional[jax.Array]) -> jax.Array:
    """SSSP relax (Listing 5): ``cand[v] = min over in-edges (u, v) of
    potentials[u] + w(u, v)`` — the pull form of ``atomicMin``."""
    src, w = plan.src, plan.weight
    return advance(plan, frontier, lambda e: potentials[src[e]] + w[e],
                   combiner="min")


def advance_frontier(plan: AdvancePlan, frontier: jax.Array) -> jax.Array:
    """Scatter-or: which destinations have at least one active in-edge.

    The max-combiner over unit values; identity ``-inf`` at untouched
    destinations, so the threshold test recovers the bool mask.
    """
    reached = advance(plan, frontier,
                      lambda e: jnp.ones(e.shape, jnp.float32),
                      combiner="max")
    return reached > 0.0


def advance_src_argmin(plan: AdvancePlan, frontier: jax.Array) -> jax.Array:
    """Smallest active in-neighbour per destination (BFS parent pointers).

    Vertex ids reduce exactly as f32 up to 2**24 vertices (enforced loudly:
    beyond that the min-combiner could return a rounded, wrong parent);
    destinations with no active in-edge come back as ``-1``.
    """
    if plan.num_vertices >= (1 << 24):
        raise ValueError(
            f"advance_src_argmin: vertex ids are reduced as f32, exact only "
            f"below 2**24 vertices (got {plan.num_vertices})")
    src = plan.src
    cand = advance(plan, frontier, lambda e: src[e].astype(jnp.float32),
                   combiner="min")
    return jnp.where(jnp.isfinite(cand), cand, -1.0).astype(jnp.int32)


def frontier_filter(plan: AdvancePlan, frontier: jax.Array,
                    keep: Optional[jax.Array] = None) -> jax.Array:
    """The paper's ``filter``: next frontier = unique destinations of active
    edges, minus those failing ``keep``.

    The expensive half of a GPU filter — deduplicating the scattered
    destination list — *is* the max-combiner tile reduce above (each
    destination tile collapses its in-edges to one bit); under TPU static
    shapes the compaction half degenerates to a mask-and, which is exactly
    what downstream advances consume.
    """
    nxt = advance_frontier(plan, frontier)
    if keep is not None:
        nxt = jnp.logical_and(nxt, keep)
    return nxt
