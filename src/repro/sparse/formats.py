"""Sparse matrix containers + the synthetic SuiteSparse-like corpus.

Formats lower to :class:`~repro.core.work.WorkSpec` (paper §3.1): CSR maps
rows->tiles and non-zeros->atoms directly from ``row_offsets``; COO sorts by
row and builds offsets with one ``bincount``+``cumsum``; CSC is CSR of the
transpose (tiles = columns).  This one-way lowering is what makes every
schedule format-agnostic — exactly the paper's argument that merge-path "is
now no longer limited to a CSR-based sparse format".
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.work import WorkSpec


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed Sparse Row.  ``shape``/``nnz`` are static metadata."""

    row_offsets: jax.Array   # int32 [rows + 1]
    col_indices: jax.Array   # int32 [nnz]
    values: jax.Array        # [nnz]
    shape: Tuple[int, int]
    nnz: int

    def tree_flatten(self):
        return ((self.row_offsets, self.col_indices, self.values),
                (self.shape, self.nnz))

    @classmethod
    def tree_unflatten(cls, aux, children):
        row_offsets, col_indices, values = children
        shape, nnz = aux
        return cls(row_offsets, col_indices, values, shape, nnz)

    # -- work definition ----------------------------------------------------
    def workspec(self) -> WorkSpec:
        return WorkSpec.from_csr(self.row_offsets, nnz=self.nnz)

    # -- conversions ---------------------------------------------------------
    @classmethod
    def from_dense(cls, dense: np.ndarray) -> "CSR":
        dense = np.asarray(dense)
        rows, cols = dense.shape
        r, c = np.nonzero(dense)
        vals = dense[r, c]
        offsets = np.zeros(rows + 1, np.int32)
        np.add.at(offsets, r + 1, 1)
        offsets = np.cumsum(offsets).astype(np.int32)
        return cls(jnp.asarray(offsets), jnp.asarray(c.astype(np.int32)),
                   jnp.asarray(vals.astype(np.float32)), (rows, cols),
                   int(len(vals)))

    def to_dense(self) -> np.ndarray:
        rows, cols = self.shape
        out = np.zeros((rows, cols), np.float64)
        off = np.asarray(self.row_offsets)
        ci = np.asarray(self.col_indices)
        v = np.asarray(self.values)
        for r in range(rows):
            for k in range(off[r], off[r + 1]):
                out[r, ci[k]] += v[k]
        return out

    def transpose(self) -> "CSR":
        coo = self.to_coo()
        return COO(coo.col_indices, coo.row_indices, coo.values,
                   (self.shape[1], self.shape[0]), self.nnz).to_csr()

    def to_coo(self) -> "COO":
        spec = self.workspec()
        return COO(spec.atom_tile_ids(), self.col_indices, self.values,
                   self.shape, self.nnz)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class COO:
    """Coordinate format (row-major sorted not required on input)."""

    row_indices: jax.Array
    col_indices: jax.Array
    values: jax.Array
    shape: Tuple[int, int]
    nnz: int

    def tree_flatten(self):
        return ((self.row_indices, self.col_indices, self.values),
                (self.shape, self.nnz))

    @classmethod
    def tree_unflatten(cls, aux, children):
        row_indices, col_indices, values = children
        shape, nnz = aux
        return cls(row_indices, col_indices, values, shape, nnz)

    def to_csr(self) -> CSR:
        order = jnp.argsort(self.row_indices, stable=True)
        rows = jnp.take(self.row_indices, order)
        sizes = jnp.bincount(rows, length=self.shape[0]).astype(jnp.int32)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(sizes, dtype=jnp.int32)])
        return CSR(offsets, jnp.take(self.col_indices, order),
                   jnp.take(self.values, order), self.shape, self.nnz)

    def workspec(self) -> WorkSpec:
        return self.to_csr().workspec()


# CSC is CSR over the transpose; tiles are columns.  Kept as an alias class
# so user code reads naturally.
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSC:
    col_offsets: jax.Array
    row_indices: jax.Array
    values: jax.Array
    shape: Tuple[int, int]
    nnz: int

    def tree_flatten(self):
        return ((self.col_offsets, self.row_indices, self.values),
                (self.shape, self.nnz))

    @classmethod
    def tree_unflatten(cls, aux, children):
        col_offsets, row_indices, values = children
        shape, nnz = aux
        return cls(col_offsets, row_indices, values, shape, nnz)

    def workspec(self) -> WorkSpec:
        return WorkSpec.from_csr(self.col_offsets, nnz=self.nnz)

    def to_csr_of_transpose(self) -> CSR:
        return CSR(self.col_offsets, self.row_indices, self.values,
                   (self.shape[1], self.shape[0]), self.nnz)


# ---------------------------------------------------------------------------
# Synthetic corpus.  SuiteSparse is a ~900 GB download; this container is
# offline, so the benchmark corpus is generated to cover the same *structural
# axes* that drive load-balancing behaviour: scale (rows/nnz), row-degree
# skew (uniform -> power-law), density, empty-row fraction, and the
# single-column "sparse vector" edge case the paper calls out in Fig. 2.
# ---------------------------------------------------------------------------

def random_csr(rows: int, cols: int, nnz_target: int, *, skew: float,
               empty_frac: float = 0.0, seed: int = 0) -> CSR:
    """Random CSR with Zipf-like row degrees (``skew=0`` -> uniform)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, rows + 1, dtype=np.float64)
    weights = ranks ** (-skew)
    rng.shuffle(weights)
    if empty_frac > 0:
        weights[rng.random(rows) < empty_frac] = 0.0
    total = weights.sum()
    if total == 0:
        weights[:] = 1.0
        total = weights.sum()
    raw = weights / total * nnz_target
    sizes = np.floor(raw + rng.random(rows)).astype(np.int64)  # stochastic
    sizes = np.minimum(sizes, cols)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    nnz = int(offsets[-1])
    cols_out = np.empty(nnz, np.int32)
    for r in range(rows):  # host-side generation; fine for test corpora
        k = sizes[r]
        if k:
            cols_out[offsets[r]:offsets[r + 1]] = np.sort(
                rng.choice(cols, size=k, replace=False))
    vals = rng.standard_normal(nnz).astype(np.float32)
    return CSR(jnp.asarray(offsets), jnp.asarray(cols_out),
               jnp.asarray(vals), (rows, cols), nnz)


def suite_like_corpus(seed: int = 0, *,
                      smoke: bool = False) -> List[Tuple[str, CSR]]:
    """~20 matrices spanning the structural axes of SuiteSparse.

    ``smoke=True`` keeps only a few tiny matrices (one per structural class)
    so benchmark smoke jobs can exercise every code path in seconds.
    """
    out: List[Tuple[str, CSR]] = []
    if smoke:
        cases = [
            ("uniform_small", 120, 120, 600, 0.0, 0.0),
            ("zipf_small", 120, 120, 900, 1.4, 0.1),
            ("tiny", 39, 39, 340, 0.3, 0.0),
        ]
        rng = np.random.default_rng(seed)
        for i, (name, r, c, nnz, skew, ef) in enumerate(cases):
            out.append((name, random_csr(r, c, nnz, skew=skew, empty_frac=ef,
                                         seed=seed + i)))
        return out
    cases = [
        # name, rows, cols, nnz, skew, empty_frac
        ("uniform_small", 300, 300, 1_500, 0.0, 0.0),
        ("uniform_mid", 4_000, 4_000, 40_000, 0.0, 0.0),
        ("uniform_wide", 1_000, 20_000, 30_000, 0.0, 0.0),
        ("zipf_mild", 4_000, 4_000, 60_000, 0.6, 0.0),
        ("zipf_heavy", 4_000, 4_000, 80_000, 1.1, 0.05),
        ("zipf_extreme", 2_000, 2_000, 60_000, 1.6, 0.10),
        ("scalefree_web", 8_000, 8_000, 120_000, 1.3, 0.30),
        ("banded_fem", 6_000, 6_000, 0, 0.0, 0.0),          # built below
        ("single_col_vec", 5_000, 1, 2_500, 0.0, 0.5),       # Fig 2 edge case
        ("empty_heavy", 3_000, 3_000, 9_000, 0.9, 0.60),
        ("tall_skinny", 20_000, 64, 60_000, 0.4, 0.0),
        ("short_fat", 64, 20_000, 60_000, 0.4, 0.0),
        ("tiny", 39, 39, 340, 0.3, 0.0),                     # ~chesapeake
    ]
    rng = np.random.default_rng(seed)
    for i, (name, r, c, nnz, skew, ef) in enumerate(cases):
        if name == "banded_fem":
            # tridiagonal-ish FEM band: perfectly regular rows.
            rows_idx = np.repeat(np.arange(r), 3)
            cols_idx = rows_idx + rng.integers(-1, 2, size=rows_idx.size)
            keep = (cols_idx >= 0) & (cols_idx < c)
            coo = COO(jnp.asarray(rows_idx[keep].astype(np.int32)),
                      jnp.asarray(cols_idx[keep].astype(np.int32)),
                      jnp.asarray(rng.standard_normal(keep.sum())
                                  .astype(np.float32)), (r, c),
                      int(keep.sum()))
            out.append((name, coo.to_csr()))
        else:
            out.append((name, random_csr(r, c, nnz, skew=skew, empty_frac=ef,
                                         seed=seed + i)))
    return out
