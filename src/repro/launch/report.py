"""Aggregate dry-run JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

GIB = 2 ** 30
HBM_BUDGET = 16 * GIB  # v5e


def load(dirname: str) -> List[Dict]:
    recs = []
    for name in sorted(os.listdir(dirname)):
        if name.endswith(".json"):
            with open(os.path.join(dirname, name)) as f:
                rec = json.load(f)
                rec["_file"] = name
                recs.append(rec)
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b / GIB:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | kind | mb | peak GiB/chip | fits 16G | "
            "compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "overrides" in r:
            continue
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        peak = r["memory"]["peak_estimate_bytes"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['kind']} | "
            f"{r['num_microbatches']} | {fmt_bytes(peak)} | "
            f"{'yes' if peak <= HBM_BUDGET else 'NO'} | "
            f"{r['compile_s']:.0f} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL_FLOPS | useful ratio |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["multi_pod"] or "roofline" not in r or "overrides" in r:
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} | "
            f"{rf['memory_s']:.4g} | {rf['collective_s']:.4g} | "
            f"{rf['bottleneck'].replace('_s', '')} | "
            f"{rf['model_flops_global']:.3g} | "
            f"{rf['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)


def collective_detail(recs: List[Dict]) -> str:
    rows = ["| arch | shape | all-reduce GiB | all-gather GiB | "
            "reduce-scatter GiB | all-to-all GiB | permute GiB |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["multi_pod"] or "roofline" not in r or "overrides" in r:
            continue
        w = r["roofline"]["wire_by_kind"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_bytes(w['all-reduce'])} | {fmt_bytes(w['all-gather'])} | "
            f"{fmt_bytes(w['reduce-scatter'])} | "
            f"{fmt_bytes(w['all-to-all'])} | "
            f"{fmt_bytes(w['collective-permute'])} |")
    return "\n".join(rows)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print("## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline table (single-pod 16x16)\n")
    print(roofline_table(recs))
    print("\n## Collective wire bytes per device (single-pod)\n")
    print(collective_detail(recs))


if __name__ == "__main__":
    main()
