"""Roofline analysis from compiled dry-run artifacts.

Terms (per device, seconds), TPU v5e constants:

    compute    = HLO_FLOPs / 197e12            (bf16 MXU peak)
    memory     = HLO_bytes / 819e9             (HBM bandwidth)
    collective = wire_bytes / 50e9             (per-link ICI)

``cost_analysis`` FLOPs/bytes and HLO-text collective parsing both count a
``while`` (scan) body ONCE, so metrics are derived from unscanned unit
compiles (L=1 and L=2, one microbatch) and composed:

    per_layer = unit(L=2) - unit(L=1)
    total     = n_micro * (unit(L=1) - per_layer) + n_micro * L * per_layer

(the optimizer update is over-counted n_micro-1 extra times by this formula;
it is O(params/chip) flops — orders of magnitude below one layer — noted in
EXPERIMENTS.md.)

Collective wire bytes use ring-algorithm factors with the replica-group size
``n`` parsed per op: all-reduce 2S(n-1)/n, all-gather/reduce-scatter
S(n-1)/n (S = full logical tensor), all-to-all S(n-1)/n, collective-permute
S (one hop).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

# --- hardware constants (TPU v5e) ------------------------------------------
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?\s*((?:\w+\[[\d,]*\][^ ]*,?\s?)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def collective_wire_bytes(hlo_text: str, num_devices: int
                          ) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring model)."""
    out: Dict[str, float] = {"all-reduce": 0.0, "all-gather": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shapes_str)  # per-device output bytes
        n = _group_size(line, num_devices)
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * size * frac
        elif kind == "all-gather":
            wire = size * frac          # size = full gathered output
        elif kind == "reduce-scatter":
            wire = size * n * frac      # size = scattered output (S/n)
        elif kind == "all-to-all":
            wire = size * frac
        else:  # collective-permute
            wire = size
        out[kind] += wire
    return out


@dataclasses.dataclass
class CellMetrics:
    flops: float                 # per device
    hbm_bytes: float             # per device
    wire_bytes: float            # per device
    wire_by_kind: Dict[str, float]

    def terms(self) -> Dict[str, float]:
        return {
            "compute_s": self.flops / PEAK_FLOPS_BF16,
            "memory_s": self.hbm_bytes / HBM_BW,
            "collective_s": self.wire_bytes / ICI_BW,
        }

    def bottleneck(self) -> str:
        t = self.terms()
        return max(t, key=t.get)


def unit_metrics(compiled, lowered_text: str, num_devices: int
                 ) -> CellMetrics:
    ca = compiled.cost_analysis()
    wire = collective_wire_bytes(lowered_text, num_devices)
    return CellMetrics(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=sum(wire.values()),
        wire_by_kind=wire)


def compose(unit1: CellMetrics, unit2: CellMetrics, num_layers: int,
            n_micro: int) -> CellMetrics:
    """total = n_micro * (rest + L * per_layer)   (see module docstring)."""
    def comb(a1, a2):
        per_layer = max(a2 - a1, 0.0)
        rest = max(a1 - per_layer, 0.0)
        return n_micro * (rest + num_layers * per_layer)

    wire = {k: comb(unit1.wire_by_kind[k], unit2.wire_by_kind[k])
            for k in unit1.wire_by_kind}
    return CellMetrics(
        flops=comb(unit1.flops, unit2.flops),
        hbm_bytes=comb(unit1.hbm_bytes, unit2.hbm_bytes),
        wire_bytes=sum(wire.values()),
        wire_by_kind=wire)


def model_flops(cfg, case, n_params_active: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward-only), global."""
    if case.kind == "train":
        tokens = case.global_batch * case.seq_len
        return 6.0 * n_params_active * tokens
    if case.kind == "prefill":
        tokens = case.global_batch * case.seq_len
        return 2.0 * n_params_active * tokens
    tokens = case.global_batch * 1  # decode: one token
    return 2.0 * n_params_active * tokens
