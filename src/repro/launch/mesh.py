"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only ``dryrun.py`` forces 512 host devices via XLA_FLAGS before any import.

Version compat: ``AxisType`` and ``make_mesh`` come from :mod:`repro.compat`
(jax 0.4.x has neither ``jax.sharding.AxisType`` nor the ``axis_types=``
kwarg); tests import them from here so they run on both API generations.

Axes:
* ``data`` — FSDP + batch data-parallel (16 chips: one v5e pod row)
* ``model`` — tensor/expert parallel (16 chips)
* ``pod`` — second data-parallel axis across pods (gradient all-reduce over
  DCN/ICI-over-pods); also the pipeline axis when PP is enabled.
"""
from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh

__all__ = ["AxisType", "make_mesh", "make_production_mesh", "make_host_mesh",
           "make_graph_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — for examples."""
    n = len(jax.devices())
    if model_axis <= 0 or n % model_axis != 0:
        raise ValueError(
            f"model_axis={model_axis} must evenly divide the local device "
            f"count ({n} available)")
    return make_mesh((n // model_axis, model_axis), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def make_graph_mesh(num_shards: int):
    """1-axis ``("shard",)`` mesh for sharded graph traversal.

    Used by :func:`repro.sparse.build_sharded_advance`; ``num_shards`` must
    not exceed the local device count (force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for CPU testing).
    """
    n = len(jax.devices())
    if num_shards <= 0 or num_shards > n:
        raise ValueError(
            f"num_shards={num_shards} must be in [1, {n}] "
            f"({n} local devices available)")
    return make_mesh((num_shards,), ("shard",),
                     axis_types=(AxisType.Auto,))
