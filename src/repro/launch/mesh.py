"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only ``dryrun.py`` forces 512 host devices via XLA_FLAGS before any import.

Axes:
* ``data`` — FSDP + batch data-parallel (16 chips: one v5e pod row)
* ``model`` — tensor/expert parallel (16 chips)
* ``pod`` — second data-parallel axis across pods (gradient all-reduce over
  DCN/ICI-over-pods); also the pipeline axis when PP is enabled.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — for examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
