"""Production training launcher.

    python -m repro.launch.train --arch olmoe_1b_7b --steps 500 \
        --seq 4096 --global-batch 256 --ckpt gs://.../run1 --compress-grads

On a real TPU slice this runs under ``jax.distributed.initialize()`` with
the production mesh; on a dev host it falls back to the local device mesh
and the reduced config (``--reduced``).  Fault tolerance: resumes from the
latest committed checkpoint; the data pipeline is stateless (step-indexed),
so restarts/membership changes need no iterator handoff.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import batch_at, for_model
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params, param_count
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (dev hosts)")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        args.seq = min(args.seq, 128)
        args.global_batch = min(args.global_batch, 8)
        args.microbatches = min(args.microbatches, 2)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    print(f"arch={cfg.name} params={param_count(cfg)/1e9:.2f}B "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps)

    grad_compress = None
    if args.compress_grads:
        from repro.train.compress import compress_roundtrip
        # int8 wire format for the cross-pod gradient reduction; the
        # error-feedback variant (repro.train.compress.ef_compress) is used
        # when the EF residual is threaded through host state.
        def grad_compress(grads):
            return jax.tree.map(compress_roundtrip, grads)

    step, psh, osh = make_train_step(
        cfg, opt_cfg, mesh, num_microbatches=args.microbatches,
        dtype=jnp.bfloat16 if not args.reduced else jnp.float32,
        grad_compress=grad_compress)

    params, _ = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params)
    start = 0
    if args.ckpt:
        restored = ckpt.restore_latest(args.ckpt, params, opt_state,
                                       param_sh=psh, opt_sh=osh)
        if restored is not None:
            params, opt_state, meta = restored
            start = meta["step"]
            print(f"resumed @ step {start}")
    if start == 0:
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, osh)

    dcfg = for_model(cfg, seq_len=args.seq, global_batch=args.global_batch,
                     seed=args.seed)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = batch_at(dcfg, i, cfg)
        if cfg.frontend is None:
            batch.pop("prefix_embeds", None)
        params, opt_state, m = step(params, opt_state, batch)
        if (i + 1) % 10 == 0 or i == start:
            toks = (i + 1 - start) * args.global_batch * args.seq
            print(f"step {i+1} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"tok/s={toks/max(time.time()-t0, 1e-9):,.0f}", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, i + 1, params, opt_state,
                      extra={"arch": cfg.name}, keep=args.keep,
                      async_save=True)
    print(f"finished {args.steps - start} steps in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
