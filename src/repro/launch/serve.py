"""Production serving launcher: builds the sharded serve_step for an
(arch, batch, cache-len) and runs a batched decode loop — or, with
``--graph``, a continuous-batching graph-query serving loop.

    python -m repro.launch.serve --arch glm4_9b --batch 128 --seq 32768
    python -m repro.launch.serve --arch rwkv6_3b --reduced --tokens 32
    python -m repro.launch.serve --graph --queries 32 --lanes 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_cache, init_params
from repro.serve.decode import make_serve_step, sample_logits


def run_graph_serving(args) -> None:
    """Drive a synthetic arrival stream through :class:`GraphServer`.

    Queries arrive Poisson-ish over serving ticks (rate ``--arrival-rate``
    per tick), mixed over BFS/SSSP/PageRank with random sources — the
    continuous-batching regime the lane batch exists for: staggered
    admission, retire-and-backfill, one trace for the whole stream.
    """
    from repro.serve.graph import GraphServer
    from repro.sparse import CSR, Graph, random_csr

    A = random_csr(args.graph_vertices, args.graph_vertices,
                   args.graph_edges, skew=1.3, empty_frac=0.1,
                   seed=args.seed)
    g = Graph(CSR(A.row_offsets, A.col_indices,
                  jnp.abs(A.values) + 0.05, A.shape, A.nnz))
    srv = GraphServer(g, lanes=args.lanes, direction=args.direction)
    rng = np.random.default_rng(args.seed)
    kinds = ["bfs", "sssp", "pagerank"]

    results = []
    submitted = 0
    t0 = time.perf_counter()
    while submitted < args.queries or srv.queued or srv.in_flight:
        if submitted < args.queries:
            for _ in range(min(int(rng.poisson(args.arrival_rate)),
                               args.queries - submitted)):
                kind = kinds[int(rng.integers(len(kinds)))]
                srv.submit(kind, source=int(rng.integers(g.num_vertices)))
                submitted += 1
        results.extend(srv.tick())
    dt = time.perf_counter() - t0

    lat = sorted(r.latency * 1e3 for r in results)
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(np.ceil(0.99 * len(lat))) - 1)]
    by_kind = {k: sum(r.kind == k for r in results) for k in kinds}
    print(f"{len(results)} queries ({by_kind}) on V={g.num_vertices} "
          f"E={g.num_edges} through {args.lanes} lanes in {dt:.2f}s "
          f"({len(results)/dt:.1f} q/s)")
    print(f"latency p50={p50:.1f}ms p99={p99:.1f}ms | "
          f"steps={srv.steps} step_traces={srv.step_traces} "
          f"admit_traces={srv.admit_traces}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM architecture (decode mode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=1024,
                    help="KV cache length")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--graph", action="store_true",
                    help="serve graph queries instead of LM decode")
    ap.add_argument("--graph-vertices", type=int, default=600)
    ap.add_argument("--graph-edges", type=int, default=4000)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean query arrivals per serving tick")
    ap.add_argument("--direction", choices=["auto", "pull", "push"],
                    default="pull")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.graph:
        run_graph_serving(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --graph is given")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        args.seq = min(args.seq, 64)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multi"))

    dtype = jnp.float32 if args.reduced else jnp.bfloat16
    step, psh, cache_sh, _ = make_serve_step(cfg, mesh, batch=args.batch,
                                             seq_len=args.seq, dtype=dtype)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(
        jax.tree.map(lambda p: p.astype(dtype) if p.dtype == jnp.float32
                     else p, params), psh)
    cache = jax.device_put(init_cache(cfg, args.batch, args.seq, dtype),
                           cache_sh)

    key = jax.random.PRNGKey(1)
    tok = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    outs = []
    for t in range(args.tokens):
        logits, cache = step(params, tok, jnp.int32(t), cache)
        key, sub = jax.random.split(key)
        tok = sample_logits(sub, logits, args.temperature,
                            vocab_size=cfg.vocab_size)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{args.tokens} tokens x {args.batch} batch in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s)")
    print("sample:", np.asarray(jnp.concatenate(outs, 1))[0][:16])


if __name__ == "__main__":
    main()
