"""Production serving launcher: builds the sharded serve_step for an
(arch, batch, cache-len) and runs a batched decode loop.

    python -m repro.launch.serve --arch glm4_9b --batch 128 --seq 32768
    python -m repro.launch.serve --arch rwkv6_3b --reduced --tokens 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_cache, init_params
from repro.serve.decode import make_serve_step, sample_logits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=1024,
                    help="KV cache length")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        args.seq = min(args.seq, 64)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multi"))

    dtype = jnp.float32 if args.reduced else jnp.bfloat16
    step, psh, cache_sh, _ = make_serve_step(cfg, mesh, batch=args.batch,
                                             seq_len=args.seq, dtype=dtype)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(
        jax.tree.map(lambda p: p.astype(dtype) if p.dtype == jnp.float32
                     else p, params), psh)
    cache = jax.device_put(init_cache(cfg, args.batch, args.seq, dtype),
                           cache_sh)

    key = jax.random.PRNGKey(1)
    tok = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    outs = []
    for t in range(args.tokens):
        logits, cache = step(params, tok, jnp.int32(t), cache)
        key, sub = jax.random.split(key)
        tok = jnp.minimum(sample_logits(sub, logits, args.temperature),
                          cfg.vocab_size - 1)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{args.tokens} tokens x {args.batch} batch in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s)")
    print("sample:", np.asarray(jnp.concatenate(outs, 1))[0][:16])


if __name__ == "__main__":
    main()
