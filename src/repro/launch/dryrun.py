"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the fake-device flag before ANY other import (jax locks the device
count on first init):
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config  # noqa
from repro.launch import roofline as RL                            # noqa
from repro.compat import use_ambient_mesh                          # noqa
from repro.launch.mesh import make_production_mesh                 # noqa
from repro.launch.specs import (decode_input_specs, pick_microbatches,  # noqa
                                prefill_input_specs, train_input_specs)
from repro.models import active_param_count, param_count           # noqa
from repro.train.optimizer import OptConfig                        # noqa


def _mem_dict(compiled):
    ma = compiled.memory_analysis()
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    out = {f: int(getattr(ma, f, 0)) for f in fields}
    out["peak_estimate_bytes"] = (out["argument_size_in_bytes"]
                                  + out["temp_size_in_bytes"]
                                  + out["output_size_in_bytes"]
                                  - out["alias_size_in_bytes"])
    return out


def build_cell(cfg, case, mesh, n_micro):
    """Returns (jitted_step, args_sds_tuple) for one cell."""
    if case.kind == "train":
        from repro.train.step import make_train_step
        step, _, _ = make_train_step(cfg, OptConfig(), mesh,
                                     num_microbatches=n_micro)
        return step, train_input_specs(cfg, case, mesh)
    if case.kind == "prefill":
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.lm import prefill
        from repro.serve.decode import cache_pspecs
        from repro.train.step import shardings_for
        args = prefill_input_specs(cfg, case, mesh)
        cache_sh = shardings_for(mesh,
                                 cache_pspecs(cfg, mesh, case.global_batch))

        if cfg.frontend is not None:
            def fn(params, tokens, prefix):
                with use_ambient_mesh(mesh):
                    return prefill(params, cfg, tokens, prefix,
                                   dtype=jnp.bfloat16)
        else:
            def fn(params, tokens):
                with use_ambient_mesh(mesh):
                    return prefill(params, cfg, tokens, dtype=jnp.bfloat16)
        step = jax.jit(fn, out_shardings=(
            NamedSharding(mesh, P()), cache_sh))
        return step, args
    # decode
    from repro.serve.decode import make_serve_step
    step, _, _, _ = make_serve_step(cfg, mesh, batch=case.global_batch,
                                    seq_len=case.seq_len)
    return step, decode_input_specs(cfg, case, mesh)


def lower_compile(step, args):
    t0 = time.time()
    lowered = step.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return lowered, compiled, t1 - t0, t2 - t1


def unit_cfg(cfg, num_layers):
    return dataclasses.replace(cfg, num_layers=num_layers,
                               scan_layers=False, unroll_inner_scans=True)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             do_roofline: bool, out_dir: str,
             overrides=None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    case = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_micro = pick_microbatches(cfg, case, mesh)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": list(mesh.devices.shape), "multi_pod": multi_pod,
           "kind": case.kind, "num_microbatches": n_micro,
           "params": param_count(cfg),
           "params_active": active_param_count(cfg)}
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}

    # --- production compile (the dry-run deliverable) -----------------------
    step, args = build_cell(cfg, case, mesh, n_micro)
    lowered, compiled, t_lower, t_compile = lower_compile(step, args)
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["memory"] = _mem_dict(compiled)
    print(f"  memory_analysis: {compiled.memory_analysis()}")
    ca = compiled.cost_analysis()
    print(f"  cost_analysis: flops={ca.get('flops')} "
          f"bytes={ca.get('bytes accessed')}")
    rec["cost_analysis_raw"] = {"flops": float(ca.get("flops", 0.0)),
                                "bytes": float(ca.get("bytes accessed", 0.0))}

    # --- roofline (single-pod only): unit compiles + composition ------------
    if do_roofline:
        case_unit = case
        nm = n_micro
        if case.kind == "train":
            micro_b = case.global_batch // n_micro
            case_unit = dataclasses.replace(case, global_batch=micro_b)
        units = []
        for nl in (1, 2):
            ucfg = unit_cfg(cfg, nl)
            ustep, uargs = build_cell(ucfg, case_unit, mesh, 1)
            _, ucomp, _, _ = lower_compile(ustep, uargs)
            # collectives only exist post-SPMD-partitioning -> compiled text
            units.append(RL.unit_metrics(ucomp, ucomp.as_text(), mesh.size))
        total = RL.compose(units[0], units[1], cfg.num_layers, nm)
        terms = total.terms()
        mf = RL.model_flops(cfg, case, rec["params_active"])
        hlo_flops_global = total.flops * mesh.size
        rec["roofline"] = {
            "flops_per_device": total.flops,
            "hbm_bytes_per_device": total.hbm_bytes,
            "wire_bytes_per_device": total.wire_bytes,
            "wire_by_kind": total.wire_by_kind,
            **{k: float(v) for k, v in terms.items()},
            "bottleneck": total.bottleneck(),
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / hlo_flops_global
                                   if hlo_flops_global else 0.0),
        }

    os.makedirs(out_dir, exist_ok=True)
    suffix = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{suffix}"
    if overrides:
        tag += "__" + "_".join(f"{k}-{v}" for k, v in overrides.items())
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([n for n, _ in cells_for(cfg)] if args.shape == "all"
                  else args.shape.split(","))
        for shape_name in shapes:
            for multi in meshes:
                suffix = "multi" if multi else "single"
                tag = f"{arch}__{shape_name}__{suffix}"
                path = os.path.join(args.out, f"{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                # roofline only on the single-pod mesh (per assignment)
                do_roof = (not multi) and (not args.no_roofline)
                print(f"[cell] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape_name, multi_pod=multi,
                                   do_roofline=do_roof, out_dir=args.out)
                    extra = ""
                    if "roofline" in rec:
                        r = rec["roofline"]
                        extra = (f" bottleneck={r['bottleneck']}"
                                 f" compute={r['compute_s']:.4f}s"
                                 f" mem={r['memory_s']:.4f}s"
                                 f" coll={r['collective_s']:.4f}s")
                    print(f"[ok]   {tag} ({time.time()-t0:.0f}s)"
                          f" peak={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB"
                          + extra, flush=True)
                except Exception as e:  # record and continue
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
    print(f"\n{len(failures)} failures")
    for tag, err in failures:
        print(f"  {tag}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
