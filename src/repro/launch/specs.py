"""input_specs: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, sharded, zero-allocation — the dry-run lowers
``step.lower(*input_specs(...))`` against the production mesh without ever
materializing a tensor.  One builder per shape kind:

* ``train``  -> (params, opt_state, batch) for ``make_train_step``
* ``prefill``-> (params, tokens[, prefix_embeds]) for jitted ``prefill``
* ``decode`` -> (params, tokens, pos, cache) for ``make_serve_step``
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCase
from repro.models import cache_shape, param_shapes
from repro.serve.decode import cache_pspecs, _data_axes
from repro.train.optimizer import init_opt_state
from repro.train.step import batch_pspec, param_specs, shardings_for


def _sds(tree_shapes, tree_sh):
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        tree_shapes, tree_sh)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def pick_microbatches(cfg: ModelConfig, case: ShapeCase, mesh: Mesh) -> int:
    """Memory-driven default: big models accumulate more; microbatch stays
    divisible by the data-parallel extent."""
    if case.kind != "train":
        return 1
    from repro.models import param_count
    n = param_count(cfg)
    preferred = 16 if n > 100e9 else 8 if n > 5e9 else 4
    max_mb = max(case.global_batch // dp_size(mesh), 1)
    return max(min(preferred, max_mb), 1)


def param_sds(cfg: ModelConfig, mesh: Mesh, dtype=None):
    shapes = param_shapes(cfg)
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if s.dtype == jnp.float32 else s.dtype),
            shapes)
    sh = shardings_for(mesh, param_specs(cfg))
    return _sds(shapes, sh)


def train_input_specs(cfg: ModelConfig, case: ShapeCase, mesh: Mesh
                      ) -> Tuple[Any, Any, Dict[str, Any]]:
    """(params, opt_state, batch) ShapeDtypeStructs for train_step."""
    p_sds = param_sds(cfg, mesh)
    opt_shapes = jax.eval_shape(init_opt_state, param_shapes(cfg))
    from repro.train.step import opt_shardings
    o_sh = opt_shardings(mesh, shardings_for(mesh, param_specs(cfg)))
    o_sds = _sds(opt_shapes, o_sh)

    bsh = NamedSharding(mesh, batch_pspec(mesh))
    b, s = case.global_batch, case.seq_len
    tok_len = s - (cfg.frontend_len if cfg.frontend else 0)
    batch: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, tok_len), jnp.int32, sharding=bsh),
        "labels": jax.ShapeDtypeStruct((b, tok_len), jnp.int32, sharding=bsh),
    }
    if cfg.frontend is not None:
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16, sharding=bsh)
    return p_sds, o_sds, batch


def prefill_input_specs(cfg: ModelConfig, case: ShapeCase, mesh: Mesh):
    """(params, tokens[, prefix_embeds]) for the prefill step (bf16 params:
    inference does not carry fp32 masters)."""
    p_sds = param_sds(cfg, mesh, dtype=jnp.bfloat16)
    daxes = _data_axes(mesh, case.global_batch)
    tsh = NamedSharding(mesh, P(daxes if daxes else None, None))
    tok_len = case.seq_len - (cfg.frontend_len if cfg.frontend else 0)
    toks = jax.ShapeDtypeStruct((case.global_batch, tok_len), jnp.int32,
                                sharding=tsh)
    out = [p_sds, toks]
    if cfg.frontend is not None:
        esh = NamedSharding(mesh, P(daxes if daxes else None, None, None))
        out.append(jax.ShapeDtypeStruct(
            (case.global_batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16,
            sharding=esh))
    return tuple(out)


def decode_input_specs(cfg: ModelConfig, case: ShapeCase, mesh: Mesh):
    """(params, tokens, pos, cache) for serve_step (KV cache of seq_len)."""
    p_sds = param_sds(cfg, mesh, dtype=jnp.bfloat16)
    b = case.global_batch
    daxes = _data_axes(mesh, b)
    tsh = NamedSharding(mesh, P(daxes if daxes else None, None))
    toks = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=tsh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    c_sh = shardings_for(mesh, cache_pspecs(cfg, mesh, b))
    c_sds = _sds(cache_shape(cfg, b, case.seq_len, jnp.bfloat16), c_sh)
    return p_sds, toks, pos, c_sds
