"""Cost-model schedule autotuner (the dissertation's "which schedule?").

Osama's dissertation (arXiv 2212.08964) frames schedule *selection* as the
load-balancing user's hardest problem: the right choice depends on the
workload's shape, skew and sparsity in ways no single heuristic captures.
The repo already owns the pieces — exact per-schedule lockstep cost models
(:mod:`repro.core.balance`) and shape statistics (``ImbalanceStats``) — so
selection is just argmin over the registered schedules' modeled costs.

Because the cost models partition the actual WorkSpec, scoring is exact but
not free (O(num_schedules * num_blocks log T)).  Workloads recur — the same
matrix shape every SpMV, the same expert count every MoE layer — so choices
are memoised twice, both levels keyed by the same *quantised* shape
fingerprint (log2 size buckets + rounded skew stats + num_blocks):

* an **in-process dict** (no I/O after the first hit), and
* a **persistent JSON cache** (``REPRO_AUTOTUNE_CACHE`` or
  ``~/.cache/repro/autotune.json``), surviving across processes the way
  kernel autotuners persist their tuning tables.

Quantisation is deliberate: workloads in the same bucket share a winner in
practice, which is what makes entries reusable across runs with fresh
random data — at the cost that two workloads near a decision boundary can
share a (slightly suboptimal) choice.  Pass ``cache=None`` for exact
argmin selection every call.

Entry points: :func:`select_schedule` (-> Schedule) and
:func:`score_schedules` (-> {schedule: cost}); ``make_partition(spec,
"auto", num_blocks)`` routes here.
"""
from __future__ import annotations

import json
import math
import os
import pathlib
import tempfile
import threading
from typing import Dict, Optional, Sequence

import jax

from repro.core.balance import ImbalanceStats, modeled_cost
from repro.core.schedules import Schedule
from repro.core.work import WorkSpec

#: Candidate schedules scored by the autotuner, in tie-break priority order
#: (earlier wins ties: prefer the simpler/static schedule on equal cost).
REGISTERED_SCHEDULES: Sequence[Schedule] = (
    Schedule.THREAD_MAPPED,
    Schedule.GROUP_MAPPED,
    Schedule.NONZERO_SPLIT,
    Schedule.MERGE_PATH,
    Schedule.ADAPTIVE,
    Schedule.CHUNKED,
)

_ENV_CACHE_PATH = "REPRO_AUTOTUNE_CACHE"


def _default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_CACHE_PATH)
    if env:
        return pathlib.Path(env)
    return pathlib.Path(os.path.expanduser("~")) / ".cache" / "repro" / \
        "autotune.json"


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def shape_key(spec: WorkSpec, num_blocks: int,
              stats: Optional[ImbalanceStats] = None) -> str:
    """Quantised workload fingerprint for the persistent cache.

    Buckets sizes by log2 and skew statistics to one decimal: the cost
    landscape moves on these scales, not on exact nnz.
    """
    if stats is None:
        stats = ImbalanceStats.measure(spec)
    lg = lambda n: int(math.log2(n)) if n > 0 else -1
    return (f"b{num_blocks}|t{lg(spec.num_tiles)}|a{lg(spec.num_atoms)}"
            f"|cv{stats.cv_atoms_per_tile:.1f}|g{stats.gini:.1f}"
            f"|e{stats.empty_tile_fraction:.1f}")


class AutotuneCache:
    """Two-level (memory + JSON file) schedule-choice cache.

    Both levels use the quantised :func:`shape_key` fingerprint — workloads
    in the same bucket share one choice.  The file path is resolved lazily
    so ``REPRO_AUTOTUNE_CACHE`` set after import is still honoured.
    """

    def __init__(self, path: Optional[pathlib.Path] = None):
        self._explicit_path = pathlib.Path(path) if path else None
        self._mem: Dict[str, str] = {}
        self._loaded = False
        self._lock = threading.Lock()

    @property
    def path(self) -> pathlib.Path:
        return self._explicit_path or _default_cache_path()

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        try:
            on_disk = json.loads(self.path.read_text())
            if isinstance(on_disk, dict):
                # memory wins on conflict (fresher within this process)
                self._mem = {**on_disk, **self._mem}
        except (OSError, ValueError):
            pass

    def get(self, key: str) -> Optional[Schedule]:
        with self._lock:
            self._load()
            name = self._mem.get(key)
        try:
            return Schedule(name) if name else None
        except ValueError:          # stale entry from an older schedule set
            return None

    def put(self, key: str, schedule: Schedule) -> None:
        with self._lock:
            self._load()
            self._mem[key] = str(schedule)
            snapshot = dict(self._mem)
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass                    # read-only FS: stay memory-only

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._loaded = True
            try:
                self.path.unlink()
            except OSError:
                pass


_DEFAULT_CACHE = AutotuneCache()


def score_schedules(spec: WorkSpec, num_blocks: int,
                    schedules: Sequence[Schedule] = REGISTERED_SCHEDULES
                    ) -> Dict[Schedule, float]:
    """Modeled lockstep cost of each candidate schedule for this workload."""
    return {s: modeled_cost(spec, s, num_blocks) for s in schedules}


def select_schedule(spec: WorkSpec, num_blocks: int, *,
                    cache: Optional[AutotuneCache] = _DEFAULT_CACHE,
                    schedules: Sequence[Schedule] = REGISTERED_SCHEDULES
                    ) -> Schedule:
    """Pick the cheapest schedule by modeled cost (cached per shape).

    Requires a concrete (non-traced) WorkSpec: selection is an inspector
    step that runs before launch.  Under tracing, callers should fall back
    to a fixed schedule (see e.g. ``repro.models.moe``).
    """
    if not _is_concrete(spec.tile_offsets):
        raise ValueError(
            "select_schedule needs a concrete WorkSpec (autotuning is a "
            "pre-launch inspector); pass an explicit schedule under jit")
    key = None
    if cache is not None:
        key = shape_key(spec, num_blocks)
        hit = cache.get(key)
        if hit is not None and hit in schedules:
            return hit
    scores = score_schedules(spec, num_blocks, schedules)
    best = min(schedules, key=lambda s: (scores[s],
                                         list(schedules).index(s)))
    if cache is not None:
        cache.put(key, best)
    return best
