"""Cost-model schedule autotuner (the dissertation's "which schedule?").

Osama's dissertation (arXiv 2212.08964) frames schedule *selection* as the
load-balancing user's hardest problem: the right choice depends on the
workload's shape, skew and sparsity in ways no single heuristic captures.
The repo already owns the pieces — exact per-schedule lockstep cost models
(:mod:`repro.core.balance`) and shape statistics (``ImbalanceStats``) — so
selection is just argmin over the registered schedules' modeled costs.

Because the cost models partition the actual WorkSpec, scoring is exact but
not free (O(num_schedules * num_blocks log T)).  Workloads recur — the same
matrix shape every SpMV, the same expert count every MoE layer — so choices
are memoised twice, both levels keyed by the same *quantised* shape
fingerprint (log2 size buckets + rounded skew stats + num_blocks):

* an **in-process dict** (no I/O after the first hit), and
* a **persistent JSON cache** (``REPRO_AUTOTUNE_CACHE`` or
  ``~/.cache/repro/autotune.json``), surviving across processes the way
  kernel autotuners persist their tuning tables.

Quantisation is deliberate: workloads in the same bucket share a winner in
practice, which is what makes entries reusable across runs with fresh
random data — at the cost that two workloads near a decision boundary can
share a (slightly suboptimal) choice.  Pass ``cache=None`` for exact
argmin selection every call.

Entry points: :func:`select_schedule` (-> Schedule, schedule-only scoring),
:func:`select_plan` (-> :class:`Plan`: schedule **and** execution path —
this is how ``"auto"`` can choose the native chunk-walking kernel), and
:func:`score_schedules` / :func:`score_plans`; ``make_partition(spec,
"auto", num_blocks)`` routes here.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import tempfile
import threading
from typing import Dict, Optional, Sequence

import jax

from repro.core.balance import (ADVANCE_ATOM_WORK, ADVANCE_DELTA_ATOM_WORK,
                                ADVANCE_DELTA_PUSH_ATOM_WORK,
                                ADVANCE_PUSH_ATOM_WORK, ImbalanceStats,
                                modeled_cost)
from repro.core.execute import ExecutionPath
from repro.core.schedules import Schedule
from repro.core.work import WorkSpec

#: Candidate schedules scored by the autotuner, in tie-break priority order
#: (earlier wins ties: prefer the simpler/static schedule on equal cost).
REGISTERED_SCHEDULES: Sequence[Schedule] = (
    Schedule.THREAD_MAPPED,
    Schedule.GROUP_MAPPED,
    Schedule.NONZERO_SPLIT,
    Schedule.MERGE_PATH,
    Schedule.ADAPTIVE,
    Schedule.CHUNKED,
)


@dataclasses.dataclass(frozen=True)
class Plan:
    """An autotuner decision: which schedule, on which execution path."""

    schedule: Schedule
    path: ExecutionPath = ExecutionPath.PURE

    def encode(self) -> str:
        return f"{self.schedule}@{self.path}"

    @classmethod
    def decode(cls, value: str) -> "Plan":
        name, _, path = value.partition("@")
        return cls(Schedule(name),
                   ExecutionPath(path) if path else ExecutionPath.PURE)


#: Candidate (schedule, path) plans, in tie-break priority order.  Only the
#: chunked queue's cost model distinguishes paths today (the native
#: chunk-walking kernel pops cheaper than the host-realized queue), so it is
#: the one schedule listed twice; native outranks pure on equal cost.
REGISTERED_PLANS: Sequence[Plan] = tuple(
    [Plan(s) for s in REGISTERED_SCHEDULES if s != Schedule.CHUNKED]
    + [Plan(Schedule.CHUNKED, ExecutionPath.NATIVE),
       Plan(Schedule.CHUNKED, ExecutionPath.PURE)])

#: Workload families the planner can score.  ``"reduce"`` is the plain
#: tile-reduce (SpMV/segmm); ``"advance"`` is the frontier-masked pull
#: advance, whose per-atom transform is heavier (mask load + select);
#: ``"advance_push"`` is the push-direction advance (tiles = sources, atoms
#: = out-edges), whose active atoms are heavier still (destination gather +
#: scatter-combine share) and whose balance problem is over *out*-degrees —
#: so the per-block overhead constants amortize differently and the argmin
#: can move per family.  ``"advance_delta"`` / ``"advance_delta_push"`` are
#: the *bucketed* (delta-stepping) siblings: every atom additionally pays
#: the light/heavy bucket-mask select, so the atom term is one step heavier
#: per direction and the argmin can move again.  Each family keeps its own
#: cache namespace (``|plan.advance`` / ``|plan.advance_push`` /
#: ``|plan.advance_delta`` / ``|plan.advance_delta_push``); scoring charges
#: the direction's full-density worst case — the density axis is the
#: *driver's* per-iteration decision, not the planner's (see
#: :func:`repro.core.balance.estimate_direction_threshold`).
WORKLOAD_ATOM_WORK = {"reduce": 1, "advance": ADVANCE_ATOM_WORK,
                      "advance_push": ADVANCE_PUSH_ATOM_WORK,
                      "advance_delta": ADVANCE_DELTA_ATOM_WORK,
                      "advance_delta_push": ADVANCE_DELTA_PUSH_ATOM_WORK}

_ENV_CACHE_PATH = "REPRO_AUTOTUNE_CACHE"


def _default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_CACHE_PATH)
    if env:
        return pathlib.Path(env)
    return pathlib.Path(os.path.expanduser("~")) / ".cache" / "repro" / \
        "autotune.json"


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def shape_key(spec: WorkSpec, num_blocks: int,
              stats: Optional[ImbalanceStats] = None) -> str:
    """Quantised workload fingerprint for the persistent cache.

    Buckets sizes by log2 and skew statistics to one decimal: the cost
    landscape moves on these scales, not on exact nnz.
    """
    if stats is None:
        stats = ImbalanceStats.measure(spec)
    lg = lambda n: int(math.log2(n)) if n > 0 else -1
    return (f"b{num_blocks}|t{lg(spec.num_tiles)}|a{lg(spec.num_atoms)}"
            f"|cv{stats.cv_atoms_per_tile:.1f}|g{stats.gini:.1f}"
            f"|e{stats.empty_tile_fraction:.1f}")


class AutotuneCache:
    """Two-level (memory + JSON file) schedule-choice cache.

    Both levels use the quantised :func:`shape_key` fingerprint — workloads
    in the same bucket share one choice.  The file path is resolved lazily
    so ``REPRO_AUTOTUNE_CACHE`` set after import is still honoured.

    Concurrency discipline: writes go through a fresh read-merge of the
    on-disk state followed by tempfile + ``os.replace`` (atomic on POSIX),
    so two processes autotuning concurrently never truncate or corrupt the
    file, and disjoint keys survive on a best-effort basis (a writer that
    read before another's replace landed can still publish a merge missing
    that key — losing a cache entry only costs a retune; same-key races
    are last-writer-wins, both writers computed a valid choice).  A corrupt
    or partially-written file is treated as empty rather than raised.
    """

    def __init__(self, path: Optional[pathlib.Path] = None):
        self._explicit_path = pathlib.Path(path) if path else None
        self._mem: Dict[str, str] = {}
        self._loaded = False
        self._lock = threading.Lock()

    @property
    def path(self) -> pathlib.Path:
        return self._explicit_path or _default_cache_path()

    def _read_disk(self) -> Dict[str, str]:
        """Best-effort parse of the on-disk table; corrupt/missing -> {}."""
        try:
            on_disk = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return {}
        if not isinstance(on_disk, dict):
            return {}
        return {str(k): str(v) for k, v in on_disk.items()}

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        # memory wins on conflict (fresher within this process)
        self._mem = {**self._read_disk(), **self._mem}

    def get(self, key: str) -> Optional[Schedule]:
        plan = self.get_plan(key)
        return plan.schedule if plan else None

    def get_plan(self, key: str) -> Optional[Plan]:
        with self._lock:
            self._load()
            value = self._mem.get(key)
        try:
            return Plan.decode(value) if value else None
        except ValueError:          # stale entry from an older schedule set
            return None

    def put(self, key: str, schedule: Schedule) -> None:
        self.put_plan(key, Plan(schedule))

    def put_plan(self, key: str, plan: Plan) -> None:
        with self._lock:
            self._load()
            self._mem[key] = plan.encode()
            snapshot = dict(self._mem)
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            # merge with the *current* disk state so a concurrent writer's
            # fresh keys survive this replace (read-modify-write without
            # this re-read silently drops them)
            merged = {**self._read_disk(), **snapshot}
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(merged, f, indent=0, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)  # do not leak tempfiles on failure
                except OSError:
                    pass
                raise
        except OSError:
            pass                    # read-only FS: stay memory-only

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._loaded = True
            try:
                self.path.unlink()
            except OSError:
                pass


_DEFAULT_CACHE = AutotuneCache()


def score_schedules(spec: WorkSpec, num_blocks: int,
                    schedules: Sequence[Schedule] = REGISTERED_SCHEDULES
                    ) -> Dict[Schedule, float]:
    """Modeled lockstep cost of each candidate schedule for this workload."""
    return {s: modeled_cost(spec, s, num_blocks) for s in schedules}


def _check_workload(workload: str) -> None:
    if workload not in WORKLOAD_ATOM_WORK:
        raise ValueError(f"unknown workload family: {workload!r} "
                         f"(expected one of {sorted(WORKLOAD_ATOM_WORK)})")


def score_plans(spec: WorkSpec, num_blocks: int,
                plans: Sequence[Plan] = REGISTERED_PLANS,
                workload: str = "reduce") -> Dict[Plan, float]:
    """Modeled lockstep cost of each (schedule, execution path) plan."""
    _check_workload(workload)
    atom_work = WORKLOAD_ATOM_WORK[workload]
    return {p: modeled_cost(spec, p.schedule, num_blocks, path=str(p.path),
                            atom_work=atom_work)
            for p in plans}


def select_plan(spec: WorkSpec, num_blocks: int, *,
                cache: Optional[AutotuneCache] = _DEFAULT_CACHE,
                plans: Sequence[Plan] = REGISTERED_PLANS,
                workload: str = "reduce") -> Plan:
    """Pick the cheapest (schedule, execution path) plan by modeled cost.

    This is the path-aware selector: the chunked schedule is scored on both
    the native chunk-walking kernel and the host-realized fallback, so
    ``"auto"`` can choose the native path outright.  Cached under a
    namespaced key (``<shape_key>|plan``, plus ``.advance`` for the graph
    advance family) so schedule-only entries written by
    :func:`select_schedule` are never misread as plans (and vice versa),
    and advance choices never shadow reduce choices for the same shape.
    ``cache=None`` selects by exact argmin every call.
    """
    _check_workload(workload)
    if not _is_concrete(spec.tile_offsets):
        raise ValueError(
            "select_plan needs a concrete WorkSpec (autotuning is a "
            "pre-launch inspector); pass an explicit schedule under jit")
    key = None
    if cache is not None:
        key = shape_key(spec, num_blocks) + "|plan"
        if workload != "reduce":
            key += f".{workload}"
        hit = cache.get_plan(key)
        if hit is not None and hit in plans:
            return hit
    scores = score_plans(spec, num_blocks, plans, workload)
    best = min(plans, key=scores.get)   # min is stable: plan order breaks ties
    if cache is not None:
        cache.put_plan(key, best)
    return best


def select_schedule(spec: WorkSpec, num_blocks: int, *,
                    cache: Optional[AutotuneCache] = _DEFAULT_CACHE,
                    schedules: Sequence[Schedule] = REGISTERED_SCHEDULES
                    ) -> Schedule:
    """Pick the cheapest schedule by modeled cost (cached per shape).

    Requires a concrete (non-traced) WorkSpec: selection is an inspector
    step that runs before launch.  Under tracing, callers should fall back
    to a fixed schedule (see e.g. ``repro.models.moe``).
    """
    if not _is_concrete(spec.tile_offsets):
        raise ValueError(
            "select_schedule needs a concrete WorkSpec (autotuning is a "
            "pre-launch inspector); pass an explicit schedule under jit")
    key = None
    if cache is not None:
        key = shape_key(spec, num_blocks)
        hit = cache.get(key)
        if hit is not None and hit in schedules:
            return hit
    scores = score_schedules(spec, num_blocks, schedules)
    best = min(schedules, key=lambda s: (scores[s],
                                         list(schedules).index(s)))
    if cache is not None:
        cache.put(key, best)
    return best
