"""Cost-model schedule autotuner (the dissertation's "which schedule?").

Osama's dissertation (arXiv 2212.08964) frames schedule *selection* as the
load-balancing user's hardest problem: the right choice depends on the
workload's shape, skew and sparsity in ways no single heuristic captures.
The repo already owns the pieces — exact per-schedule lockstep cost models
(:mod:`repro.core.balance`) and shape statistics (``ImbalanceStats``) — so
selection is just argmin over the registered schedules' modeled costs.

Because the cost models partition the actual WorkSpec, scoring is exact but
not free (O(num_schedules * num_blocks log T)).  Workloads recur — the same
matrix shape every SpMV, the same expert count every MoE layer — so choices
are memoised twice, both levels keyed by the same *quantised* shape
fingerprint (log2 size buckets + rounded skew stats + num_blocks):

* an **in-process dict** (no I/O after the first hit), and
* a **persistent JSON cache** (``REPRO_AUTOTUNE_CACHE`` or
  ``~/.cache/repro/autotune.json``), surviving across processes the way
  kernel autotuners persist their tuning tables.

Quantisation is deliberate: workloads in the same bucket share a winner in
practice, which is what makes entries reusable across runs with fresh
random data — at the cost that two workloads near a decision boundary can
share a (slightly suboptimal) choice.  Pass ``cache=None`` for exact
argmin selection every call.

**Measured-cost feedback (PR 6):** the model is a hand-set prior; on
hardware it has never seen, the trustworthy signal is a wall clock.  With
measurement enabled (``REPRO_AUTOTUNE_MEASURE=1`` and a ``measure=``
callable passed by the call site), :func:`select_plan` times the top-k
model-ranked candidates **once**, persists the measured medians into the
cache record (the v2 format below), and thereafter ranks by
*measurement-as-posterior over model-as-prior*: measured candidates score
their measured time; unmeasured ones score the model cost scaled into
wall-clock units by the geometric-mean measured/modeled ratio of the
measured set.  Reloading a measured cache re-ranks without re-measuring
(:func:`repro.core.measure.measurement_count` is the regression hook).
Accumulated records also feed :func:`repro.core.balance.fit_coefficients`
via :func:`collect_fit_samples` (the ``benchmarks/fit_cost_model.py`` CLI).

Cache values take two shapes (see docs/autotune.md for the full contract):

* **v1 (legacy)** — a bare ``"schedule@path"`` string; still written for
  purely model-driven choices and decoded forever.
* **v2 (measured)** — ``{"v": 2, "plan": "schedule@path", "measured_us":
  {"schedule@path": us, ...}, "features": {"schedule@path": [base,
  {coef: count}], ...}}``.  ``measured_us`` holds each timed candidate's
  median; ``features`` its model-cost decomposition over the tunable
  coefficients at measure time (what the re-fit consumes).  Corrupt or
  torn sub-fields degrade to model-only behaviour, never raise.

Entry points: :func:`select_schedule` (-> Schedule, schedule-only scoring),
:func:`select_plan` (-> :class:`Plan`: schedule **and** execution path —
this is how ``"auto"`` can choose the native chunk-walking kernel), and
:func:`score_schedules` / :func:`score_plans`; ``make_partition(spec,
"auto", num_blocks)`` routes here.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import tempfile
import threading
from typing import (Callable, ClassVar, Dict, List, Optional, Sequence,
                    Tuple)

import jax

from repro.core.balance import (ADVANCE_ATOM_WORK, ADVANCE_DELTA_ATOM_WORK,
                                ADVANCE_DELTA_PUSH_ATOM_WORK,
                                ADVANCE_PUSH_ATOM_WORK,
                                WAVEFRONT_ATOM_WORK,
                                WAVEFRONT_PUSH_ATOM_WORK, ImbalanceStats,
                                cost_features, modeled_cost,
                                modeled_sharded_cost)
from repro.core.execute import ExecutionPath
from repro.core.measure import geomean
from repro.core.schedules import Schedule
from repro.core.work import WorkSpec

#: Candidate schedules scored by the autotuner, in tie-break priority order
#: (earlier wins ties: prefer the simpler/static schedule on equal cost).
REGISTERED_SCHEDULES: Sequence[Schedule] = (
    Schedule.THREAD_MAPPED,
    Schedule.GROUP_MAPPED,
    Schedule.NONZERO_SPLIT,
    Schedule.MERGE_PATH,
    Schedule.ADAPTIVE,
    Schedule.CHUNKED,
)


@dataclasses.dataclass(frozen=True)
class Plan:
    """An autotuner decision: which schedule, on which execution path."""

    schedule: Schedule
    path: ExecutionPath = ExecutionPath.PURE

    def encode(self) -> str:
        return f"{self.schedule}@{self.path}"

    @classmethod
    def decode(cls, value: str) -> "Plan":
        name, _, path = value.partition("@")
        return cls(Schedule(name),
                   ExecutionPath(path) if path else ExecutionPath.PURE)


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """An autotuner decision one level up: schedule, path, shard count *and*
    the shard boundary schedule.

    The recursion the sharded traversal introduces — shards balance devices
    the way chunks balance blocks — adds two axes to the decision space:
    how many shards, and which boundary schedule places the contiguous
    split points (``"equal_width"``, ``"edge_balanced"``,
    ``"lpt_contiguous"``; see ``repro.sparse.shard.SHARD_SCHEDULES``).
    Every shard runs the same (schedule, path) pair (``shard_map`` traces a
    single program), so the plan is four-dimensional, not per-shard.
    Encoded ``"schedule@path@sN@bname"``; legacy three-field
    ``"schedule@path@sN"`` entries still decode (boundary defaults to
    ``equal_width``, which is exactly what they meant).  The trailing
    fields are what keep :class:`Plan` and :class:`ShardedPlan` encodings
    mutually un-decodable — a sharded entry can never be misread as a
    single-device plan (or vice versa), on top of the separate
    ``|plan.advance_sharded.b`` cache namespace.
    """

    schedule: Schedule
    path: ExecutionPath = ExecutionPath.PURE
    num_shards: int = 1
    boundary: str = "equal_width"

    def encode(self) -> str:
        return (f"{self.schedule}@{self.path}@s{self.num_shards}"
                f"@b{self.boundary}")

    @classmethod
    def decode(cls, value: str) -> "ShardedPlan":
        fields = value.split("@")
        if len(fields) not in (3, 4) or not fields[2].startswith("s"):
            raise ValueError(f"not a sharded plan encoding: {value!r}")
        boundary = "equal_width"
        if len(fields) == 4:
            if not fields[3].startswith("b"):
                raise ValueError(f"not a sharded plan encoding: {value!r}")
            boundary = fields[3][1:]
        return cls(Schedule(fields[0]), ExecutionPath(fields[1]),
                   int(fields[2][1:]), boundary)


#: Candidate (schedule, path) plans, in tie-break priority order.  Only the
#: chunked queue's cost model distinguishes paths today (the native
#: chunk-walking kernel pops cheaper than the host-realized queue), so it is
#: the one schedule listed twice; native outranks pure on equal cost.
REGISTERED_PLANS: Sequence[Plan] = tuple(
    [Plan(s) for s in REGISTERED_SCHEDULES if s != Schedule.CHUNKED]
    + [Plan(Schedule.CHUNKED, ExecutionPath.NATIVE),
       Plan(Schedule.CHUNKED, ExecutionPath.PURE)])

#: Workload families the planner can score.  ``"reduce"`` is the plain
#: tile-reduce (SpMV/segmm); ``"advance"`` is the frontier-masked pull
#: advance, whose per-atom transform is heavier (mask load + select);
#: ``"advance_push"`` is the push-direction advance (tiles = sources, atoms
#: = out-edges), whose active atoms are heavier still (destination gather +
#: scatter-combine share) and whose balance problem is over *out*-degrees —
#: so the per-block overhead constants amortize differently and the argmin
#: can move per family.  ``"advance_delta"`` / ``"advance_delta_push"`` are
#: the *bucketed* (delta-stepping) siblings: every atom additionally pays
#: the light/heavy bucket-mask select, so the atom term is one step heavier
#: per direction and the argmin can move again.  Each family keeps its own
#: cache namespace (``|plan.advance`` / ``|plan.advance_push`` /
#: ``|plan.advance_delta`` / ``|plan.advance_delta_push``); scoring charges
#: the direction's full-density worst case — the density axis is the
#: *driver's* per-iteration decision, not the planner's (see
#: :func:`repro.core.balance.estimate_direction_threshold`).
WORKLOAD_ATOM_WORK = {"reduce": 1, "advance": ADVANCE_ATOM_WORK,
                      "advance_push": ADVANCE_PUSH_ATOM_WORK,
                      "advance_delta": ADVANCE_DELTA_ATOM_WORK,
                      "advance_delta_push": ADVANCE_DELTA_PUSH_ATOM_WORK,
                      # the sharded family scores each shard's pull view at
                      # the plain advance atom charge and its push view at
                      # the push charge; the shard axis is priced by
                      # modeled_sharded_cost's comm term, not the atom term
                      # (see select_sharded_plan)
                      "advance_sharded": ADVANCE_ATOM_WORK,
                      "advance_sharded_push": ADVANCE_PUSH_ATOM_WORK,
                      # the serving family (repro.serve.graph): the batched
                      # step replays the same per-atom relax once per lane,
                      # so the per-lane atom charge matches the plain
                      # advance and the lane width cancels out of the
                      # schedule ranking — but the family keeps its own
                      # cache namespace so measured-mode medians come from
                      # the *vmapped* serving workload, not the
                      # single-query one
                      "advance_serve": ADVANCE_ATOM_WORK,
                      "advance_serve_push": ADVANCE_PUSH_ATOM_WORK,
                      # the wavefront family (repro.sparse.wavefront): the
                      # level loop's dependency combine is a pull advance
                      # whose frontier is the resolved set, replayed per
                      # feature column — the column count multiplies every
                      # candidate equally, so only the heavier per-atom
                      # charge (mask + select + feature gather) enters the
                      # ranking, under the family's own cache namespace
                      "wavefront": WAVEFRONT_ATOM_WORK,
                      "wavefront_push": WAVEFRONT_PUSH_ATOM_WORK}

_ENV_CACHE_PATH = "REPRO_AUTOTUNE_CACHE"
_ENV_MEASURE = "REPRO_AUTOTUNE_MEASURE"
_ENV_MEASURE_TOPK = "REPRO_AUTOTUNE_TOPK"

#: How many model-ranked candidates measured mode times (override per call
#: with ``select_plan(measure_k=)`` or globally with REPRO_AUTOTUNE_TOPK).
#: Three covers the model's realistic confusion set — the argmin plus the
#: schedules whose modeled costs sit within noise of it — while keeping
#: the one-off measurement bill at three compiles, not eight.
DEFAULT_MEASURE_TOPK = 3


def measurement_enabled() -> bool:
    """True when ``REPRO_AUTOTUNE_MEASURE`` opts this process into timing
    candidates (the knob the "auto" call sites consult before building
    their measure closures)."""
    return os.environ.get(_ENV_MEASURE, "").strip().lower() in (
        "1", "true", "on", "yes")


def _measure_topk(override: Optional[int]) -> int:
    if override is not None:
        return max(int(override), 1)
    env = os.environ.get(_ENV_MEASURE_TOPK, "").strip()
    if env:
        try:
            return max(int(env), 1)
        except ValueError:
            pass
    return DEFAULT_MEASURE_TOPK


def _default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_CACHE_PATH)
    if env:
        return pathlib.Path(env)
    return pathlib.Path(os.path.expanduser("~")) / ".cache" / "repro" / \
        "autotune.json"


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def shape_key(spec: WorkSpec, num_blocks: int,
              stats: Optional[ImbalanceStats] = None) -> str:
    """Quantised workload fingerprint for the persistent cache.

    Buckets sizes by log2 and skew statistics to one decimal: the cost
    landscape moves on these scales, not on exact nnz.
    """
    if stats is None:
        stats = ImbalanceStats.measure(spec)
    lg = lambda n: int(math.log2(n)) if n > 0 else -1
    return (f"b{num_blocks}|t{lg(spec.num_tiles)}|a{lg(spec.num_atoms)}"
            f"|cv{stats.cv_atoms_per_tile:.1f}|g{stats.gini:.1f}"
            f"|e{stats.empty_tile_fraction:.1f}")


@dataclasses.dataclass(frozen=True)
class CacheRecord:
    """One decoded cache entry: the chosen plan plus any measurements.

    ``measured_us`` maps encoded plans to their measured median wall time
    (us); ``features`` maps encoded plans to their ``(base, {coef: count})``
    model-cost decomposition at measure time
    (:func:`repro.core.balance.cost_features`) — the re-fit's raw material.
    Legacy v1 string entries decode to a record with empty measurements.

    ``_PLAN_CODEC`` is the plan encoding this record validates against —
    :class:`ShardedCacheRecord` swaps in :class:`ShardedPlan` and inherits
    everything else, so both record families share one storage format and
    one merge discipline while staying mutually un-decodable.
    """

    plan: Optional[Plan] = None
    measured_us: Dict[str, float] = dataclasses.field(default_factory=dict)
    features: Dict[str, Tuple[float, Dict[str, float]]] = \
        dataclasses.field(default_factory=dict)

    _PLAN_CODEC: ClassVar[type] = Plan

    @property
    def is_measured(self) -> bool:
        return bool(self.measured_us)

    def encode(self):
        """JSON value: bare v1 string when unmeasured, v2 dict otherwise."""
        plan = self.plan.encode() if self.plan else None
        if not self.measured_us and not self.features:
            return plan
        out = {"v": 2, "plan": plan,
               "measured_us": {k: round(float(v), 3)
                               for k, v in self.measured_us.items()}}
        if self.features:
            out["features"] = {k: [float(b), {n: float(c)
                                              for n, c in f.items()}]
                               for k, (b, f) in self.features.items()}
        return out

    @classmethod
    def decode(cls, value) -> "CacheRecord":
        """Best-effort decode of a v1 string or v2 dict cache value.

        Corrupt sub-fields are dropped, not raised: a torn ``measured_us``
        degrades the entry to model-only behaviour (the satellite-test
        contract), and an unparseable plan leaves ``plan=None`` so the
        caller re-selects.
        """
        if isinstance(value, str):
            try:
                return cls(plan=cls._PLAN_CODEC.decode(value))
            except ValueError:            # stale schedule name
                return cls()
        if not isinstance(value, dict):
            return cls()
        plan = None
        raw_plan = value.get("plan")
        if isinstance(raw_plan, str):
            try:
                plan = cls._PLAN_CODEC.decode(raw_plan)
            except ValueError:
                plan = None
        measured: Dict[str, float] = {}
        raw_m = value.get("measured_us")
        if isinstance(raw_m, dict):
            for k, v in raw_m.items():
                try:
                    cls._PLAN_CODEC.decode(str(k))
                    us = float(v)
                except (ValueError, TypeError):
                    continue              # torn entry: skip, keep the rest
                if math.isfinite(us) and us > 0:
                    measured[str(k)] = us
        feats: Dict[str, Tuple[float, Dict[str, float]]] = {}
        raw_f = value.get("features")
        if isinstance(raw_f, dict):
            for k, v in raw_f.items():
                try:
                    base = float(v[0])
                    fd = {str(n): float(c) for n, c in v[1].items()}
                except (ValueError, TypeError, IndexError, KeyError,
                        AttributeError):
                    continue
                feats[str(k)] = (base, fd)
        return cls(plan=plan, measured_us=measured, features=feats)


@dataclasses.dataclass(frozen=True)
class ShardedCacheRecord(CacheRecord):
    """Cache entry for the ``advance_sharded`` family.

    Same storage format and merge behaviour as :class:`CacheRecord`; only
    the plan codec differs (``"schedule@path@sN"``), so entries from the
    two families can never be misread as one another even if their keys
    collided — :meth:`Plan.decode` rejects the ``@sN`` suffix and
    :meth:`ShardedPlan.decode` requires it.
    """

    plan: Optional[ShardedPlan] = None

    _PLAN_CODEC: ClassVar[type] = ShardedPlan


class AutotuneCache:
    """Two-level (memory + JSON file) schedule-choice cache.

    Both levels use the quantised :func:`shape_key` fingerprint — workloads
    in the same bucket share one choice.  The file path is resolved lazily
    so ``REPRO_AUTOTUNE_CACHE`` set after import is still honoured.

    Concurrency discipline: writes go through a fresh read-merge of the
    on-disk state followed by tempfile + ``os.replace`` (atomic on POSIX),
    so two processes autotuning concurrently never truncate or corrupt the
    file, and disjoint keys survive on a best-effort basis (a writer that
    read before another's replace landed can still publish a merge missing
    that key — losing a cache entry only costs a retune; same-key races
    are last-writer-wins, both writers computed a valid choice).  A corrupt
    or partially-written file is treated as empty rather than raised.
    """

    def __init__(self, path: Optional[pathlib.Path] = None):
        self._explicit_path = pathlib.Path(path) if path else None
        # raw JSON values: v1 "schedule@path" strings or v2 record dicts
        self._mem: Dict[str, object] = {}
        self._loaded = False
        self._lock = threading.Lock()

    @property
    def path(self) -> pathlib.Path:
        return self._explicit_path or _default_cache_path()

    def _read_disk(self) -> Dict[str, object]:
        """Best-effort parse of the on-disk table; corrupt/missing -> {}."""
        try:
            on_disk = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return {}
        if not isinstance(on_disk, dict):
            return {}
        # keep v1 strings and v2 dicts verbatim; anything else is torn
        return {str(k): v for k, v in on_disk.items()
                if isinstance(v, (str, dict))}

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        # memory wins on conflict (fresher within this process)
        self._mem = {**self._read_disk(), **self._mem}

    def get(self, key: str) -> Optional[Schedule]:
        plan = self.get_plan(key)
        return plan.schedule if plan else None

    def get_plan(self, key: str) -> Optional[Plan]:
        record = self.get_record(key)
        return record.plan if record else None

    def get_record(self, key: str) -> Optional[CacheRecord]:
        """Decoded record (v1 or v2) for ``key``; ``None`` when absent."""
        with self._lock:
            self._load()
            value = self._mem.get(key)
        return CacheRecord.decode(value) if value is not None else None

    def get_sharded_record(self, key: str) -> Optional[ShardedCacheRecord]:
        """Like :meth:`get_record`, validated against sharded encodings."""
        with self._lock:
            self._load()
            value = self._mem.get(key)
        return ShardedCacheRecord.decode(value) if value is not None else None

    def records(self) -> Dict[str, CacheRecord]:
        """Every decoded entry (memory + disk) — the fit tool's view."""
        with self._lock:
            self._load()
            snapshot = dict(self._mem)
        return {k: CacheRecord.decode(v) for k, v in snapshot.items()}

    def put(self, key: str, schedule: Schedule) -> None:
        self.put_plan(key, Plan(schedule))

    def put_plan(self, key: str, plan: Plan) -> None:
        self.put_record(key, CacheRecord(plan=plan))

    def put_record(self, key: str, record: CacheRecord) -> None:
        """Store a record (v1 string when unmeasured, v2 dict otherwise).

        Same-key merge: measured entries already present on disk or in
        memory for this key survive a write that carries fewer (a
        model-only re-selection must never erase paid-for measurements);
        on per-plan conflicts the incoming measurement wins (fresher).
        The record's own class (plain or :class:`ShardedCacheRecord`)
        drives the prior's decode, so each family merges against itself.
        """
        record_cls = type(record)
        with self._lock:
            self._load()
            prior = record_cls.decode(self._mem.get(key)) \
                if key in self._mem else None
            if prior is not None and (prior.is_measured or prior.features):
                record = record_cls(
                    plan=record.plan or prior.plan,
                    measured_us={**prior.measured_us, **record.measured_us},
                    features={**prior.features, **record.features})
            self._mem[key] = record.encode()
            snapshot = dict(self._mem)
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            # merge with the *current* disk state so a concurrent writer's
            # fresh keys survive this replace (read-modify-write without
            # this re-read silently drops them)
            merged = {**self._read_disk(), **snapshot}
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(merged, f, indent=0, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)  # do not leak tempfiles on failure
                except OSError:
                    pass
                raise
        except OSError:
            pass                    # read-only FS: stay memory-only

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._loaded = True
            try:
                self.path.unlink()
            except OSError:
                pass


_DEFAULT_CACHE = AutotuneCache()


def score_schedules(spec: WorkSpec, num_blocks: int,
                    schedules: Sequence[Schedule] = REGISTERED_SCHEDULES
                    ) -> Dict[Schedule, float]:
    """Modeled lockstep cost of each candidate schedule for this workload."""
    return {s: modeled_cost(spec, s, num_blocks) for s in schedules}


def _check_workload(workload: str) -> None:
    if workload not in WORKLOAD_ATOM_WORK:
        raise ValueError(f"unknown workload family: {workload!r} "
                         f"(expected one of {sorted(WORKLOAD_ATOM_WORK)})")


def score_plans(spec: WorkSpec, num_blocks: int,
                plans: Sequence[Plan] = REGISTERED_PLANS,
                workload: str = "reduce") -> Dict[Plan, float]:
    """Modeled lockstep cost of each (schedule, execution path) plan."""
    _check_workload(workload)
    atom_work = WORKLOAD_ATOM_WORK[workload]
    return {p: modeled_cost(spec, p.schedule, num_blocks, path=str(p.path),
                            atom_work=atom_work)
            for p in plans}


def blend_scores(scores: Dict[Plan, float],
                 measured: Dict[Plan, float]) -> Dict[Plan, float]:
    """Measurement-as-posterior over model-as-prior, in wall-clock units.

    A measured plan scores its measured median outright (the posterior
    collapses onto the observation — repeated medians of the same plan are
    the ground truth selection exists to honour).  An *unmeasured* plan
    scores its modeled cost scaled by the geometric-mean measured/modeled
    ratio of the measured set — the model keeps its job of *interpolating*
    to candidates nobody paid to time, but in units calibrated by the
    measurements, so a model that is systematically off by a constant
    factor (the common hardware-mismatch mode) stops distorting the
    comparison.  With no measurements this is the identity (pure prior).
    """
    if not measured:
        return dict(scores)
    alpha = geomean([us / max(scores[p], 1e-9)
                     for p, us in measured.items() if p in scores])
    return {p: measured[p] if p in measured else alpha * c
            for p, c in scores.items()}


def _plan_features(spec: WorkSpec, num_blocks: int, plan: Plan,
                   workload: str):
    try:
        return cost_features(spec, plan.schedule, num_blocks,
                             path=str(plan.path), workload=workload)
    except ValueError:               # family without a feature story
        return None


def select_plan(spec: WorkSpec, num_blocks: int, *,
                cache: Optional[AutotuneCache] = _DEFAULT_CACHE,
                plans: Sequence[Plan] = REGISTERED_PLANS,
                workload: str = "reduce",
                measure: Optional[Callable[[Plan], float]] = None,
                measure_k: Optional[int] = None) -> Plan:
    """Pick the cheapest (schedule, execution path) plan.

    This is the path-aware selector: the chunked schedule is scored on both
    the native chunk-walking kernel and the host-realized fallback, so
    ``"auto"`` can choose the native path outright.  Cached under a
    namespaced key (``<shape_key>|plan``, plus ``.advance`` for the graph
    advance family) so schedule-only entries written by
    :func:`select_schedule` are never misread as plans (and vice versa),
    and advance choices never shadow reduce choices for the same shape.
    ``cache=None`` selects by exact argmin every call.

    **Measured mode:** when ``measure`` (a callable timing one candidate
    ``Plan`` on the caller's actual workload, returning median us — build
    it with :func:`repro.core.measure.time_fn`) is given *and*
    ``REPRO_AUTOTUNE_MEASURE`` is on, the ``measure_k`` (default
    :data:`DEFAULT_MEASURE_TOPK`) model-ranked cheapest candidates are
    timed once, the medians persisted into the cache's v2 record, and the
    choice is the argmin of :func:`blend_scores` (measurement as
    posterior, model as prior).  A cache that already holds measurements
    for the needed candidates re-ranks **without re-measuring** — that is
    the hook :func:`repro.core.measure.measurement_count` guards.  Without
    a cache, measured mode still measures and blends, it just cannot
    amortize.  Records carrying measurements also store each measured
    plan's model-feature decomposition, the raw material of
    :func:`repro.core.balance.fit_coefficients`.
    """
    _check_workload(workload)
    if not _is_concrete(spec.tile_offsets):
        raise ValueError(
            "select_plan needs a concrete WorkSpec (autotuning is a "
            "pre-launch inspector); pass an explicit schedule under jit")
    measuring = measure is not None and measurement_enabled()
    key = None
    record = None
    if cache is not None:
        key = shape_key(spec, num_blocks) + "|plan"
        if workload != "reduce":
            key += f".{workload}"
        record = cache.get_record(key)
    measured: Dict[Plan, float] = {}
    if record is not None:
        for enc, us in record.measured_us.items():
            try:
                p = Plan.decode(enc)
            except ValueError:
                continue
            if p in plans:
                measured[p] = us
    if record is not None and record.plan is not None \
            and record.plan in plans and not measuring:
        # model-only fast path (also serves measured-mode records: the
        # stored plan already encodes the blended decision)
        return record.plan
    scores = score_plans(spec, num_blocks, plans, workload)
    new_measurements: Dict[Plan, float] = {}
    if measuring:
        k = min(_measure_topk(measure_k), len(plans))
        # stable model ranking: plan order breaks ties, like the argmin
        ranked = sorted(plans, key=lambda p: (scores[p],
                                              list(plans).index(p)))
        for p in ranked[:k]:
            if p not in measured:
                us = float(measure(p))
                if math.isfinite(us) and us > 0:
                    measured[p] = us
                    new_measurements[p] = us
        if record is not None and record.plan is not None \
                and record.plan in plans and not new_measurements:
            # every needed candidate was already measured: the stored
            # choice is the blended one — reuse it, zero re-measurement
            return record.plan
    blended = blend_scores(scores, measured)
    best = min(plans, key=lambda p: (blended[p], list(plans).index(p)))
    if cache is not None:
        feats = {}
        for p, us in new_measurements.items():
            f = _plan_features(spec, num_blocks, p, workload)
            if f is not None:
                base, fd = f
                feats[p.encode()] = (base, fd)
        cache.put_record(key, CacheRecord(
            plan=best,
            measured_us={p.encode(): us
                         for p, us in new_measurements.items()},
            features=feats))
    return best


def select_sharded_plan(global_spec: WorkSpec, shard_specs_by_count,
                        num_blocks: int, *,
                        push_spec: Optional[WorkSpec] = None,
                        cache: Optional[AutotuneCache] = _DEFAULT_CACHE,
                        plans: Sequence[Plan] = REGISTERED_PLANS,
                        halo_elems: Optional[int] = None,
                        elem_bytes: int = 4,
                        measure: Optional[Callable[[ShardedPlan],
                                                   float]] = None,
                        measure_k: Optional[int] = None) -> ShardedPlan:
    """Pick the cheapest (shard count, boundary, schedule, path) tuple.

    ``shard_specs_by_count`` maps each candidate shard count to its
    boundary candidates.  Two forms per count:

    * ``{boundary_name: boundaries}`` — each value the ``[S+1]``
      contiguous vertex split a shard boundary schedule produced
      (``repro.sparse.shard.shard_boundaries``); scoring slices the
      *global* work views by those boundaries
      (:func:`repro.core.balance.shard_specs_from_boundaries`), so the
      model sees each schedule's real max-over-shards balance.
    * a plain sequence of per-shard pull :class:`WorkSpec` views (the
      pre-PR-10 form, kept decodable for callers that pre-padded their
      own views) — one ``equal_width`` candidate scored on those specs.

    The candidate set is the cross product with ``plans``.  Scoring is
    :func:`repro.core.balance.modeled_sharded_cost`: max-over-shards
    compute (shards run concurrently, like blocks one level down) plus the
    per-iteration communication term — ``SHARD_SYNC_OVERHEAD`` and
    ``HALO_BYTE_COST`` over the ``halo_elems`` halo carry (default: one
    element per global tile, the frontier/state vector ``all_gather``
    moves).  When ``push_spec`` (the forward CSR's global work view) is
    given, every boundary-form candidate additionally pays its push view's
    sharded cost at the push atom charge — direction-optimized traversals
    execute both views, so the plan is ranked on both (the comm term is
    charged per direction: each executed iteration is one direction's
    advance plus its collective).  On small graphs the comm term rightly
    collapses the choice to 1 shard — the model trading halo traffic
    against balance is the point.

    Cached under ``<global shape_key>|plan.advance_sharded.b`` with
    :class:`ShardedCacheRecord` (its own namespace *and* its own plan
    codec; pre-boundary ``...|plan.advance_sharded`` entries are simply
    ignored, and their three-field plan strings still decode).  Measured
    mode mirrors :func:`select_plan`: the top-k model-ranked candidates
    are timed once via ``measure`` (callable ``ShardedPlan -> median
    us``, gated by ``REPRO_AUTOTUNE_MEASURE``), medians persist into the
    record, and ranking is measurement-as-posterior via
    :func:`blend_scores` with zero re-measurement on reload.
    """
    if not _is_concrete(global_spec.tile_offsets):
        raise ValueError(
            "select_sharded_plan needs a concrete WorkSpec (autotuning is "
            "a pre-launch inspector); pass an explicit plan under jit")
    counts = sorted(int(s) for s in shard_specs_by_count)
    if not counts:
        raise ValueError("shard_specs_by_count must name at least one "
                         "candidate shard count")
    # (count, boundary) -> boundaries array, or None for the legacy
    # pre-sliced-specs form (scored on the given padded views, pull only)
    bounds_by_cand: Dict[Tuple[int, str], object] = {}
    for c in counts:
        entry = shard_specs_by_count[c]
        if isinstance(entry, dict):
            if not entry:
                raise ValueError(f"count {c}: no boundary candidates")
            for bname, bounds in entry.items():
                bounds_by_cand[(c, str(bname))] = bounds
        else:
            bounds_by_cand[(c, "equal_width")] = None
    candidates: Tuple[ShardedPlan, ...] = tuple(
        ShardedPlan(p.schedule, p.path, c, bname)
        for (c, bname) in bounds_by_cand for p in plans)
    if halo_elems is None:
        halo_elems = global_spec.num_tiles
    atom_work = WORKLOAD_ATOM_WORK["advance_sharded"]
    push_atom_work = WORKLOAD_ATOM_WORK["advance_sharded_push"]
    measuring = measure is not None and measurement_enabled()
    key = None
    record = None
    if cache is not None:
        key = shape_key(global_spec, num_blocks) + "|plan.advance_sharded.b"
        record = cache.get_sharded_record(key)
    measured: Dict[ShardedPlan, float] = {}
    if record is not None:
        for enc, us in record.measured_us.items():
            try:
                sp = ShardedPlan.decode(enc)
            except ValueError:
                continue
            if sp in candidates:
                measured[sp] = us
    if record is not None and record.plan is not None \
            and record.plan in candidates and not measuring:
        return record.plan

    def _score(sp: ShardedPlan) -> float:
        bounds = bounds_by_cand[(sp.num_shards, sp.boundary)]
        if bounds is None:
            return modeled_sharded_cost(
                shard_specs_by_count[sp.num_shards], sp.schedule,
                num_blocks, path=str(sp.path), atom_work=atom_work,
                halo_elems=halo_elems, elem_bytes=elem_bytes)
        cost = modeled_sharded_cost(
            global_spec, sp.schedule, num_blocks, path=str(sp.path),
            atom_work=atom_work, halo_elems=halo_elems,
            elem_bytes=elem_bytes, boundaries=bounds)
        if push_spec is not None:
            cost += modeled_sharded_cost(
                push_spec, sp.schedule, num_blocks, path=str(sp.path),
                atom_work=push_atom_work, halo_elems=halo_elems,
                elem_bytes=elem_bytes, boundaries=bounds)
        return cost

    scores = {sp: _score(sp) for sp in candidates}
    new_measurements: Dict[ShardedPlan, float] = {}
    if measuring:
        k = min(_measure_topk(measure_k), len(candidates))
        ranked = sorted(candidates,
                        key=lambda p: (scores[p], candidates.index(p)))
        for p in ranked[:k]:
            if p not in measured:
                us = float(measure(p))
                if math.isfinite(us) and us > 0:
                    measured[p] = us
                    new_measurements[p] = us
        if record is not None and record.plan is not None \
                and record.plan in candidates and not new_measurements:
            return record.plan
    blended = blend_scores(scores, measured)
    best = min(candidates, key=lambda p: (blended[p], candidates.index(p)))
    if cache is not None:
        cache.put_record(key, ShardedCacheRecord(
            plan=best,
            measured_us={p.encode(): us
                         for p, us in new_measurements.items()}))
    return best


def collect_fit_samples(cache: AutotuneCache,
                        ) -> List[Tuple[float, Dict[str, float], float]]:
    """Extract ``(base, feats, measured_us)`` fit samples from a cache.

    Walks every record (all workload namespaces) and yields one sample per
    plan that has *both* a measured median and a feature decomposition —
    exactly the triples :func:`repro.core.balance.fit_coefficients`
    consumes.  Records written by model-only runs contribute nothing.
    """
    samples: List[Tuple[float, Dict[str, float], float]] = []
    for record in cache.records().values():
        for enc, us in record.measured_us.items():
            if enc in record.features:
                base, feats = record.features[enc]
                samples.append((base, dict(feats), float(us)))
    return samples


def select_schedule(spec: WorkSpec, num_blocks: int, *,
                    cache: Optional[AutotuneCache] = _DEFAULT_CACHE,
                    schedules: Sequence[Schedule] = REGISTERED_SCHEDULES
                    ) -> Schedule:
    """Pick the cheapest schedule by modeled cost (cached per shape).

    Requires a concrete (non-traced) WorkSpec: selection is an inspector
    step that runs before launch.  Under tracing, callers should fall back
    to a fixed schedule (see e.g. ``repro.models.moe``).
    """
    if not _is_concrete(spec.tile_offsets):
        raise ValueError(
            "select_schedule needs a concrete WorkSpec (autotuning is a "
            "pre-launch inspector); pass an explicit schedule under jit")
    key = None
    if cache is not None:
        key = shape_key(spec, num_blocks)
        hit = cache.get(key)
        if hit is not None and hit in schedules:
            return hit
    scores = score_schedules(spec, num_blocks, schedules)
    best = min(schedules, key=lambda s: (scores[s],
                                         list(schedules).index(s)))
    if cache is not None:
        cache.put(key, best)
    return best
