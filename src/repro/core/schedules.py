"""Load-balancing schedules (paper §3.2, §4.2, §5.2).

A *schedule* partitions the atoms/tiles of a :class:`~repro.core.work.WorkSpec`
across ``num_blocks`` processors.  On the GPU the paper's processors are
threads/warps/blocks/cooperative-groups; on TPU they are Pallas grid blocks
(and, one level up, chips of the device mesh — the same partitioners drive
cross-chip balancing of MoE dispatch and document packing).

All partitioners are pure, vectorized JAX: O(G log T) ``searchsorted`` calls
computed *before* the kernel launch.  This replaces the GPU's per-thread
in-kernel binary search — on TPU the partition is static per input, so we lift
the search out of the kernel and feed block coordinates in via scalar prefetch.

Every partitioner returns a :class:`Partition` with the same contract, so work
execution (kernels, executors) is schedule-agnostic — the separation of
concerns at the heart of the paper.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.work import WorkSpec


class Schedule(str, enum.Enum):
    """Named schedules shipped with the library (paper §5.2)."""

    THREAD_MAPPED = "thread_mapped"    # tile-per-lane (paper Listing 2)
    GROUP_MAPPED = "group_mapped"      # tiles-per-group + prefix-sum binning
    WARP_MAPPED = "warp_mapped"        # group_mapped with group = 128 lanes
    BLOCK_MAPPED = "block_mapped"      # group_mapped with group = 8*128 lanes
    NONZERO_SPLIT = "nonzero_split"    # equal atoms per block + fixup
    MERGE_PATH = "merge_path"          # equal (atoms + tiles) per block

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Partition:
    """Assignment of atom/tile subsequences to ``num_blocks`` processors.

    Block ``b`` owns atoms ``[atom_starts[b], atom_starts[b+1])`` and touches
    tiles ``[tile_starts[b], tile_starts[b+1]]`` — the final tile may be
    *shared* with block ``b+1`` (a partial tile), in which case the executor
    must combine cross-block partial results (the merge-path "fixup").
    For tile-aligned schedules (thread/group-mapped) tiles are never shared.
    """

    schedule: Schedule                 # static
    num_blocks: int                    # static
    items_per_block: int               # static: balance granule per block
    atom_starts: jax.Array             # int32 [num_blocks + 1]
    tile_starts: jax.Array             # int32 [num_blocks + 1]
    tile_aligned: bool                 # static: atom_starts on tile boundaries

    def tree_flatten(self):
        return ((self.atom_starts, self.tile_starts),
                (self.schedule, self.num_blocks, self.items_per_block,
                 self.tile_aligned))

    @classmethod
    def tree_unflatten(cls, aux, children):
        atom_starts, tile_starts = children
        schedule, num_blocks, items_per_block, tile_aligned = aux
        return cls(schedule=schedule, num_blocks=num_blocks,
                   items_per_block=items_per_block, atom_starts=atom_starts,
                   tile_starts=tile_starts, tile_aligned=tile_aligned)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Tile-aligned schedules: thread-, warp-, block- and group-mapped.
# ---------------------------------------------------------------------------

def tile_mapped_partition(spec: WorkSpec, num_blocks: int,
                          schedule: Schedule = Schedule.THREAD_MAPPED
                          ) -> Partition:
    """Assign an equal, contiguous span of *tiles* to each block.

    This is the common partition underlying the paper's thread-, warp-,
    block- and group-mapped schedules: equal tile counts, arbitrary atom
    counts (so imbalanced when tile sizes vary).  On the GPU the paper
    strides tiles by grid size; on TPU contiguous spans are preferred so a
    block's atoms form one dense VMEM window.
    """
    tiles_per_block = _ceil_div(spec.num_tiles, num_blocks)
    tile_starts = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * tiles_per_block,
        spec.num_tiles)
    atom_starts = spec.tile_offsets[tile_starts]
    return Partition(schedule=schedule, num_blocks=num_blocks,
                     items_per_block=tiles_per_block,
                     atom_starts=atom_starts.astype(jnp.int32),
                     tile_starts=tile_starts, tile_aligned=True)


def group_mapped_partition(spec: WorkSpec, num_blocks: int,
                           group_tiles: Optional[int] = None) -> Partition:
    """Paper §5.2.3 — the novel Cooperative-Groups generalization.

    A "group" owns ``group_tiles`` tiles; within the group, a prefix sum of
    atoms-per-tile (in VMEM scratch on TPU, shared memory on GPU) maps lanes
    to atoms and ``get_tile(atom)`` is a binary search into that prefix sum.
    The partition itself is tile-aligned; the *execution strategy* (atom-
    parallel within the group) is what distinguishes it — see
    :mod:`repro.core.execute` and the Pallas kernels.
    """
    if group_tiles is not None:
        num_blocks = _ceil_div(spec.num_tiles, group_tiles)
    return tile_mapped_partition(spec, num_blocks, Schedule.GROUP_MAPPED)


# ---------------------------------------------------------------------------
# Atom-aligned schedule: nonzero splitting.
# ---------------------------------------------------------------------------

def nonzero_split_partition(spec: WorkSpec, num_blocks: int) -> Partition:
    """Equal *atoms* per block (Baxter's / Dalton's nonzero split).

    Perfectly balanced in atoms but ignores per-tile bookkeeping cost; blocks
    may start/end mid-tile, requiring a fixup pass.  Tile coordinates are
    recovered with one vectorized searchsorted over the block boundaries.
    """
    atoms_per_block = _ceil_div(max(spec.num_atoms, 1), num_blocks)
    atom_starts = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * atoms_per_block,
        spec.num_atoms)
    # tile_starts[b] = tile owning the first atom of block b.
    tile_starts = (jnp.searchsorted(spec.tile_offsets, atom_starts,
                                    side="right").astype(jnp.int32) - 1)
    tile_starts = jnp.clip(tile_starts, 0, spec.num_tiles)
    return Partition(schedule=Schedule.NONZERO_SPLIT, num_blocks=num_blocks,
                     items_per_block=atoms_per_block,
                     atom_starts=atom_starts, tile_starts=tile_starts,
                     tile_aligned=False)


# ---------------------------------------------------------------------------
# Merge-path (paper §5.2.1; Merrill & Garland / Green et al.).
# ---------------------------------------------------------------------------

def merge_path_partition(spec: WorkSpec, num_blocks: int) -> Partition:
    """Split ``num_atoms + num_tiles`` work items exactly evenly.

    Model: a 2-D merge of ``A[t] = tile_offsets[t+1]`` (tile-end markers,
    consumed *after* the tile's atoms) against ``B = 0..num_atoms-1`` (atom
    indices).  Block ``b`` starts at diagonal ``d_b = b * items_per_block``.
    The split point of diagonal ``d`` is the largest ``t`` such that
    ``tile_offsets[t] + t <= d`` (both row-end count and atom count consumed
    before the path crosses the diagonal); the atom coordinate is then
    ``d - t``.  ``f(t) = tile_offsets[t] + t`` is *strictly* increasing, so a
    single vectorized ``searchsorted`` over all block boundaries replaces the
    per-thread binary search of the CUDA implementation.
    """
    total = spec.total_work()
    items_per_block = _ceil_div(max(total, 1), num_blocks)
    diagonals = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * items_per_block, total)
    path = spec.tile_offsets.astype(jnp.int32) + jnp.arange(
        spec.num_tiles + 1, dtype=jnp.int32)  # f(t), strictly increasing
    tile_starts = (jnp.searchsorted(path, diagonals, side="right")
                   .astype(jnp.int32) - 1)
    tile_starts = jnp.clip(tile_starts, 0, spec.num_tiles)
    atom_starts = diagonals - tile_starts
    return Partition(schedule=Schedule.MERGE_PATH, num_blocks=num_blocks,
                     items_per_block=items_per_block,
                     atom_starts=atom_starts.astype(jnp.int32),
                     tile_starts=tile_starts, tile_aligned=False)


# ---------------------------------------------------------------------------
# Registry / dispatch.
# ---------------------------------------------------------------------------

def make_partition(spec: WorkSpec, schedule: Schedule | str,
                   num_blocks: int) -> Partition:
    schedule = Schedule(schedule)
    if schedule in (Schedule.THREAD_MAPPED,):
        return tile_mapped_partition(spec, num_blocks, schedule)
    if schedule in (Schedule.GROUP_MAPPED, Schedule.WARP_MAPPED,
                    Schedule.BLOCK_MAPPED):
        part = group_mapped_partition(spec, num_blocks)
        return dataclasses.replace(part, schedule=schedule)
    if schedule == Schedule.NONZERO_SPLIT:
        return nonzero_split_partition(spec, num_blocks)
    if schedule == Schedule.MERGE_PATH:
        return merge_path_partition(spec, num_blocks)
    raise ValueError(f"unknown schedule: {schedule}")
