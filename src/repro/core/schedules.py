"""Load-balancing schedules (paper §3.2, §4.2, §5.2).

A *schedule* partitions the atoms/tiles of a :class:`~repro.core.work.WorkSpec`
across ``num_blocks`` processors.  On the GPU the paper's processors are
threads/warps/blocks/cooperative-groups; on TPU they are Pallas grid blocks
(and, one level up, chips of the device mesh — the same partitioners drive
cross-chip balancing of MoE dispatch and document packing).

All partitioners are pure, vectorized JAX: O(G log T) ``searchsorted`` calls
computed *before* the kernel launch.  This replaces the GPU's per-thread
in-kernel binary search — on TPU the partition is static per input, so we lift
the search out of the kernel and feed block coordinates in via scalar prefetch.

Every partitioner returns a :class:`Partition` with the same contract, so work
execution (kernels, executors) is schedule-agnostic — the separation of
concerns at the heart of the paper.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.work import WorkSpec


class Schedule(str, enum.Enum):
    """Named schedules shipped with the library (paper §5.2)."""

    THREAD_MAPPED = "thread_mapped"    # tile-per-lane (paper Listing 2)
    GROUP_MAPPED = "group_mapped"      # tiles-per-group + prefix-sum binning
    WARP_MAPPED = "warp_mapped"        # group_mapped with group = 128 lanes
    BLOCK_MAPPED = "block_mapped"      # group_mapped with group = 8*128 lanes
    NONZERO_SPLIT = "nonzero_split"    # equal atoms per block + fixup
    MERGE_PATH = "merge_path"          # equal (atoms + tiles) per block
    # dynamic schedules (repro.core.dynamic; Atos-style work queues)
    CHUNKED = "chunked"                # oversplit into K*B chunks + queue
    ADAPTIVE = "adaptive"              # inspect-then-balance two-phase
    # sentinel: cost-model-driven selection (repro.core.autotune)
    AUTO = "auto"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


#: Schedules that produce partitions directly (everything except AUTO).
CONCRETE_SCHEDULES = (
    Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED, Schedule.WARP_MAPPED,
    Schedule.BLOCK_MAPPED, Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH,
    Schedule.CHUNKED, Schedule.ADAPTIVE,
)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Partition:
    """Assignment of atom/tile subsequences to ``num_blocks`` processors.

    Block ``b`` owns atoms ``[atom_starts[b], atom_starts[b+1])`` and touches
    tiles ``[tile_starts[b], tile_starts[b+1]]`` — the final tile may be
    *shared* with block ``b+1`` (a partial tile), in which case the executor
    must combine cross-block partial results (the merge-path "fixup").
    For tile-aligned schedules (thread/group-mapped) tiles are never shared.
    """

    schedule: Schedule                 # static
    num_blocks: int                    # static
    items_per_block: int               # static: balance granule per block
    atom_starts: jax.Array             # int32 [num_blocks + 1]
    tile_starts: jax.Array             # int32 [num_blocks + 1]
    tile_aligned: bool                 # static: atom_starts on tile boundaries
    # Dynamic (chunked) schedules oversplit the work into num_blocks entries
    # ("chunks") that a smaller pool of physical processors drains as a
    # queue: ``block_map[c]`` is the physical block assigned chunk ``c`` and
    # ``num_physical_blocks`` the pool size.  None for static schedules,
    # where entries and physical blocks coincide.
    block_map: Optional[jax.Array] = None       # int32 [num_blocks] or None
    num_physical_blocks: Optional[int] = None   # static
    # Static sizing hints captured at (concrete) build time.  Executors need
    # static window shapes; under jit the boundary arrays are tracers, so
    # without these hints they must fall back to worst-case windows — or,
    # worse, guess from items_per_block, which undercounts the tile span of
    # blocks crossing empty tiles.  atom_span = max atoms any block owns;
    # tile_span = max tiles any block touches (inclusive of a shared tile).
    atom_span: Optional[int] = None             # static
    tile_span: Optional[int] = None             # static
    # Inverted, padded CSR-style view of ``block_map``, built once at
    # construction (see :func:`invert_block_map`): ``block_chunks[p, i]`` is
    # the i-th chunk physical block ``p`` pops from its queue (rows padded
    # with 0 past ``block_chunk_counts[p]``).  This is the scalar-prefetch
    # payload of the native chunk-walking Pallas kernels — each block reads
    # its row and loops over its chunks *inside* the kernel.  None when
    # ``block_map`` is None (static schedules: block == chunk) or traced.
    block_chunks: Optional[jax.Array] = None        # int32 [P, max_chunks]
    block_chunk_counts: Optional[jax.Array] = None  # int32 [P]

    def tree_flatten(self):
        return ((self.atom_starts, self.tile_starts, self.block_map,
                 self.block_chunks, self.block_chunk_counts),
                (self.schedule, self.num_blocks, self.items_per_block,
                 self.tile_aligned, self.num_physical_blocks,
                 self.atom_span, self.tile_span))

    @classmethod
    def tree_unflatten(cls, aux, children):
        (atom_starts, tile_starts, block_map,
         block_chunks, block_chunk_counts) = children
        (schedule, num_blocks, items_per_block, tile_aligned,
         num_physical_blocks, atom_span, tile_span) = aux
        return cls(schedule=schedule, num_blocks=num_blocks,
                   items_per_block=items_per_block, atom_starts=atom_starts,
                   tile_starts=tile_starts, tile_aligned=tile_aligned,
                   block_map=block_map,
                   num_physical_blocks=num_physical_blocks,
                   atom_span=atom_span, tile_span=tile_span,
                   block_chunks=block_chunks,
                   block_chunk_counts=block_chunk_counts)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def invert_block_map(block_map: jax.Array, num_physical_blocks: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Invert a chunk -> block map into per-block chunk lists (padded CSR).

    Returns ``(block_chunks, block_chunk_counts)``: ``block_chunks[p, :]``
    lists the chunks assigned to physical block ``p`` in chunk order (the
    pop order of its queue), padded with ``0`` up to the max queue length;
    ``block_chunk_counts[p]`` is the true length.  This is the static-shape
    payload the Pallas chunk-walking kernels scalar-prefetch: TPU grids
    cannot pop a shared queue at runtime, so the queue discipline is
    materialized per block before launch.

    Requires a concrete (non-traced) ``block_map`` — inversion is an
    inspector step.
    """
    if isinstance(block_map, jax.core.Tracer):
        raise ValueError("invert_block_map needs a concrete block_map "
                         "(schedule inversion is a pre-launch inspector)")
    bm = np.asarray(block_map, np.int64)
    num_physical_blocks = max(int(num_physical_blocks), 1)
    counts = np.bincount(bm, minlength=num_physical_blocks)
    max_chunks = max(int(counts.max()) if counts.size else 0, 1)
    chunks = np.zeros((num_physical_blocks, max_chunks), np.int32)
    # stable sort groups chunks by block while preserving chunk order
    # within each block — i.e. the queue's pop order
    order = np.argsort(bm, kind="stable")
    slot = np.arange(bm.size) - np.concatenate(
        [[0], np.cumsum(counts)])[bm[order]]
    chunks[bm[order], slot] = order
    return (jnp.asarray(chunks),
            jnp.asarray(counts.astype(np.int32)))


def finalize_partition(part: Partition) -> Partition:
    """Record static atom/tile span hints while boundaries are concrete.

    Partitions are built by a pre-launch inspector, so boundaries are
    normally concrete here even when the *consumer* later runs under jit
    (where they become closure tracers and can no longer be concretised).
    Also builds the inverted ``block_chunks`` view of ``block_map`` (once,
    here) so the native chunk-walking kernels can scalar-prefetch it.
    No-op for traced boundaries.
    """
    if (part.atom_span is not None or part.num_blocks < 1
            or isinstance(part.atom_starts, jax.core.Tracer)):
        return part
    atom_span = int(jnp.max(part.atom_starts[1:] - part.atom_starts[:-1]))
    tile_span = int(jnp.max(part.tile_starts[1:] - part.tile_starts[:-1])) + 1
    block_chunks, block_chunk_counts = part.block_chunks, part.block_chunk_counts
    if (part.block_map is not None and block_chunks is None
            and not isinstance(part.block_map, jax.core.Tracer)):
        block_chunks, block_chunk_counts = invert_block_map(
            part.block_map, part.num_physical_blocks or part.num_blocks)
    return dataclasses.replace(part, atom_span=max(atom_span, 1),
                               tile_span=max(tile_span, 1),
                               block_chunks=block_chunks,
                               block_chunk_counts=block_chunk_counts)


# ---------------------------------------------------------------------------
# Tile-aligned schedules: thread-, warp-, block- and group-mapped.
# ---------------------------------------------------------------------------

def tile_mapped_partition(spec: WorkSpec, num_blocks: int,
                          schedule: Schedule = Schedule.THREAD_MAPPED
                          ) -> Partition:
    """Assign an equal, contiguous span of *tiles* to each block.

    This is the common partition underlying the paper's thread-, warp-,
    block- and group-mapped schedules: equal tile counts, arbitrary atom
    counts (so imbalanced when tile sizes vary).  On the GPU the paper
    strides tiles by grid size; on TPU contiguous spans are preferred so a
    block's atoms form one dense VMEM window.
    """
    tiles_per_block = _ceil_div(spec.num_tiles, num_blocks)
    tile_starts = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * tiles_per_block,
        spec.num_tiles)
    atom_starts = spec.tile_offsets[tile_starts]
    return finalize_partition(Partition(
        schedule=schedule, num_blocks=num_blocks,
        items_per_block=tiles_per_block,
        atom_starts=atom_starts.astype(jnp.int32),
        tile_starts=tile_starts, tile_aligned=True))


def group_mapped_partition(spec: WorkSpec, num_blocks: int,
                           group_tiles: Optional[int] = None) -> Partition:
    """Paper §5.2.3 — the novel Cooperative-Groups generalization.

    A "group" owns ``group_tiles`` tiles; within the group, a prefix sum of
    atoms-per-tile (in VMEM scratch on TPU, shared memory on GPU) maps lanes
    to atoms and ``get_tile(atom)`` is a binary search into that prefix sum.
    The partition itself is tile-aligned; the *execution strategy* (atom-
    parallel within the group) is what distinguishes it — see
    :mod:`repro.core.execute` and the Pallas kernels.
    """
    if group_tiles is not None:
        num_blocks = _ceil_div(spec.num_tiles, group_tiles)
    return tile_mapped_partition(spec, num_blocks, Schedule.GROUP_MAPPED)


# ---------------------------------------------------------------------------
# Atom-aligned schedule: nonzero splitting.
# ---------------------------------------------------------------------------

def nonzero_split_partition(spec: WorkSpec, num_blocks: int) -> Partition:
    """Equal *atoms* per block (Baxter's / Dalton's nonzero split).

    Perfectly balanced in atoms but ignores per-tile bookkeeping cost; blocks
    may start/end mid-tile, requiring a fixup pass.  Tile coordinates are
    recovered with one vectorized searchsorted over the block boundaries.
    """
    atoms_per_block = _ceil_div(max(spec.num_atoms, 1), num_blocks)
    atom_starts = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * atoms_per_block,
        spec.num_atoms)
    # tile_starts[b] = tile owning the first atom of block b.
    tile_starts = (jnp.searchsorted(spec.tile_offsets, atom_starts,
                                    side="right").astype(jnp.int32) - 1)
    tile_starts = jnp.clip(tile_starts, 0, spec.num_tiles)
    return finalize_partition(Partition(
        schedule=Schedule.NONZERO_SPLIT, num_blocks=num_blocks,
        items_per_block=atoms_per_block,
        atom_starts=atom_starts, tile_starts=tile_starts,
        tile_aligned=False))


# ---------------------------------------------------------------------------
# Merge-path (paper §5.2.1; Merrill & Garland / Green et al.).
# ---------------------------------------------------------------------------

def merge_path_partition(spec: WorkSpec, num_blocks: int) -> Partition:
    """Split ``num_atoms + num_tiles`` work items exactly evenly.

    Model: a 2-D merge of ``A[t] = tile_offsets[t+1]`` (tile-end markers,
    consumed *after* the tile's atoms) against ``B = 0..num_atoms-1`` (atom
    indices).  Block ``b`` starts at diagonal ``d_b = b * items_per_block``.
    The split point of diagonal ``d`` is the largest ``t`` such that
    ``tile_offsets[t] + t <= d`` (both row-end count and atom count consumed
    before the path crosses the diagonal); the atom coordinate is then
    ``d - t``.  ``f(t) = tile_offsets[t] + t`` is *strictly* increasing, so a
    single vectorized ``searchsorted`` over all block boundaries replaces the
    per-thread binary search of the CUDA implementation.
    """
    total = spec.total_work()
    items_per_block = _ceil_div(max(total, 1), num_blocks)
    diagonals = jnp.minimum(
        jnp.arange(num_blocks + 1, dtype=jnp.int32) * items_per_block, total)
    path = spec.tile_offsets.astype(jnp.int32) + jnp.arange(
        spec.num_tiles + 1, dtype=jnp.int32)  # f(t), strictly increasing
    tile_starts = (jnp.searchsorted(path, diagonals, side="right")
                   .astype(jnp.int32) - 1)
    tile_starts = jnp.clip(tile_starts, 0, spec.num_tiles)
    atom_starts = diagonals - tile_starts
    return finalize_partition(Partition(
        schedule=Schedule.MERGE_PATH, num_blocks=num_blocks,
        items_per_block=items_per_block,
        atom_starts=atom_starts.astype(jnp.int32),
        tile_starts=tile_starts, tile_aligned=False))


# ---------------------------------------------------------------------------
# Registry / dispatch.
# ---------------------------------------------------------------------------

# Build counter for regression tests: ops that batch many computations over
# one workload (spmm over B's columns, graph traversals over iterations)
# must build their Partition once, not per column/iteration.  Counting at
# the registry keeps the invariant checkable from the outside.
_PARTITION_BUILD_COUNT = 0


def partition_build_count() -> int:
    """Process-wide count of concrete partition builds via make_partition.

    Monotonic.  Counts every concrete-schedule build, including the ones
    the cost models perform while *scoring*: ``schedule="auto"`` on a cold
    autotune cache therefore adds one count per scored schedule plus one
    for the winning build (a warm cache adds exactly one).  Regression
    tests should pin explicit schedules, where one call == one build.
    """
    return _PARTITION_BUILD_COUNT


def make_partition(spec: WorkSpec, schedule: Schedule | str,
                   num_blocks: int, *, chunk_policy: str = "lpt"
                   ) -> Partition:
    global _PARTITION_BUILD_COUNT
    schedule = Schedule(schedule)
    if schedule != Schedule.AUTO:
        _PARTITION_BUILD_COUNT += 1
    if schedule in (Schedule.THREAD_MAPPED,):
        return tile_mapped_partition(spec, num_blocks, schedule)
    if schedule in (Schedule.GROUP_MAPPED, Schedule.WARP_MAPPED,
                    Schedule.BLOCK_MAPPED):
        part = group_mapped_partition(spec, num_blocks)
        return dataclasses.replace(part, schedule=schedule)
    if schedule == Schedule.NONZERO_SPLIT:
        return nonzero_split_partition(spec, num_blocks)
    if schedule == Schedule.MERGE_PATH:
        return merge_path_partition(spec, num_blocks)
    if schedule == Schedule.CHUNKED:
        from repro.core.dynamic import chunked_partition
        return chunked_partition(spec, num_blocks, policy=chunk_policy)
    if schedule == Schedule.ADAPTIVE:
        from repro.core.dynamic import adaptive_partition
        return adaptive_partition(spec, num_blocks)
    if schedule == Schedule.AUTO:
        from repro.core.autotune import select_schedule
        return make_partition(spec, select_schedule(spec, num_blocks),
                              num_blocks)
    raise ValueError(f"unknown schedule: {schedule}")
