"""Imbalance metrics, per-schedule cost models, and the paper's heuristic.

The container for this reproduction is CPU-only, so wall-clock timings of
Pallas kernels are meaningless for the TPU target.  We therefore model the
*lockstep cost* of each schedule exactly the way the hardware would pay it:
a block of ``lanes`` SIMD lanes pays ``max``, not ``mean``, over its lanes.
These models reproduce the paper's Fig. 3 performance landscape structurally
(which schedule wins for which matrix shape) and drive the §6.2 heuristic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedules import Schedule, make_partition
from repro.core.work import WorkSpec

# TPU v5e-flavoured constants for the cost model.
LANES = 8 * 128          # one VPU tile worth of parallel lanes per block
SEARCH_OVERHEAD = 32     # per-block partition/search setup cost (work items)
PREFIX_OVERHEAD = 8      # group-mapped per-tile prefix-sum cost
CHUNK_OVERHEAD = 2       # chunked queue, host-realized (pure path): the
                         # per-chunk share of the host-side gather/permute
                         # that materializes the queue order + fixup share
NATIVE_CHUNK_OVERHEAD = 1  # chunked queue, chunk-walking kernel (native
                         # path): a pop is one scalar-prefetched SMEM read
                         # + a DMA re-target — no host gather at all
                         # (Atos: a pop is one atomic increment — cheap)
INSPECT_OVERHEAD = 2     # adaptive: per-block share of the inspector pass
FIXUP_OVERHEAD = 4       # adaptive: boundary fixup when tiles were split
ADVANCE_ATOM_WORK = 2    # frontier-masked graph advance: each edge atom pays
                         # a mask load + select on top of the base transform
                         # (~2 lockstep steps per wave instead of 1).  Scaling
                         # only the atom-proportional term — never the
                         # per-block overheads — is what shifts the argmin:
                         # search/queue/inspect constants amortize better
                         # when atoms are heavier.


@dataclasses.dataclass(frozen=True)
class ImbalanceStats:
    max_atoms_per_tile: int
    mean_atoms_per_tile: float
    cv_atoms_per_tile: float          # coefficient of variation
    empty_tile_fraction: float
    gini: float                       # work concentration

    @classmethod
    def measure(cls, spec: WorkSpec) -> "ImbalanceStats":
        sizes = np.asarray(spec.atoms_per_tile())
        if sizes.size == 0:
            return cls(0, 0.0, 0.0, 0.0, 0.0)
        mean = float(sizes.mean())
        cv = float(sizes.std() / mean) if mean > 0 else 0.0
        srt = np.sort(sizes).astype(np.float64)
        n = srt.size
        csum = srt.cumsum()
        gini = float((n + 1 - 2 * (csum / csum[-1]).sum()) / n) if csum[-1] > 0 else 0.0
        return cls(int(sizes.max()), mean, cv,
                   float((sizes == 0).mean()), gini)


def modeled_block_cost(spec: WorkSpec, schedule: Schedule | str,
                       num_blocks: int, *,
                       path: str = "pure",
                       atom_work: int = 1) -> jax.Array:
    """Lockstep cost (work-item steps) each block pays, shape [num_blocks].

    ``path`` (``"pure"`` | ``"native"``, see
    :class:`repro.core.execute.ExecutionPath`) currently only moves the
    chunked queue's per-pop overhead: the native chunk-walking kernel pops
    from a scalar-prefetched list in-kernel, the pure path pays the host
    gather that realizes the queue order.

    ``atom_work`` scales the *atom-proportional* term only (never the
    per-block search/queue/inspect constants): it models workloads whose
    per-atom transform costs more lockstep steps than a plain multiply —
    e.g. the frontier-masked graph advance (:data:`ADVANCE_ATOM_WORK`).
    """
    schedule = Schedule(schedule)
    atom_work = max(int(atom_work), 1)
    if spec.num_tiles == 0:      # empty tile set: nothing to schedule
        return jnp.zeros((num_blocks,), jnp.int32)
    part = make_partition(spec, schedule, num_blocks)
    sizes = spec.atoms_per_tile()
    if schedule == Schedule.THREAD_MAPPED:
        # One tile per lane: a block of LANES lanes processes LANES tiles in
        # lockstep; cost = max tile size among its lanes.  With fewer tiles
        # than lanes the cost is the global max.
        tiles_per_block = part.items_per_block
        starts = part.tile_starts
        # max tile size within each block's contiguous span.
        idx = (starts[:-1, None]
               + jnp.arange(max(tiles_per_block, 1), dtype=jnp.int32)[None, :])
        valid = idx < starts[1:, None]
        span = jnp.where(valid, sizes[jnp.minimum(idx, spec.num_tiles - 1)], 0)
        per_block_max = span.max(axis=1)
        waves = -(-max(tiles_per_block, 1) // LANES)
        return per_block_max * waves * atom_work
    if schedule in (Schedule.GROUP_MAPPED, Schedule.WARP_MAPPED,
                    Schedule.BLOCK_MAPPED):
        # Atoms within the group processed LANES-parallel after a prefix sum.
        atoms_in_block = part.atom_starts[1:] - part.atom_starts[:-1]
        tiles_in_block = part.tile_starts[1:] - part.tile_starts[:-1]
        return (-(-atoms_in_block // LANES) * atom_work
                + PREFIX_OVERHEAD * -(-tiles_in_block // LANES))
    if schedule == Schedule.NONZERO_SPLIT:
        atoms_in_block = part.atom_starts[1:] - part.atom_starts[:-1]
        return -(-atoms_in_block // LANES) * atom_work + SEARCH_OVERHEAD
    if schedule == Schedule.MERGE_PATH:
        ipb = jnp.full((num_blocks,), part.items_per_block, jnp.int32)
        return -(-ipb // LANES) * atom_work + SEARCH_OVERHEAD
    if schedule == Schedule.CHUNKED:
        # The chunk-level partition mirrors merge-path's host-built stream
        # (no in-kernel search), but each physical block drains *several*
        # chunks: its cost is the sum over assigned chunks of the chunk's
        # lockstep steps plus the queue-pop/fixup overhead.  LPT/round-robin
        # assignment is what keeps that sum flat across blocks.
        atoms_per_chunk = part.atom_starts[1:] - part.atom_starts[:-1]
        pop = NATIVE_CHUNK_OVERHEAD if path == "native" else CHUNK_OVERHEAD
        per_chunk = -(-atoms_per_chunk // LANES) * atom_work + pop
        phys = part.num_physical_blocks or num_blocks
        return jax.ops.segment_sum(per_chunk, part.block_map,
                                   num_segments=phys)
    if schedule == Schedule.ADAPTIVE:
        # Balanced like group-mapped (atoms LANES-parallel after the local
        # prefix sum) plus the inspector's share; split tiles pay a fixup.
        atoms_in_block = part.atom_starts[1:] - part.atom_starts[:-1]
        tiles_in_block = part.tile_starts[1:] - part.tile_starts[:-1]
        fixup = 0 if part.tile_aligned else FIXUP_OVERHEAD
        return (-(-atoms_in_block // LANES) * atom_work
                + PREFIX_OVERHEAD * -(-tiles_in_block // LANES)
                + INSPECT_OVERHEAD + fixup)
    raise ValueError(schedule)


def modeled_cost(spec: WorkSpec, schedule: Schedule | str,
                 num_blocks: int, *, path: str = "pure",
                 atom_work: int = 1) -> float:
    """Total modeled time = max over blocks (blocks run concurrently up to
    core count; we report the bottleneck wave cost × number of waves)."""
    costs = modeled_block_cost(spec, schedule, num_blocks, path=path,
                               atom_work=atom_work)
    return float(jnp.max(costs)) * 1.0


def modeled_advance_cost(spec: WorkSpec, schedule: Schedule | str,
                         num_blocks: int, *, path: str = "pure") -> float:
    """Modeled cost of a frontier-masked graph advance over this tile set.

    The advance is the same blocked tile-reduce the cost models already
    describe, with a heavier per-atom transform (mask load + select):
    ``atom_work = ADVANCE_ATOM_WORK``.  Used by
    :func:`repro.core.autotune.select_plan` with ``workload="advance"``.
    """
    return modeled_cost(spec, schedule, num_blocks, path=path,
                        atom_work=ADVANCE_ATOM_WORK)


def choose_schedule(num_tiles: int, num_atoms: int, *, alpha: int = 500,
                    beta: int = 10_000) -> Schedule:
    """The paper's §6.2 heuristic, verbatim: merge-path unless the matrix is
    small (rows or cols < alpha and nnz < beta), in which case the cheaper
    thread-/group-mapped schedules win because merge-path's search overhead
    dominates tiny workloads."""
    if num_tiles < alpha and num_atoms < beta:
        if num_atoms <= num_tiles * 2:       # near-uniform, tiny tiles
            return Schedule.THREAD_MAPPED
        return Schedule.GROUP_MAPPED
    return Schedule.MERGE_PATH


def landscape(spec: WorkSpec, num_blocks: int, *,
              include_dynamic: bool = False) -> Dict[str, float]:
    """Modeled cost of every schedule for one workload (Fig. 3 datapoint)."""
    scheds = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
              Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]
    if include_dynamic:
        scheds += [Schedule.CHUNKED, Schedule.ADAPTIVE]
    return {str(s): modeled_cost(spec, s, num_blocks) for s in scheds}
