"""Imbalance metrics, per-schedule cost models, and the paper's heuristic.

The container for this reproduction is CPU-only, so wall-clock timings of
Pallas kernels are meaningless for the TPU target.  We therefore model the
*lockstep cost* of each schedule exactly the way the hardware would pay it:
a block of ``lanes`` SIMD lanes pays ``max``, not ``mean``, over its lanes.
These models reproduce the paper's Fig. 3 performance landscape structurally
(which schedule wins for which matrix shape) and drive the §6.2 heuristic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedules import Schedule, make_partition
from repro.core.work import WorkSpec

# TPU v5e-flavoured constants for the cost model.
LANES = 8 * 128          # one VPU tile worth of parallel lanes per block
SEARCH_OVERHEAD = 32     # per-block partition/search setup cost (work items)
PREFIX_OVERHEAD = 8      # group-mapped per-tile prefix-sum cost
CHUNK_OVERHEAD = 2       # chunked queue, host-realized (pure path): the
                         # per-chunk share of the host-side gather/permute
                         # that materializes the queue order + fixup share
NATIVE_CHUNK_OVERHEAD = 1  # chunked queue, chunk-walking kernel (native
                         # path): a pop is one scalar-prefetched SMEM read
                         # + a DMA re-target — no host gather at all
                         # (Atos: a pop is one atomic increment — cheap)
INSPECT_OVERHEAD = 2     # adaptive: per-block share of the inspector pass
FIXUP_OVERHEAD = 4       # adaptive: boundary fixup when tiles were split
ADVANCE_ATOM_WORK = 2    # frontier-masked pull advance: each edge atom pays
                         # a mask load + select on top of the base transform
                         # (~2 lockstep steps per wave instead of 1).  Scaling
                         # only the atom-proportional term — never the
                         # per-block overheads — is what shifts the argmin:
                         # search/queue/inspect constants amortize better
                         # when atoms are heavier.
ADVANCE_PUSH_ATOM_WORK = 4  # push-direction advance: each *active* out-edge
                         # pays the value compute plus a destination gather
                         # and a scatter-combine share (the pull direction
                         # streams its combine; push pays the scatter).  Only
                         # frontier out-edges do work — the push view is
                         # frontier-compacted — so the effective atom term
                         # scales with frontier density (see
                         # modeled_advance_cost), which is what makes push
                         # win sparse frontiers and lose dense ones.
ADVANCE_DELTA_ATOM_WORK = 3  # bucketed (delta-stepping) pull advance: each
                         # in-edge atom pays the frontier-mask load + the
                         # light/heavy bucket-mask load + the select — one
                         # lockstep step more than the plain masked advance.
ADVANCE_DELTA_PUSH_ATOM_WORK = ADVANCE_PUSH_ATOM_WORK + 1  # bucketed push:
                         # the scatter charge plus the extra bucket-mask
                         # select per active out-edge.
WAVEFRONT_ATOM_WORK = 3  # wavefront dependency combine: each in-edge atom
                         # pays the resolved-mask load + the select plus the
                         # feature-row gather share (the combine replays once
                         # per feature column under vmap, but the column
                         # count multiplies every candidate equally and
                         # cancels out of the ranking — same argument as the
                         # serving family's lane width).
WAVEFRONT_PUSH_ATOM_WORK = ADVANCE_PUSH_ATOM_WORK + 1  # wavefront push:
                         # the scatter charge plus the per-column feature
                         # gather share per active dependency edge.
COMPACT_GATHER_WORK = 1  # compacted-window push advance: each *active* atom
                         # pays one extra indirection (the gathered edge id
                         # load) on top of the push scatter charge.
COMPACT_BUILD_OVERHEAD = 8  # per-block share of building the compacted
                         # index (the masked cumsum/scatter that realizes
                         # jnp.nonzero(frontier_mask)) plus the capacity
                         # bounds check that guards the masked fallback.
HALO_BYTE_COST = 1 / 512  # work-units per byte of frontier-halo traffic: a
                         # sharded advance all-gathers the frontier/state
                         # carry and all-reduces the push partials every
                         # iteration; interconnect bandwidth is ~2-3 orders
                         # below the lane-parallel compute rate, so one
                         # LANES-wide unit of work buys roughly half a KiB
                         # on the wire.  This is the term that lets the
                         # autotuner trade halo traffic against balance —
                         # small graphs rightly collapse to 1 shard.
SHARD_SYNC_OVERHEAD = 48  # per-collective launch/sync charge of a sharded
                         # iteration (latency, not bandwidth): paid once a
                         # mesh axis is involved, independent of bytes.
                         # Sits between the per-block CHUNK/INSPECT scale
                         # and a kernel launch — collectives serialize the
                         # whole mesh, so the charge is deliberately steep.


@dataclasses.dataclass(frozen=True)
class ImbalanceStats:
    max_atoms_per_tile: int
    mean_atoms_per_tile: float
    cv_atoms_per_tile: float          # coefficient of variation
    empty_tile_fraction: float
    gini: float                       # work concentration

    @classmethod
    def measure(cls, spec: WorkSpec) -> "ImbalanceStats":
        sizes = np.asarray(spec.atoms_per_tile())
        if sizes.size == 0:
            return cls(0, 0.0, 0.0, 0.0, 0.0)
        mean = float(sizes.mean())
        cv = float(sizes.std() / mean) if mean > 0 else 0.0
        srt = np.sort(sizes).astype(np.float64)
        n = srt.size
        csum = srt.cumsum()
        gini = float((n + 1 - 2 * (csum / csum[-1]).sum()) / n) if csum[-1] > 0 else 0.0
        return cls(int(sizes.max()), mean, cv,
                   float((sizes == 0).mean()), gini)


def modeled_block_cost(spec: WorkSpec, schedule: Schedule | str,
                       num_blocks: int, *,
                       path: str = "pure",
                       atom_work: float = 1) -> jax.Array:
    """Lockstep cost (work-item steps) each block pays, shape [num_blocks].

    ``path`` (``"pure"`` | ``"native"``, see
    :class:`repro.core.execute.ExecutionPath`) currently only moves the
    chunked queue's per-pop overhead: the native chunk-walking kernel pops
    from a scalar-prefetched list in-kernel, the pure path pays the host
    gather that realizes the queue order.

    ``atom_work`` scales the *atom-proportional* term only (never the
    per-block search/queue/inspect constants): it models workloads whose
    per-atom transform costs more lockstep steps than a plain multiply —
    e.g. the frontier-masked graph advance (:data:`ADVANCE_ATOM_WORK`).
    Fractional values are legal (density-scaled direction costs: a push
    advance charges only the frontier's out-edges, so its effective per-atom
    term is ``density * ADVANCE_PUSH_ATOM_WORK``); the per-block overhead
    constants still apply in full — blocks are launched either way.
    """
    atom_units, overhead = block_cost_terms(spec, schedule, num_blocks,
                                            path=path)
    if isinstance(atom_work, (int, np.integer)):
        atom_work = max(int(atom_work), 1)   # integer requests: exact ints
    else:
        atom_work = max(float(atom_work), 0.0)
    return atom_units * atom_work + overhead


def block_cost_terms(spec: WorkSpec, schedule: Schedule | str,
                     num_blocks: int, *, path: str = "pure",
                     part=None) -> Tuple[jax.Array, jax.Array]:
    """Per-block ``(atom_units, overhead)`` such that the lockstep cost is
    ``atom_units * atom_work + overhead`` for any per-atom work weight.

    Every schedule's cost model is affine in the per-atom transform weight —
    this factorization lets callers sweep ``atom_work`` (e.g. the density
    axis of :func:`estimate_direction_threshold`) without re-partitioning
    per sample.  ``part`` reuses a Partition the caller already built for
    this (spec, schedule, num_blocks) instead of inspecting again.
    """
    schedule = Schedule(schedule)
    if spec.num_tiles == 0:      # empty tile set: nothing to schedule
        zero = jnp.zeros((num_blocks,), jnp.int32)
        return zero, zero
    if part is None:
        part = make_partition(spec, schedule, num_blocks)
    sizes = spec.atoms_per_tile()
    if schedule == Schedule.THREAD_MAPPED:
        # One tile per lane: a block of LANES lanes processes LANES tiles in
        # lockstep; cost = max tile size among its lanes.  With fewer tiles
        # than lanes the cost is the global max.
        tiles_per_block = part.items_per_block
        starts = part.tile_starts
        # max tile size within each block's contiguous span.
        idx = (starts[:-1, None]
               + jnp.arange(max(tiles_per_block, 1), dtype=jnp.int32)[None, :])
        valid = idx < starts[1:, None]
        span = jnp.where(valid, sizes[jnp.minimum(idx, spec.num_tiles - 1)], 0)
        per_block_max = span.max(axis=1)
        waves = -(-max(tiles_per_block, 1) // LANES)
        return per_block_max * waves, jnp.zeros_like(per_block_max)
    if schedule in (Schedule.GROUP_MAPPED, Schedule.WARP_MAPPED,
                    Schedule.BLOCK_MAPPED):
        # Atoms within the group processed LANES-parallel after a prefix sum.
        atoms_in_block = part.atom_starts[1:] - part.atom_starts[:-1]
        tiles_in_block = part.tile_starts[1:] - part.tile_starts[:-1]
        return (-(-atoms_in_block // LANES),
                PREFIX_OVERHEAD * -(-tiles_in_block // LANES))
    if schedule == Schedule.NONZERO_SPLIT:
        atoms_in_block = part.atom_starts[1:] - part.atom_starts[:-1]
        units = -(-atoms_in_block // LANES)
        return units, jnp.full_like(units, SEARCH_OVERHEAD)
    if schedule == Schedule.MERGE_PATH:
        ipb = jnp.full((num_blocks,), part.items_per_block, jnp.int32)
        units = -(-ipb // LANES)
        return units, jnp.full_like(units, SEARCH_OVERHEAD)
    if schedule == Schedule.CHUNKED:
        # The chunk-level partition mirrors merge-path's host-built stream
        # (no in-kernel search), but each physical block drains *several*
        # chunks: its cost is the sum over assigned chunks of the chunk's
        # lockstep steps plus the queue-pop/fixup overhead.  LPT/round-robin
        # assignment is what keeps that sum flat across blocks.
        atoms_per_chunk = part.atom_starts[1:] - part.atom_starts[:-1]
        pop = NATIVE_CHUNK_OVERHEAD if path == "native" else CHUNK_OVERHEAD
        phys = part.num_physical_blocks or num_blocks
        units = jax.ops.segment_sum(-(-atoms_per_chunk // LANES),
                                    part.block_map, num_segments=phys)
        chunks_per_block = jax.ops.segment_sum(
            jnp.ones_like(atoms_per_chunk), part.block_map,
            num_segments=phys)
        return units, pop * chunks_per_block
    if schedule == Schedule.ADAPTIVE:
        # Balanced like group-mapped (atoms LANES-parallel after the local
        # prefix sum) plus the inspector's share; split tiles pay a fixup.
        atoms_in_block = part.atom_starts[1:] - part.atom_starts[:-1]
        tiles_in_block = part.tile_starts[1:] - part.tile_starts[:-1]
        fixup = 0 if part.tile_aligned else FIXUP_OVERHEAD
        return (-(-atoms_in_block // LANES),
                PREFIX_OVERHEAD * -(-tiles_in_block // LANES)
                + INSPECT_OVERHEAD + fixup)
    raise ValueError(schedule)


def modeled_cost(spec: WorkSpec, schedule: Schedule | str,
                 num_blocks: int, *, path: str = "pure",
                 atom_work: float = 1) -> float:
    """Total modeled time = max over blocks (blocks run concurrently up to
    core count; we report the bottleneck wave cost × number of waves)."""
    costs = modeled_block_cost(spec, schedule, num_blocks, path=path,
                               atom_work=atom_work)
    return float(jnp.max(costs)) * 1.0


def shard_specs_from_boundaries(spec: WorkSpec, boundaries):
    """Slice a *global* work view into per-shard real (unpadded) sub-views.

    ``boundaries`` is the ``[S+1]`` non-decreasing tile (vertex) split a
    shard boundary schedule produced (``boundaries[s]`` is shard ``s``'s
    first owned tile); each sub-spec is rows ``[b[s], b[s+1])`` of the
    global segment-offset array, rebased to start at atom 0.  Unlike the
    padded local views the sharded inspector executes, these carry each
    shard's *actual* tile and atom counts — which is the whole point of
    scoring a boundary schedule: the model must see the real max-over-
    shards work, not ``V/S`` rows padded to a common ``E_max``.
    """
    off = np.asarray(spec.tile_offsets)
    bounds = [int(b) for b in boundaries]
    if not bounds or bounds[0] != 0 or bounds[-1] != spec.num_tiles \
            or any(b > a for b, a in zip(bounds, bounds[1:])):
        raise ValueError(
            f"boundaries must be a non-decreasing [S+1] split of "
            f"[0, {spec.num_tiles}], got {bounds}")
    out = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        sub = (off[lo:hi + 1] - off[lo]).astype(np.int32)
        out.append(WorkSpec.from_segment_offsets(
            jnp.asarray(sub), num_atoms=int(sub[-1]), num_tiles=hi - lo))
    return out


def modeled_sharded_cost(shard_specs, schedule: Schedule | str,
                         num_blocks: int, *, path: str = "pure",
                         atom_work: float = 1,
                         halo_elems: int = 0,
                         elem_bytes: int = 4,
                         boundaries=None) -> float:
    """Modeled per-iteration cost of an advance sharded over a mesh.

    The recursion of :func:`modeled_cost` one level up: shards run
    concurrently like blocks do, so compute is the *max* over each shard's
    own modeled cost (each shard spec is that shard's local work view), and
    multi-shard plans additionally pay the communication term —
    ``SHARD_SYNC_OVERHEAD`` per iteration plus ``HALO_BYTE_COST`` per byte
    of halo state exchanged (``halo_elems`` elements of ``elem_bytes``; the
    frontier/state carry that ``all_gather`` moves each iteration).  A
    1-shard "mesh" pays no comm term at all, which is what lets
    :func:`repro.core.autotune.select_sharded_plan` legitimately decide a
    graph is too small to shard.

    With ``boundaries=`` the first argument is ONE global
    :class:`~repro.core.work.WorkSpec` and the per-shard views are sliced
    from it by :func:`shard_specs_from_boundaries` — the real split, so
    degree-aware boundary schedules score their actual balance instead of
    the uniform-width padding every executed local view shares.  Shards a
    boundary schedule leaves empty cost nothing (they run the all-masked
    pad program).
    """
    if boundaries is not None:
        shard_specs = shard_specs_from_boundaries(shard_specs, boundaries)
    shard_specs = list(shard_specs)
    if not shard_specs:
        return 0.0
    nonempty = [s for s in shard_specs if s.num_tiles > 0]
    compute = max((modeled_cost(s, schedule, num_blocks, path=path,
                                atom_work=atom_work) for s in nonempty),
                  default=0.0)
    if len(shard_specs) <= 1:
        return float(compute)
    comm = SHARD_SYNC_OVERHEAD + HALO_BYTE_COST * float(
        max(halo_elems, 0) * elem_bytes)
    return float(compute + comm)


def modeled_advance_cost(spec: WorkSpec, schedule: Schedule | str,
                         num_blocks: int, *, path: str = "pure",
                         direction: str = "pull",
                         density: float = 1.0,
                         window_mode: str = "masked") -> float:
    """Modeled cost of a frontier-masked graph advance over this tile set.

    ``spec`` must be the *direction's own* work view: the pull/transpose CSR
    (tiles = destinations, atoms = in-edges) for ``direction="pull"``, the
    forward CSR (tiles = sources, atoms = out-edges) for ``"push"``.

    The direction-dependent atom terms (``density`` = fraction of the edge
    set leaving the frontier, in [0, 1]):

    * **pull** streams *all* in-edges every iteration — each pays the mask
      load + select, and the ``density`` fraction that survives the mask
      additionally pays the gather + combine.  Effective atom work:
      ``1 + density * (ADVANCE_ATOM_WORK - 1)``; at full density this is
      exactly the PR-3 ``ADVANCE_ATOM_WORK`` charge.
    * **push** is frontier-compacted — only active out-edges do work, but
      each pays the scatter-combine by destination:
      ``density * ADVANCE_PUSH_ATOM_WORK``.  Per-block overheads stay at
      full charge (blocks launch regardless of the frontier).

    ``window_mode`` models how the push advance materializes its windows:

    * ``"masked"`` (default, and the only pull mode) — the PR-4 behaviour:
      full partition windows with identity at inactive slots.  The block
      skew of the direction's own degree distribution is what the schedule
      terms capture.
    * ``"compact"`` (push only) — the gather-compacted active-edge windows
      of :func:`repro.core.execute.execute_scatter_reduce`: the active
      atoms are compacted into an even per-chunk split, so the per-block
      cost is the *mean* active load, not the schedule's max — compaction
      flattens frontier skew at the price of one gather indirection per
      active atom (:data:`COMPACT_GATHER_WORK`) and the per-block index
      build share (:data:`COMPACT_BUILD_OVERHEAD`).

    Used by :func:`repro.core.autotune.select_plan` with
    ``workload="advance"`` / ``"advance_push"`` (at density 1: the
    schedule/path choice must hold up in the direction's worst case) and by
    :func:`estimate_direction_threshold` across the density axis.
    """
    if direction not in ("pull", "push"):
        raise ValueError(f"unknown direction: {direction!r}")
    if window_mode not in ("masked", "compact"):
        raise ValueError(f"unknown window mode: {window_mode!r}")
    density = min(max(float(density), 0.0), 1.0)
    if window_mode == "compact":
        if direction != "push":
            raise ValueError("compacted windows are a push-direction mode "
                             "(pull streams its combine, nothing to compact)")
        active = int(np.ceil(density * spec.num_atoms))
        per_block = -(-max(active, 0) // max(num_blocks, 1))
        units = -(-per_block // LANES)
        return float(units * (ADVANCE_PUSH_ATOM_WORK + COMPACT_GATHER_WORK)
                     + COMPACT_BUILD_OVERHEAD)
    if direction == "pull":
        atom_work = 1.0 + density * (ADVANCE_ATOM_WORK - 1)
    else:
        atom_work = density * ADVANCE_PUSH_ATOM_WORK
    return modeled_cost(spec, schedule, num_blocks, path=path,
                        atom_work=atom_work)


def estimate_direction_threshold(pull_spec: WorkSpec, push_spec: WorkSpec,
                                 num_blocks: int, *,
                                 pull_schedule: Schedule | str,
                                 push_schedule: Schedule | str,
                                 pull_path: str = "pure",
                                 push_path: str = "pure",
                                 pull_part=None, push_part=None,
                                 samples: int = 17) -> float:
    """Frontier density above which the pull direction is modeled cheaper.

    Scans ``samples`` densities in [0, 1] and returns the smallest density
    where the pull advance's modeled cost drops to (or below) the push
    advance's — the direction-optimizing drivers switch push -> pull once
    the measured frontier out-edge fraction crosses this.  Returns 0.0 when
    pull is never beaten (e.g. a push schedule whose overheads dominate)
    and 1.0 when push wins everywhere.  Each direction is partitioned once
    (:func:`block_cost_terms` — the cost is affine in the atom weight, so
    the density sweep is arithmetic, not re-inspection).
    """
    pull_units, pull_over = block_cost_terms(pull_spec, pull_schedule,
                                             num_blocks, path=pull_path,
                                             part=pull_part)
    push_units, push_over = block_cost_terms(push_spec, push_schedule,
                                             num_blocks, path=push_path,
                                             part=push_part)
    for i in range(samples):
        d = i / (samples - 1)
        pull = float(jnp.max(
            pull_units * (1.0 + d * (ADVANCE_ATOM_WORK - 1)) + pull_over))
        push = float(jnp.max(
            push_units * (d * ADVANCE_PUSH_ATOM_WORK) + push_over))
        if pull <= push:
            return d
    return 1.0


def estimate_compact_capacity(num_edges: int, direction_threshold: float, *,
                              slack: float = 1.25, floor: int = 32) -> int:
    """Static slot count for the gather-compacted push windows.

    Compacted windows need a static capacity (TPU shapes are static); the
    direction-optimizing drivers only run push advances while the measured
    frontier out-edge fraction is *below* the plan's ``direction_threshold``,
    so ``threshold * num_edges`` bounds the active-edge count of every push
    iteration.  ``slack`` absorbs the threshold-crossing iteration (measured
    density is from the *previous* frontier) and ``floor`` keeps tiny plans
    from degenerate one-slot windows.  Capacity never exceeds the edge
    count — at that point compaction is a no-op and the executor's masked
    fallback is free.  Overflow is safe regardless: the executor falls back
    to masked full windows whenever the active count exceeds capacity.
    """
    frac = min(max(float(direction_threshold), 0.0), 1.0)
    want = int(np.ceil(frac * max(num_edges, 0) * max(slack, 1.0)))
    return int(min(max(want, floor), max(num_edges, 1)))


#: The tunable cost-model coefficients the measured-cost feedback loop can
#: re-fit (:func:`fit_coefficients`), with their current hand-set values.
#: These are the constants whose *ratios* move the autotuner's argmin; the
#: remaining constants (LANES, SEARCH/PREFIX/CHUNK/INSPECT/FIXUP overheads)
#: are treated as known and folded into each sample's base term — they are
#: either hardware-structural (LANES) or shared by every candidate so they
#: cancel in the ranking.  Documented one by one in docs/autotune.md.
def _fit_targets() -> Dict[str, float]:
    return {
        "ADVANCE_ATOM_WORK": float(ADVANCE_ATOM_WORK),
        "ADVANCE_PUSH_ATOM_WORK": float(ADVANCE_PUSH_ATOM_WORK),
        "ADVANCE_DELTA_ATOM_WORK": float(ADVANCE_DELTA_ATOM_WORK),
        "ADVANCE_DELTA_PUSH_ATOM_WORK": float(ADVANCE_DELTA_PUSH_ATOM_WORK),
        "WAVEFRONT_ATOM_WORK": float(WAVEFRONT_ATOM_WORK),
        "WAVEFRONT_PUSH_ATOM_WORK": float(WAVEFRONT_PUSH_ATOM_WORK),
        "NATIVE_CHUNK_OVERHEAD": float(NATIVE_CHUNK_OVERHEAD),
        "COMPACT_GATHER_WORK": float(COMPACT_GATHER_WORK),
        "COMPACT_BUILD_OVERHEAD": float(COMPACT_BUILD_OVERHEAD),
    }


#: Workload family -> the fit-target coefficient its atom term carries
#: (``None``: the plain tile-reduce, whose atom weight is the fixed 1).
WORKLOAD_ATOM_COEF = {"reduce": None,
                      "advance": "ADVANCE_ATOM_WORK",
                      "advance_push": "ADVANCE_PUSH_ATOM_WORK",
                      "advance_delta": "ADVANCE_DELTA_ATOM_WORK",
                      "advance_delta_push": "ADVANCE_DELTA_PUSH_ATOM_WORK",
                      "advance_sharded": "ADVANCE_ATOM_WORK",
                      "advance_sharded_push": "ADVANCE_PUSH_ATOM_WORK",
                      "advance_serve": "ADVANCE_ATOM_WORK",
                      "advance_serve_push": "ADVANCE_PUSH_ATOM_WORK",
                      "wavefront": "WAVEFRONT_ATOM_WORK",
                      "wavefront_push": "WAVEFRONT_PUSH_ATOM_WORK"}


def cost_features(spec: WorkSpec, schedule: Schedule | str, num_blocks: int,
                  *, path: str = "pure", workload: str = "reduce",
                  window_mode: str = "masked",
                  part=None) -> Tuple[float, Dict[str, float]]:
    """Decompose one plan's modeled cost over the tunable coefficients.

    Returns ``(base, feats)`` such that, at the *bottleneck block* under the
    current coefficient values, ``modeled cost == base + sum(feats[name] *
    coefficient[name])`` over the :func:`fit_coefficients` targets.  ``base``
    absorbs every non-tunable term (the unit atom work, LANES-quantised
    units, search/prefix/inspect overheads).

    The max over blocks makes the full model piecewise-linear in the
    coefficients; this linearizes at the current values by freezing the
    bottleneck block — exact as long as a re-fit does not move the argmax
    block, and a fine first-order story for the report-only fit either way.
    ``window_mode="compact"`` (push families only) decomposes the
    gather-compacted window model instead, which has no per-schedule max —
    compaction's even split is the point.
    """
    if workload not in WORKLOAD_ATOM_COEF:
        raise ValueError(f"unknown workload family: {workload!r} "
                         f"(expected one of {sorted(WORKLOAD_ATOM_COEF)})")
    targets = _fit_targets()
    atom_coef = WORKLOAD_ATOM_COEF[workload]
    if window_mode == "compact":
        if atom_coef not in ("ADVANCE_PUSH_ATOM_WORK",
                             "ADVANCE_DELTA_PUSH_ATOM_WORK"):
            raise ValueError("compact window features are a push-family "
                             "mode (window_mode='masked' for pull/reduce)")
        per_block = -(-max(spec.num_atoms, 0) // max(num_blocks, 1))
        units = float(-(-per_block // LANES))
        return 0.0, {atom_coef: units, "COMPACT_GATHER_WORK": units,
                     "COMPACT_BUILD_OVERHEAD": 1.0}
    schedule = Schedule(schedule)
    atom_units, overhead = block_cost_terms(spec, schedule, num_blocks,
                                            path=path, part=part)
    atom_work = 1.0 if atom_coef is None else targets[atom_coef]
    costs = np.asarray(atom_units) * atom_work + np.asarray(overhead)
    if costs.size == 0:
        return 0.0, {}
    b = int(np.argmax(costs))
    units = float(np.asarray(atom_units)[b])
    over = float(np.asarray(overhead)[b])
    feats: Dict[str, float] = {}
    base = 0.0
    if atom_coef is None:
        base += units
    else:
        feats[atom_coef] = units
    if schedule == Schedule.CHUNKED and path == "native":
        # the native pop charge is a fit target: overhead = pop * chunks
        feats["NATIVE_CHUNK_OVERHEAD"] = over / max(
            float(NATIVE_CHUNK_OVERHEAD), 1e-12)
    else:
        base += over
    return base, feats


@dataclasses.dataclass(frozen=True)
class FitResult:
    """Report of a measured-cost least-squares re-fit (report-only)."""

    coefficients: Dict[str, float]    # fitted values, current ones if unseen
    current: Dict[str, float]         # the hand-set values being judged
    scale_us_per_step: float          # wall-us per modeled lockstep step
    residual_rel: float               # ||r|| / ||t|| of the LS solve
    num_samples: int
    constrained: Tuple[str, ...]      # coefficients the samples actually hit

    def report(self) -> str:
        lines = [f"fit over {self.num_samples} measured samples: "
                 f"scale {self.scale_us_per_step:.3g} us/step, "
                 f"relative residual {self.residual_rel:.3f}",
                 f"{'coefficient':32s} {'current':>10s} {'fitted':>10s}"]
        for name, cur in sorted(self.current.items()):
            fit = self.coefficients[name]
            mark = "" if name in self.constrained else "  (unconstrained)"
            lines.append(f"{name:32s} {cur:10.3g} {fit:10.3g}{mark}")
        return "\n".join(lines)


def fit_coefficients(samples: Sequence[Tuple[float, Dict[str, float], float]],
                     *, min_scale: float = 1e-9) -> FitResult:
    """Least-squares re-fit of the tunable coefficients from measurements.

    ``samples`` are ``(base, feats, measured_us)`` triples as produced by
    :func:`cost_features` plus a wall-clock measurement of the same plan
    (the autotuner's v2 cache records carry exactly these — see
    :func:`repro.core.autotune.collect_fit_samples`).  The model is

        ``measured_us ~= s * (base + sum_j feats[j] * c_j)``

    with unknown time scale ``s`` (us per modeled lockstep step) and
    coefficients ``c_j``.  Substituting ``w_j = s * c_j`` makes it linear:
    solve ``t ~= s * base + F @ w`` by ordinary least squares, then recover
    ``c_j = w_j / s``.  Coefficients no sample exercises keep their current
    value (flagged in the result).  Fitted values are floored at a small
    positive epsilon — a negative coefficient means the model's *structure*
    (not its weights) disagrees with the hardware, which the residual
    reports honestly.

    This is **report-only**: nothing mutates the module constants.  Editing
    ``balance.py`` with fitted values is a deliberate, reviewed act
    (docs/autotune.md walks through it).
    """
    samples = list(samples)
    current = _fit_targets()
    if not samples:
        raise ValueError("fit_coefficients needs at least one measured "
                         "sample (run the autotuner with "
                         "REPRO_AUTOTUNE_MEASURE=1 first)")
    names = sorted({n for _, feats, _ in samples for n in feats
                    if n in current})
    A = np.zeros((len(samples), 1 + len(names)))
    t = np.zeros(len(samples))
    for i, (base, feats, us) in enumerate(samples):
        A[i, 0] = float(base)
        for j, n in enumerate(names):
            A[i, 1 + j] = float(feats.get(n, 0.0))
        t[i] = float(us)
    sol, *_ = np.linalg.lstsq(A, t, rcond=None)
    s = float(sol[0])
    if not s > min_scale:
        # no sample carried base weight (or the solve degenerated): anchor
        # the scale on the median measured-us per modeled step instead
        steps = A[:, 0] + A[:, 1:] @ np.asarray(
            [current[n] for n in names]) if names else A[:, 0]
        steps = np.where(steps > 0, steps, 1.0)
        s = float(np.median(t / steps))
        s = max(s, min_scale)
    fitted = dict(current)
    for j, n in enumerate(names):
        fitted[n] = max(float(sol[1 + j]) / s, 1e-3)
    resid = A @ sol - t
    denom = float(np.linalg.norm(t))
    return FitResult(coefficients=fitted, current=current,
                     scale_us_per_step=s,
                     residual_rel=float(np.linalg.norm(resid)) /
                     max(denom, 1e-12),
                     num_samples=len(samples),
                     constrained=tuple(names))


def choose_schedule(num_tiles: int, num_atoms: int, *, alpha: int = 500,
                    beta: int = 10_000) -> Schedule:
    """The paper's §6.2 heuristic, verbatim: merge-path unless the matrix is
    small (rows or cols < alpha and nnz < beta), in which case the cheaper
    thread-/group-mapped schedules win because merge-path's search overhead
    dominates tiny workloads."""
    if num_tiles < alpha and num_atoms < beta:
        if num_atoms <= num_tiles * 2:       # near-uniform, tiny tiles
            return Schedule.THREAD_MAPPED
        return Schedule.GROUP_MAPPED
    return Schedule.MERGE_PATH


def landscape(spec: WorkSpec, num_blocks: int, *,
              include_dynamic: bool = False) -> Dict[str, float]:
    """Modeled cost of every schedule for one workload (Fig. 3 datapoint)."""
    scheds = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
              Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]
    if include_dynamic:
        scheds += [Schedule.CHUNKED, Schedule.ADAPTIVE]
    return {str(s): modeled_cost(spec, s, num_blocks) for s in scheds}
