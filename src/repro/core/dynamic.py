"""Dynamic load-balancing schedules (paper §3.2's "dynamic" half).

The paper's abstraction "aims to support both static and dynamic schedules";
the static four live in :mod:`repro.core.schedules`.  This module adds the
dynamic side, following Atos (arXiv 2112.00132): instead of computing one
final block assignment, *oversplit* the work into many more chunks than
processors and let a work queue drain them.  On TPU there is no in-kernel
queue, so the queue discipline is made static per input: the inspector runs
on the host (or in XLA, pre-launch), produces a chunk-level
:class:`~repro.core.schedules.Partition` — the same contract every executor
and Pallas kernel already consumes — and records the chunk -> physical block
assignment in ``Partition.block_map``.

Two schedules:

* :func:`chunked_partition` — Atos-style chunked work queue.  The WorkSpec
  is oversplit into ``chunk_factor * num_blocks`` chunks of roughly equal
  atom count; chunk boundaries snap to tile boundaries when one is close
  (so most chunks need no cross-chunk fixup) but heavy tiles are split
  mid-tile (so no chunk is ever larger than ~2x the target).  Chunks are
  assigned to physical blocks round-robin or greedily by
  longest-processing-time (LPT), the classic makespan heuristic.

* :func:`adaptive_partition` — two-phase "inspect then balance".  Phase 1
  inspects the cheap tile-mapped partition; if its atom imbalance is under
  ``imbalance_threshold`` it is returned unchanged (zero extra cost — the
  common case for regular workloads).  Otherwise phase 2 re-partitions with
  equal-atom cuts that stay tile-aligned everywhere except inside tiles too
  heavy to place on one block — only the tiles that exceed the threshold pay
  for the repartition.

Both partitioners prefer concrete (host) inputs — schedule construction is
an inspector that runs before kernel launch — but degrade gracefully under
tracing: snapping and cuts are pure jnp; only the LPT policy and the
adaptive early-exit need concrete sizes and fall back (to round-robin and
"always balance" respectively) when traced.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.balance import CHUNK_OVERHEAD, LANES
from repro.core.schedules import (Partition, Schedule, finalize_partition,
                                  tile_mapped_partition)
from repro.core.work import WorkSpec

#: Default oversplit factor: chunks per physical block (Atos uses 4-16).
DEFAULT_CHUNK_FACTOR = 4

#: Default adaptive trigger: re-balance when max block load > 1.5x mean.
DEFAULT_IMBALANCE_THRESHOLD = 1.5


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


# ---------------------------------------------------------------------------
# Shared inspector: equal-atom cuts with tile-boundary snapping.
# ---------------------------------------------------------------------------

def _snapped_atom_cuts(spec: WorkSpec, num_cuts: int, quantum: int
                       ) -> jax.Array:
    """``num_cuts + 1`` non-decreasing atom boundaries covering all atoms.

    Cut ``c`` targets atom ``c * quantum`` and snaps to the nearest tile
    boundary when that boundary is within ``quantum // 2`` atoms; cuts inside
    heavier tiles stay mid-tile (the tile gets split).  Snap tolerance of
    half a quantum keeps the snapped sequence non-decreasing and bounds every
    span by ``2 * quantum``.
    """
    cuts = jnp.minimum(
        jnp.arange(num_cuts + 1, dtype=jnp.int32) * quantum, spec.num_atoms)
    if spec.num_tiles == 0 or spec.num_atoms == 0:
        return cuts
    tol = max(quantum // 2, 0)
    owner = jnp.clip(
        jnp.searchsorted(spec.tile_offsets, cuts, side="right") - 1,
        0, spec.num_tiles - 1).astype(jnp.int32)
    lo = spec.tile_offsets[owner]          # tile start at/before the cut
    hi = spec.tile_offsets[owner + 1]      # tile end at/after the cut
    d_lo = cuts - lo
    d_hi = hi - cuts
    snapped = jnp.where(
        (d_lo <= d_hi) & (d_lo <= tol), lo,
        jnp.where(d_hi <= tol, hi, cuts))
    # endpoints are structural, never snapped
    snapped = snapped.at[0].set(0).at[-1].set(spec.num_atoms)
    return snapped.astype(jnp.int32)


def _partition_from_atom_cuts(spec: WorkSpec, cuts: jax.Array,
                              schedule: Schedule, quantum: int,
                              block_map: Optional[jax.Array] = None,
                              num_physical_blocks: Optional[int] = None
                              ) -> Partition:
    """Assemble a Partition from atom boundaries (possibly mid-tile)."""
    tile_starts = (jnp.searchsorted(spec.tile_offsets, cuts, side="right")
                   .astype(jnp.int32) - 1)
    tile_starts = jnp.clip(tile_starts, 0, spec.num_tiles)
    spans = cuts[1:] - cuts[:-1]
    if _is_concrete(spans) and spans.shape[0]:
        items = max(int(jnp.max(spans)), 1)
    else:
        items = max(2 * quantum, 1)   # snap tolerance bounds spans by 2q
    aligned = False
    if _is_concrete(cuts):
        boundary = np.isin(np.asarray(cuts), np.asarray(spec.tile_offsets))
        aligned = bool(boundary.all())
    return finalize_partition(Partition(
        schedule=schedule, num_blocks=int(spans.shape[0]),
        items_per_block=items,
        atom_starts=cuts.astype(jnp.int32),
        tile_starts=tile_starts, tile_aligned=aligned,
        block_map=block_map,
        num_physical_blocks=num_physical_blocks))


# ---------------------------------------------------------------------------
# Chunked work queue (Atos-style).
# ---------------------------------------------------------------------------

def assign_chunks(chunk_cost: jax.Array, num_blocks: int,
                  policy: str = "lpt") -> jax.Array:
    """Map each chunk to a physical block.

    ``round_robin``: chunk ``c`` -> block ``c % num_blocks`` (static, works
    under tracing).  ``lpt``: sort chunks by cost descending, give each to
    the least-loaded block so far — the classic greedy makespan bound of
    4/3 OPT.  LPT needs concrete costs; traced inputs fall back to
    round-robin.
    """
    n = int(chunk_cost.shape[0])
    if policy == "round_robin" or not _is_concrete(chunk_cost):
        return jnp.arange(n, dtype=jnp.int32) % num_blocks
    if policy != "lpt":
        raise ValueError(f"unknown chunk policy: {policy}")
    cost = np.asarray(chunk_cost, np.int64)
    order = np.argsort(-cost, kind="stable")
    load = np.zeros(num_blocks, np.int64)
    out = np.zeros(n, np.int32)
    for c in order:
        b = int(np.argmin(load))
        out[c] = b
        load[b] += int(cost[c])
    return jnp.asarray(out)


def chunked_partition(spec: WorkSpec, num_blocks: int, *,
                      chunk_factor: int = DEFAULT_CHUNK_FACTOR,
                      policy: str = "lpt") -> Partition:
    """Atos-style chunked work queue as a static TPU schedule.

    Oversplits into ``chunk_factor * num_blocks`` chunks of ~equal atoms
    (tile-snapped; heavy tiles split), then assigns chunks to the
    ``num_blocks`` physical blocks.  The returned Partition has one entry
    per *chunk* — executors consume it unchanged and stay correct; the
    queue discipline lives in ``block_map`` and is what the cost model
    (and a sequential-grid TPU launch) pays.
    """
    num_blocks = max(int(num_blocks), 1)
    num_chunks = max(chunk_factor, 1) * num_blocks
    # never oversplit beyond one atom per chunk (keeps windows non-trivial)
    num_chunks = min(num_chunks, max(spec.num_atoms, 1))
    quantum = _ceil_div(max(spec.num_atoms, 1), num_chunks)
    cuts = _snapped_atom_cuts(spec, num_chunks, quantum)
    # LPT must balance what a block actually pays per chunk — lockstep steps
    # plus the constant queue-pop overhead (balancing raw atoms would let
    # every zero-cost chunk pile onto one block).
    spans = cuts[1:] - cuts[:-1]
    chunk_cost = -(-spans // LANES) + CHUNK_OVERHEAD
    block_map = assign_chunks(chunk_cost, num_blocks, policy)
    return _partition_from_atom_cuts(spec, cuts, Schedule.CHUNKED, quantum,
                                     block_map=block_map,
                                     num_physical_blocks=num_blocks)


# ---------------------------------------------------------------------------
# Adaptive inspect-then-balance.
# ---------------------------------------------------------------------------

# Serving-loop memoisation: ``adaptive_partition`` is an inspector, and a
# serving loop calls it per request — without a cache it re-inspects the
# workload every call even when the routing/shape recurs (and ``jit`` cannot
# help: the inspector needs concrete sizes, so it runs *outside* the traced
# computation).  Keyed by an exact content fingerprint of the offsets — not
# the autotuner's quantised shape bucket — because the partition's cut
# points depend on the actual offsets, not just their shape statistics.
_ADAPTIVE_CACHE: "OrderedDict[tuple, Partition]" = OrderedDict()
_ADAPTIVE_CACHE_CAPACITY = 256
_ADAPTIVE_CACHE_LOCK = threading.Lock()
_INSPECTION_COUNT = 0


def adaptive_inspection_count() -> int:
    """How many times the adaptive inspector actually ran (cache misses).

    Monotonic process-wide counter for regression tests: repeated calls on
    the same workload must not re-inspect.
    """
    return _INSPECTION_COUNT


def clear_adaptive_cache() -> None:
    with _ADAPTIVE_CACHE_LOCK:
        _ADAPTIVE_CACHE.clear()


def _workload_fingerprint(spec: WorkSpec) -> Optional[str]:
    """Exact (not quantised) content hash of a concrete WorkSpec."""
    if not _is_concrete(spec.tile_offsets):
        return None
    digest = hashlib.sha1(np.ascontiguousarray(
        np.asarray(spec.tile_offsets, np.int64)).tobytes()).hexdigest()
    return f"{spec.num_tiles}:{spec.num_atoms}:{digest}"


def adaptive_partition(spec: WorkSpec, num_blocks: int, *,
                       imbalance_threshold: float =
                       DEFAULT_IMBALANCE_THRESHOLD,
                       cache: bool = True) -> Partition:
    """Two-phase schedule: keep the cheap tile-mapped partition when it is
    balanced; re-partition (splitting only over-threshold tiles) when not.

    Built partitions are memoised per (workload fingerprint, num_blocks,
    threshold) — the analogue of the autotuner's schedule-choice cache, so
    a serving loop can call this per request without paying the inspector
    each time.  ``cache=False`` forces a fresh inspection.
    """
    global _INSPECTION_COUNT
    num_blocks = max(int(num_blocks), 1)
    key = None
    if cache:
        fingerprint = _workload_fingerprint(spec)
        if fingerprint is not None:
            key = (fingerprint, num_blocks, float(imbalance_threshold))
            with _ADAPTIVE_CACHE_LOCK:
                hit = _ADAPTIVE_CACHE.get(key)
                if hit is not None:
                    _ADAPTIVE_CACHE.move_to_end(key)
                    return hit
    _INSPECTION_COUNT += 1
    part = _adaptive_partition_uncached(spec, num_blocks,
                                        imbalance_threshold)
    if key is not None:
        with _ADAPTIVE_CACHE_LOCK:
            _ADAPTIVE_CACHE[key] = part
            while len(_ADAPTIVE_CACHE) > _ADAPTIVE_CACHE_CAPACITY:
                _ADAPTIVE_CACHE.popitem(last=False)
    return part


def _adaptive_partition_uncached(spec: WorkSpec, num_blocks: int,
                                 imbalance_threshold: float) -> Partition:
    phase1 = tile_mapped_partition(spec, num_blocks, Schedule.ADAPTIVE)
    if spec.num_atoms == 0 or spec.num_tiles == 0 or num_blocks == 1:
        return phase1
    if _is_concrete(phase1.atom_starts):
        loads = np.diff(np.asarray(phase1.atom_starts))
        mean = spec.num_atoms / num_blocks
        if loads.max() <= imbalance_threshold * max(mean, 1.0):
            return phase1              # inspector says: balanced already
    quantum = _ceil_div(spec.num_atoms, num_blocks)
    cuts = _snapped_atom_cuts(spec, num_blocks, quantum)
    return _partition_from_atom_cuts(spec, cuts, Schedule.ADAPTIVE, quantum)
