"""Work execution (paper §3.3 / §4.3) — schedule-agnostic consumers.

The paper's users write ``for tile in cfg.tiles(): for atom in cfg.atoms(tile)``
inside their own CUDA kernel.  The TPU analogue: the user supplies an
*atom transform* (a function of atom index -> value, e.g.
``lambda nz: vals[nz] * x[col[nz]]`` for SpMV) and a reduction; the executor
consumes a :class:`Partition` and materializes the blocked execution.

Two executors are provided:

* :func:`tile_reduce` — the oracle path: one segment-sum over the whole
  problem.  Schedule-independent result, used as ground truth everywhere.
* :func:`blocked_tile_reduce` — the *faithful blocked* execution: every block
  processes exactly its partition slice with static shapes + masking, interior
  tiles complete locally, and boundary tiles are combined in a fixup pass.
  This is bit-for-bit the algorithm the Pallas kernels implement, kept in
  pure JAX so kernels have an executable specification to test against.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedules import Partition
from repro.core.segops import segment_sum
from repro.core.work import WorkSpec

AtomFn = Callable[[jax.Array], jax.Array]  # [n] int32 atom ids -> [n] values


def tile_reduce(spec: WorkSpec, atom_fn: AtomFn,
                dtype=jnp.float32) -> jax.Array:
    """Oracle: per-tile sum of ``atom_fn(atom)`` over all atoms."""
    atoms = jnp.arange(spec.num_atoms, dtype=jnp.int32)
    values = atom_fn(atoms).astype(dtype)
    return segment_sum(values, spec.atom_tile_ids(), spec.num_tiles)


def blocked_tile_reduce(spec: WorkSpec, part: Partition, atom_fn: AtomFn,
                        dtype=jnp.float32) -> jax.Array:
    """Blocked execution faithful to the partition.

    Shapes are static: each block materializes a ``[items_per_block]`` window
    of atoms (masked past its end) and reduces into at most
    ``items_per_block + 1`` local tiles via a one-hot contraction — the same
    MXU-shaped inner loop as the Pallas kernels.  Cross-block partial tiles
    are resolved by a scatter-add fixup (Merrill & Garland's "segmented
    fixup", adapted: TPU grid blocks cannot order-depend, so the fixup is a
    separate reduction over per-block partials).
    """
    if spec.num_atoms == 0:
        return jnp.zeros((spec.num_tiles,), dtype)
    grid = part.num_blocks
    from repro.core.schedules import Schedule

    # Static window sizing.  Preferred source: the span hints captured by
    # ``finalize_partition`` when the boundaries were still concrete (under
    # jit the closure-captured boundary arrays are tracers, so they cannot
    # be concretised here).  Fallbacks are schedule-aware worst cases.
    if part.atom_span is not None:
        window = max(part.atom_span, 1)
    elif part.tile_aligned:
        # items_per_block counts *tiles*; the atom window is data-dependent.
        try:
            window = max(int(jnp.max(part.atom_starts[1:]
                                     - part.atom_starts[:-1])), 1)
        except jax.errors.ConcretizationTypeError:
            window = max(spec.num_atoms, 1)
    else:
        # merge-path / chunked / nonzero-split: items_per_block bounds atoms.
        window = max(int(part.items_per_block), 1)

    # Local tile window: a block touches tiles [tile_starts[b],
    # tile_starts[b+1]] inclusive.  Sizing it from items_per_block alone
    # undercounts when a block's span crosses *empty* tiles (atoms bound
    # work, not tile span) and would silently drop their neighbours' sums.
    if part.tile_span is not None:
        local_tiles = max(part.tile_span, 1)
    else:
        try:
            local_tiles = max(int(jnp.max(part.tile_starts[1:]
                                          - part.tile_starts[:-1])) + 1, 1)
        except jax.errors.ConcretizationTypeError:
            if part.schedule == Schedule.MERGE_PATH:
                # merge items bound atoms + tile markers: span <= items + 1
                local_tiles = max(int(part.items_per_block), 1) + 1
            elif part.tile_aligned and part.schedule not in (
                    Schedule.CHUNKED, Schedule.ADAPTIVE):
                local_tiles = max(int(part.items_per_block), 1) + 1
            else:
                # no static bound relates atoms to tile span: worst case
                local_tiles = spec.num_tiles + 1

    atom_base = part.atom_starts[:-1]                       # [G]
    idx = atom_base[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    valid = idx < part.atom_starts[1:, None]                # [G, W]
    safe_idx = jnp.clip(idx, 0, max(spec.num_atoms - 1, 0))

    values = atom_fn(safe_idx.reshape(-1)).astype(dtype).reshape(grid, window)
    values = jnp.where(valid, values, jnp.zeros((), dtype))

    tile_ids = spec.atom_tile_ids()                          # [A]
    tids = tile_ids[safe_idx]                                # [G, W]
    local = tids - part.tile_starts[:-1, None]               # [G, W]
    local = jnp.where(valid, local, local_tiles)             # mask -> OOB bin

    # One-hot contraction per block: [G, W] x [W, local_tiles] on the MXU.
    onehot = (local[..., None]
              == jnp.arange(local_tiles, dtype=jnp.int32)[None, None, :])
    partials = jnp.einsum("gw,gwl->gl", values, onehot.astype(dtype))

    # Fixup: scatter-add per-block partials at their global tile offsets.
    gtid = part.tile_starts[:-1, None] + jnp.arange(local_tiles,
                                                    dtype=jnp.int32)[None, :]
    gtid = jnp.where(gtid < spec.num_tiles, gtid, spec.num_tiles)  # drop OOB
    return segment_sum(partials.reshape(-1), gtid.reshape(-1),
                       spec.num_tiles + 1)[:-1]
