"""Work execution (paper §3.3 / §4.3) — schedule-agnostic consumers.

The paper's users write ``for tile in cfg.tiles(): for atom in cfg.atoms(tile)``
inside their own CUDA kernel.  The TPU analogue: the user supplies an
*atom transform* (a function of atom index -> value, e.g.
``lambda nz: vals[nz] * x[col[nz]]`` for SpMV) and a reduction; the executor
consumes a :class:`Partition` and materializes the blocked execution.

Three executors are provided, behind one dispatcher:

* :func:`tile_reduce` — the oracle path: one segment-sum over the whole
  problem.  Schedule-independent result, used as ground truth everywhere.
* :func:`blocked_tile_reduce` — the *faithful blocked* execution: every block
  processes exactly its partition slice with static shapes + masking, interior
  tiles complete locally, and boundary tiles are combined in a fixup pass.
  This is bit-for-bit the algorithm the Pallas kernels implement, kept in
  pure JAX so kernels have an executable specification to test against.
* :func:`native_chunk_tile_reduce` — the *device-side* execution: a Pallas
  kernel (``repro.kernels.spmv_merge.chunk_walk_reduce``) whose grid is the
  *physical* blocks; each block scalar-prefetches its chunk queue (the
  inverted ``Partition.block_map``) and walks it inside the kernel — the
  Atos work-queue discipline on-device, which is where the paper's dynamic
  schedules actually pay off.

:func:`execute_tile_reduce` routes any Partition (static, chunked, adaptive)
to one of the latter two via :class:`ExecutionPath`; ``"auto"`` picks the
native kernel whenever the partition carries the structures it needs.
"""
from __future__ import annotations

import enum
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.schedules import Partition, invert_block_map
from repro.core.segops import segment_sum
from repro.core.work import WorkSpec

AtomFn = Callable[[jax.Array], jax.Array]  # [n] int32 atom ids -> [n] values

#: Reduction combiners usable by every executor.  ``sum`` is the paper's
#: tile-reduce; ``min``/``max`` are the graph advance's scatter-min (SSSP
#: relax) and scatter-or (BFS frontier expansion, over {0, 1} values).  All
#: three are associative and commutative; min/max are additionally *exact*
#: in floating point, so every schedule/path produces identical bits.
COMBINER_IDENTITY = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _check_combiner(combiner: str, dtype) -> float:
    """Validate and return the combiner's identity element."""
    if combiner not in COMBINER_IDENTITY:
        raise ValueError(f"unknown combiner: {combiner!r} "
                         f"(expected one of {sorted(COMBINER_IDENTITY)})")
    if combiner != "sum" and not jnp.issubdtype(jnp.dtype(dtype),
                                                jnp.floating):
        raise ValueError(f"combiner {combiner!r} needs a floating dtype "
                         f"(its identity is +/-inf), got {jnp.dtype(dtype)}")
    return COMBINER_IDENTITY[combiner]


def _segment_reduce(combiner: str, values: jax.Array, segment_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    """Segmented reduction under the named combiner (identity fill)."""
    if combiner == "sum":
        return segment_sum(values, segment_ids, num_segments)
    if combiner == "min":
        return jax.ops.segment_min(values, segment_ids,
                                   num_segments=num_segments)
    return jax.ops.segment_max(values, segment_ids,
                               num_segments=num_segments)


class ExecutionPath(str, enum.Enum):
    """Which executor consumes a Partition.

    ``PURE`` — the pure-JAX blocked executor (:func:`blocked_tile_reduce`),
    always available (also the name segmm's permuted-grid fallback routes
    under).  ``NATIVE`` — the Pallas chunk-walking kernel.  ``AUTO`` — native
    when the partition supports it, pure otherwise.
    """

    AUTO = "auto"
    PURE = "pure"
    NATIVE = "native"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


def supports_native_execution(part: Partition) -> bool:
    """True when a Partition carries what the chunk-walking kernel needs.

    Requirements: static ``atom_span``/``tile_span`` window hints (the
    kernel's VMEM windows are static shapes) and, for dynamic schedules, a
    concrete inverted ``block_map`` view (or a ``block_map`` that can still
    be inverted).  Partitions built under jit tracing have neither — the
    inspector must run pre-launch for the native path, by design.
    """
    if part.atom_span is None or part.tile_span is None:
        return False
    if part.block_map is None:
        return True                       # static schedule: block == chunk
    if part.block_chunks is not None:
        return True
    return not isinstance(part.block_map, jax.core.Tracer)


def resolve_execution_path(request: ExecutionPath | str, *,
                           native_supported: bool) -> ExecutionPath:
    """Collapse an ``auto``/``pure``/``native`` request to a concrete path."""
    request = ExecutionPath(request)
    if request == ExecutionPath.NATIVE and not native_supported:
        raise ValueError(
            "native execution path requested but the partition/workload "
            "does not support it (needs concrete span hints + block map; "
            "build the partition outside jit)")
    if request == ExecutionPath.AUTO:
        return (ExecutionPath.NATIVE if native_supported
                else ExecutionPath.PURE)
    return request


def choose_execution_path(part: Partition,
                          request: ExecutionPath | str = ExecutionPath.AUTO
                          ) -> ExecutionPath:
    """The dispatcher's routing rule for a given Partition."""
    return resolve_execution_path(request,
                                  native_supported=supports_native_execution(part))


def tile_reduce(spec: WorkSpec, atom_fn: AtomFn,
                dtype=jnp.float32, *, combiner: str = "sum",
                atom_mask: jax.Array | None = None) -> jax.Array:
    """Oracle: per-tile ``combiner``-reduce of ``atom_fn(atom)`` over atoms.

    ``atom_mask`` (bool ``[num_atoms]``, optional) drops atoms by replacing
    their value with the combiner's identity — the frontier mask of a graph
    advance.  Tiles with no (unmasked) atoms come back as the identity.
    """
    identity = _check_combiner(combiner, dtype)
    atoms = jnp.arange(spec.num_atoms, dtype=jnp.int32)
    values = atom_fn(atoms).astype(dtype)
    if atom_mask is not None:
        values = jnp.where(atom_mask, values, jnp.asarray(identity, dtype))
    return _segment_reduce(combiner, values, spec.atom_tile_ids(),
                           spec.num_tiles)


def _window_sizes(spec: WorkSpec, part: Partition) -> Tuple[int, int]:
    """Static (atom window, local tile window) sizes for blocked execution.

    Preferred source: the span hints captured by ``finalize_partition`` when
    the boundaries were still concrete (under jit the closure-captured
    boundary arrays are tracers, so they cannot be concretised here).
    Fallbacks are schedule-aware worst cases.
    """
    from repro.core.schedules import Schedule

    if part.atom_span is not None:
        window = max(part.atom_span, 1)
    elif part.tile_aligned:
        # items_per_block counts *tiles*; the atom window is data-dependent.
        try:
            window = max(int(jnp.max(part.atom_starts[1:]
                                     - part.atom_starts[:-1])), 1)
        except jax.errors.ConcretizationTypeError:
            window = max(spec.num_atoms, 1)
    else:
        # merge-path / chunked / nonzero-split: items_per_block bounds atoms.
        window = max(int(part.items_per_block), 1)

    # Local tile window: a block touches tiles [tile_starts[b],
    # tile_starts[b+1]] inclusive.  Sizing it from items_per_block alone
    # undercounts when a block's span crosses *empty* tiles (atoms bound
    # work, not tile span) and would silently drop their neighbours' sums.
    if part.tile_span is not None:
        local_tiles = max(part.tile_span, 1)
    else:
        try:
            local_tiles = max(int(jnp.max(part.tile_starts[1:]
                                          - part.tile_starts[:-1])) + 1, 1)
        except jax.errors.ConcretizationTypeError:
            if part.schedule == Schedule.MERGE_PATH:
                # merge items bound atoms + tile markers: span <= items + 1
                local_tiles = max(int(part.items_per_block), 1) + 1
            elif part.tile_aligned and part.schedule not in (
                    Schedule.CHUNKED, Schedule.ADAPTIVE):
                local_tiles = max(int(part.items_per_block), 1) + 1
            else:
                # no static bound relates atoms to tile span: worst case
                local_tiles = spec.num_tiles + 1
    return window, local_tiles


def fixup_partials(spec: WorkSpec, part: Partition, partials: jax.Array,
                   local_tiles: int, combiner: str = "sum") -> jax.Array:
    """Scatter-combine per-chunk partials at their global tile offsets.

    Merrill & Garland's "segmented fixup", adapted: TPU grid blocks cannot
    order-depend, so the fixup is a separate reduction over per-block
    partials.  Shared by the pure-JAX and native Pallas paths so the two are
    reduction-order-identical.  Local-tile bins a block never touched carry
    the combiner's identity, so they drop out of the scatter.
    """
    gtid = part.tile_starts[:-1, None] + jnp.arange(local_tiles,
                                                    dtype=jnp.int32)[None, :]
    gtid = jnp.where(gtid < spec.num_tiles, gtid, spec.num_tiles)  # drop OOB
    return _segment_reduce(combiner, partials.reshape(-1), gtid.reshape(-1),
                           spec.num_tiles + 1)[:-1]


def blocked_tile_reduce(spec: WorkSpec, part: Partition, atom_fn: AtomFn,
                        dtype=jnp.float32, *, combiner: str = "sum",
                        atom_mask: jax.Array | None = None) -> jax.Array:
    """Blocked execution faithful to the partition (pure JAX).

    Shapes are static: each block materializes a ``[items_per_block]`` window
    of atoms (masked past its end) and reduces into at most
    ``items_per_block + 1`` local tiles via a one-hot contraction — the same
    MXU-shaped inner loop as the Pallas kernels.  Cross-block partial tiles
    are resolved by the shared scatter fixup.

    ``combiner`` selects the reduction (``sum``/``min``/``max``);
    ``atom_mask`` (bool ``[num_atoms]``) is the frontier mask of a graph
    advance — masked atoms contribute the combiner's identity, exactly as if
    they were past the block's end.
    """
    identity = _check_combiner(combiner, dtype)
    if spec.num_atoms == 0:
        return jnp.full((spec.num_tiles,), identity, dtype)
    grid = part.num_blocks
    window, local_tiles = _window_sizes(spec, part)

    atom_base = part.atom_starts[:-1]                       # [G]
    idx = atom_base[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    valid = idx < part.atom_starts[1:, None]                # [G, W]
    safe_idx = jnp.clip(idx, 0, max(spec.num_atoms - 1, 0))
    if atom_mask is not None:
        valid = jnp.logical_and(valid, atom_mask[safe_idx])

    values = atom_fn(safe_idx.reshape(-1)).astype(dtype).reshape(grid, window)
    values = jnp.where(valid, values, jnp.asarray(identity, dtype))

    tile_ids = spec.atom_tile_ids()                          # [A]
    tids = tile_ids[safe_idx]                                # [G, W]
    local = tids - part.tile_starts[:-1, None]               # [G, W]
    local = jnp.where(valid, local, local_tiles)             # mask -> OOB bin

    onehot = (local[..., None]
              == jnp.arange(local_tiles, dtype=jnp.int32)[None, None, :])
    if combiner == "sum":
        # One-hot contraction per block: [G, W] x [W, local_tiles] (MXU).
        partials = jnp.einsum("gw,gwl->gl", values, onehot.astype(dtype))
    else:
        # min/max: masked elementwise reduce over the window — no dot
        # product expresses these, but the window/bin shapes are identical
        # to the sum path so the fixup stays shared.
        contrib = jnp.where(onehot, values[..., None],
                            jnp.asarray(identity, dtype))    # [G, W, L]
        partials = (contrib.min(axis=1) if combiner == "min"
                    else contrib.max(axis=1))

    return fixup_partials(spec, part, partials, local_tiles, combiner)


def _chunk_queue_view(part: Partition) -> Tuple[jax.Array, jax.Array, int]:
    """(block_chunks [P, Cmax], counts [P], P) — identity for static parts."""
    if part.block_chunks is not None:
        counts = part.block_chunk_counts
        return part.block_chunks, counts, int(counts.shape[0])
    if part.block_map is not None:
        phys = part.num_physical_blocks or part.num_blocks
        chunks, counts = invert_block_map(part.block_map, phys)
        return chunks, counts, int(counts.shape[0])
    # static schedule: every block is its own single-chunk queue
    n = part.num_blocks
    return (jnp.arange(n, dtype=jnp.int32)[:, None],
            jnp.ones((n,), jnp.int32), n)


def native_chunk_tile_reduce(spec: WorkSpec, part: Partition, atom_fn: AtomFn,
                             dtype=jnp.float32, *, combiner: str = "sum",
                             atom_mask: jax.Array | None = None,
                             interpret: bool = True) -> jax.Array:
    """Device-side execution: the Pallas chunk-walking kernel.

    Materializes the atom transform once (``atom_fn`` over all atoms plus
    the ``atom -> tile`` map), then launches one grid step per *physical*
    block; each walks its scalar-prefetched chunk queue in-kernel (see
    ``repro.kernels.spmv_merge.kernel.chunk_walk_reduce``) and the shared
    fixup resolves cross-chunk partial tiles.  Bit-identical to
    :func:`blocked_tile_reduce` (same windows, same contraction shape, same
    fixup) — asserted by tests across every schedule and combiner.

    ``atom_mask`` rides into the kernel as its own operand (the frontier
    mask of a graph advance): per-iteration frontiers change while the atom
    values/topology windows stay byte-identical, so the mask is the only
    re-streamed input.
    """
    identity = _check_combiner(combiner, dtype)
    if jnp.dtype(dtype) != jnp.dtype(jnp.float32):
        raise ValueError("native path accumulates in float32")
    if spec.num_atoms == 0:
        return jnp.full((spec.num_tiles,), identity, dtype)
    if not supports_native_execution(part):
        raise ValueError("partition does not support the native path "
                         "(see supports_native_execution)")
    from repro.kernels.spmv_merge.kernel import chunk_walk_reduce

    window, local_tiles = _window_sizes(spec, part)
    block_chunks, counts, _ = _chunk_queue_view(part)
    max_chunks = int(block_chunks.shape[1])

    atoms = jnp.arange(spec.num_atoms, dtype=jnp.int32)
    values = atom_fn(atoms).astype(dtype)
    tids = spec.atom_tile_ids()
    # Pad so every chunk's static window read stays in bounds; padded values
    # are masked in-kernel (idx >= atom_starts[c+1]), content irrelevant.
    values = jnp.concatenate([values, jnp.full((window,), identity, dtype)])
    tids = jnp.concatenate(
        [tids, jnp.full((window,), spec.num_tiles, jnp.int32)])
    mask = None
    if atom_mask is not None:
        mask = jnp.concatenate(
            [atom_mask.astype(jnp.int32),
             jnp.zeros((window,), jnp.int32)])

    partials = chunk_walk_reduce(
        values, tids, part.atom_starts.astype(jnp.int32),
        part.tile_starts.astype(jnp.int32),
        block_chunks.reshape(-1).astype(jnp.int32),
        counts.astype(jnp.int32), mask,
        window=window, local_tiles=local_tiles, max_chunks=max_chunks,
        combiner=combiner, interpret=interpret)
    return fixup_partials(spec, part, partials, local_tiles, combiner)


# ---------------------------------------------------------------------------
# Scatter-reduce: balanced value windows combined by arbitrary per-atom
# output ids (the push-direction graph advance).
# ---------------------------------------------------------------------------

def blocked_value_windows(spec: WorkSpec, part: Partition, atom_fn: AtomFn,
                          dtype=jnp.float32, *, combiner: str = "sum",
                          atom_mask: jax.Array | None = None) -> jax.Array:
    """Per-block masked value windows ``[num_blocks, window]`` (pure JAX).

    The first half of a scatter-reduce: each block materializes its
    partition slice of atoms (the same static window discipline as
    :func:`blocked_tile_reduce`), applies the atom transform, and replaces
    atoms past its end — or dropped by ``atom_mask`` — with the combiner's
    identity.  These are the push advance's *frontier-compacted per-source
    partials*: windows follow the (source-tile-grouped) atom order of the
    push view, masked to frontier sources; no local binning happens because
    the output ids (edge destinations) are unrelated to the walked tiles.
    """
    identity = _check_combiner(combiner, dtype)
    grid = part.num_blocks
    window, _ = _window_sizes(spec, part)
    if spec.num_atoms == 0:
        return jnp.full((grid, window), identity, dtype)

    atom_base = part.atom_starts[:-1]                       # [G]
    idx = atom_base[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    valid = idx < part.atom_starts[1:, None]                # [G, W]
    safe_idx = jnp.clip(idx, 0, max(spec.num_atoms - 1, 0))
    if atom_mask is not None:
        valid = jnp.logical_and(valid, atom_mask[safe_idx])
    values = atom_fn(safe_idx.reshape(-1)).astype(dtype).reshape(grid, window)
    return jnp.where(valid, values, jnp.asarray(identity, dtype))


def native_chunk_value_windows(spec: WorkSpec, part: Partition,
                               atom_fn: AtomFn, dtype=jnp.float32, *,
                               combiner: str = "sum",
                               atom_mask: jax.Array | None = None,
                               interpret: bool = True) -> jax.Array:
    """Per-chunk masked value windows via the chunk-walking Pallas kernel.

    The device-side counterpart of :func:`blocked_value_windows`: the same
    grid/queue discipline as :func:`native_chunk_tile_reduce`, with the
    kernel's ``emit="atoms"`` mode writing the masked window itself instead
    of per-tile bins.  Chunk boundaries equal the pure path's logical block
    boundaries (``part.atom_starts``), so both paths produce identical
    windows — the scatter step is shared and the paths stay bit-identical.
    """
    identity = _check_combiner(combiner, dtype)
    if jnp.dtype(dtype) != jnp.dtype(jnp.float32):
        raise ValueError("native path accumulates in float32")
    if not supports_native_execution(part):
        raise ValueError("partition does not support the native path "
                         "(see supports_native_execution)")
    window, local_tiles = _window_sizes(spec, part)
    if spec.num_atoms == 0:
        return jnp.full((part.num_blocks, window), identity, dtype)
    from repro.kernels.spmv_merge.kernel import chunk_walk_reduce

    block_chunks, counts, _ = _chunk_queue_view(part)
    max_chunks = int(block_chunks.shape[1])

    atoms = jnp.arange(spec.num_atoms, dtype=jnp.int32)
    values = atom_fn(atoms).astype(dtype)
    values = jnp.concatenate([values, jnp.full((window,), identity, dtype)])
    mask = None
    if atom_mask is not None:
        mask = jnp.concatenate(
            [atom_mask.astype(jnp.int32),
             jnp.zeros((window,), jnp.int32)])

    # no tile-id operand: atoms mode never bins locally
    return chunk_walk_reduce(
        values, None, part.atom_starts.astype(jnp.int32),
        part.tile_starts.astype(jnp.int32),
        block_chunks.reshape(-1).astype(jnp.int32),
        counts.astype(jnp.int32), mask,
        window=window, local_tiles=local_tiles, max_chunks=max_chunks,
        combiner=combiner, emit="atoms", interpret=interpret)


def scatter_value_windows(spec: WorkSpec, part: Partition,
                          windows: jax.Array, out_ids: jax.Array,
                          num_out: int, combiner: str = "sum") -> jax.Array:
    """Combine value windows by per-atom output ids (``[num_out]`` result).

    The second half of a scatter-reduce and the sibling of
    :func:`fixup_partials`: window slot ``(b, i)`` holds atom
    ``atom_starts[b] + i``, whose output segment is ``out_ids`` of that atom
    (e.g. the edge's *destination* vertex in a push advance — the pull form
    of ``atomicMin`` by destination).  Out-of-range slots and masked atoms
    already carry the combiner's identity, so they drop out of the segmented
    reduce; output segments nothing scatters to come back as the identity,
    exactly like untouched tiles of a tile-reduce.
    """
    window = int(windows.shape[1])
    idx = part.atom_starts[:-1, None] + jnp.arange(window,
                                                   dtype=jnp.int32)[None, :]
    safe_idx = jnp.clip(idx, 0, max(spec.num_atoms - 1, 0))
    gid = jnp.where(idx < spec.num_atoms, out_ids[safe_idx], num_out)
    return _segment_reduce(combiner, windows.reshape(-1), gid.reshape(-1),
                          num_out + 1)[:-1]


# -- gather-compacted active-atom windows (sparse-frontier push mode) -------

def compact_active_atoms(atom_mask: jax.Array,
                         capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Compact a bool atom mask into ``(idx [capacity], count)``.

    ``idx`` lists the active atom ids in ascending order, padded with
    ``num_atoms`` past the true count (so padded slots are recognisably out
    of range); ``count`` is the exact active-atom total, which callers
    compare against ``capacity`` to decide whether the compacted view is
    complete (``jnp.nonzero(size=...)`` silently truncates past it).
    Jit-safe: ``size=`` makes the nonzero shape static.
    """
    num_atoms = int(atom_mask.shape[0])
    (idx,) = jnp.nonzero(atom_mask, size=capacity, fill_value=num_atoms)
    return idx.astype(jnp.int32), jnp.sum(atom_mask.astype(jnp.int32))


def compact_chunk_starts(num_chunks: int, capacity: int) -> jax.Array:
    """Even chunk boundaries over ``[0, capacity]`` compacted slots.

    Compacted atoms are interchangeable units of equal cost, so the even
    split *is* the balanced partition — frontier skew was flattened by the
    gather.  The chunk count mirrors the partition's own so the dynamic
    schedules' queue discipline (``block_chunks``) applies unchanged.
    """
    per = -(-max(capacity, 1) // max(num_chunks, 1))
    return jnp.minimum(jnp.arange(num_chunks + 1, dtype=jnp.int32) * per,
                       capacity)


def _compact_window(num_chunks: int, capacity: int) -> int:
    return -(-max(capacity, 1) // max(num_chunks, 1))


def _compact_slot_view(spec: WorkSpec, idx: jax.Array, num_chunks: int,
                       window: int):
    """Shared slot -> atom addressing of the compacted windows.

    Returns ``(a, valid, safe_a)`` for the ``[num_chunks, window]`` slot
    grid: the compacted atom id per slot, whether the slot holds a real
    active atom (in-chunk and in-range), and a clamped id safe to gather
    with.  The window producers and :func:`scatter_compact_windows` MUST
    agree on this mapping — that is the whole correctness coupling of the
    compact mode, so it lives in exactly one place.
    """
    capacity = int(idx.shape[0])
    starts = compact_chunk_starts(num_chunks, capacity)
    slot = starts[:-1, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    a = idx[jnp.clip(slot, 0, capacity - 1)]
    valid = jnp.logical_and(slot < starts[1:, None], a < spec.num_atoms)
    safe_a = jnp.clip(a, 0, max(spec.num_atoms - 1, 0))
    return a, valid, safe_a


def blocked_compact_value_windows(spec: WorkSpec, part: Partition,
                                  atom_fn: AtomFn, idx: jax.Array,
                                  dtype=jnp.float32, *,
                                  combiner: str = "sum") -> jax.Array:
    """Per-chunk value windows over a *compacted* active-atom list (pure).

    The sparse-frontier sibling of :func:`blocked_value_windows`: window
    slot ``(c, i)`` holds the value of compacted atom
    ``idx[compact_chunk_starts(c) + i]`` — only active atoms occupy slots,
    so the streamed window volume is the capacity, not the edge count.
    Padded index slots (``idx`` carries ``num_atoms`` past the true active
    count) come back as the combiner's identity.
    """
    identity = _check_combiner(combiner, dtype)
    num_chunks = int(part.atom_starts.shape[0]) - 1
    window = _compact_window(num_chunks, int(idx.shape[0]))
    _, valid, safe_a = _compact_slot_view(spec, idx, num_chunks, window)
    values = atom_fn(safe_a.reshape(-1)).astype(dtype).reshape(num_chunks,
                                                               window)
    return jnp.where(valid, values, jnp.asarray(identity, dtype))


def native_compact_value_windows(spec: WorkSpec, part: Partition,
                                 atom_fn: AtomFn, idx: jax.Array,
                                 dtype=jnp.float32, *,
                                 combiner: str = "sum",
                                 interpret: bool = True) -> jax.Array:
    """Compacted value windows via the chunk-walking kernel's gather mode.

    Same chunk/queue discipline as :func:`native_chunk_value_windows`, with
    ``emit="compact"``: the kernel walks even chunk splits of the compacted
    index list and gathers each slot's value through the indirection —
    streaming only active atoms.  Chunk boundaries equal the pure path's,
    so both paths produce identical windows and share one
    :func:`scatter_compact_windows` call.
    """
    identity = _check_combiner(combiner, dtype)
    if jnp.dtype(dtype) != jnp.dtype(jnp.float32):
        raise ValueError("native path accumulates in float32")
    if not supports_native_execution(part):
        raise ValueError("partition does not support the native path "
                         "(see supports_native_execution)")
    from repro.kernels.spmv_merge.kernel import chunk_walk_reduce

    num_chunks = int(part.atom_starts.shape[0]) - 1
    capacity = int(idx.shape[0])
    window = _compact_window(num_chunks, capacity)
    starts = compact_chunk_starts(num_chunks, capacity)
    block_chunks, counts, _ = _chunk_queue_view(part)
    max_chunks = int(block_chunks.shape[1])

    atoms = jnp.arange(spec.num_atoms, dtype=jnp.int32)
    values = atom_fn(atoms).astype(dtype)
    # identity padding doubles as the gather target of padded index slots
    values = jnp.concatenate([values, jnp.full((window,), identity, dtype)])
    idx_padded = jnp.concatenate(
        [jnp.minimum(idx, spec.num_atoms),      # padded ids -> identity slot
         jnp.full((window,), spec.num_atoms, jnp.int32)])

    return chunk_walk_reduce(
        values, None, starts.astype(jnp.int32),
        jnp.zeros_like(starts),                  # no tile structure
        block_chunks.reshape(-1).astype(jnp.int32),
        counts.astype(jnp.int32), None, idx_padded,
        window=window, local_tiles=1, max_chunks=max_chunks,
        combiner=combiner, emit="compact", interpret=interpret)


def scatter_compact_windows(spec: WorkSpec, windows: jax.Array,
                            idx: jax.Array, out_ids: jax.Array,
                            num_out: int, combiner: str = "sum") -> jax.Array:
    """Combine compacted value windows by per-atom output ids.

    The compact-mode sibling of :func:`scatter_value_windows`: window slot
    ``(c, i)`` holds compacted atom ``idx[starts[c] + i]``, whose output
    segment is that atom's ``out_ids`` entry.  Padded/out-of-range slots
    already carry the combiner's identity and are routed to the dropped
    overflow segment.  Active atoms keep their ascending order, so for the
    exact combiners — and exactly-summable values — results are
    bit-identical to the masked full-window scatter.
    """
    num_chunks, window = int(windows.shape[0]), int(windows.shape[1])
    _, valid, safe_a = _compact_slot_view(spec, idx, num_chunks, window)
    gid = jnp.where(valid, out_ids[safe_a], num_out)
    return _segment_reduce(combiner, windows.reshape(-1), gid.reshape(-1),
                           num_out + 1)[:-1]


def execute_scatter_reduce(spec: WorkSpec, part: Partition, atom_fn: AtomFn,
                           out_ids: jax.Array, num_out: int,
                           dtype=jnp.float32, *,
                           path: ExecutionPath | str = ExecutionPath.AUTO,
                           combiner: str = "sum",
                           atom_mask: jax.Array | None = None,
                           compact_capacity: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """One API over both scatter-reduce executors (the push-advance call).

    Balanced per-atom value production over ``spec``/``part`` (any schedule,
    either execution path — same routing rule as
    :func:`execute_tile_reduce`) followed by the shared destination scatter.
    ``out_ids`` (int32 ``[num_atoms]``) names each atom's output segment in
    ``[0, num_out)``; ``atom_mask`` drops atoms exactly as in a tile-reduce.
    Because both paths produce identical windows and share one
    :func:`scatter_value_windows` call, results are bit-identical across
    every schedule x path, and — for exact combiners (min/max) or
    exactly-summable values — to the corresponding pull-direction
    tile-reduce over the same edge multiset.

    ``compact_capacity`` (static int, requires ``atom_mask``) enables the
    gather-compacted window mode: the active atoms are compacted into a
    ``capacity``-slot index list and only those slots are streamed — the
    ROADMAP's frontier compaction.  When the runtime active count exceeds
    the capacity, a ``lax.cond`` falls back to the masked full-window mode,
    so any capacity is *correct*; a well-chosen one (see
    :func:`repro.core.balance.estimate_compact_capacity`) is merely fast.
    Both modes share the segmented scatter in ascending atom order, so
    results stay bit-identical for exact combiners and exactly-summable
    values.
    """
    identity = _check_combiner(combiner, dtype)
    if spec.num_atoms == 0:
        return jnp.full((num_out,), identity, dtype)
    native_ok = (supports_native_execution(part)
                 and jnp.dtype(dtype) == jnp.dtype(jnp.float32))
    resolved = resolve_execution_path(path, native_supported=native_ok)

    def masked(_=None):
        if resolved == ExecutionPath.NATIVE:
            windows = native_chunk_value_windows(spec, part, atom_fn, dtype,
                                                 combiner=combiner,
                                                 atom_mask=atom_mask,
                                                 interpret=interpret)
        else:
            windows = blocked_value_windows(spec, part, atom_fn, dtype,
                                            combiner=combiner,
                                            atom_mask=atom_mask)
        return scatter_value_windows(spec, part, windows, out_ids, num_out,
                                     combiner)

    if compact_capacity is None or atom_mask is None:
        return masked()
    capacity = int(min(max(int(compact_capacity), 1), spec.num_atoms))
    idx, count = compact_active_atoms(atom_mask, capacity)

    def compact(_):
        if resolved == ExecutionPath.NATIVE:
            windows = native_compact_value_windows(spec, part, atom_fn, idx,
                                                   dtype, combiner=combiner,
                                                   interpret=interpret)
        else:
            windows = blocked_compact_value_windows(spec, part, atom_fn, idx,
                                                    dtype, combiner=combiner)
        return scatter_compact_windows(spec, windows, idx, out_ids, num_out,
                                       combiner)

    return jax.lax.cond(count <= capacity, compact, masked, operand=None)


def execute_tile_reduce(spec: WorkSpec, part: Partition, atom_fn: AtomFn,
                        dtype=jnp.float32, *,
                        path: ExecutionPath | str = ExecutionPath.AUTO,
                        combiner: str = "sum",
                        atom_mask: jax.Array | None = None,
                        interpret: bool = True) -> jax.Array:
    """One API over both executors — the dispatcher the ops layers call.

    Routes any Partition (static, chunked_rr/chunked_lpt, adaptive) to the
    native Pallas chunk-walking kernel or the pure-JAX blocked executor.
    ``path="auto"`` prefers native exactly when the partition supports it
    (concrete span hints; invertible block map) *and* the requested dtype
    is float32 (the native kernel's accumulator); other dtypes fall back
    to the pure executor rather than raise.  ``combiner``/``atom_mask``
    (sum/min/max; frontier mask) apply identically on either path — this is
    what lets graph advance ride every schedule unchanged.
    """
    native_ok = (supports_native_execution(part)
                 and jnp.dtype(dtype) == jnp.dtype(jnp.float32))
    resolved = resolve_execution_path(path, native_supported=native_ok)
    if resolved == ExecutionPath.NATIVE:
        return native_chunk_tile_reduce(spec, part, atom_fn, dtype,
                                        combiner=combiner,
                                        atom_mask=atom_mask,
                                        interpret=interpret)
    return blocked_tile_reduce(spec, part, atom_fn, dtype,
                               combiner=combiner, atom_mask=atom_mask)


# ---------------------------------------------------------------------------
# Shard-local dispatch (multi-device: the same executors one level up)
# ---------------------------------------------------------------------------

#: Cross-device collective matching each combiner — the shard-level
#: continuation of a scatter reduce.  Exactly the pairing that keeps the
#: sharded result bit-identical to single-device: min/max collectives are
#: exact, and psum of disjoint per-shard contributions (every shard holds
#: identity except the edge owners) adds identity elements bit-exactly.
COMBINER_COLLECTIVE = {"sum": jax.lax.psum, "min": jax.lax.pmin,
                       "max": jax.lax.pmax}


def execute_sharded_tile_reduce(spec: WorkSpec, part: Partition,
                                atom_fn: AtomFn, dtype=jnp.float32, *,
                                axis_name: str = "shard",
                                path: ExecutionPath | str = ExecutionPath.AUTO,
                                combiner: str = "sum",
                                atom_mask: jax.Array | None = None,
                                interpret: bool = True) -> jax.Array:
    """:func:`execute_tile_reduce` inside a ``shard_map`` body.

    The pull-direction shard contract: each shard's local spec owns *all*
    atoms (in-edges) of its own tiles (destinations), so the local reduce is
    already the final per-tile answer — no collective is needed and the
    result bits come from exactly the same executor call a single device
    makes.  ``axis_name`` is accepted (and ignored) so both directions share
    a call shape; it documents that this runs under a mesh axis.
    """
    del axis_name  # pull owns all in-edges of its tiles; purely local
    return execute_tile_reduce(spec, part, atom_fn, dtype, path=path,
                               combiner=combiner, atom_mask=atom_mask,
                               interpret=interpret)


def execute_sharded_scatter_reduce(spec: WorkSpec, part: Partition,
                                   atom_fn: AtomFn, out_ids: jax.Array,
                                   num_out: int, dtype=jnp.float32, *,
                                   axis_name: str = "shard",
                                   path: ExecutionPath | str =
                                   ExecutionPath.AUTO,
                                   combiner: str = "sum",
                                   atom_mask: jax.Array | None = None,
                                   compact_capacity: int | None = None,
                                   interpret: bool = True) -> jax.Array:
    """:func:`execute_scatter_reduce` inside a ``shard_map`` body.

    The push-direction shard contract: each shard streams only its own
    out-edges but their destinations land anywhere, so every shard produces
    a full ``[num_out]`` partial (identity at untouched destinations) and
    the partials combine across the mesh axis with the combiner's matching
    collective (:data:`COMBINER_COLLECTIVE`).  Per shard the pure/native
    paths stay bit-identical (same single-device dispatcher); the collective
    is exact for min/max and adds disjoint-support partials exactly for sum,
    so the sharded result matches single-device bitwise under the same
    conditions the two directions match each other.
    """
    partial = execute_scatter_reduce(spec, part, atom_fn, out_ids, num_out,
                                     dtype, path=path, combiner=combiner,
                                     atom_mask=atom_mask,
                                     compact_capacity=compact_capacity,
                                     interpret=interpret)
    return COMBINER_COLLECTIVE[combiner](partial, axis_name)
