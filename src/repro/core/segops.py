"""Segmented primitives used by work-execution stages.

Two families:

* ``segment_*`` — XLA scatter-based segmented reductions (the portable
  oracle path, also used directly when the segment structure is dynamic).
* ``onehot_segment_sum`` — the MXU-shaped path: a ``[atoms, tiles]`` one-hot
  matmul performs the per-tile reduction on the systolic array.  This is the
  TPU-native replacement for the GPU's warp-cooperative segmented reductions
  and is what the Pallas kernels use per block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(values: jax.Array, segment_ids: jax.Array,
                num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)


def segment_max(values: jax.Array, segment_ids: jax.Array,
                num_segments: int) -> jax.Array:
    return jax.ops.segment_max(values, segment_ids, num_segments=num_segments)


def segment_count(segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jnp.bincount(segment_ids, length=num_segments).astype(jnp.int32)


def onehot_segment_sum(values: jax.Array, local_ids: jax.Array,
                       num_segments: int,
                       dtype=jnp.float32) -> jax.Array:
    """Per-segment sum via one-hot matmul: ``onehot.T @ values``.

    ``values``: ``[n]`` or ``[n, d]``; ``local_ids``: ``[n]`` int ids in
    ``[0, num_segments)`` (ids outside the range contribute nothing, which
    the kernels exploit for masking).  Cost is ``n * num_segments`` MACs —
    MXU-aligned when both are multiples of 128.
    """
    onehot = (local_ids[:, None] == jnp.arange(num_segments,
                                               dtype=local_ids.dtype)[None, :])
    onehot = onehot.astype(dtype)
    if values.ndim == 1:
        return onehot.T @ values.astype(dtype)
    return jnp.einsum("ns,nd->sd", onehot, values.astype(dtype))


def segment_softmax(logits: jax.Array, segment_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    """Numerically stable per-segment softmax (used by graph kernels)."""
    seg_max = segment_max(logits, segment_ids, num_segments)
    shifted = logits - seg_max[segment_ids]
    exp = jnp.exp(shifted)
    denom = segment_sum(exp, segment_ids, num_segments)
    return exp / jnp.maximum(denom[segment_ids], 1e-30)


def exclusive_cumsum(x: jax.Array, axis: int = -1) -> jax.Array:
    """Exclusive prefix sum — the group-mapped schedule's setup primitive."""
    inclusive = jnp.cumsum(x, axis=axis)
    return inclusive - x
