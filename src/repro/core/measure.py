"""Library-level wall-clock measurement for the measured-cost feedback loop.

The cost models in :mod:`repro.core.balance` are hand-set lockstep-step
counts; on hardware the model has never seen, the only ground truth is a
wall clock.  This module is the one place the repo times anything: the
benchmark harness (``benchmarks/_timing`` re-exports from here) and the
autotuner's measured mode (:func:`repro.core.autotune.select_plan` with
``measure=``) share the same helper, so every recorded microsecond obeys
the same warmup/median discipline and the same counter instrumentation.

The warmup contract
-------------------

``time_fn`` reports *steady-state* medians.  JAX callables pay their
tracing + compilation cost on the **first** call (and jitted callables may
re-trace on fresh shapes), so at least one warmup call is mandatory — it is
what isolates compile time from the steady state being measured.  Callers
passing an *unjitted* function still need the warmup: the first call
triggers any lazy constant foldings / op-by-op dispatch caches.  The
helper therefore **enforces** ``warmup >= 1`` and ``iters >= 1`` with a
clear error instead of silently returning a compile-polluted number (the
pre-PR-6 ``benchmarks/_timing.time_fn`` accepted ``warmup=0`` and would
happily report a median dominated by compilation).

Measurement counting
--------------------

Every ``time_fn`` call bumps a module-level counter,
:func:`measurement_count` — the regression hook tests use to assert the
autotuner's persisted measurements are *reused* on reload rather than
re-taken (measuring is the expensive step the v2 cache exists to amortize).
"""
from __future__ import annotations

import math
import time
from typing import Iterable

import jax

_measurement_count = 0


def measurement_count() -> int:
    """Total ``time_fn`` invocations in this process (re-measurement hook)."""
    return _measurement_count


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median steady-state wall-time (us) of a callable, blocked until ready.

    ``warmup`` calls run first and are discarded — they absorb trace +
    compile (see the module docstring's warmup contract; ``warmup >= 1``
    and ``iters >= 1`` are enforced).  The reported number is the median of
    ``iters`` timed calls, each blocked with ``jax.block_until_ready`` so
    async dispatch cannot leak work past the clock.
    """
    if warmup < 1:
        raise ValueError(
            f"time_fn needs warmup >= 1 (got {warmup}): the first call pays "
            f"trace/compile, which must not pollute the steady-state median")
    if iters < 1:
        raise ValueError(f"time_fn needs iters >= 1 (got {iters})")
    global _measurement_count
    _measurement_count += 1
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def geomean(xs: Iterable[float]) -> float:
    """Geometric mean of positive samples; empty input is a loud error.

    The benchmark summaries aggregate speedup *ratios*, where the geometric
    mean is the only mean that commutes with inversion.  An empty sweep is
    a harness bug (``exp(0/0)`` territory), not a statistic — raise rather
    than return garbage.  Values are floored at 1e-12 so a zero-time ratio
    degrades gracefully instead of taking ``log(0)``.
    """
    xs = list(xs)
    if not xs:
        raise ValueError("geomean of an empty sequence is undefined "
                         "(empty benchmark sweep?)")
    xs = [max(float(x), 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))
