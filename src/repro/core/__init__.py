"""repro.core — the paper's load-balancing abstraction, TPU-native.

Pipeline (paper Fig. 1): sparse input -> :class:`WorkSpec` (atoms/tiles) ->
:class:`Partition` via a :class:`Schedule` -> work execution (executors here,
Pallas kernels in :mod:`repro.kernels`).
"""
from repro.core.work import WorkSpec, validate_workspec
from repro.core.schedules import (
    Partition,
    Schedule,
    group_mapped_partition,
    invert_block_map,
    make_partition,
    merge_path_partition,
    nonzero_split_partition,
    partition_build_count,
    tile_mapped_partition,
)
from repro.core.execute import (
    COMBINER_IDENTITY,
    ExecutionPath,
    blocked_compact_value_windows,
    blocked_tile_reduce,
    blocked_value_windows,
    choose_execution_path,
    compact_active_atoms,
    compact_chunk_starts,
    execute_scatter_reduce,
    execute_tile_reduce,
    native_chunk_tile_reduce,
    native_chunk_value_windows,
    native_compact_value_windows,
    resolve_execution_path,
    scatter_compact_windows,
    scatter_value_windows,
    supports_native_execution,
    tile_reduce,
)
from repro.core.balance import (
    ADVANCE_ATOM_WORK,
    ADVANCE_DELTA_ATOM_WORK,
    ADVANCE_DELTA_PUSH_ATOM_WORK,
    ADVANCE_PUSH_ATOM_WORK,
    COMPACT_GATHER_WORK,
    ImbalanceStats,
    block_cost_terms,
    choose_schedule,
    estimate_compact_capacity,
    estimate_direction_threshold,
    landscape,
    modeled_advance_cost,
    modeled_block_cost,
    modeled_cost,
)
from repro.core.dynamic import (
    adaptive_inspection_count,
    adaptive_partition,
    assign_chunks,
    chunked_partition,
    clear_adaptive_cache,
)
from repro.core.autotune import (
    AutotuneCache,
    Plan,
    REGISTERED_PLANS,
    REGISTERED_SCHEDULES,
    WORKLOAD_ATOM_WORK,
    score_plans,
    score_schedules,
    select_plan,
    select_schedule,
)
from repro.core import segops

__all__ = [
    "WorkSpec", "validate_workspec", "Partition", "Schedule",
    "make_partition", "merge_path_partition", "nonzero_split_partition",
    "tile_mapped_partition", "group_mapped_partition", "invert_block_map",
    "partition_build_count",
    "chunked_partition", "adaptive_partition", "assign_chunks",
    "adaptive_inspection_count", "clear_adaptive_cache",
    "tile_reduce", "blocked_tile_reduce", "execute_tile_reduce",
    "native_chunk_tile_reduce", "ExecutionPath", "choose_execution_path",
    "resolve_execution_path", "supports_native_execution",
    "COMBINER_IDENTITY",
    "blocked_value_windows", "native_chunk_value_windows",
    "scatter_value_windows", "execute_scatter_reduce",
    "blocked_compact_value_windows", "native_compact_value_windows",
    "scatter_compact_windows", "compact_active_atoms", "compact_chunk_starts",
    "ImbalanceStats", "ADVANCE_ATOM_WORK", "ADVANCE_PUSH_ATOM_WORK",
    "ADVANCE_DELTA_ATOM_WORK", "ADVANCE_DELTA_PUSH_ATOM_WORK",
    "COMPACT_GATHER_WORK", "estimate_compact_capacity",
    "modeled_advance_cost", "block_cost_terms",
    "estimate_direction_threshold",
    "choose_schedule", "landscape", "modeled_block_cost", "modeled_cost",
    "AutotuneCache", "Plan", "REGISTERED_PLANS", "REGISTERED_SCHEDULES",
    "WORKLOAD_ATOM_WORK",
    "score_plans", "score_schedules", "select_plan", "select_schedule",
    "segops",
]
