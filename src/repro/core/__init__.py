"""repro.core — the paper's load-balancing abstraction, TPU-native.

Pipeline (paper Fig. 1): sparse input -> :class:`WorkSpec` (atoms/tiles) ->
:class:`Partition` via a :class:`Schedule` -> work execution (executors here,
Pallas kernels in :mod:`repro.kernels`).
"""
from repro.core.work import WorkSpec, validate_workspec
from repro.core.schedules import (
    Partition,
    Schedule,
    group_mapped_partition,
    make_partition,
    merge_path_partition,
    nonzero_split_partition,
    tile_mapped_partition,
)
from repro.core.execute import blocked_tile_reduce, tile_reduce
from repro.core.balance import (
    ImbalanceStats,
    choose_schedule,
    landscape,
    modeled_block_cost,
    modeled_cost,
)
from repro.core.dynamic import (
    adaptive_partition,
    assign_chunks,
    chunked_partition,
)
from repro.core.autotune import (
    AutotuneCache,
    REGISTERED_SCHEDULES,
    score_schedules,
    select_schedule,
)
from repro.core import segops

__all__ = [
    "WorkSpec", "validate_workspec", "Partition", "Schedule",
    "make_partition", "merge_path_partition", "nonzero_split_partition",
    "tile_mapped_partition", "group_mapped_partition",
    "chunked_partition", "adaptive_partition", "assign_chunks",
    "tile_reduce", "blocked_tile_reduce", "ImbalanceStats",
    "choose_schedule", "landscape", "modeled_block_cost", "modeled_cost",
    "AutotuneCache", "REGISTERED_SCHEDULES", "score_schedules",
    "select_schedule",
    "segops",
]
