"""Work definition: atoms, tiles and tile sets (paper §3.1).

The paper maps sparse data structures onto a three-level vocabulary:

* **work atom** — a single schedulable unit of work (e.g. one non-zero of a
  sparse matrix, one routed (token, expert) pair of an MoE layer).
* **work tile** — a logical set of atoms (e.g. one matrix row, one expert).
  Tiles have *variable* cost; atoms are assumed equal-cost.
* **tile set** — the whole problem; tiles are independent and parallelizable.

On the GPU the paper expresses these as C++ iterators.  The TPU-native
encoding is a single *segment-offset array*: ``tile_offsets[t]`` is the index
of the first atom of tile ``t`` (so tile ``t`` owns atoms
``[tile_offsets[t], tile_offsets[t+1])``).  Every sparse format supported by
the framework lowers to this encoding, after which all load-balancing
schedules (:mod:`repro.core.schedules`) apply uniformly — the separation of
concerns that is the paper's core contribution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class WorkSpec:
    """A tile set: ``num_tiles`` tiles over ``num_atoms`` atoms.

    ``tile_offsets`` is an int32 array of shape ``[num_tiles + 1]`` with
    ``tile_offsets[0] == 0`` and ``tile_offsets[-1] == num_atoms``.  Empty
    tiles (repeated offsets) are legal and common (e.g. empty matrix rows).

    ``num_atoms``/``num_tiles`` are *static* Python ints: schedules use them
    to size grids and blocks at trace time.
    """

    tile_offsets: jax.Array  # int32 [num_tiles + 1]
    num_atoms: int
    num_tiles: int

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.tile_offsets,), (self.num_atoms, self.num_tiles)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (tile_offsets,) = children
        num_atoms, num_tiles = aux
        return cls(tile_offsets=tile_offsets, num_atoms=num_atoms,
                   num_tiles=num_tiles)

    # -- constructors (the "input from sparse data structures" stage) -------
    @classmethod
    def from_segment_offsets(cls, offsets: jax.Array, *, num_atoms: int,
                             num_tiles: Optional[int] = None) -> "WorkSpec":
        offsets = jnp.asarray(offsets, jnp.int32)
        if num_tiles is None:
            num_tiles = int(offsets.shape[0]) - 1
        return cls(tile_offsets=offsets, num_atoms=int(num_atoms),
                   num_tiles=int(num_tiles))

    @classmethod
    def from_csr(cls, row_offsets: jax.Array, nnz: int) -> "WorkSpec":
        """CSR: atoms = non-zeros, tiles = rows (paper Listing 1)."""
        return cls.from_segment_offsets(row_offsets, num_atoms=nnz)

    @classmethod
    def from_segment_sizes(cls, sizes: jax.Array, *, num_atoms: int) -> "WorkSpec":
        """E.g. MoE: ``sizes[e]`` = number of tokens routed to expert ``e``."""
        sizes = jnp.asarray(sizes, jnp.int32)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes, dtype=jnp.int32)])
        return cls.from_segment_offsets(offsets, num_atoms=num_atoms,
                                        num_tiles=int(sizes.shape[0]))

    @classmethod
    def from_sorted_tile_ids(cls, tile_ids: jax.Array, *, num_tiles: int,
                             num_atoms: int) -> "WorkSpec":
        """COO-style: per-atom tile ids (must be sorted ascending)."""
        sizes = jnp.bincount(tile_ids, length=num_tiles).astype(jnp.int32)
        return cls.from_segment_sizes(sizes, num_atoms=num_atoms)

    # -- derived quantities --------------------------------------------------
    def atoms_per_tile(self) -> jax.Array:
        """The paper's ``atoms_per_tile`` transform iterator (Listing 1)."""
        return self.tile_offsets[1:] - self.tile_offsets[:-1]

    def atom_tile_ids(self) -> jax.Array:
        """Map atom index -> owning tile id, shape [num_atoms].

        ``tile_of(a) = max { t : tile_offsets[t] <= a }``.  Uses a single
        vectorized ``searchsorted`` — the TPU replacement for the per-thread
        binary search the paper performs inside ``get_tile(atom_id)``.
        """
        atoms = jnp.arange(self.num_atoms, dtype=jnp.int32)
        return (jnp.searchsorted(self.tile_offsets, atoms, side="right")
                .astype(jnp.int32) - 1)

    def total_work(self) -> int:
        """Merge-path work measure: one unit per atom + one per tile."""
        return self.num_atoms + self.num_tiles


def validate_workspec(spec: WorkSpec) -> None:
    """Host-side structural validation (used by tests and data loaders)."""
    off = np.asarray(spec.tile_offsets)
    assert off.ndim == 1 and off.shape[0] == spec.num_tiles + 1, "offset shape"
    assert off[0] == 0, "offsets must start at 0"
    assert off[-1] == spec.num_atoms, "offsets must end at num_atoms"
    assert np.all(np.diff(off) >= 0), "offsets must be non-decreasing"
