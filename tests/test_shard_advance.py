"""Sharded advance conformance: multi-device == single-device == oracle.

The sharded plan pair (:mod:`repro.sparse.shard`) must be a *pure
decomposition*: partitioning the vertex set over a ``("shard",)`` mesh,
exchanging frontier halos with collectives, and recombining per-shard
results must reproduce the single-device drivers **bitwise** — same
reduction order per destination (the contiguous-slice property), same
direction switches (the density threshold is computed globally), same
f32 rounding in every relaxation.

Run the full matrix on forced host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_shard_advance.py

On a single device the multi-shard cases skip and the suite degrades to
the 1-shard == unsharded contract plus construction/validation logic.

``REPRO_TEST_BOUNDARY`` (default ``equal_width``) selects the boundary
schedule the main acceptance matrix builds with — CI's ``multi-device``
job runs the whole file once per registered schedule.  The
``TestBoundarySchedules`` class additionally sweeps all schedules
unconditionally, so even a single matrix leg covers every one.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Schedule
from repro.core.balance import modeled_sharded_cost
from repro.launch.mesh import make_graph_mesh
from repro.sparse import (CSR, SHARD_SCHEDULES, Graph, ShardedAdvancePlan,
                          bfs, bfs_multi, build_advance,
                          build_sharded_advance, delta_stepping, pagerank,
                          shard_boundaries, sharded_bfs, sharded_bfs_multi,
                          sharded_delta_stepping, sharded_pagerank,
                          sharded_sssp, sssp)
from _conformance import (SCHEDULE_PATH_CASES, adversarial_graphs,
                          assert_bitwise_equal, np_bfs, np_delta_stepping,
                          np_pagerank, np_sssp, powerlaw_graph_dense,
                          shard_slices)

_NDEV = len(jax.devices())
_BOUNDARY = os.environ.get("REPRO_TEST_BOUNDARY", "equal_width")
assert _BOUNDARY in SHARD_SCHEDULES, _BOUNDARY


def _counts(*counts):
    """Parametrize over shard counts, skipping those the host can't mesh."""
    return [pytest.param(s, marks=pytest.mark.skipif(
        _NDEV < s, reason=f"needs {s} devices ({_NDEV} available)"),
        id=f"s{s}") for s in counts]


MULTI_COUNTS = _counts(2, 4, 8)
ALL_COUNTS = _counts(1, 2, 4, 8)

_WEIGHTS = powerlaw_graph_dense(24, avg_degree=3.0, seed=7)
_GRAPH = Graph(CSR.from_dense(_WEIGHTS))


def _build(graph, num_shards, **kw):
    """Build a sharded plan under the CI matrix's boundary schedule."""
    kw.setdefault("shard_schedule", _BOUNDARY)
    return build_sharded_advance(graph, num_shards, **kw)


def _dyadic_weights(V: int = 32, seed: int = 1) -> np.ndarray:
    """Unit weights, power-of-two out-degrees: PageRank stays dyadic, so
    the damping=0.5 power iteration is bit-exact in any summation order."""
    rng = np.random.default_rng(seed)
    deg = 2 ** rng.integers(0, 3, V)
    w = np.zeros((V, V), np.float32)
    for i in range(V):
        cols = rng.choice([c for c in range(V) if c != i], size=deg[i],
                          replace=False)
        w[i, cols] = 1.0
    return w


class TestShardedMatchesSingleDevice:
    """The CI acceptance matrix: >= 3 shard counts x all 6 schedules x
    both execution paths, bit-identical to the single-device drivers."""

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("schedule,path", SCHEDULE_PATH_CASES)
    def test_bfs_bitwise(self, num_shards, schedule, path):
        splan = _build(_GRAPH, num_shards, schedule=schedule, path=path,
                       num_blocks=4)
        want_d, want_p = bfs(_GRAPH, 0, schedule=schedule, path=path,
                             num_blocks=4, return_parents=True)
        got_d, got_p = sharded_bfs(splan, 0, return_parents=True)
        np.testing.assert_array_equal(got_d, want_d)
        np.testing.assert_array_equal(got_p, want_p)
        oracle_d, oracle_p = np_bfs(_WEIGHTS, 0)
        np.testing.assert_array_equal(got_d, oracle_d)
        np.testing.assert_array_equal(got_p, oracle_p)

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("schedule,path", SCHEDULE_PATH_CASES)
    def test_sssp_bitwise(self, num_shards, schedule, path):
        splan = _build(_GRAPH, num_shards, schedule=schedule, path=path,
                       num_blocks=4)
        want = sssp(_GRAPH, 0, schedule=schedule, path=path, num_blocks=4)
        got = sharded_sssp(splan, 0)
        assert_bitwise_equal(got, want, f"sssp s{num_shards} {schedule}")
        np.testing.assert_allclose(np.asarray(got), np_sssp(_WEIGHTS, 0),
                                   rtol=1e-6)

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("schedule,path", SCHEDULE_PATH_CASES)
    def test_pagerank_dyadic_bitwise(self, num_shards, schedule, path):
        w = _dyadic_weights()
        g = Graph(CSR.from_dense(w))
        splan = _build(g, num_shards, schedule=schedule, path=path,
                       num_blocks=4)
        want = pagerank(g, damping=0.5, num_iters=3, tol=0.0,
                        schedule=schedule, path=path, num_blocks=4)
        got = sharded_pagerank(splan, damping=0.5, num_iters=3, tol=0.0)
        assert_bitwise_equal(got, want, f"pagerank s{num_shards} {schedule}")

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("direction", ["auto", "pull", "push"])
    def test_direction_policies_bitwise(self, num_shards, direction):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        want_d = bfs(_GRAPH, 0, schedule="merge_path", path="pure",
                     num_blocks=4, direction=direction)
        got_d = sharded_bfs(splan, 0, direction=direction)
        np.testing.assert_array_equal(got_d, want_d)
        want_s = sssp(_GRAPH, 0, schedule="merge_path", path="pure",
                      num_blocks=4, direction=direction)
        assert_bitwise_equal(sharded_sssp(splan, 0, direction=direction),
                             want_s, f"sssp dir={direction}")


class TestShardedDeltaStepping:
    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("schedule,path",
                             [("merge_path", "pure"), ("chunked", "native"),
                              ("group_mapped", "pure")])
    def test_delta_bitwise_vs_single_device(self, num_shards, schedule, path):
        splan = _build(_GRAPH, num_shards, schedule=schedule, path=path,
                       num_blocks=4, delta="auto")
        want = delta_stepping(_GRAPH, 0, schedule=schedule, path=path,
                              num_blocks=4, compact=None)
        got = sharded_delta_stepping(splan, 0)
        assert_bitwise_equal(got, want, f"delta s{num_shards} {schedule}")
        assert_bitwise_equal(got, np_delta_stepping(_WEIGHTS, 0),
                             "delta vs oracle")

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_explicit_delta_width(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4, delta=3.0)
        want = delta_stepping(_GRAPH, 0, delta=3.0, schedule="merge_path",
                              path="pure", num_blocks=4, compact=None)
        assert_bitwise_equal(sharded_delta_stepping(splan, 0, delta=3.0),
                             want, "explicit delta width")
        assert_bitwise_equal(sharded_delta_stepping(splan, 0, delta=3.0),
                             np_delta_stepping(_WEIGHTS, 0, 3.0),
                             "explicit delta vs oracle")

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_with_delta_rebuilds_light_masks(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        assert splan.delta is None
        widened = splan.with_delta(None)     # None -> estimate from weights
        assert widened.delta is not None and widened.delta > 0
        want = delta_stepping(_GRAPH, 0, schedule="merge_path", path="pure",
                              num_blocks=4, compact=None)
        assert_bitwise_equal(sharded_delta_stepping(widened, 0), want,
                             "with_delta rebuild")


class TestMeshGlobalCompactCapacity:
    """``compact=`` must resolve against the *global* edge count, exactly
    as the single-device builder resolves it — not against any per-shard
    padded edge count, which varies with the mesh size (PR 7 remainder)."""

    @pytest.mark.parametrize("num_shards", ALL_COUNTS)
    @pytest.mark.parametrize("compact", [True, 0.25, 17],
                             ids=["auto", "fraction", "explicit"])
    def test_capacity_matches_single_device(self, num_shards, compact):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4, compact=compact)
        want = build_advance(_GRAPH, schedule="merge_path", path="pure",
                             num_blocks=4, compact=compact).compact_capacity
        assert splan.template.compact_capacity == want

    @pytest.mark.parametrize("compact", [0.0, 1.5, 0, -3],
                             ids=["zero-frac", "over-frac", "zero", "neg"])
    def test_invalid_compact_rejected(self, compact):
        with pytest.raises(ValueError):
            build_sharded_advance(_GRAPH, 1, schedule="merge_path",
                                  path="pure", num_blocks=4, compact=compact)

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_compacted_delta_bitwise(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4, delta="auto",
                       compact=True)
        want = delta_stepping(_GRAPH, 0, schedule="merge_path", path="pure",
                              num_blocks=4, compact=True)
        assert_bitwise_equal(sharded_delta_stepping(splan, 0), want,
                             f"compacted delta s{num_shards}")


class TestShardedPagerank:
    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_pagerank_close_general_graph(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        want = pagerank(_GRAPH, num_iters=12, schedule="merge_path",
                        path="pure", num_blocks=4)
        got = sharded_pagerank(splan, num_iters=12)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(got),
                                   np_pagerank(_WEIGHTS, num_iters=12),
                                   rtol=1e-4, atol=1e-6)

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_pagerank_mass_conserved(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        got = np.asarray(sharded_pagerank(splan, num_iters=20))
        assert got.shape == (_GRAPH.csr.shape[0],)
        np.testing.assert_allclose(got.sum(), 1.0, rtol=1e-5)


class TestPerShardOwnership:
    """Each device's slice of the result equals the oracle's slice — the
    halo exchange never leaks another shard's vertices into local state."""

    @pytest.mark.parametrize("num_shards", ALL_COUNTS)
    def test_bfs_slices_match_oracle_slices(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        got = np.asarray(sharded_bfs(splan, 0))
        oracle_d, _ = np_bfs(_WEIGHTS, 0)
        V = _WEIGHTS.shape[0]
        slices = shard_slices(V, num_shards)
        assert sum(hi - lo for lo, hi in slices) == V
        for lo, hi in slices:
            np.testing.assert_array_equal(got[lo:hi], oracle_d[lo:hi])

    @pytest.mark.parametrize("num_shards", ALL_COUNTS)
    def test_local_views_cover_every_edge_exactly_once(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        E = _GRAPH.csr.nnz
        assert int(np.asarray(splan.arrays["pull_valid"]).sum()) == E
        assert int(np.asarray(splan.arrays["push_valid"]).sum()) == E
        out_deg = np.asarray(splan.arrays["out_degrees"])
        assert int(out_deg.sum()) == E


class TestAdversarialGraphs:
    @pytest.mark.parametrize("num_shards", _counts(4))
    @pytest.mark.parametrize("name", sorted(adversarial_graphs()))
    def test_bfs_sssp_bitwise(self, name, num_shards):
        w = adversarial_graphs()[name]
        g = Graph(CSR.from_dense(w))
        splan = build_sharded_advance(g, num_shards, schedule="group_mapped",
                                      path="pure", num_blocks=4)
        np.testing.assert_array_equal(
            sharded_bfs(splan, 0),
            bfs(g, 0, schedule="group_mapped", path="pure", num_blocks=4))
        assert_bitwise_equal(
            sharded_sssp(splan, 0),
            sssp(g, 0, schedule="group_mapped", path="pure", num_blocks=4),
            name)

    @pytest.mark.parametrize("num_shards", _counts(8))
    def test_graph_smaller_than_mesh(self, num_shards):
        """V=5 over 8 shards: trailing shards hold only padding."""
        w = powerlaw_graph_dense(5, avg_degree=2.0, seed=3)
        g = Graph(CSR.from_dense(w))
        splan = build_sharded_advance(g, num_shards, schedule="merge_path",
                                      path="pure")
        assert splan.num_shards == num_shards
        np.testing.assert_array_equal(
            sharded_bfs(splan, 0),
            bfs(g, 0, schedule="merge_path", path="pure"))
        assert_bitwise_equal(sharded_sssp(splan, 0),
                             sssp(g, 0, schedule="merge_path", path="pure"),
                             "tiny graph sssp")

    @pytest.mark.parametrize("num_shards", _counts(2))
    def test_single_vertex_graph(self, num_shards):
        g = Graph(CSR.from_dense(np.zeros((1, 1), np.float32)))
        splan = build_sharded_advance(g, num_shards, schedule="merge_path",
                                      path="pure")
        np.testing.assert_array_equal(sharded_bfs(splan, 0), [0])


class TestOneShardMatchesUnsharded:
    """The recursion's base case, runnable on any device count: a 1-shard
    mesh must be a bitwise no-op relative to the unsharded drivers."""

    @pytest.mark.parametrize("schedule,path", SCHEDULE_PATH_CASES)
    def test_bfs_sssp_bitwise(self, schedule, path):
        splan = _build(_GRAPH, 1, schedule=schedule, path=path,
                       num_blocks=4)
        want_d, want_p = bfs(_GRAPH, 0, schedule=schedule, path=path,
                             num_blocks=4, return_parents=True)
        got_d, got_p = sharded_bfs(splan, 0, return_parents=True)
        np.testing.assert_array_equal(got_d, want_d)
        np.testing.assert_array_equal(got_p, want_p)
        assert_bitwise_equal(
            sharded_sssp(splan, 0),
            sssp(_GRAPH, 0, schedule=schedule, path=path, num_blocks=4),
            f"1-shard sssp {schedule}@{path}")

    def test_threshold_matches_unsharded_inspector(self):
        splan = build_sharded_advance(_GRAPH, 1, schedule="merge_path",
                                      path="pure", num_blocks=4)
        plan = build_advance(_GRAPH, schedule="merge_path", path="pure",
                             num_blocks=4)
        assert splan.direction_threshold == plan.direction_threshold


class TestShardedBfsMulti:
    @pytest.mark.parametrize("num_shards", ALL_COUNTS)
    def test_batched_sources_bitwise(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        sources = [0, 5, 11]
        want = bfs_multi(_GRAPH, sources, schedule="merge_path", path="pure",
                         num_blocks=4)
        got = sharded_bfs_multi(splan, sources)
        np.testing.assert_array_equal(got, want)
        for i, s in enumerate(sources):
            np.testing.assert_array_equal(np.asarray(got)[i],
                                          np_bfs(_WEIGHTS, s)[0])


class TestDriverMeshDispatch:
    """``mesh=`` on the top-level drivers routes through the sharded path."""

    @pytest.mark.parametrize("num_shards", _counts(2))
    def test_bfs_mesh_kwarg(self, num_shards):
        mesh = make_graph_mesh(num_shards)
        np.testing.assert_array_equal(
            bfs(_GRAPH, 0, mesh=mesh, schedule="merge_path", path="pure",
                num_blocks=4),
            bfs(_GRAPH, 0, schedule="merge_path", path="pure", num_blocks=4))

    @pytest.mark.parametrize("num_shards", _counts(2))
    def test_sssp_prebuilt_plan(self, num_shards):
        splan = _build(_GRAPH, num_shards, schedule="merge_path",
                       path="pure", num_blocks=4)
        assert isinstance(splan, ShardedAdvancePlan)
        assert_bitwise_equal(
            sssp(_GRAPH, 0, plan=splan),
            sssp(_GRAPH, 0, schedule="merge_path", path="pure", num_blocks=4),
            "prebuilt sharded plan via sssp driver")

    @pytest.mark.parametrize("num_shards", _counts(2))
    def test_pagerank_and_delta_mesh_kwarg(self, num_shards):
        mesh = make_graph_mesh(num_shards)
        np.testing.assert_allclose(
            np.asarray(pagerank(_GRAPH, num_iters=8, mesh=mesh,
                                schedule="merge_path", path="pure",
                                num_blocks=4)),
            np.asarray(pagerank(_GRAPH, num_iters=8, schedule="merge_path",
                                path="pure", num_blocks=4)),
            rtol=1e-6, atol=1e-7)
        assert_bitwise_equal(
            delta_stepping(_GRAPH, 0, mesh=mesh, schedule="merge_path",
                           path="pure", num_blocks=4, compact=None),
            delta_stepping(_GRAPH, 0, schedule="merge_path", path="pure",
                           num_blocks=4, compact=None),
            "delta_stepping mesh kwarg")

    def test_mesh_with_wrong_plan_type_raises(self):
        plan = build_advance(_GRAPH, schedule="merge_path", path="pure",
                             num_blocks=4)
        mesh = make_graph_mesh(1)
        with pytest.raises(TypeError):
            bfs(_GRAPH, 0, plan=plan, mesh=mesh)


class TestConstructionValidation:
    def test_make_graph_mesh_rejects_bad_counts(self):
        with pytest.raises(ValueError):
            make_graph_mesh(0)
        with pytest.raises(ValueError):
            make_graph_mesh(_NDEV + 1)

    def test_build_rejects_nonpositive_counts(self):
        with pytest.raises(ValueError):
            build_sharded_advance(_GRAPH, 0)
        with pytest.raises(ValueError):
            build_sharded_advance(_GRAPH, -2)

    @pytest.mark.skipif(_NDEV < 2, reason="needs a 2-axis mesh")
    def test_build_rejects_multi_axis_mesh(self):
        from jax.sharding import Mesh
        devs = np.asarray(jax.devices()[:2]).reshape(2, 1)
        bad = Mesh(devs, ("a", "b"))
        with pytest.raises(ValueError):
            build_sharded_advance(_GRAPH, bad)

    def test_auto_selection_returns_valid_plan(self):
        splan = build_sharded_advance(_GRAPH, None, schedule="auto")
        assert splan.num_shards >= 1
        assert splan.num_shards <= _NDEV
        np.testing.assert_array_equal(
            sharded_bfs(splan, 0),
            bfs(_GRAPH, 0, schedule=splan.schedule, path=splan.path))


def _hub_graph(V: int = 16384) -> Graph:
    """A planted-hub digraph, built directly in CSR form: a ring plus an
    in-hub (every vertex points at vertex 0), so the pull view's tile 0
    owns ~V atoms while every other tile owns 1 — the skew equal-width
    boundaries pay max-over-shards cost for."""
    rows = np.concatenate([np.arange(V), np.arange(1, V)])
    cols = np.concatenate([(np.arange(V) + 1) % V, np.zeros(V - 1, np.int64)])
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    roff = np.cumsum(np.bincount(rows + 1, minlength=V + 1))
    return Graph(CSR(jnp.asarray(roff, jnp.int32), jnp.asarray(cols, jnp.int32),
                     jnp.ones(len(cols), jnp.float32), (V, V), len(cols)))


class TestBoundarySchedules:
    """Every registered boundary schedule, swept unconditionally (no env
    matrix needed): contiguous uneven shards must stay bitwise-identical
    to single-device, own every edge exactly once, and keep equal_width's
    layout byte-identical to the pre-boundary-schedule identity."""

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("boundary", sorted(SHARD_SCHEDULES))
    def test_bfs_sssp_delta_bitwise(self, boundary, num_shards):
        splan = build_sharded_advance(_GRAPH, num_shards,
                                      schedule="merge_path", path="pure",
                                      num_blocks=4, shard_schedule=boundary,
                                      delta="auto")
        assert splan.shard_schedule == boundary
        want_d, want_p = bfs(_GRAPH, 0, schedule="merge_path", path="pure",
                             num_blocks=4, return_parents=True)
        got_d, got_p = sharded_bfs(splan, 0, return_parents=True)
        np.testing.assert_array_equal(got_d, want_d)
        np.testing.assert_array_equal(got_p, want_p)
        assert_bitwise_equal(
            sharded_sssp(splan, 0),
            sssp(_GRAPH, 0, schedule="merge_path", path="pure", num_blocks=4),
            f"sssp {boundary} s{num_shards}")
        assert_bitwise_equal(
            sharded_delta_stepping(splan, 0),
            delta_stepping(_GRAPH, 0, schedule="merge_path", path="pure",
                           num_blocks=4, compact=None),
            f"delta {boundary} s{num_shards}")

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    @pytest.mark.parametrize("boundary", sorted(SHARD_SCHEDULES))
    def test_edges_owned_exactly_once(self, boundary, num_shards):
        splan = build_sharded_advance(_GRAPH, num_shards,
                                      schedule="merge_path", path="pure",
                                      num_blocks=4, shard_schedule=boundary)
        E = _GRAPH.csr.nnz
        assert int(np.asarray(splan.arrays["pull_valid"]).sum()) == E
        assert int(np.asarray(splan.arrays["push_valid"]).sum()) == E
        assert int(np.asarray(splan.arrays["out_degrees"]).sum()) == E
        bounds = np.asarray(splan.boundaries)
        assert bounds[0] == 0 and bounds[-1] == _GRAPH.num_vertices
        assert (np.diff(bounds) >= 0).all()

    @pytest.mark.parametrize("num_shards", ALL_COUNTS)
    def test_equal_width_permutation_is_identity(self, num_shards):
        """The byte-identity guard: the default layout's global<->padded
        maps must be the identity, so equal_width plans index, gather, and
        slice exactly as the pre-boundary-schedule implementation did."""
        splan = build_sharded_advance(_GRAPH, num_shards,
                                      schedule="merge_path", path="pure",
                                      num_blocks=4)
        assert splan.shard_schedule == "equal_width"
        ident = np.arange(splan.padded_vertices, dtype=np.int32)
        np.testing.assert_array_equal(np.asarray(splan.glob2pad), ident)
        np.testing.assert_array_equal(np.asarray(splan.pad2glob), ident)
        np.testing.assert_array_equal(
            np.asarray(splan.boundaries),
            [min(s * splan.shard_size, _GRAPH.num_vertices)
             for s in range(num_shards + 1)])

    @pytest.mark.parametrize("boundary", ["edge_balanced", "lpt_contiguous"])
    def test_driver_shard_schedule_kwarg(self, boundary):
        if _NDEV < 2:
            pytest.skip("needs 2 devices")
        mesh = make_graph_mesh(2)
        np.testing.assert_array_equal(
            bfs(_GRAPH, 0, mesh=mesh, shard_schedule=boundary,
                schedule="merge_path", path="pure", num_blocks=4),
            bfs(_GRAPH, 0, schedule="merge_path", path="pure", num_blocks=4))
        assert_bitwise_equal(
            sssp(_GRAPH, 0, mesh=mesh, shard_schedule=boundary,
                 schedule="merge_path", path="pure", num_blocks=4),
            sssp(_GRAPH, 0, schedule="merge_path", path="pure", num_blocks=4),
            f"sssp driver shard_schedule={boundary}")

    @pytest.mark.parametrize("boundary", ["edge_balanced", "lpt_contiguous"])
    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_pad_atoms_spread_over_empty_slots(self, boundary, num_shards):
        """Uneven boundaries must not dump all padding atoms into one pad
        segment: a monolithic pad tile (plus the narrow shards' long runs
        of zero-atom slots) inflates the blocked executor's static
        window/local-tile maxima, and the mesh-uniform statics impose that
        worst block shape on every shard — a multiple of the advance cost
        for nothing.  Padding is masked, so the only contract on its
        placement is balance: no tile's segment may exceed the even split
        of the shard's pad atoms over its empty slots + pad tile."""
        splan = build_sharded_advance(_GRAPH, num_shards,
                                      schedule="merge_path", path="pure",
                                      num_blocks=4, shard_schedule=boundary)
        bounds = np.asarray(splan.boundaries)
        for s in range(splan.num_shards):
            spec = jax.tree_util.tree_unflatten(
                splan.pull_spec_treedef,
                [l[s] for l in splan.pull_spec_leaves])
            counts = np.diff(np.asarray(spec.tile_offsets))
            width = int(bounds[s + 1] - bounds[s])
            pad_counts = counts[width:]
            if pad_counts.size == 0:
                continue
            cap = -(-int(pad_counts.sum()) // pad_counts.size)
            assert pad_counts.max() <= cap, (
                f"shard {s}: pad segment {pad_counts.max()} exceeds even "
                f"split {cap} over {pad_counts.size} padding tiles")

    @pytest.mark.parametrize("num_shards", MULTI_COUNTS)
    def test_bfs_multi_and_pagerank_uneven(self, num_shards):
        splan = build_sharded_advance(_GRAPH, num_shards,
                                      schedule="merge_path", path="pure",
                                      num_blocks=4,
                                      shard_schedule="edge_balanced")
        np.testing.assert_array_equal(
            sharded_bfs_multi(splan, [0, 5, 11]),
            bfs_multi(_GRAPH, [0, 5, 11], schedule="merge_path", path="pure",
                      num_blocks=4))
        np.testing.assert_allclose(
            np.asarray(sharded_pagerank(splan, num_iters=8)),
            np.asarray(pagerank(_GRAPH, num_iters=8, schedule="merge_path",
                                path="pure", num_blocks=4)),
            rtol=1e-6, atol=1e-7)


class TestBoundaryCostModel:
    """The planted-hub cost-model contract: degree-aware boundaries must
    strictly lower the modeled max-shard cost the autotuner ranks on."""

    @pytest.mark.parametrize("num_shards", [2, 4, 8])
    def test_edge_balanced_strictly_beats_equal_width_on_hub(self,
                                                             num_shards):
        g = _hub_graph()
        spec = g.csr.transpose().workspec()
        costs = {}
        for name in ("equal_width", "edge_balanced", "lpt_contiguous"):
            bounds = shard_boundaries(g, num_shards, name)
            costs[name] = modeled_sharded_cost(
                spec, Schedule.MERGE_PATH, 3, path="pure", atom_work=2,
                halo_elems=g.num_vertices, boundaries=bounds)
        assert costs["edge_balanced"] < costs["equal_width"], costs
        assert costs["lpt_contiguous"] <= costs["edge_balanced"], costs

    def test_boundaries_cover_and_balance(self):
        g = _hub_graph(4096)
        roff = np.asarray(g.csr.row_offsets)
        rev_roff = np.asarray(g.csr.transpose().row_offsets)
        loads = np.diff(roff) + np.diff(rev_roff) + 1
        for name in SHARD_SCHEDULES:
            b = shard_boundaries(g, 4, name)
            assert b[0] == 0 and b[-1] == g.num_vertices
            assert (np.diff(b) >= 0).all()
        eq = shard_boundaries(g, 4, "equal_width")
        eb = shard_boundaries(g, 4, "edge_balanced")
        seg = lambda bb: max(loads[lo:hi].sum()
                             for lo, hi in zip(bb[:-1], bb[1:]))
        assert seg(eb) < seg(eq)


class TestNumShardsValidation:
    """Degree-aware schedules reject S > V outright (there is no
    contiguous non-degenerate split); equal_width keeps the documented
    all-empty-trailing-shards contract."""

    def test_degree_aware_rejects_more_shards_than_vertices(self):
        w = powerlaw_graph_dense(5, avg_degree=2.0, seed=3)
        g = Graph(CSR.from_dense(w))
        for name in ("edge_balanced", "lpt_contiguous"):
            with pytest.raises(ValueError, match=r"V=5.*S=8"):
                shard_boundaries(g, 8, name)
        if _NDEV >= 8:
            for name in ("edge_balanced", "lpt_contiguous"):
                with pytest.raises(ValueError, match=r"V=5.*S=8"):
                    build_sharded_advance(g, 8, schedule="merge_path",
                                          path="pure", shard_schedule=name)

    def test_unknown_shard_schedule_rejected(self):
        with pytest.raises(ValueError, match="unknown shard schedule"):
            build_sharded_advance(_GRAPH, 1, schedule="merge_path",
                                  path="pure", shard_schedule="bogus")
        with pytest.raises(ValueError, match="unknown shard schedule"):
            shard_boundaries(_GRAPH, 2, "bogus")

    @pytest.mark.parametrize("num_shards", _counts(8))
    def test_equal_width_keeps_small_graph_contract(self, num_shards):
        """V=5 over 8 equal-width shards stays legal (trailing padding)."""
        w = powerlaw_graph_dense(5, avg_degree=2.0, seed=3)
        g = Graph(CSR.from_dense(w))
        splan = build_sharded_advance(g, num_shards, schedule="merge_path",
                                      path="pure",
                                      shard_schedule="equal_width")
        np.testing.assert_array_equal(
            sharded_bfs(splan, 0),
            bfs(g, 0, schedule="merge_path", path="pure"))

    def test_auto_boundary_on_small_graph_falls_back(self):
        """Joint auto-selection over a mesh wider than the graph must not
        crash on the degree-aware candidates — they are skipped, and the
        equal_width fallback survives."""
        w = powerlaw_graph_dense(5, avg_degree=2.0, seed=3)
        g = Graph(CSR.from_dense(w))
        splan = build_sharded_advance(g, None, schedule="merge_path",
                                      path="pure", shard_schedule="auto")
        assert splan.num_shards >= 1
        np.testing.assert_array_equal(
            sharded_bfs(splan, 0),
            bfs(g, 0, schedule="merge_path", path="pure"))
