"""Unit tests for the dynamic schedules + autotuner (repro.core.dynamic/.autotune).

Deterministic companion to tests/test_dynamic_props.py (which needs
hypothesis): these run everywhere, including environments without the
optional dev dependency.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    REGISTERED_SCHEDULES, AutotuneCache, Schedule, WorkSpec,
    adaptive_partition, assign_chunks, blocked_tile_reduce,
    chunked_partition, make_partition, modeled_cost, score_schedules,
    select_schedule, tile_reduce,
)

DYNAMIC = [Schedule.CHUNKED, Schedule.ADAPTIVE]

WORKLOADS = {
    "uniform": [5] * 40,
    "empty_tiles": [3, 0, 0, 7, 0, 1, 0, 0, 0, 12],
    "one_heavy": [0, 0, 1000, 0, 3, 5],
    "empties_between": [1] + [0] * 30 + [1],
    "powerlaw": [1, 1, 2, 2, 3, 4, 6, 9, 14, 22, 35, 56, 90, 144, 400],
    "single_tile": [64],
    "all_empty": [0, 0, 0],
}


def spec_from_sizes(sizes):
    sizes = np.asarray(sizes, np.int32)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(offsets),
                                         num_atoms=int(offsets[-1]))


def assert_covers_exactly_once(spec, part):
    a = np.asarray(part.atom_starts)
    ts = np.asarray(part.tile_starts)
    assert a[0] == 0 and a[-1] == spec.num_atoms
    assert (np.diff(a) >= 0).all() and (np.diff(ts) >= 0).all()
    # contiguous spans partition [0, num_atoms): exactly-once by construction
    counts = np.zeros(spec.num_atoms, np.int64)
    for b in range(len(a) - 1):
        counts[a[b]:a[b + 1]] += 1
    assert (counts == 1).all()


class TestChunked:
    @pytest.mark.parametrize("name", sorted(WORKLOADS))
    @pytest.mark.parametrize("num_blocks", [1, 3, 8])
    @pytest.mark.parametrize("policy", ["lpt", "round_robin"])
    def test_coverage_and_block_map(self, name, num_blocks, policy):
        spec = spec_from_sizes(WORKLOADS[name])
        part = chunked_partition(spec, num_blocks, policy=policy)
        assert part.schedule == Schedule.CHUNKED
        assert_covers_exactly_once(spec, part)
        bm = np.asarray(part.block_map)
        assert part.num_physical_blocks == num_blocks
        assert bm.shape == (part.num_blocks,)
        assert bm.min() >= 0 and bm.max() < num_blocks

    def test_oversplits(self):
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        part = chunked_partition(spec, 4, chunk_factor=4)
        assert part.num_blocks == 16       # 4 chunks per physical block

    def test_heavy_tile_is_split(self):
        spec = spec_from_sizes(WORKLOADS["one_heavy"])
        part = chunked_partition(spec, 8)
        spans = np.diff(np.asarray(part.atom_starts))
        # the 1000-atom tile must not land on a single chunk
        assert spans.max() < 1000

    def test_modeled_cost_uses_block_map(self):
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        from repro.core.balance import modeled_block_cost
        per_block = np.asarray(modeled_block_cost(spec, Schedule.CHUNKED, 4))
        assert per_block.shape == (4,)     # physical blocks, not chunks
        assert modeled_cost(spec, Schedule.CHUNKED, 4) == per_block.max()

    def test_assign_chunks_lpt_is_balanced(self):
        cost = jnp.asarray([10, 9, 8, 1, 1, 1, 1, 1], jnp.int32)
        bm = np.asarray(assign_chunks(cost, 3, policy="lpt"))
        loads = np.bincount(bm, weights=np.asarray(cost), minlength=3)
        assert loads.max() <= 12           # LPT: {10,1,1}, {9,1,1}, {8,1,1}


class TestAdaptive:
    def test_balanced_early_exit_stays_tile_aligned(self):
        spec = spec_from_sizes(WORKLOADS["uniform"])
        part = adaptive_partition(spec, 8)
        assert part.schedule == Schedule.ADAPTIVE and part.tile_aligned
        assert_covers_exactly_once(spec, part)

    def test_skewed_input_rebalances(self):
        spec = spec_from_sizes(WORKLOADS["one_heavy"])
        part = adaptive_partition(spec, 8)
        assert_covers_exactly_once(spec, part)
        spans = np.diff(np.asarray(part.atom_starts))
        # the heavy tile is split: max block load well under the tile size
        assert spans.max() <= 2 * -(-spec.num_atoms // 8)

    @pytest.mark.parametrize("name", sorted(WORKLOADS))
    @pytest.mark.parametrize("num_blocks", [1, 5, 16])
    def test_coverage(self, name, num_blocks):
        spec = spec_from_sizes(WORKLOADS[name])
        part = adaptive_partition(spec, num_blocks)
        assert_covers_exactly_once(spec, part)


class TestBlockedExecutionDynamic:
    @pytest.mark.parametrize("schedule", DYNAMIC)
    @pytest.mark.parametrize("name", sorted(WORKLOADS))
    @pytest.mark.parametrize("num_blocks", [1, 4, 9])
    def test_matches_oracle_exactly(self, schedule, name, num_blocks):
        spec = spec_from_sizes(WORKLOADS[name])
        part = make_partition(spec, schedule, num_blocks)
        rng = np.random.default_rng(0)
        # integer-valued floats: segment sums are exact -> bitwise equality
        vals = jnp.asarray(rng.integers(-8, 9, max(spec.num_atoms, 1))
                           .astype(np.float32))
        fn = lambda a: vals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]
        got = np.asarray(blocked_tile_reduce(spec, part, fn))
        want = np.asarray(tile_reduce(spec, fn))
        np.testing.assert_array_equal(got, want)

    def test_empties_between_regression(self):
        # seed bug: non-tile-aligned blocks spanning many empty tiles
        # overflowed the local one-hot and silently dropped atoms
        spec = spec_from_sizes(WORKLOADS["empties_between"])
        part = make_partition(spec, Schedule.NONZERO_SPLIT, 1)
        vals = jnp.ones(2, jnp.float32)
        fn = lambda a: vals[jnp.minimum(a, 1)]
        got = np.asarray(blocked_tile_reduce(spec, part, fn))
        np.testing.assert_array_equal(got, np.asarray(tile_reduce(spec, fn)))


class TestAutotune:
    def test_auto_is_argmin_of_model(self, tmp_path):
        cache = AutotuneCache(tmp_path / "at.json")
        for name, sizes in WORKLOADS.items():
            spec = spec_from_sizes(sizes)
            choice = select_schedule(spec, 16, cache=cache)
            scores = score_schedules(spec, 16)
            assert scores[choice] == min(scores.values()), name

    def test_make_partition_auto(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        part = make_partition(spec, "auto", 8)
        assert part.schedule in REGISTERED_SCHEDULES
        assert_covers_exactly_once(spec, part)

    def test_persistent_cache_roundtrip(self, tmp_path):
        path = tmp_path / "cache.json"
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        first = select_schedule(spec, 16, cache=AutotuneCache(path))
        assert path.exists()
        # a fresh cache object (fresh process, same file) must hit
        from repro.core.autotune import shape_key
        reloaded = AutotuneCache(path)
        assert reloaded.get(shape_key(spec, 16)) == first

    def test_chunked_beats_statics_on_powerlaw(self):
        rng = np.random.default_rng(0)
        sizes = (rng.pareto(0.8, 500) * 20 + 1).astype(np.int64)
        spec = spec_from_sizes(sizes)
        scores = score_schedules(spec, 64)
        statics = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
                   Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]
        assert scores[Schedule.CHUNKED] < min(scores[s] for s in statics)

    def test_auto_regret_within_10pct_over_sweep(self):
        rng = np.random.default_rng(3)
        sweep = [rng.integers(1, 9, 300),
                 (rng.pareto(1.1, 400) * 30 + 1).astype(np.int64),
                 np.where(rng.random(200) < 0.6, 0,
                          rng.integers(1, 50, 200))]
        for i, sizes in enumerate(sweep):
            spec = spec_from_sizes(sizes)
            choice = select_schedule(spec, 64, cache=None)
            scores = score_schedules(spec, 64)
            assert scores[choice] <= 1.10 * min(scores.values()), i


class TestAdaptivePartitionCache:
    """Regression: the adaptive inspector must not re-run per call."""

    def _fresh_spec(self, seed):
        rng = np.random.default_rng(seed)
        # unique content per seed so cross-test cache state cannot alias
        return spec_from_sizes(rng.integers(0, 50, 37))

    def test_repeat_calls_inspect_once(self):
        from repro.core import adaptive_inspection_count, clear_adaptive_cache
        clear_adaptive_cache()
        spec = self._fresh_spec(101)
        base = adaptive_inspection_count()
        p1 = adaptive_partition(spec, 8)
        assert adaptive_inspection_count() == base + 1
        for _ in range(5):                       # the serving-loop pattern
            p2 = adaptive_partition(spec, 8)
        assert adaptive_inspection_count() == base + 1   # no re-inspection
        assert p2 is p1                                  # memoised object

    def test_key_includes_threshold_and_blocks(self):
        from repro.core import adaptive_inspection_count, clear_adaptive_cache
        clear_adaptive_cache()
        spec = self._fresh_spec(202)
        base = adaptive_inspection_count()
        adaptive_partition(spec, 8)
        adaptive_partition(spec, 8, imbalance_threshold=1.1)
        adaptive_partition(spec, 4)
        assert adaptive_inspection_count() == base + 3
        adaptive_partition(spec, 8, imbalance_threshold=1.1)  # hit
        assert adaptive_inspection_count() == base + 3

    def test_content_not_just_shape(self):
        # same shape statistics bucket, different offsets -> distinct entry
        from repro.core import adaptive_inspection_count, clear_adaptive_cache
        clear_adaptive_cache()
        a = spec_from_sizes([3, 0, 50, 2, 2, 9])
        b = spec_from_sizes([3, 0, 50, 2, 9, 2])
        base = adaptive_inspection_count()
        adaptive_partition(a, 4)
        adaptive_partition(b, 4)
        assert adaptive_inspection_count() == base + 2   # no key collision

    def test_cache_opt_out(self):
        from repro.core import adaptive_inspection_count, clear_adaptive_cache
        clear_adaptive_cache()
        spec = self._fresh_spec(303)
        base = adaptive_inspection_count()
        adaptive_partition(spec, 8, cache=False)
        adaptive_partition(spec, 8, cache=False)
        assert adaptive_inspection_count() == base + 2


class TestAutotuneCacheRobustness:
    """The persistent JSON cache must survive corruption and concurrency."""

    def test_corrupt_file_falls_back_to_empty(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text("{this is not json")
        cache = AutotuneCache(path)
        assert cache.get("anything") is None      # tolerated, not raised
        cache.put("k", Schedule.MERGE_PATH)       # put repairs the file
        import json
        assert "k" in json.loads(path.read_text())

    def test_partial_truncated_file(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text('{"k1": "merge_pa')       # torn write from a crash
        cache = AutotuneCache(path)
        assert cache.get("k1") is None
        cache.put("k2", Schedule.CHUNKED)
        assert AutotuneCache(path).get("k2") == Schedule.CHUNKED

    def test_wrong_json_type_falls_back(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text('["not", "a", "dict"]')
        cache = AutotuneCache(path)
        assert cache.get("k") is None
        cache.put("k", Schedule.ADAPTIVE)
        assert AutotuneCache(path).get("k") == Schedule.ADAPTIVE

    def test_concurrent_writers_keep_disjoint_keys(self, tmp_path):
        # two cache objects = two processes doing read-modify-write; the
        # re-read + atomic-replace discipline must preserve both keys
        import json
        path = tmp_path / "cache.json"
        c1 = AutotuneCache(path)
        c2 = AutotuneCache(path)
        c1.put("k1", Schedule.MERGE_PATH)         # c2 has already loaded ({})
        c2.put("k2", Schedule.CHUNKED)            # must not clobber k1
        final = json.loads(path.read_text())
        assert set(final) >= {"k1", "k2"}
        assert AutotuneCache(path).get("k1") == Schedule.MERGE_PATH

    def test_no_leaked_tempfiles(self, tmp_path):
        path = tmp_path / "cache.json"
        cache = AutotuneCache(path)
        for i in range(4):
            cache.put(f"k{i}", Schedule.MERGE_PATH)
        leftovers = [p for p in tmp_path.iterdir()
                     if p.suffix == ".tmp"]
        assert leftovers == []

    def test_stale_schedule_name_ignored(self, tmp_path):
        import json
        path = tmp_path / "cache.json"
        path.write_text(json.dumps({"k": "warp_speed_schedule"}))
        assert AutotuneCache(path).get("k") is None

    # -- v2 (measured) record format, PR 6 ---------------------------------

    def test_v2_record_round_trip(self, tmp_path):
        import json
        from repro.core.autotune import CacheRecord, Plan
        path = tmp_path / "cache.json"
        rec = CacheRecord(plan=Plan.decode("chunked@native"),
                          measured_us={"chunked@native": 12.5,
                                       "merge_path@pure": 20.0},
                          features={"merge_path@pure":
                                    (3.0, {"ADVANCE_ATOM_WORK": 40.0})})
        AutotuneCache(path).put_record("k", rec)
        raw = json.loads(path.read_text())["k"]
        assert raw["v"] == 2 and raw["plan"] == "chunked@native"
        got = AutotuneCache(path).get_record("k")
        assert got.plan == rec.plan
        assert got.measured_us == rec.measured_us
        assert got.features["merge_path@pure"][1] == {
            "ADVANCE_ATOM_WORK": 40.0}

    def test_v1_legacy_string_still_decodes(self, tmp_path):
        import json
        path = tmp_path / "cache.json"
        path.write_text(json.dumps({"k": "merge_path@pure"}))
        cache = AutotuneCache(path)
        assert cache.get("k") == Schedule.MERGE_PATH
        rec = cache.get_record("k")
        assert str(rec.plan.schedule) == "merge_path"
        assert rec.measured_us == {} and not rec.is_measured

    def test_model_only_choices_still_write_v1_strings(self, tmp_path):
        import json
        from repro.core.autotune import CacheRecord, Plan
        path = tmp_path / "cache.json"
        AutotuneCache(path).put_record(
            "k", CacheRecord(plan=Plan.decode("merge_path@pure")))
        # unmeasured records stay bare strings: forward-compatible with
        # every pre-PR-6 reader
        assert json.loads(path.read_text())["k"] == "merge_path@pure"

    def test_corrupt_measured_field_degrades_to_model_only(self, tmp_path):
        import json
        path = tmp_path / "cache.json"
        path.write_text(json.dumps({"k": {
            "v": 2, "plan": "merge_path@pure",
            "measured_us": {"merge_path@pure": "NaN-garbage",
                            "not a plan": 5.0,
                            "chunked@native": -3.0}}}))
        rec = AutotuneCache(path).get_record("k")
        assert rec.plan is not None            # plan survives
        assert rec.measured_us == {}           # every torn entry dropped
        assert not rec.is_measured

    def test_torn_v2_keeps_valid_measurements(self, tmp_path):
        import json
        path = tmp_path / "cache.json"
        path.write_text(json.dumps({"k": {
            "v": 2, "plan": "chunked@pure",
            "measured_us": {"chunked@pure": 9.0, "bogus@plan": 1.0},
            "features": {"chunked@pure": [1.0, {"CHUNK": 2.0}],
                         "broken": "not-a-pair"}}}))
        rec = AutotuneCache(path).get_record("k")
        assert rec.measured_us == {"chunked@pure": 9.0}
        assert list(rec.features) == ["chunked@pure"]

    def test_concurrent_writers_disjoint_measured_keys(self, tmp_path):
        import json
        from repro.core.autotune import CacheRecord, Plan
        path = tmp_path / "cache.json"
        c1, c2 = AutotuneCache(path), AutotuneCache(path)
        c1.put_record("m1", CacheRecord(plan=Plan.decode("merge_path@pure"),
                                        measured_us={"merge_path@pure": 7.0}))
        c2.put_record("m2", CacheRecord(plan=Plan.decode("chunked@native"),
                                        measured_us={"chunked@native": 3.0}))
        final = json.loads(path.read_text())
        assert set(final) >= {"m1", "m2"}      # merge-on-write kept both
        fresh = AutotuneCache(path)
        assert fresh.get_record("m1").measured_us == {"merge_path@pure": 7.0}
        assert fresh.get_record("m2").measured_us == {"chunked@native": 3.0}

    def test_put_record_merges_prior_measurements(self, tmp_path):
        from repro.core.autotune import CacheRecord, Plan
        path = tmp_path / "cache.json"
        cache = AutotuneCache(path)
        cache.put_record("k", CacheRecord(
            plan=Plan.decode("merge_path@pure"),
            measured_us={"merge_path@pure": 7.0}))
        cache.put_record("k", CacheRecord(
            plan=Plan.decode("chunked@native"),
            measured_us={"chunked@native": 3.0}))
        rec = AutotuneCache(path).get_record("k")
        assert rec.measured_us == {"merge_path@pure": 7.0,
                                   "chunked@native": 3.0}
        assert rec.plan == Plan.decode("chunked@native")
