"""Property tests for the native chunk-walking path (requires hypothesis).

For arbitrary tile-size vectors and block counts — including empty tiles,
empty chunks, and ``num_chunks < num_blocks`` — the native Pallas executor
must be bit-identical to the pure-JAX blocked executor and to the
``tile_reduce`` oracle, under every schedule (atom values are integer-valued
floats, so every summation order is exact).
"""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (
    Schedule, WorkSpec, blocked_tile_reduce, make_partition,
    native_chunk_tile_reduce, supports_native_execution, tile_reduce,
)

tile_sizes = st.lists(st.integers(min_value=0, max_value=40), min_size=1,
                      max_size=40)

ALL_SCHEDULES = [Schedule.CHUNKED, Schedule.ADAPTIVE, Schedule.MERGE_PATH,
                 Schedule.NONZERO_SPLIT, Schedule.THREAD_MAPPED,
                 Schedule.GROUP_MAPPED]


def spec_from_sizes(sizes):
    sizes = np.asarray(sizes, np.int32)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(offsets),
                                         num_atoms=int(offsets[-1]))


class TestNativeMatchesPureAndOracle:
    @pytest.mark.parametrize("schedule", ALL_SCHEDULES)
    @given(sizes=tile_sizes, num_blocks=st.integers(min_value=1, max_value=9),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_bit_for_bit(self, schedule, sizes, num_blocks, seed):
        spec = spec_from_sizes(sizes)
        part = make_partition(spec, schedule, num_blocks)
        assert supports_native_execution(part)
        rng = np.random.default_rng(seed)
        vals = jnp.asarray(rng.integers(-8, 9, max(spec.num_atoms, 1))
                           .astype(np.float32))
        fn = lambda a: vals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]
        native = np.asarray(native_chunk_tile_reduce(spec, part, fn))
        pure = np.asarray(blocked_tile_reduce(spec, part, fn))
        oracle = np.asarray(tile_reduce(spec, fn)) if spec.num_atoms else \
            np.zeros(spec.num_tiles, np.float32)
        np.testing.assert_array_equal(native.view(np.uint32),
                                      pure.view(np.uint32))
        np.testing.assert_array_equal(native, oracle)
