"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          lm_loss, make_frontend_embeds, param_count,
                          active_param_count)

RNG = np.random.default_rng(0)


def make_batch(cfg, b=2, s=16, key=0):
    rng = np.random.default_rng(key)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))
                         .astype(np.int32))
    labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((b, 1), jnp.int32)],
                             axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend is not None:
        batch["prefix_embeds"] = make_frontend_embeds(
            cfg, b, jax.random.PRNGKey(key), dtype=jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        """One forward + one grad step on the reduced config: shapes + no
        NaNs (the per-arch smoke test required by the assignment)."""
        cfg = get_config(arch).reduced()
        params, specs = init_params(cfg, jax.random.PRNGKey(1))
        assert jax.tree.structure(params) == jax.tree.structure(
            jax.tree.map(lambda *_: 0, params, specs))
        batch = make_batch(cfg)

        logits, aux = forward(params, cfg, batch["tokens"],
                              batch.get("prefix_embeds"), dtype=jnp.float32)
        s_total = 16 + (cfg.frontend_len if cfg.frontend else 0)
        assert logits.shape == (2, s_total, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch, dtype=jnp.float32),
            has_aux=True)(params)
        assert bool(jnp.isfinite(loss)), "NaN loss"
        gleaves = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in gleaves), "NaN grads"
        assert any(float(jnp.abs(g).max()) > 0 for g in gleaves), "zero grads"

    def test_decode_step_runs(self, arch):
        cfg = get_config(arch).reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(2))
        cache = init_cache(cfg, 2, 16, dtype=jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, new_cache = decode_step(params, cfg, tok, jnp.int32(0), cache,
                                        dtype=jnp.float32)
        assert logits.shape == (2, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
        for a, b in zip(jax.tree.leaves(new_cache), jax.tree.leaves(cache)):
            assert a.shape == b.shape and a.dtype == b.dtype


DECODE_PARITY_ARCHS = ["qwen15_05b", "h2o_danube3_4b", "rwkv6_3b",
                       "hymba_15b", "glm4_9b", "nemotron4_340b"]


@pytest.mark.parametrize("arch", DECODE_PARITY_ARCHS)
def test_decode_matches_forward(arch):
    """Sequential one-token decode must reproduce the training forward's
    next-token logits — validates KV ring caches, RWKV/Mamba states and
    token-shift carries in one shot."""
    cfg = get_config(arch).reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(3))
    b, s = 2, 12
    tokens = jnp.asarray(np.random.default_rng(4).integers(
        0, cfg.vocab_size, (b, s)).astype(np.int32))

    want, _ = forward(params, cfg, tokens, dtype=jnp.float32)

    cache = init_cache(cfg, b, s, dtype=jnp.float32)
    got = []
    for t in range(s):
        logits, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                    jnp.int32(t), cache, dtype=jnp.float32)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_wraps_correctly():
    """Decode longer than the window: ring buffer must equal SWA forward."""
    cfg = get_config("h2o_danube3_4b").reduced()
    assert cfg.sliding_window == 8
    params, _ = init_params(cfg, jax.random.PRNGKey(5))
    b, s = 1, 20  # > 2x window
    tokens = jnp.asarray(np.random.default_rng(6).integers(
        0, cfg.vocab_size, (b, s)).astype(np.int32))
    want, _ = forward(params, cfg, tokens, dtype=jnp.float32)
    cache = init_cache(cfg, b, s, dtype=jnp.float32)  # sized to window
    assert cache["k"].shape[2] == cfg.sliding_window
    got = []
    for t in range(s):
        logits, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                    jnp.int32(t), cache, dtype=jnp.float32)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_sorted_matches_capacity_uncapped():
    """The paper's sorted LB dispatch == capacity dispatch when nothing
    drops (capacity -> inf), on identical params/router."""
    from repro.models import moe as M
    cfg = get_config("olmoe_1b_7b").reduced()
    params, _ = M.moe_init(jax.random.PRNGKey(7), cfg.d_model, cfg.d_ff,
                           cfg.num_experts, 0, cfg.activation)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, cfg.d_model),
                          jnp.float32) * 0.5
    out_cap, aux1 = M.moe_capacity(params, x, num_experts=cfg.num_experts,
                                   top_k=cfg.top_k, capacity_factor=100.0)
    out_sort, aux2 = M.moe_sorted(params, x, num_experts=cfg.num_experts,
                                  top_k=cfg.top_k)
    np.testing.assert_allclose(np.asarray(out_cap), np.asarray(out_sort),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_param_counts_full_configs():
    """Full-size param counts from abstract init (no allocation): sanity
    bands vs the published model sizes."""
    expectations = {
        "olmoe_1b_7b": (5e9, 9e9),          # ~6.9B total
        "deepseek_moe_16b": (13e9, 20e9),
        "qwen15_05b": (0.4e9, 0.8e9),
        "nemotron4_340b": (280e9, 400e9),
        "glm4_9b": (8e9, 12e9),
        "rwkv6_3b": (2.5e9, 5e9),
        "h2o_danube3_4b": (3e9, 5.5e9),
        "hymba_15b": (1e9, 2.5e9),
        "musicgen_large": (2e9, 5e9),       # backbone only (frontend stubbed)
        "internvl2_1b": (0.5e9, 1.2e9),
    }
    for arch, (lo, hi) in expectations.items():
        cfg = get_config(arch)
        n = param_count(cfg)
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
        if cfg.num_experts:
            assert active_param_count(cfg) < n


def test_chunked_recurrences_match_scan():
    from repro.models.ssm import (ssm_chunked, ssm_scan, wkv_chunked,
                                  wkv_scan)
    rng = np.random.default_rng(1)
    B, S, H, K, V = 2, 64, 2, 8, 8
    r = jnp.asarray(rng.standard_normal((B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, K)).astype(np.float32)) * .3
    v = jnp.asarray(rng.standard_normal((B, S, H, V)).astype(np.float32))
    logw = -jnp.exp(jnp.asarray(
        rng.standard_normal((B, S, H, K)).astype(np.float32)))
    u = jnp.asarray(rng.standard_normal((H, K)).astype(np.float32)) * 0.2
    o1, s1 = wkv_scan(r, k, v, logw, u)
    for chunk in (1, 8, 16, 64):
        o2, s2 = wkv_chunked(r, k, v, logw, u, chunk=chunk)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                                   atol=1e-4)
    D, N = 6, 4
    a = jnp.asarray(rng.uniform(0.01, 0.999, (B, S, D, N)).astype(np.float32))
    bx = jnp.asarray(rng.standard_normal((B, S, D, N)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    y1, h1 = ssm_scan(a, bx, c)
    y2, h2 = ssm_chunked(a, bx, c, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)


def test_moe_sort_dispatch_matches_einsum():
    """Production sort-based capacity dispatch == einsum reference, at the
    same (small) capacity, including token dropping."""
    from repro.models import moe as M
    cfg = get_config("olmoe_1b_7b").reduced()
    params, _ = M.moe_init(jax.random.PRNGKey(9), cfg.d_model, cfg.d_ff,
                           cfg.num_experts, 0, cfg.activation)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    for cf in (0.5, 1.25, 4.0):
        o1, a1 = M.moe_capacity_einsum(params, x,
                                       num_experts=cfg.num_experts,
                                       top_k=cfg.top_k, capacity_factor=cf)
        o2, a2 = M.moe_capacity(params, x, num_experts=cfg.num_experts,
                                top_k=cfg.top_k, capacity_factor=cf)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


PREFILL_PARITY_ARCHS = ["qwen15_05b", "h2o_danube3_4b", "rwkv6_3b",
                        "hymba_15b", "olmoe_1b_7b"]


@pytest.mark.parametrize("arch", PREFILL_PARITY_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """prefill(prompt) + decode continuation == full forward logits.

    MoE uses a drop-free capacity factor: capacity dropping is a function of
    the dispatch batch, so exact parity across prefill/decode batch shapes
    only holds when nothing drops (the serving configuration)."""
    from repro.models.lm import prefill
    cfg = get_config(arch).reduced(capacity_factor=8.0)
    params, _ = init_params(cfg, jax.random.PRNGKey(11))
    b, s_prompt, s_total = 2, 9, 14
    tokens = jnp.asarray(np.random.default_rng(12).integers(
        0, cfg.vocab_size, (b, s_total)).astype(np.int32))

    want, _ = forward(params, cfg, tokens, dtype=jnp.float32)

    logits, cache = prefill(params, cfg, tokens[:, :s_prompt],
                            dtype=jnp.float32, cache_len=s_total)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(want[:, s_prompt - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(s_prompt, s_total):
        logits, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                    jnp.int32(t), cache, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(want[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_query_chunked_attention_matches_full():
    cfg = get_config("glm4_9b").reduced()
    cfgc = get_config("glm4_9b").reduced(attn_query_chunk=4)
    params, _ = init_params(cfg, jax.random.PRNGKey(13))
    tokens = jnp.asarray(np.random.default_rng(14).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32))
    full, _ = forward(params, cfg, tokens, dtype=jnp.float32)
    chunked, _ = forward(params, cfgc, tokens, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-4, atol=1e-4)


def test_query_chunked_swa_matches_full():
    cfg = get_config("h2o_danube3_4b").reduced()
    cfgc = get_config("h2o_danube3_4b").reduced(attn_query_chunk=4)
    params, _ = init_params(cfg, jax.random.PRNGKey(15))
    tokens = jnp.asarray(np.random.default_rng(16).integers(
        0, cfg.vocab_size, (2, 16)).astype(np.int32))
    full, _ = forward(params, cfg, tokens, dtype=jnp.float32)
    chunked, _ = forward(params, cfgc, tokens, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-4, atol=1e-4)


def test_banded_swa_matches_full():
    """Banded SWA (window-band KV slices per query chunk) == full SWA."""
    cfg = get_config("h2o_danube3_4b").reduced(
        sliding_window=4, attn_query_chunk=4, swa_banded=True)
    cfg_ref = get_config("h2o_danube3_4b").reduced(sliding_window=4)
    params, _ = init_params(cfg, jax.random.PRNGKey(21))
    tokens = jnp.asarray(np.random.default_rng(22).integers(
        0, cfg.vocab_size, (2, 32)).astype(np.int32))
    got, _ = forward(params, cfg, tokens, dtype=jnp.float32)
    want, _ = forward(params, cfg_ref, tokens, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_chunked_loss_matches_full():
    """Sequence-chunked CE (never materializes [B,S,V]) == full CE, incl.
    gradients."""
    cfg = get_config("qwen15_05b").reduced()
    cfg_c = get_config("qwen15_05b").reduced(loss_seq_chunk=4)
    params, _ = init_params(cfg, jax.random.PRNGKey(23))
    batch = make_batch(cfg, s=16, key=24)
    (l1, _), g1 = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch, dtype=jnp.float32),
        has_aux=True)(params)
    (l2, _), g2 = jax.value_and_grad(
        lambda p: lm_loss(p, cfg_c, batch, dtype=jnp.float32),
        has_aux=True)(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_moe_grouped_dispatch_matches_sorted_uncapped():
    """Grouped (per-row local sort) dispatch == drop-free sorted dispatch
    when capacity is ample."""
    from repro.models import moe as M
    cfg = get_config("olmoe_1b_7b").reduced()
    params, _ = M.moe_init(jax.random.PRNGKey(30), cfg.d_model, cfg.d_ff,
                           cfg.num_experts, 0, cfg.activation)
    x = jax.random.normal(jax.random.PRNGKey(31), (3, 8, cfg.d_model),
                          jnp.float32) * 0.5
    out_g, aux_g = M.moe_capacity_grouped(params, x,
                                          num_experts=cfg.num_experts,
                                          top_k=cfg.top_k,
                                          capacity_factor=100.0)
    out_s, aux_s = M.moe_sorted(params, x, num_experts=cfg.num_experts,
                                top_k=cfg.top_k)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_s),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux_g), float(aux_s), rtol=1e-5)
