"""Shared pytest config: the known-failures quarantine.

``tests/known_failures.txt`` lists test node ids that fail for known,
environment-level reasons (tracked in the file's comments).  They are
*quarantined* — marked ``xfail(strict=False)`` so the tier-1 gate stays
green without deleting the tests — and un-quarantine automatically the
moment they start passing (xpass is not an error; just remove the line).

Set ``REPRO_NO_QUARANTINE=1`` to run the suite without the marker (e.g. to
regenerate the list).
"""
from __future__ import annotations

import os
import pathlib

import pytest

_LIST = pathlib.Path(__file__).parent / "known_failures.txt"


def _load_known_failures() -> set[str]:
    if os.environ.get("REPRO_NO_QUARANTINE") or not _LIST.exists():
        return set()
    out = set()
    for line in _LIST.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


_KNOWN = _load_known_failures()


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.nodeid in _KNOWN:
            item.add_marker(pytest.mark.xfail(
                reason="quarantined: see tests/known_failures.txt",
                strict=False))
