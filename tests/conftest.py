"""Shared pytest config: the known-failures quarantine.

``tests/known_failures.txt`` lists test node ids that fail for known,
environment-level reasons (tracked in the file's comments).  They are
*quarantined* — marked ``xfail(strict=False)`` so the tier-1 gate stays
green without deleting the tests — and un-quarantine automatically the
moment they start passing (xpass is not an error; just remove the line).

Set ``REPRO_NO_QUARANTINE=1`` to run the suite without the marker (e.g. to
regenerate the list).
"""
from __future__ import annotations

import os
import pathlib

import pytest

_LIST = pathlib.Path(__file__).parent / "known_failures.txt"


def _load_known_failures() -> set[str]:
    if os.environ.get("REPRO_NO_QUARANTINE") or not _LIST.exists():
        return set()
    out = set()
    for line in _LIST.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


_KNOWN = _load_known_failures()


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.nodeid in _KNOWN:
            item.add_marker(pytest.mark.xfail(
                reason="quarantined: see tests/known_failures.txt",
                strict=False))


@pytest.fixture(autouse=True, scope="module")
def _bound_jax_compile_cache():
    """Flush jax's in-process caches at each module boundary.

    A full tier-1 run compiles thousands of distinct programs into one
    process; past a few hundred, XLA:CPU's compiler can segfault on an
    otherwise-fine compile (observed deterministically at ~470 tests in —
    the same test passes in isolation or any shorter prefix).  Clearing
    between modules keeps the live compiled-program population bounded;
    within a module, tests still share traces, so the re-trace cost is one
    warmup per module, not per test.
    """
    yield
    import jax
    jax.clear_caches()
