"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.sparse import CSR, random_csr
from repro.kernels.spmv_merge import ops as spmv_ops
from repro.kernels.spmv_merge import ref as spmv_ref
from repro.kernels.segmm import ops as segmm_ops
from repro.kernels.segmm import ref as segmm_ref


# ---------------------------------------------------------------------------
# merge-path SpMV
# ---------------------------------------------------------------------------

SPMV_CASES = [
    # rows, cols, nnz, skew, empty_frac
    (64, 64, 512, 0.0, 0.0),
    (300, 200, 4_000, 1.2, 0.2),       # skewed + empty rows
    (1, 500, 400, 0.0, 0.0),           # single dense-ish row
    (500, 1, 250, 0.0, 0.5),           # single-column "sparse vector"
    (1000, 1000, 50, 0.0, 0.9),        # nearly empty
    (128, 4096, 20_000, 1.6, 0.0),     # heavy skew, wide
]


class TestSpMVMergePath:
    @pytest.mark.parametrize("rows,cols,nnz,skew,ef", SPMV_CASES)
    def test_shape_sweep(self, rows, cols, nnz, skew, ef):
        A = random_csr(rows, cols, nnz, skew=skew, empty_frac=ef, seed=rows)
        x = jnp.asarray(np.random.default_rng(1).standard_normal(cols)
                        .astype(np.float32))
        got = spmv_ops.spmv_merge_path(A, x)
        want = spmv_ref.spmv_ref(A.row_offsets, A.col_indices, A.values, x,
                                 rows)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("block_items", [128, 256, 512, 1024])
    def test_block_size_sweep(self, block_items):
        A = random_csr(256, 256, 3_000, skew=1.0, empty_frac=0.1, seed=2)
        x = jnp.asarray(np.random.default_rng(2).standard_normal(256)
                        .astype(np.float32))
        got = spmv_ops.spmv_merge_path(A, x, block_items=block_items)
        want = spmv_ref.spmv_ref(A.row_offsets, A.col_indices, A.values, x,
                                 256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        A0 = random_csr(100, 100, 1_000, skew=0.8, seed=3)
        A = CSR(A0.row_offsets, A0.col_indices, A0.values.astype(dtype),
                A0.shape, A0.nnz)
        x = jnp.asarray(np.random.default_rng(3).standard_normal(100)
                        .astype(np.float32)).astype(dtype)
        got = spmv_ops.spmv_merge_path(A, x)
        want = spmv_ref.spmv_ref(A.row_offsets, A.col_indices,
                                 A.values.astype(jnp.float32),
                                 x.astype(jnp.float32), 100)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    @given(rows=st.integers(1, 80), nnz=st.integers(0, 400),
           skew=st.floats(0.0, 1.8), seed=st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_property_random(self, rows, nnz, skew, seed):
        A = random_csr(rows, 60, nnz, skew=skew, seed=seed)
        x = jnp.asarray(np.random.default_rng(seed).standard_normal(60)
                        .astype(np.float32))
        got = spmv_ops.spmv_merge_path(A, x, block_items=128)
        want = spmv_ref.spmv_ref(A.row_offsets, A.col_indices, A.values, x,
                                 rows)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_merge_stream_is_bijection(self):
        A = random_csr(50, 50, 300, skew=1.0, empty_frac=0.2, seed=9)
        total = 50 + A.nnz
        x = jnp.ones((50,), jnp.float32)
        sv, sr = spmv_ref.merge_stream_ref(A.row_offsets, A.col_indices,
                                           A.values, x, 50, A.nnz, total)
        sr = np.asarray(sr)
        assert (sr < 50).all()                    # every slot claimed
        assert (np.diff(sr) >= 0).all()           # rows appear in order


# ---------------------------------------------------------------------------
# segmented (grouped) matmul
# ---------------------------------------------------------------------------

SEGMM_CASES = [
    # T, K, N, E, bm, bn, bk
    (256, 64, 64, 4, 32, 32, 32),
    (300, 64, 96, 5, 32, 96, 64),      # non-divisible T
    (64, 128, 128, 8, 64, 128, 128),
    (512, 32, 32, 1, 128, 32, 32),     # single expert
    (100, 48, 80, 16, 16, 16, 16),     # many experts, few tokens
]


class TestSegmentedMatmul:
    @pytest.mark.parametrize("T,K,N,E,bm,bn,bk", SEGMM_CASES)
    def test_shape_sweep(self, T, K, N, E, bm, bn, bk):
        rng = np.random.default_rng(T + E)
        tokens = jnp.asarray(rng.standard_normal((T, K)).astype(np.float32))
        eot = jnp.asarray(rng.integers(0, E, T).astype(np.int32))
        rhs = jnp.asarray(rng.standard_normal((E, K, N)).astype(np.float32))
        out = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=E,
                                       bm=bm, bn=bn, bk=bk)
        want = segmm_ref.grouped_matmul_ref(tokens, eot, rhs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_collapsed_routing(self):
        """Router collapse: all tokens to one expert — worst-case imbalance."""
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.standard_normal((128, 32)).astype(np.float32))
        eot = jnp.full((128,), 3, jnp.int32)
        rhs = jnp.asarray(rng.standard_normal((8, 32, 48)).astype(np.float32))
        out = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=8,
                                       bm=32, bn=48, bk=32)
        want = segmm_ref.grouped_matmul_ref(tokens, eot, rhs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        rng = np.random.default_rng(5)
        tokens = jnp.asarray(rng.standard_normal((96, 32)).astype(np.float32)
                             ).astype(dtype)
        eot = jnp.asarray(rng.integers(0, 4, 96).astype(np.int32))
        rhs = jnp.asarray(rng.standard_normal((4, 32, 32)).astype(np.float32)
                          ).astype(dtype)
        out = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=4,
                                       bm=32, bn=32, bk=32)
        want = segmm_ref.grouped_matmul_ref(tokens.astype(jnp.float32), eot,
                                            rhs.astype(jnp.float32))
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=tol, atol=tol)

    @given(T=st.integers(1, 120), E=st.integers(1, 9),
           seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_property_random_routing(self, T, E, seed):
        rng = np.random.default_rng(seed)
        tokens = jnp.asarray(rng.standard_normal((T, 16)).astype(np.float32))
        eot = jnp.asarray(rng.integers(0, E, T).astype(np.int32))
        rhs = jnp.asarray(rng.standard_normal((E, 16, 16)).astype(np.float32))
        out = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=E,
                                       bm=8, bn=16, bk=16)
        want = segmm_ref.grouped_matmul_ref(tokens, eot, rhs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
