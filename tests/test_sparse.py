"""Tests: sparse formats, load-balanced SpMV/SpMM, BFS/SSSP."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Schedule
from repro.sparse import (COO, CSR, Graph, bfs, random_csr, spmm, spmv,
                          spmv_reference, sssp, suite_like_corpus)

RNG = np.random.default_rng(7)


def dense_random(rows, cols, density=0.3, seed=0):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((rows, cols)).astype(np.float32)
    d[rng.random((rows, cols)) >= density] = 0.0
    return d


class TestFormats:
    @pytest.mark.parametrize("rows,cols,density", [(17, 13, 0.3), (1, 40, 0.8),
                                                   (40, 1, 0.5), (8, 8, 0.0)])
    def test_dense_roundtrip(self, rows, cols, density):
        d = dense_random(rows, cols, density)
        A = CSR.from_dense(d)
        np.testing.assert_allclose(A.to_dense(), d, rtol=1e-6)

    def test_coo_to_csr_unsorted(self):
        d = dense_random(9, 9, 0.4, seed=3)
        A = CSR.from_dense(d)
        coo = A.to_coo()
        perm = RNG.permutation(A.nnz)
        shuffled = COO(coo.row_indices[perm], coo.col_indices[perm],
                       coo.values[perm], coo.shape, coo.nnz)
        np.testing.assert_allclose(shuffled.to_csr().to_dense(), d, rtol=1e-6)

    def test_transpose(self):
        d = dense_random(6, 11, 0.5, seed=4)
        A = CSR.from_dense(d)
        np.testing.assert_allclose(A.transpose().to_dense(), d.T, rtol=1e-6)

    def test_random_csr_structure(self):
        A = random_csr(200, 100, 2000, skew=1.0, empty_frac=0.2, seed=1)
        off = np.asarray(A.row_offsets)
        assert off[0] == 0 and off[-1] == A.nnz
        assert (np.diff(off) >= 0).all()
        assert (np.asarray(A.col_indices) < 100).all()

    def test_corpus_generates(self):
        corpus = suite_like_corpus()
        assert len(corpus) >= 12
        for name, A in corpus:
            off = np.asarray(A.row_offsets)
            assert off[-1] == A.nnz, name


ALL_SCHEDULES = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
                 Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]


class TestSpMV:
    @pytest.mark.parametrize("schedule", ALL_SCHEDULES)
    def test_all_schedules_match_dense(self, schedule):
        d = dense_random(50, 70, 0.2, seed=5)
        A = CSR.from_dense(d)
        x = RNG.standard_normal(70).astype(np.float32)
        y = spmv(A, jnp.asarray(x), schedule=schedule, num_blocks=7)
        np.testing.assert_allclose(np.asarray(y), d @ x, rtol=1e-4, atol=1e-4)

    def test_heuristic_dispatch(self):
        d = dense_random(30, 30, 0.3, seed=6)
        A = CSR.from_dense(d)
        x = RNG.standard_normal(30).astype(np.float32)
        y = spmv(A, jnp.asarray(x))  # schedule=None -> heuristic
        np.testing.assert_allclose(np.asarray(y), d @ x, rtol=1e-4, atol=1e-4)

    def test_skewed_matrix(self):
        A = random_csr(300, 300, 5000, skew=1.4, empty_frac=0.3, seed=2)
        x = RNG.standard_normal(300).astype(np.float32)
        want = np.asarray(spmv_reference(A, jnp.asarray(x)))
        for schedule in ALL_SCHEDULES:
            got = spmv(A, jnp.asarray(x), schedule=schedule, num_blocks=32)
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                       atol=1e-4)


class TestSpMM:
    def test_matches_dense(self):
        d = dense_random(40, 30, 0.25, seed=8)
        A = CSR.from_dense(d)
        B = RNG.standard_normal((30, 9)).astype(np.float32)
        C = spmm(A, jnp.asarray(B), schedule=Schedule.MERGE_PATH,
                 num_blocks=11)
        np.testing.assert_allclose(np.asarray(C), d @ B, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("schedule", ALL_SCHEDULES
                             + [Schedule.CHUNKED, Schedule.ADAPTIVE])
    def test_all_schedules_match_dense(self, schedule):
        d = dense_random(30, 20, 0.3, seed=9)
        A = CSR.from_dense(d)
        B = RNG.standard_normal((20, 5)).astype(np.float32)
        C = spmm(A, jnp.asarray(B), schedule=schedule, num_blocks=6)
        np.testing.assert_allclose(np.asarray(C), d @ B, rtol=1e-4, atol=1e-4)

    def test_one_partition_build_per_call(self):
        # regression: spmm's inspector must run once per *matrix*, not once
        # per column of B (the partition is column-invariant)
        from repro.core import partition_build_count
        d = dense_random(25, 18, 0.3, seed=10)
        A = CSR.from_dense(d)
        B = jnp.asarray(RNG.standard_normal((18, 12)).astype(np.float32))
        before = partition_build_count()
        C = spmm(A, B, schedule=Schedule.NONZERO_SPLIT, num_blocks=5)
        C.block_until_ready()
        assert partition_build_count() - before == 1
        np.testing.assert_allclose(np.asarray(C), d @ np.asarray(B),
                                   rtol=1e-4, atol=1e-4)


class TestGraph:
    def _random_graph(self, V=25, density=0.15, seed=11):
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.1, 2.0, (V, V)) * (rng.random((V, V)) < density)
        np.fill_diagonal(w, 0.0)
        return w, Graph(CSR.from_dense(w.astype(np.float32)))

    def test_sssp_matches_bellman_ford(self):
        from _conformance import np_sssp
        w, g = self._random_graph()
        dist = np.asarray(sssp(g, 0))
        np.testing.assert_allclose(dist, np_sssp(w, 0), rtol=1e-5)

    def test_bfs_depths(self):
        # path graph 0->1->2->3 plus shortcut 0->2
        d = np.zeros((4, 4), np.float32)
        d[0, 1] = d[1, 2] = d[2, 3] = 1.0
        d[0, 2] = 1.0
        g = Graph(CSR.from_dense(d))
        np.testing.assert_array_equal(np.asarray(bfs(g, 0)), [0, 1, 1, 2])

    def test_bfs_unreachable(self):
        d = np.zeros((3, 3), np.float32)
        d[0, 1] = 1.0
        g = Graph(CSR.from_dense(d))
        np.testing.assert_array_equal(np.asarray(bfs(g, 0)), [0, 1, -1])
