"""Measured-cost feedback loop (repro.core.measure / autotune measured mode).

The PR-6 acceptance contracts, as tests:

* measurement-as-posterior ranking — fake measurements that invert the
  model's order must flip the selection;
* a cache written in measured mode re-ranks on reload **without
  re-measuring** (asserted via the ``measurement_count`` hook);
* ``fit_coefficients`` recovers planted coefficients from synthetic
  measured samples and refuses an empty sample set;
* the ``time_fn`` warmup contract and ``geomean``'s empty-input error.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (WorkSpec, blend_scores, collect_fit_samples,
                        fit_coefficients, geomean, measurement_count,
                        time_fn)
from repro.core.autotune import (AutotuneCache, Plan, REGISTERED_PLANS,
                                 measurement_enabled, score_plans,
                                 select_plan)
from repro.core.balance import WORKLOAD_ATOM_COEF, cost_features

NB = 16


def spec_from_sizes(sizes):
    sizes = np.asarray(sizes, np.int32)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(offsets),
                                         num_atoms=int(offsets[-1]))


SPEC = spec_from_sizes([1, 1, 2, 2, 3, 4, 6, 9, 14, 22, 35, 56, 90, 144])


class TestTimeFnContract:
    def test_warmup_zero_rejected(self):
        with pytest.raises(ValueError, match="warmup"):
            time_fn(lambda: 1, warmup=0)

    def test_iters_zero_rejected(self):
        with pytest.raises(ValueError, match="iters"):
            time_fn(lambda: 1, iters=0)

    def test_returns_positive_us_and_counts(self):
        before = measurement_count()
        us = time_fn(lambda x: x + 1, jnp.ones(8), warmup=1, iters=2)
        assert us > 0
        assert measurement_count() == before + 1

    def test_geomean_empty_is_error(self):
        with pytest.raises(ValueError, match="empty"):
            geomean([])

    def test_geomean_of_ratios(self):
        assert geomean([2.0, 8.0]) == pytest.approx(4.0)
        assert geomean([1.0]) == pytest.approx(1.0)


class TestBlendScores:
    def test_no_measurements_is_identity(self):
        scores = {Plan.decode("merge_path@pure"): 10.0,
                  Plan.decode("chunked@pure"): 20.0}
        assert blend_scores(scores, {}) == scores

    def test_measured_plans_score_measured_time(self):
        p1, p2 = (Plan.decode("merge_path@pure"),
                  Plan.decode("chunked@pure"))
        blended = blend_scores({p1: 10.0, p2: 20.0}, {p1: 5.0})
        assert blended[p1] == 5.0
        # unmeasured plan: model cost scaled by the measured/model ratio
        assert blended[p2] == pytest.approx(20.0 * (5.0 / 10.0))

    def test_inverted_measurements_flip_ranking(self):
        p1, p2 = (Plan.decode("merge_path@pure"),
                  Plan.decode("chunked@pure"))
        scores = {p1: 10.0, p2: 20.0}           # model prefers p1
        blended = blend_scores(scores, {p1: 9.0, p2: 3.0})
        assert blended[p2] < blended[p1]        # measurement prefers p2


class TestMeasuredSelection:
    def _fake_measure(self, table, calls):
        def run(plan):
            calls.append(plan.encode())
            return table[plan.encode()]
        return run

    def test_env_gate_off_means_model_only(self, monkeypatch, tmp_path):
        monkeypatch.delenv("REPRO_AUTOTUNE_MEASURE", raising=False)
        assert not measurement_enabled()
        calls = []
        plan = select_plan(SPEC, NB,
                           cache=AutotuneCache(tmp_path / "c.json"),
                           measure=self._fake_measure({}, calls))
        assert calls == []                      # closure never consulted
        scores = score_plans(SPEC, NB, REGISTERED_PLANS, "reduce")
        assert scores[plan] == min(scores.values())

    def test_measurement_overrides_model(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_AUTOTUNE_MEASURE", "1")
        cache = AutotuneCache(tmp_path / "c.json")
        scores = score_plans(SPEC, NB, REGISTERED_PLANS, "reduce")
        ranked = sorted(REGISTERED_PLANS, key=lambda p: scores[p])
        # fake wall clock inverts the model's top-3: the model's 3rd pick
        # measures fastest
        table = {ranked[0].encode(): 30.0, ranked[1].encode(): 20.0,
                 ranked[2].encode(): 10.0}
        calls = []
        plan = select_plan(SPEC, NB, cache=cache,
                           measure=self._fake_measure(table, calls))
        assert len(calls) == 3                  # top-k measured once each
        assert plan == ranked[2]                # measurement won

    def test_reload_reranks_without_remeasuring(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_AUTOTUNE_MEASURE", "1")
        path = tmp_path / "c.json"
        scores = score_plans(SPEC, NB, REGISTERED_PLANS, "reduce")
        ranked = sorted(REGISTERED_PLANS, key=lambda p: scores[p])
        table = {ranked[0].encode(): 30.0, ranked[1].encode(): 20.0,
                 ranked[2].encode(): 10.0}
        calls = []
        first = select_plan(SPEC, NB, cache=AutotuneCache(path),
                            measure=self._fake_measure(table, calls))
        assert len(calls) == 3
        # fresh cache object = new process reloading the persisted JSON
        calls2 = []
        again = select_plan(SPEC, NB, cache=AutotuneCache(path),
                            measure=self._fake_measure(table, calls2))
        assert calls2 == []                     # zero re-measurement
        assert again == first

    def test_measured_records_carry_features(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_AUTOTUNE_MEASURE", "1")
        cache = AutotuneCache(tmp_path / "c.json")
        select_plan(SPEC, NB, cache=cache,
                    measure=lambda p: 5.0)
        samples = collect_fit_samples(cache)
        assert len(samples) == 3                # one per measured candidate
        for base, feats, us in samples:
            assert us == 5.0
            assert base >= 0
        # at least one candidate exercises a tunable coefficient (a static
        # pure-path reduce folds everything into base — that is fine, it
        # still anchors the fitted time scale)
        assert any(feats for _, feats, _ in samples)

    def test_no_cache_still_measures_and_blends(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_MEASURE", "1")
        scores = score_plans(SPEC, NB, REGISTERED_PLANS, "reduce")
        ranked = sorted(REGISTERED_PLANS, key=lambda p: scores[p])
        table = {ranked[0].encode(): 30.0, ranked[1].encode(): 20.0,
                 ranked[2].encode(): 10.0}
        calls = []
        plan = select_plan(SPEC, NB, cache=None,
                           measure=self._fake_measure(table, calls))
        assert plan == ranked[2] and len(calls) == 3


class TestCostFeatures:
    def test_features_reconstruct_modeled_cost(self):
        from repro.core import modeled_advance_cost, modeled_cost
        for sched in ("merge_path", "nonzero_split", "chunked"):
            base, feats = cost_features(SPEC, sched, NB, workload="advance")
            import repro.core.balance as B
            total = base + sum(feats[n] * getattr(B, n) for n in feats)
            want = modeled_advance_cost(SPEC, sched, NB)
            assert total == pytest.approx(want, rel=1e-6), sched

    def test_atom_coef_map_covers_workloads(self):
        from repro.core.autotune import WORKLOAD_ATOM_WORK
        assert set(WORKLOAD_ATOM_COEF) == set(WORKLOAD_ATOM_WORK)


class TestFitCoefficients:
    def test_empty_samples_rejected(self):
        with pytest.raises(ValueError, match="at least one"):
            fit_coefficients([])

    def test_recovers_planted_coefficients(self):
        rng = np.random.default_rng(7)
        true = {"ADVANCE_ATOM_WORK": 3.5, "NATIVE_CHUNK_OVERHEAD": 0.4}
        scale = 2.0
        samples = []
        for _ in range(40):
            base = float(rng.uniform(1, 50))
            feats = {"ADVANCE_ATOM_WORK": float(rng.uniform(1, 100)),
                     "NATIVE_CHUNK_OVERHEAD": float(rng.uniform(1, 100))}
            t = scale * (base + sum(feats[n] * true[n] for n in feats))
            samples.append((base, feats, t))
        fit = fit_coefficients(samples)
        assert fit.scale_us_per_step == pytest.approx(scale, rel=1e-4)
        assert fit.coefficients["ADVANCE_ATOM_WORK"] == pytest.approx(
            3.5, rel=1e-3)
        assert fit.coefficients["NATIVE_CHUNK_OVERHEAD"] == pytest.approx(
            0.4, rel=1e-3)
        assert fit.residual_rel < 1e-6
        # untouched coefficients stay at their current value, unflagged
        assert set(fit.constrained) == {"ADVANCE_ATOM_WORK",
                                        "NATIVE_CHUNK_OVERHEAD"}
        assert fit.coefficients["COMPACT_GATHER_WORK"] == \
            fit.current["COMPACT_GATHER_WORK"]

    def test_report_renders(self):
        samples = [(1.0, {"ADVANCE_ATOM_WORK": 10.0}, 42.0)]
        rep = fit_coefficients(samples).report()
        assert "ADVANCE_ATOM_WORK" in rep and "scale" in rep
