"""Native chunk-walking execution path (repro.core.execute + Pallas kernels).

The acceptance bar for the device-side dynamic-schedule path: the native
Pallas chunk-walking kernels must be *bit-identical* to the pure-JAX blocked
executor and to the reference implementations, for every schedule, every
combiner, including empty chunks and ``num_chunks < num_blocks``.  Workload
zoo, oracles and comparators live in the shared conformance library
(``tests/_conformance.py``); this file owns the native-path-specific
routing/fallback/queue-inversion checks.
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    ExecutionPath, Plan, Schedule, WorkSpec, blocked_tile_reduce,
    choose_execution_path, execute_tile_reduce, invert_block_map,
    make_partition, native_chunk_tile_reduce, resolve_execution_path,
    score_plans, select_plan, supports_native_execution, tile_reduce,
)
from _conformance import (
    COMBINERS, WORKLOADS, assert_bitwise_equal,
    check_tile_reduce_conformance, int_valued_atom_fn, np_tile_reduce,
    int_valued_atom_values, spec_from_sizes,
)

SCHEDULES = [Schedule.CHUNKED, Schedule.ADAPTIVE, Schedule.NONZERO_SPLIT,
             Schedule.MERGE_PATH, Schedule.THREAD_MAPPED]


class TestNativeTileReduce:
    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("name", sorted(WORKLOADS))
    def test_bit_identical_to_pure_and_oracle(self, schedule, name):
        spec = spec_from_sizes(WORKLOADS[name])
        part = make_partition(spec, schedule, 4)
        fn = int_valued_atom_fn(spec)
        native = native_chunk_tile_reduce(spec, part, fn)
        pure = blocked_tile_reduce(spec, part, fn)
        oracle = tile_reduce(spec, fn)
        assert_bitwise_equal(native, pure, f"{schedule}/{name} vs pure")
        assert_bitwise_equal(native, oracle, f"{schedule}/{name} vs oracle")

    @pytest.mark.parametrize("combiner", COMBINERS)
    @pytest.mark.parametrize("name", sorted(WORKLOADS))
    def test_combiner_matrix_matches_numpy_oracle(self, name, combiner):
        # the full schedule x path matrix per combiner, differenced against
        # the pure-NumPy oracle (no jax on the reference side)
        spec = spec_from_sizes(WORKLOADS[name])
        vals = int_valued_atom_values(spec.num_atoms, seed=3)
        jvals = jnp.asarray(vals)
        fn = lambda a: jvals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]
        oracle = np_tile_reduce(np.asarray(spec.tile_offsets), vals, combiner)
        check_tile_reduce_conformance(spec, fn, combiner=combiner,
                                      oracle=oracle)

    @pytest.mark.parametrize("combiner", COMBINERS)
    def test_atom_mask_matrix_matches_numpy_oracle(self, combiner):
        # the frontier-mask operand: masked atoms contribute the identity
        # on every schedule x path, bit-identically to NumPy
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        vals = int_valued_atom_values(spec.num_atoms, seed=5)
        mask = np.random.default_rng(6).random(spec.num_atoms) < 0.4
        jvals, jmask = jnp.asarray(vals), jnp.asarray(mask)
        fn = lambda a: jvals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]
        oracle = np_tile_reduce(np.asarray(spec.tile_offsets), vals,
                                combiner, mask)
        check_tile_reduce_conformance(spec, fn, combiner=combiner,
                                      atom_mask=jmask, oracle=oracle)

    @pytest.mark.parametrize("schedule",
                             [Schedule.CHUNKED, Schedule.ADAPTIVE])
    def test_fewer_chunks_than_blocks(self, schedule):
        # num_atoms=2 caps the chunked oversplit at 2 chunks for 8 blocks;
        # most physical blocks then own an empty queue.
        spec = spec_from_sizes([0, 1, 0, 1, 0])
        part = make_partition(spec, schedule, 8)
        fn = int_valued_atom_fn(spec)
        assert_bitwise_equal(native_chunk_tile_reduce(spec, part, fn),
                             tile_reduce(spec, fn))

    def test_empty_chunks(self):
        # all-empty tiles inside the span produce zero-atom chunks
        spec = spec_from_sizes([4, 0, 0, 0, 0, 4])
        part = make_partition(spec, Schedule.CHUNKED, 4)
        fn = int_valued_atom_fn(spec)
        assert_bitwise_equal(native_chunk_tile_reduce(spec, part, fn),
                             tile_reduce(spec, fn))

    def test_all_empty_workload(self):
        spec = spec_from_sizes([0, 0, 0])
        part = make_partition(spec, Schedule.CHUNKED, 4)
        out = native_chunk_tile_reduce(spec, part, lambda a: a * 0.0)
        np.testing.assert_array_equal(np.asarray(out), np.zeros(3, np.float32))

    def test_dispatcher_routes_dynamic_to_native(self):
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        part = make_partition(spec, Schedule.CHUNKED, 4)
        assert choose_execution_path(part) == ExecutionPath.NATIVE
        fn = int_valued_atom_fn(spec)
        assert_bitwise_equal(execute_tile_reduce(spec, part, fn),
                             tile_reduce(spec, fn))

    def test_dispatcher_dtype_fallback(self):
        # the native kernel accumulates in f32: auto must fall back to
        # pure for other dtypes (not raise), and accept f32 spellings
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        part = make_partition(spec, Schedule.CHUNKED, 4)
        fn = int_valued_atom_fn(spec)
        got = execute_tile_reduce(spec, part, fn, dtype=jnp.bfloat16)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(tile_reduce(spec, fn, dtype=jnp.bfloat16),
                       np.float32), rtol=0.05, atol=0.5)
        assert_bitwise_equal(
            execute_tile_reduce(spec, part, fn, dtype="float32"),
            tile_reduce(spec, fn))
        with pytest.raises(ValueError):
            execute_tile_reduce(spec, part, fn, dtype=jnp.bfloat16,
                                path="native")

    def test_dispatcher_pure_fallback_under_tracing(self):
        # a partition built inside jit has traced boundaries and no span
        # hints: auto must fall back to pure, native must raise
        spec = spec_from_sizes(WORKLOADS["uniform"])
        fn = int_valued_atom_fn(spec)

        def traced(offsets):
            s = WorkSpec.from_segment_offsets(offsets,
                                              num_atoms=spec.num_atoms,
                                              num_tiles=spec.num_tiles)
            p = make_partition(s, Schedule.NONZERO_SPLIT, 4)
            assert not supports_native_execution(p)
            assert choose_execution_path(p) == ExecutionPath.PURE
            with pytest.raises(ValueError):
                resolve_execution_path("native", native_supported=False)
            return execute_tile_reduce(s, p, fn)

        got = jax.jit(traced)(spec.tile_offsets)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(tile_reduce(spec, fn)))


class TestInvertBlockMap:
    def test_round_trip(self):
        bm = jnp.asarray([2, 0, 1, 0, 2, 2], jnp.int32)
        chunks, counts = invert_block_map(bm, 3)
        assert chunks.shape == (3, 3)
        np.testing.assert_array_equal(np.asarray(counts), [2, 1, 3])
        np.testing.assert_array_equal(np.asarray(chunks[0, :2]), [1, 3])
        np.testing.assert_array_equal(np.asarray(chunks[1, :1]), [2])
        np.testing.assert_array_equal(np.asarray(chunks[2, :3]), [0, 4, 5])

    def test_built_once_on_partition(self):
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        part = make_partition(spec, Schedule.CHUNKED, 4)
        assert part.block_chunks is not None
        assert part.block_chunk_counts is not None
        assert int(part.block_chunk_counts.sum()) == part.num_blocks
        # every chunk appears exactly once across the queues
        seen = []
        bc = np.asarray(part.block_chunks)
        for p, n in enumerate(np.asarray(part.block_chunk_counts)):
            seen.extend(bc[p, :n].tolist())
        assert sorted(seen) == list(range(part.num_blocks))


class TestSegmmNativePath:
    def _setup(self, seed=0, T=96, K=32, N=16, E=5):
        rng = np.random.default_rng(seed)
        tokens = jnp.asarray(rng.integers(-3, 4, (T, K)).astype(np.float32))
        rhs = jnp.asarray(rng.integers(-3, 4, (E, K, N)).astype(np.float32))
        eot = jnp.asarray(rng.integers(0, E, T).astype(np.int32))
        return tokens, eot, rhs, E

    @pytest.mark.parametrize("sched", ["chunked_rr", "chunked_lpt"])
    def test_native_bit_identical_to_pure_and_static(self, sched):
        from repro.kernels.segmm import ops as segmm_ops
        from repro.kernels.segmm import ref as segmm_ref
        tokens, eot, rhs, E = self._setup()
        base = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=E,
                                        bm=16, schedule="group_mapped")
        native = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=E,
                                          bm=16, schedule=sched,
                                          execution_path="native")
        pure = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=E,
                                        bm=16, schedule=sched,
                                        execution_path="pure")
        assert_bitwise_equal(native, pure)
        assert_bitwise_equal(native, base)
        np.testing.assert_allclose(
            np.asarray(native),
            np.asarray(segmm_ref.grouped_matmul_ref(tokens, eot, rhs)),
            rtol=1e-6)

    def test_native_under_jit(self):
        from repro.kernels.segmm import ops as segmm_ops
        tokens, eot, rhs, E = self._setup(seed=1)
        f = jax.jit(lambda t, e, r: segmm_ops.grouped_matmul(
            t, e, r, num_experts=E, bm=16, schedule="chunked_lpt",
            execution_path="native"))
        base = segmm_ops.grouped_matmul(tokens, eot, rhs, num_experts=E,
                                        bm=16, schedule="group_mapped")
        assert_bitwise_equal(f(tokens, eot, rhs), base)


class TestSpmvNativePath:
    def _matrix(self, seed=0, rows=48, cols=32):
        from repro.sparse.formats import CSR
        rng = np.random.default_rng(seed)
        dens = np.round(rng.random((rows, cols)) * 8)
        dens *= rng.random((rows, cols)) < 0.15
        dens[rows // 2] = np.round(rng.random(cols) * 8)   # heavy row
        A = CSR.from_dense(jnp.asarray(dens.astype(np.float32)))
        x = jnp.asarray(rng.integers(-4, 5, cols).astype(np.float32))
        return A, x, dens

    @pytest.mark.parametrize("sched", ["chunked_lpt", "chunked_rr",
                                       "adaptive"])
    def test_native_matches_executor_and_reference(self, sched):
        from repro.core.dynamic import adaptive_partition, chunked_partition
        from repro.kernels.spmv_merge import ops as spmv_ops
        A, x, dens = self._matrix()
        got = spmv_ops.spmv_merge_path(A, x, schedule=sched, num_blocks=8)
        want = dens @ np.asarray(x)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
        spec = A.workspec()
        if sched == "adaptive":
            part = adaptive_partition(spec, 8)
        else:
            policy = "lpt" if sched == "chunked_lpt" else "round_robin"
            part = chunked_partition(spec, 8, policy=policy)
        vals, cols_ = A.values, A.col_indices
        atom_fn = lambda nz: vals[nz] * x[cols_[nz]]
        assert_bitwise_equal(got, blocked_tile_reduce(spec, part, atom_fn))

    def test_pure_fallback_matches(self):
        from repro.kernels.spmv_merge import ops as spmv_ops
        A, x, dens = self._matrix(seed=2)
        got = spmv_ops.spmv_merge_path(A, x, schedule="chunked_lpt",
                                       num_blocks=8, execution_path="pure")
        np.testing.assert_allclose(np.asarray(got), dens @ np.asarray(x),
                                   rtol=1e-6)


class TestPlanSelection:
    def test_select_plan_is_argmin(self):
        for sizes in WORKLOADS.values():
            spec = spec_from_sizes(sizes)
            plan = select_plan(spec, 16, cache=None)
            scores = score_plans(spec, 16)
            assert scores[plan] == min(scores.values())

    def test_native_chunked_outranks_pure_chunked(self):
        rng = np.random.default_rng(0)
        sizes = (rng.pareto(0.8, 500) * 20 + 1).astype(np.int64)
        spec = spec_from_sizes(sizes)
        scores = score_plans(spec, 64)
        native = Plan(Schedule.CHUNKED, ExecutionPath.NATIVE)
        pure = Plan(Schedule.CHUNKED, ExecutionPath.PURE)
        assert scores[native] < scores[pure]
        assert select_plan(spec, 64, cache=None) == native

    def test_auto_partition_supports_native(self):
        # acceptance: make_partition(spec, "auto", nb) can select the
        # native path — the partition it returns must be consumable by the
        # native executor whenever a dynamic schedule wins
        rng = np.random.default_rng(0)
        sizes = (rng.pareto(0.8, 500) * 20 + 1).astype(np.int64)
        spec = spec_from_sizes(sizes)
        part = make_partition(spec, "auto", 64)
        assert supports_native_execution(part)
        fn = int_valued_atom_fn(spec)
        assert_bitwise_equal(execute_tile_reduce(spec, part, fn),
                             tile_reduce(spec, fn))

    def test_plan_cache_roundtrip_and_legacy_values(self, tmp_path):
        from repro.core import AutotuneCache
        path = tmp_path / "cache.json"
        cache = AutotuneCache(path)
        spec = spec_from_sizes(WORKLOADS["powerlaw"])
        plan = select_plan(spec, 16, cache=cache)
        reloaded = AutotuneCache(path)
        assert select_plan(spec, 16, cache=reloaded) == plan
        # PR-1 files store bare schedule names: decoded as pure-path plans
        path.write_text(json.dumps({"legacy": "merge_path"}))
        fresh = AutotuneCache(path)
        assert fresh.get_plan("legacy") == Plan(Schedule.MERGE_PATH,
                                                ExecutionPath.PURE)
        assert fresh.get("legacy") == Schedule.MERGE_PATH
