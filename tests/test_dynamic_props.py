"""Property tests for the dynamic schedules (requires hypothesis).

Every dynamic Partition must cover all atoms exactly once, and blocked
execution under any dynamic schedule must match the ``tile_reduce`` oracle
bit-for-bit (atom values are integer-valued floats, so every summation
order is exact).
"""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (
    Schedule, WorkSpec, adaptive_partition, blocked_tile_reduce,
    chunked_partition, make_partition, tile_reduce,
)

tile_sizes = st.lists(st.integers(min_value=0, max_value=40), min_size=0,
                      max_size=60)


def spec_from_sizes(sizes):
    sizes = np.asarray(sizes, np.int32)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(offsets),
                                         num_atoms=int(offsets[-1]))


class TestCoverage:
    @given(tile_sizes, st.integers(min_value=1, max_value=9),
           st.sampled_from(["lpt", "round_robin"]))
    @settings(max_examples=40, deadline=None)
    def test_chunked_covers_exactly_once(self, sizes, num_blocks, policy):
        spec = spec_from_sizes(sizes)
        part = chunked_partition(spec, num_blocks, policy=policy)
        a = np.asarray(part.atom_starts)
        assert a[0] == 0 and a[-1] == spec.num_atoms
        assert (np.diff(a) >= 0).all()
        counts = np.zeros(spec.num_atoms, np.int64)
        for b in range(len(a) - 1):
            counts[a[b]:a[b + 1]] += 1
        assert (counts == 1).all()
        bm = np.asarray(part.block_map)
        assert bm.shape[0] == part.num_blocks
        assert (bm >= 0).all() and (bm < num_blocks).all()

    @given(tile_sizes, st.integers(min_value=1, max_value=9))
    @settings(max_examples=40, deadline=None)
    def test_adaptive_covers_exactly_once(self, sizes, num_blocks):
        spec = spec_from_sizes(sizes)
        part = adaptive_partition(spec, num_blocks)
        a = np.asarray(part.atom_starts)
        assert a[0] == 0 and a[-1] == spec.num_atoms
        assert (np.diff(a) >= 0).all()
        counts = np.zeros(spec.num_atoms, np.int64)
        for b in range(len(a) - 1):
            counts[a[b]:a[b + 1]] += 1
        assert (counts == 1).all()


class TestBlockedMatchesOracle:
    @pytest.mark.parametrize("schedule",
                             [Schedule.CHUNKED, Schedule.ADAPTIVE])
    @given(sizes=tile_sizes, num_blocks=st.integers(min_value=1, max_value=9),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_bit_for_bit(self, schedule, sizes, num_blocks, seed):
        spec = spec_from_sizes(sizes)
        if spec.num_tiles == 0:
            return
        part = make_partition(spec, schedule, num_blocks)
        rng = np.random.default_rng(seed)
        # integer-valued floats: every summation order is exact, so the
        # blocked result must equal the oracle bitwise, not just approx
        vals = jnp.asarray(rng.integers(-8, 9, max(spec.num_atoms, 1))
                           .astype(np.float32))
        fn = lambda a: vals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]
        got = np.asarray(blocked_tile_reduce(spec, part, fn))
        want = np.asarray(tile_reduce(spec, fn)) if spec.num_atoms else \
            np.zeros(spec.num_tiles, np.float32)
        np.testing.assert_array_equal(got, want)
