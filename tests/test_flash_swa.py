"""Shape/dtype sweeps for the banded SWA flash attention kernel."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_swa import ops, ref


def _rand(shape, seed, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape)
                       .astype(dtype))


CASES = [
    # S, H, hd, window, qc
    (64, 2, 16, 16, 8),
    (128, 1, 32, 32, 16),
    (64, 3, 16, 64, 8),      # window == S (full causal)
    (256, 2, 8, 32, 32),     # window == qc (narrowest band)
    (96, 2, 16, 48, 16),     # non-power-of-two S
]


class TestFlashSWA:
    @pytest.mark.parametrize("S,H,hd,window,qc", CASES)
    def test_shape_sweep(self, S, H, hd, window, qc):
        q = _rand((2, S, H, hd), 1)
        k = _rand((2, S, H, hd), 2)
        v = _rand((2, S, H, hd), 3)
        got = ops.flash_swa(q, k, v, window=window, qc=qc)
        want = ref.swa_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                           (jnp.bfloat16, 3e-2)])
    def test_dtype_sweep(self, dtype, tol):
        q = _rand((1, 64, 2, 16), 4).astype(dtype)
        k = _rand((1, 64, 2, 16), 5).astype(dtype)
        v = _rand((1, 64, 2, 16), 6).astype(dtype)
        got = ops.flash_swa(q, k, v, window=16, qc=8)
        want = ref.swa_attention_ref(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), window=16)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), rtol=tol, atol=tol)

    def test_gqa(self):
        q = _rand((2, 64, 4, 16), 7)
        k = _rand((2, 64, 2, 16), 8)
        v = _rand((2, 64, 2, 16), 9)
        got = ops.flash_swa_gqa(q, k, v, window=32, qc=8)
        want = ref.swa_attention_ref(q, jnp.repeat(k, 2, 2),
                                     jnp.repeat(v, 2, 2), window=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_attend(self):
        """Kernel == the model's masked-softmax SWA core."""
        from repro.models.layers import _attend
        q = _rand((1, 32, 2, 8), 10)
        k = _rand((1, 32, 2, 8), 11)
        v = _rand((1, 32, 2, 8), 12)
        pos = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
        want = _attend(q, k, v, pos, pos, 8 ** -0.5, 8)
        got = ops.flash_swa(q, k, v, window=8, qc=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @given(s_blocks=st.integers(2, 6), wb=st.integers(1, 4),
           seed=st.integers(0, 1000))
    @settings(max_examples=8, deadline=None)
    def test_property_random_bands(self, s_blocks, wb, seed):
        qc = 8
        S, window = s_blocks * qc, min(wb, s_blocks) * qc
        q = _rand((1, S, 1, 8), seed)
        k = _rand((1, S, 1, 8), seed + 1)
        v = _rand((1, S, 1, 8), seed + 2)
        got = ops.flash_swa(q, k, v, window=window, qc=qc)
        want = ref.swa_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
