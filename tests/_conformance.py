"""Shared differential-testing library for the schedule x path matrix.

Every executor in this repo makes the same promise: for any workload, any
registered schedule, and either execution path (pure blocked executor or the
native chunk-walking Pallas kernel), the result is **bit-identical** to a
schedule-free oracle, and every atom is reduced **exactly once**.  This
module is the single home for the machinery that checks that promise, so
each new operator (spmv, segmm, graph advance, ...) gets the full matrix
for free instead of re-growing private copies of it per test file:

* **workload generators** — the canonical shape zoo (``WORKLOADS``), the
  empty-tile window-hazard zoo (``HAZARD_WORKLOADS``), and graph builders
  (power-law + adversarial: isolated vertices, self-loops, disconnected
  components, zero-degree tails);
* **oracle builders** — pure-NumPy segmented reduce and frontier-advance
  references (no jax on the oracle side, so an XLA bug cannot cancel out);
* **fixtures** — the schedule x path product (``SCHEDULE_PATH_CASES``), the
  bitwise comparator, and :func:`check_tile_reduce_conformance`, the
  one-call full-matrix assertion.

Atom values are integer-valued floats throughout so every summation order
is exact and bitwise comparison is meaningful; min/max are exact regardless.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import (ExecutionPath, Schedule, WorkSpec, execute_tile_reduce,
                        make_partition, tile_reduce)

# ---------------------------------------------------------------------------
# The schedule x path matrix.
# ---------------------------------------------------------------------------

#: All six registered concrete schedules (what ``"auto"`` selects among).
SCHEDULES = (Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
             Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH,
             Schedule.CHUNKED, Schedule.ADAPTIVE)

PATHS = (ExecutionPath.PURE, ExecutionPath.NATIVE)

#: The full product, as (schedule, path) pairs for parametrize.
SCHEDULE_PATH_CASES = tuple((s, p) for s in SCHEDULES for p in PATHS)

COMBINERS = ("sum", "min", "max")

# ---------------------------------------------------------------------------
# Workload generators.
# ---------------------------------------------------------------------------

#: Canonical tile-size zoo: uniform, single-heavy, empties, power-law tails.
WORKLOADS = {
    "uniform": [5] * 24,
    "one_heavy": [0, 0, 200, 0, 3, 5],
    "empties_between": [1] + [0] * 30 + [1],
    "powerlaw": [1, 1, 2, 3, 9, 14, 56, 144],
    "single_tile": [64],
}

#: Adversarial shapes for the empty-tile window hazard: atoms bound work,
#: but the tile span of a single block/chunk crosses long empty runs (the
#: PR-1 ``blocked_tile_reduce`` bug class).
HAZARD_WORKLOADS = {
    "empties_between": [1] + [0] * 30 + [1],
    "empty_runs": [2, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 1],
    "heavy_then_empties": [40] + [0] * 25 + [1],
    "alternating": [1, 0] * 20,
    "leading_empties": [0] * 20 + [5, 5],
}


def spec_from_sizes(sizes) -> WorkSpec:
    sizes = np.asarray(sizes, np.int32)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(offsets),
                                         num_atoms=int(offsets[-1]))


def int_valued_atom_values(num_atoms: int, seed: int = 0) -> np.ndarray:
    """Integer-valued f32 atom values: every reduction order is exact."""
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 9, max(num_atoms, 1)).astype(np.float32)


def int_valued_atom_fn(spec: WorkSpec, seed: int = 0):
    vals = jnp.asarray(int_valued_atom_values(spec.num_atoms, seed))
    return lambda a: vals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]


# -- graph workloads --------------------------------------------------------

def powerlaw_graph_dense(V: int, avg_degree: float = 4.0,
                         skew: float = 1.2, seed: int = 0) -> np.ndarray:
    """Dense weight matrix of a scale-free-ish directed graph.

    Out-degrees follow a Zipf-like law (a few hubs own most edges — the
    frontier load-imbalance regime the advance schedules exist for); weights
    are positive integer-valued floats so SSSP sums stay exact.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, V + 1, dtype=np.float64) ** (-skew)
    rng.shuffle(ranks)
    deg = np.minimum((ranks / ranks.sum() * V * avg_degree + rng.random(V))
                     .astype(np.int64), V - 1)
    w = np.zeros((V, V), np.float32)
    for u in range(V):
        if deg[u]:
            dst = rng.choice(V, size=int(deg[u]), replace=False)
            dst = dst[dst != u]
            w[u, dst] = rng.integers(1, 8, dst.size).astype(np.float32)
    return w


def adversarial_graphs(seed: int = 0) -> Dict[str, np.ndarray]:
    """Dense weight matrices for the graph edge cases the suite must cover.

    Edge exists iff weight > 0 (weights integer-valued positive floats).
    """
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}

    # isolated vertices: no in- or out-edges mixed into a random graph
    w = (rng.random((18, 18)) < 0.2) * rng.integers(1, 6, (18, 18))
    w = w.astype(np.float32)
    np.fill_diagonal(w, 0.0)
    for v in (3, 7, 11):
        w[v, :] = 0.0
        w[:, v] = 0.0
    out["isolated_vertices"] = w

    # self-loops on top of a path + shortcut
    w = np.zeros((8, 8), np.float32)
    for v in range(7):
        w[v, v + 1] = 1.0
    w[0, 4] = 3.0
    for v in (0, 2, 5):
        w[v, v] = 1.0          # self-loop must never improve or re-reach
    out["self_loops"] = w

    # two disconnected components (source reaches only the first)
    w = np.zeros((16, 16), np.float32)
    blockA = (rng.random((8, 8)) < 0.4) * rng.integers(1, 5, (8, 8))
    blockB = (rng.random((8, 8)) < 0.4) * rng.integers(1, 5, (8, 8))
    w[:8, :8] = blockA
    w[8:, 8:] = blockB
    np.fill_diagonal(w, 0.0)
    out["disconnected"] = w

    # zero-degree tail: a long run of trailing vertices with no edges at
    # all — empty tiles in both push and pull views (the window hazard)
    w = np.zeros((30, 30), np.float32)
    core = (rng.random((8, 8)) < 0.5) * rng.integers(1, 5, (8, 8))
    w[:8, :8] = core
    np.fill_diagonal(w, 0.0)
    w[7, 8] = 2.0              # one bridge into the tail's first vertex
    out["zero_degree_tail"] = w

    # star: one hub fans out to everyone (max frontier skew in one step)
    w = np.zeros((12, 12), np.float32)
    w[0, 1:] = rng.integers(1, 5, 11).astype(np.float32)
    w[5, 3] = 1.0
    out["star_hub"] = w

    return out


# ---------------------------------------------------------------------------
# Pure-NumPy oracle builders.
# ---------------------------------------------------------------------------

_NP_IDENTITY = {"sum": 0.0, "min": np.inf, "max": -np.inf}
_NP_REDUCE = {"sum": np.sum, "min": np.min, "max": np.max}


def np_tile_reduce(offsets: np.ndarray, values: np.ndarray,
                   combiner: str = "sum",
                   mask: Optional[np.ndarray] = None) -> np.ndarray:
    """Schedule-free segmented reduce, entirely in NumPy."""
    offsets = np.asarray(offsets, np.int64)
    values = np.asarray(values, np.float32)
    out = np.full(offsets.size - 1, _NP_IDENTITY[combiner], np.float32)
    for t in range(offsets.size - 1):
        seg = values[offsets[t]:offsets[t + 1]]
        if mask is not None:
            seg = seg[np.asarray(mask[offsets[t]:offsets[t + 1]], bool)]
        if seg.size:
            out[t] = np.float32(_NP_REDUCE[combiner](seg.astype(np.float32)))
    return out


def np_advance(pull_offsets: np.ndarray, src: np.ndarray,
               edge_values: np.ndarray, frontier: Optional[np.ndarray],
               combiner: str) -> np.ndarray:
    """Frontier-masked advance oracle over a pull (dst-grouped) edge list."""
    mask = None if frontier is None else np.asarray(frontier, bool)[src]
    return np_tile_reduce(pull_offsets, edge_values, combiner, mask)


def np_advance_push(fwd_offsets: np.ndarray, dst: np.ndarray,
                    edge_values: np.ndarray, frontier: Optional[np.ndarray],
                    combiner: str, num_vertices: int) -> np.ndarray:
    """Push-direction advance oracle over a forward (src-grouped) edge list.

    Walks each *source* vertex's out-edges (skipping sources outside the
    frontier — the push view's frontier compaction) and scatter-combines
    into the destinations, entirely in NumPy — the sequential form of
    Listing 5's ``atomicMin`` loop.  For min/max (exact) and integer-valued
    sums this must match :func:`np_advance` over the transposed edge list
    bit for bit; the direction-equivalence tests assert exactly that.
    """
    fwd_offsets = np.asarray(fwd_offsets, np.int64)
    dst = np.asarray(dst, np.int64)
    edge_values = np.asarray(edge_values, np.float32)
    combine = {"sum": np.add, "min": np.minimum, "max": np.maximum}[combiner]
    out = np.full(num_vertices, _NP_IDENTITY[combiner], np.float32)
    for u in range(fwd_offsets.size - 1):
        if frontier is not None and not frontier[u]:
            continue
        for k in range(fwd_offsets[u], fwd_offsets[u + 1]):
            out[dst[k]] = np.float32(combine(out[dst[k]], edge_values[k]))
    return out


def check_advance_direction_equivalence(
        w: np.ndarray, *, combiner: str = "min",
        frontier: Optional[np.ndarray] = None,
        num_blocks: int = 4, seed: int = 0,
        schedules=None, paths=None) -> None:
    """The push/pull direction-equivalence matrix for one graph.

    Builds the advance plan pair for every schedule x execution path and
    asserts, bitwise: pull == its NumPy oracle, push == the push NumPy
    oracle, and push == pull (candidate values are integer-valued, so every
    combine order is exact and direction can never change a single bit).
    One call per (graph, combiner) inherits the whole conformance matrix.
    """
    from repro.sparse import CSR, Graph, advance, advance_push, build_advance

    g = Graph(CSR.from_dense(np.asarray(w, np.float32)))
    V = g.num_vertices
    rng = np.random.default_rng(seed)
    vertex_vals = rng.integers(1, 9, max(V, 1)).astype(np.float32)
    if frontier is None:
        frontier = rng.random(V) < 0.4
        if V:
            frontier[0] = True
    jf = jnp.asarray(frontier)
    jv = jnp.asarray(vertex_vals)
    want_pull = want_push = None
    for schedule in (schedules or SCHEDULES):
        for path in (paths or PATHS):
            plan = build_advance(g, schedule=schedule,
                                 num_blocks=num_blocks, path=path)
            src, psrc = plan.src, plan.push_src
            got_pull = advance(plan, jf, lambda e: jv[src[e]],
                               combiner=combiner)
            got_push = advance_push(plan, jf, lambda e: jv[psrc[e]],
                                    combiner=combiner)
            if want_pull is None:
                nsrc = np.asarray(src)
                want_pull = np_advance(np.asarray(plan.spec.tile_offsets),
                                       nsrc, vertex_vals[nsrc], frontier,
                                       combiner)
                npsrc = np.asarray(psrc)
                want_push = np_advance_push(
                    np.asarray(plan.push_spec.tile_offsets),
                    np.asarray(plan.dst), vertex_vals[npsrc], frontier,
                    combiner, V)
                assert_bitwise_equal(want_push, want_pull,
                                     msg=f"push/pull oracles disagree "
                                         f"({combiner})")
            tag = f"{schedule}/{path}/{combiner}"
            assert_bitwise_equal(got_pull, want_pull,
                                 msg=f"pull diverged from oracle: {tag}")
            assert_bitwise_equal(got_push, want_push,
                                 msg=f"push diverged from oracle: {tag}")
            assert_bitwise_equal(got_push, got_pull,
                                 msg=f"directions diverged: {tag}")


def np_bfs(w: np.ndarray, source: int):
    """Level-synchronous BFS on a dense weight matrix (edge iff w > 0).

    Returns (depth, parent); parent[v] is the *smallest* frontier
    in-neighbour at first reach — the deterministic tie-break the TPU
    advance implements (min-combiner over source ids).
    """
    adj = np.asarray(w) > 0
    V = adj.shape[0]
    depth = np.full(V, -1, np.int64)
    parent = np.full(V, -1, np.int64)
    depth[source] = 0
    frontier = np.zeros(V, bool)
    frontier[source] = True
    d = 0
    while frontier.any():
        preds = adj & frontier[:, None]            # [u, v]: active edge u->v
        reached = preds.any(axis=0) & (depth < 0)
        for v in np.flatnonzero(reached):
            parent[v] = int(np.flatnonzero(preds[:, v]).min())
        depth[reached] = d + 1
        frontier = reached
        d += 1
    return depth, parent


def np_sssp(w: np.ndarray, source: int) -> np.ndarray:
    """Bellman-Ford on a dense weight matrix (edge iff w > 0)."""
    w = np.asarray(w, np.float64)
    V = w.shape[0]
    dist = np.full(V, np.inf)
    dist[source] = 0.0
    for _ in range(V):
        cand = np.where(w > 0, dist[:, None] + w, np.inf).min(axis=0)
        new = np.minimum(dist, cand)
        if np.array_equal(new, dist, equal_nan=True):
            break
        dist = new
    return dist


def np_delta_stepping(w: np.ndarray, source: int,
                      delta: Optional[float] = None) -> np.ndarray:
    """Sequential f32 delta-stepping on a dense weight matrix (edge iff
    w > 0) — the NumPy oracle of :func:`repro.sparse.graph.delta_stepping`.

    Every relaxation is computed in f32 (``np.float32(dist[u] + w)``),
    mirroring the TPU driver's arithmetic, and the bucket loops run to full
    quiescence — so the result is THE least fixed point of f32 edge
    relaxation from ``source`` and must match both jax SSSP drivers
    (Bellman-Ford and delta-stepping) **bit for bit**, for any positive
    ``delta``.  ``delta=None`` reproduces the driver's default width (the
    mean positive weight, floored at the min — see
    ``repro.sparse.advance.estimate_delta``).
    """
    w = np.asarray(w, np.float32)
    V = w.shape[0]
    pos = w > 0
    weights = w[pos]
    if delta is None:
        delta = (float(max(np.float32(weights.mean()), weights.min()))
                 if weights.size else 1.0)
    delta = np.float32(delta)
    assert delta > 0, "delta-stepping needs a positive bucket width"
    light = pos & (w <= delta)
    heavy = pos & (w > delta)
    dist = np.full(V, np.inf, np.float32)
    needs = np.zeros(V, bool)
    if V:
        dist[source] = np.float32(0)
        needs[source] = True

    def bucket_of(d):
        with np.errstate(invalid="ignore"):
            return np.where(np.isfinite(d), np.floor(d / delta), np.inf)

    guard = 0
    while needs.any():
        guard += 1
        assert guard <= 4 * V + 8, "delta-stepping oracle failed to settle"
        b = bucket_of(dist)[needs].min()
        settled = np.zeros(V, bool)
        while True:
            frontier = needs & (bucket_of(dist) == b)
            if not frontier.any():
                break
            needs &= ~frontier
            settled |= frontier
            for u in np.flatnonzero(frontier):
                for v in np.flatnonzero(light[u]):
                    cand = np.float32(dist[u] + w[u, v])
                    if cand < dist[v]:
                        dist[v] = cand
                        needs[v] = True
        for u in np.flatnonzero(settled):
            for v in np.flatnonzero(heavy[u]):
                cand = np.float32(dist[u] + w[u, v])
                if cand < dist[v]:
                    dist[v] = cand
                    needs[v] = True
    return dist


def np_pagerank(w: np.ndarray, damping: float = 0.85,
                num_iters: int = 50) -> np.ndarray:
    """Power-iteration PageRank with uniform dangling redistribution."""
    adj = (np.asarray(w) > 0).astype(np.float64)
    V = adj.shape[0]
    outdeg = adj.sum(axis=1)
    P = np.divide(adj, outdeg[:, None], out=np.zeros_like(adj),
                  where=outdeg[:, None] > 0)
    x = np.full(V, 1.0 / V)
    for _ in range(num_iters):
        x = (1 - damping) / V + damping * (P.T @ x + x[outdeg == 0].sum() / V)
    return x


# -- wavefront (DAG/tree) workloads -----------------------------------------

def wavefront_dags(seed: int = 0) -> Dict[str, np.ndarray]:
    """Dense dependency matrices for the wavefront DAG classes.

    Edge ``u -> v`` iff entry > 0: *u must be evaluated before v* (for
    trees, children point at their parent).  In-degree is the dependency
    fan-in — the skew the schedules balance — and the four classes span
    the regimes: maximal depth (chain), uniform fan-in (balanced tree),
    arbitrary precedence (random DAG), and hub-skewed fan-in over ragged
    components (skewed forest, the chunked queue's regime).
    """
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}

    # chain: levels == nodes, in-degree 1 everywhere (worst-case depth)
    n = 20
    w = np.zeros((n, n), np.float32)
    for v in range(n - 1):
        w[v, v + 1] = 1.0
    out["chain"] = w

    # balanced binary tree, children -> parent (uniform fan-in 2)
    n = 2 ** 5 - 1
    w = np.zeros((n, n), np.float32)
    for child in range(1, n):
        w[child, (child - 1) // 2] = 1.0
    out["balanced_tree"] = w

    # random DAG: edges sprinkled forward along a hidden topological order
    n = 40
    order = rng.permutation(n)
    w = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < 0.12:
                w[order[i], order[j]] = 1.0
    out["random_dag"] = w

    # skewed forest: one hub aggregator (fan-in 16), small cherries, and
    # single-node trees, block-diagonal — ragged components whose levels
    # advance in the same wavefront
    blocks = []
    hub = np.zeros((19, 19), np.float32)
    hub[:16, 16] = 1.0             # 16 leaves -> aggregator
    hub[16, 18] = hub[17, 18] = 1.0  # aggregator + one leaf -> root
    blocks.append(hub)
    for _ in range(4):             # cherries: two leaves -> root
        cherry = np.zeros((3, 3), np.float32)
        cherry[0, 2] = cherry[1, 2] = 1.0
        blocks.append(cherry)
    for _ in range(3):             # single-node trees
        blocks.append(np.zeros((1, 1), np.float32))
    n = sum(b.shape[0] for b in blocks)
    w = np.zeros((n, n), np.float32)
    at = 0
    for b in blocks:
        k = b.shape[0]
        w[at:at + k, at:at + k] = b
        at += k
    out["skewed_forest"] = w
    return out


def np_topo_levels(w: np.ndarray) -> np.ndarray:
    """Longest-dependency-chain level per node on a dense dependency
    matrix (sources are level 0); raises on cycles — the independent
    check of ``build_wavefront``'s host-side Kahn leveling."""
    adj = np.asarray(w) > 0
    V = adj.shape[0]
    indeg = adj.sum(axis=0).astype(np.int64)
    level = np.full(V, -1, np.int64)
    frontier = np.flatnonzero(indeg == 0)
    lv = 0
    while frontier.size:
        level[frontier] = lv
        succ = adj[frontier].any(axis=0)
        indeg -= adj[frontier].sum(axis=0)
        frontier = np.flatnonzero(succ & (indeg == 0) & (level < 0))
        lv += 1
    if (level < 0).any():
        raise ValueError(f"cycle: nodes {np.flatnonzero(level < 0)[:8]}")
    return level


def np_wavefront(w: np.ndarray, x: np.ndarray, op_of_node: np.ndarray,
                 weights: np.ndarray, bias: Optional[np.ndarray] = None,
                 act: Callable = lambda z: np.maximum(z, np.float32(0.0))
                 ) -> np.ndarray:
    """Sequential per-node topological oracle of ``wavefront_eval``.

    Evaluates one node at a time in dependency order — the naive
    recursion the wavefront scheduler replaces — entirely in ``np.float32``:
    ``h[v] = act((x[v] + sum of h[preds]) @ weights[op[v]] + bias[op[v]])``.
    With integer-valued inputs (and an exact ``act``: relu, clip,
    identity) every combine and accumulation order is exact, so the
    balanced level-batched driver must match **bit for bit** across the
    whole schedule x path matrix.
    """
    adj = np.asarray(w) > 0
    levels = np_topo_levels(w)
    x = np.asarray(x, np.float32)
    weights = np.asarray(weights, np.float32)
    op_of_node = np.asarray(op_of_node)
    h = np.zeros_like(x)
    for v in np.argsort(levels, kind="stable"):
        comb = x[v] + h[adj[:, v]].sum(axis=0, dtype=np.float32)
        z = comb @ weights[op_of_node[v]]
        if bias is not None:
            z = z + np.asarray(bias, np.float32)[op_of_node[v]]
        h[v] = act(z.astype(np.float32)).astype(np.float32)
    return h


def check_wavefront_conformance(w: np.ndarray, *, num_blocks: int = 4,
                                seed: int = 0, schedules=None,
                                paths=None) -> None:
    """The wavefront schedule x path matrix for one DAG.

    Builds the wavefront plan for every schedule x execution path and
    asserts the level-batched evaluation bitwise against the sequential
    per-node oracle, plus the level-count contract (the device loop runs
    exactly the host-validated level count).  Integer-valued fixtures and
    a bounded exact clip activation keep every f32 sum exact at any DAG
    depth, so this is a true bitwise gate, not an allclose.
    """
    from repro.sparse import CSR, Graph, build_wavefront, wavefront_eval

    rng = np.random.default_rng(seed)
    V = int(np.asarray(w).shape[0])
    K, O = 4, 3
    x = rng.integers(-4, 5, (V, K)).astype(np.float32)
    W = rng.integers(-2, 3, (O, K, K)).astype(np.float32)
    b = rng.integers(-3, 4, (O, K)).astype(np.float32)
    ops = rng.integers(0, O, V).astype(np.int32)
    clip_j = lambda z: jnp.clip(z, -16.0, 16.0)
    clip_n = lambda z: np.clip(z, np.float32(-16.0), np.float32(16.0))
    want = np_wavefront(w, x, ops, W, bias=b, act=clip_n)
    g = Graph(CSR.from_dense(np.asarray(w, np.float32)))
    for schedule in (schedules or SCHEDULES):
        for path in (paths or PATHS):
            wp = build_wavefront(g, schedule=schedule,
                                 num_blocks=num_blocks, path=path)
            np.testing.assert_array_equal(wp.level_of, np_topo_levels(w))
            got, lv = wavefront_eval(wp, x, ops, W, bias=b,
                                     activation=clip_j, return_levels=True)
            assert int(lv) == wp.num_levels, \
                f"level count diverged: {schedule}/{path}"
            assert_bitwise_equal(got, want,
                                 msg=f"wavefront diverged from sequential "
                                     f"oracle: {schedule}/{path}")


def shard_slices(num_vertices: int, num_shards: int):
    """Contiguous per-shard vertex ranges, matching the sharded inspector.

    Returns ``[(lo, hi), ...]`` with ``hi - lo <= ceil(V / S)``; trailing
    shards of a graph smaller than the mesh are empty (``lo == hi``).  Use
    to slice a global NumPy-oracle result into the pieces each device owns.
    """
    shard_size = max(-(-num_vertices // num_shards) if num_vertices else 1, 1)
    los = [s * shard_size for s in range(num_shards)]
    his = [min(lo + shard_size, num_vertices) for lo in los]
    return [(min(lo, hi), hi) for lo, hi in zip(los, his)]


# ---------------------------------------------------------------------------
# Assertions.
# ---------------------------------------------------------------------------

def assert_bitwise_equal(got, want, msg: str = "") -> None:
    np.testing.assert_array_equal(
        np.asarray(got, np.float32).view(np.uint32),
        np.asarray(want, np.float32).view(np.uint32), err_msg=msg)


def check_tile_reduce_conformance(
        spec: WorkSpec,
        atom_fn: Callable,
        *,
        combiner: str = "sum",
        atom_mask=None,
        num_blocks: int = 4,
        schedules=SCHEDULES,
        paths=PATHS,
        oracle: Optional[np.ndarray] = None) -> None:
    """The full-matrix assertion: every schedule x path is bit-identical.

    ``oracle`` defaults to the jax segmented reference
    (:func:`repro.core.tile_reduce`); pass a :func:`np_tile_reduce` result
    to difference against pure NumPy instead.  New operators call this once
    per workload and inherit the whole conformance matrix.
    """
    if oracle is None:
        oracle = tile_reduce(spec, atom_fn, combiner=combiner,
                             atom_mask=atom_mask)
    for schedule in schedules:
        part = make_partition(spec, schedule, num_blocks)
        for path in paths:
            got = execute_tile_reduce(spec, part, atom_fn, path=path,
                                      combiner=combiner, atom_mask=atom_mask)
            assert_bitwise_equal(
                got, oracle,
                msg=f"{schedule}/{path}/{combiner} diverged from oracle")
