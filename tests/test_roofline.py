"""Unit tests for the roofline analyzer (HLO collective parsing, ring
factors, unit composition)."""
import numpy as np

from repro.launch import roofline as RL


HLO_SNIPPET = """
ENTRY main {
  %ar = f32[128,1024]{1,0} all-reduce(f32[128,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,4096]{1,0} all-gather(bf16[4,4096]{1,0} %y), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %z), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %a2a = s32[16,16]{1,0} all-to-all(s32[16,16]{1,0} %w), replica_groups={{0,1}}
  %cp = f32[100]{0} collective-permute(f32[100]{0} %v), source_target_pairs={{0,1}}
  %ars = (f32[10]{0}, f32[10]{0}) all-reduce-start(f32[10]{0} %p, f32[10]{0} %q), replica_groups={{0,1,2,3}}
}
"""


class TestCollectiveParse:
    def test_wire_bytes(self):
        wire = RL.collective_wire_bytes(HLO_SNIPPET, 256)
        # all-reduce: 2 * S * (n-1)/n; S = 128*1024*4, n=4
        ar_single = 2 * 128 * 1024 * 4 * 3 / 4
        # the -start tuple op: two f32[10] operands, n=4
        ar_start = 2 * (10 * 4 * 2) * 3 / 4
        np.testing.assert_allclose(wire["all-reduce"], ar_single + ar_start)
        # all-gather: gathered output bytes * (n-1)/n; iota groups size 16
        np.testing.assert_allclose(wire["all-gather"],
                                   64 * 4096 * 2 * 15 / 16)
        # reduce-scatter: out * n * (n-1)/n; out = 8*32*4, n=8
        np.testing.assert_allclose(wire["reduce-scatter"],
                                   8 * 32 * 4 * 8 * 7 / 8)
        np.testing.assert_allclose(wire["all-to-all"], 16 * 16 * 4 * 1 / 2)
        np.testing.assert_allclose(wire["collective-permute"], 400)

    def test_no_collectives(self):
        wire = RL.collective_wire_bytes("%x = f32[8]{0} add(%a, %b)", 8)
        assert sum(wire.values()) == 0


class TestCompose:
    def _m(self, flops, by=0.0, wire=0.0):
        kinds = {k: 0.0 for k in ("all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute")}
        kinds["all-reduce"] = wire
        return RL.CellMetrics(flops=flops, hbm_bytes=by, wire_bytes=wire,
                              wire_by_kind=kinds)

    def test_layer_extrapolation(self):
        # unit(L=1) = rest + layer; unit(L=2) = rest + 2*layer
        rest, layer = 100.0, 10.0
        u1, u2 = self._m(rest + layer), self._m(rest + 2 * layer)
        total = RL.compose(u1, u2, num_layers=24, n_micro=4)
        assert total.flops == 4 * (rest + 24 * layer)

    def test_terms_and_bottleneck(self):
        m = RL.CellMetrics(flops=197e12, hbm_bytes=819e9 * 2,
                           wire_bytes=50e9,
                           wire_by_kind={"all-reduce": 50e9, "all-gather": 0,
                                         "reduce-scatter": 0, "all-to-all": 0,
                                         "collective-permute": 0})
        t = m.terms()
        np.testing.assert_allclose(t["compute_s"], 1.0)
        np.testing.assert_allclose(t["memory_s"], 2.0)
        np.testing.assert_allclose(t["collective_s"], 1.0)
        assert m.bottleneck() == "memory_s"


class TestModelFlops:
    def test_train_6nd(self):
        from repro.configs import SHAPES
        assert RL.model_flops(None, SHAPES["train_4k"], 10**9) == (
            6.0 * 10**9 * 256 * 4096)

    def test_decode_is_per_token(self):
        from repro.configs import SHAPES
        assert RL.model_flops(None, SHAPES["decode_32k"], 10**9) == (
            2.0 * 10**9 * 128)
