"""Serving-layer tests: cache partition policy, sampling, generation."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import AxisType, make_mesh
from repro.models import init_cache, init_params
from repro.serve.decode import (cache_pspecs, generate, sample_logits,
                                _data_axes)


def mesh_11():
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


class TestCachePolicy:
    def test_data_axes_divisibility(self):
        m = mesh_11()
        assert _data_axes(m, 4) == ("data",)   # 4 % 1 == 0
        # a fake 2-wide data mesh would reject odd batches; emulate the
        # logic directly: batch 1 never shards
        assert _data_axes(m, 0) == ()

    def test_kv_head_vs_seq_sharding_rule(self):
        m = mesh_11()
        glm = get_config("glm4_9b")        # kv=2: seq-sharded rule
        qwen = get_config("qwen15_05b")    # kv=16: head-sharded rule
        s_glm = cache_pspecs(glm, m, 128)
        s_qwen = cache_pspecs(qwen, m, 128)
        # on a 1-wide model axis both degenerate, but the specs must exist
        # for k and v and be rank-5
        for specs, cfg in ((s_glm, glm), (s_qwen, qwen)):
            assert len(specs["k"]) == 5 and len(specs["v"]) == 5

    def test_ssm_cache_specs(self):
        m = mesh_11()
        specs = cache_pspecs(get_config("rwkv6_3b"), m, 8)
        assert set(specs) == {"wkv", "xprev_t", "xprev_c"}


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray([[[0.1, 5.0, -1.0]]], jnp.float32)
        tok = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.0)
        assert tok.shape == (1, 1) and int(tok[0, 0]) == 1

    def test_temperature_sampling_in_range(self):
        logits = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 32))
        tok = sample_logits(jax.random.PRNGKey(2), logits, temperature=1.0)
        assert tok.shape == (4, 1)
        assert bool((tok >= 0).all()) and bool((tok < 32).all())

    def test_generate_deterministic_greedy(self):
        cfg = get_config("qwen15_05b").reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(3))
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        total = 4 + 6
        out1, _ = generate(params, cfg, prompt, steps=6,
                           cache=init_cache(cfg, 1, total, jnp.float32),
                           temperature=0.0)
        out2, _ = generate(params, cfg, prompt, steps=6,
                           cache=init_cache(cfg, 1, total, jnp.float32),
                           temperature=0.0)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert out1.shape == (1, 6)
