"""Serving-layer tests: cache partition policy, sampling, generation."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import AxisType, make_mesh
from repro.models import init_cache, init_params
from repro.serve.decode import (cache_pspecs, generate, sample_logits,
                                _data_axes)


def mesh_11():
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


class TestCachePolicy:
    def test_data_axes_divisibility(self):
        m = mesh_11()
        assert _data_axes(m, 4) == ("data",)   # 4 % 1 == 0
        # a fake 2-wide data mesh would reject odd batches; emulate the
        # logic directly: batch 1 never shards
        assert _data_axes(m, 0) == ()

    def test_kv_head_vs_seq_sharding_rule(self):
        m = mesh_11()
        glm = get_config("glm4_9b")        # kv=2: seq-sharded rule
        qwen = get_config("qwen15_05b")    # kv=16: head-sharded rule
        s_glm = cache_pspecs(glm, m, 128)
        s_qwen = cache_pspecs(qwen, m, 128)
        # on a 1-wide model axis both degenerate, but the specs must exist
        # for k and v and be rank-5
        for specs, cfg in ((s_glm, glm), (s_qwen, qwen)):
            assert len(specs["k"]) == 5 and len(specs["v"]) == 5

    def test_ssm_cache_specs(self):
        m = mesh_11()
        specs = cache_pspecs(get_config("rwkv6_3b"), m, 8)
        assert set(specs) == {"wkv", "xprev_t", "xprev_c"}


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray([[[0.1, 5.0, -1.0]]], jnp.float32)
        tok = sample_logits(jax.random.PRNGKey(0), logits, temperature=0.0)
        assert tok.shape == (1, 1) and int(tok[0, 0]) == 1

    def test_temperature_sampling_in_range(self):
        logits = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 32))
        tok = sample_logits(jax.random.PRNGKey(2), logits, temperature=1.0)
        assert tok.shape == (4, 1)
        assert bool((tok >= 0).all()) and bool((tok < 32).all())

    def test_padded_vocab_slots_never_sampled(self):
        # logits [B=2, T=1, padded=8] with the pad slots (>= vocab_size=5)
        # holding by far the largest values — unmasked, both greedy and
        # temperature sampling would pick them (the old launcher clamp
        # mapped them all onto vocab_size-1, silently skewing sampling)
        logits = jnp.full((2, 1, 8), -1.0, jnp.float32)
        logits = logits.at[:, :, 6].set(100.0).at[:, :, 2].set(1.0)
        greedy = sample_logits(jax.random.PRNGKey(0), logits,
                               temperature=0.0, vocab_size=5)
        assert int(greedy[0, 0]) == 2 and int(greedy[1, 0]) == 2
        for seed in range(8):
            tok = sample_logits(jax.random.PRNGKey(seed), logits,
                                temperature=1.0, vocab_size=5)
            assert bool((tok < 5).all()), f"pad token sampled (seed {seed})"

    def test_vocab_size_none_or_full_is_identity(self):
        logits = jax.random.normal(jax.random.PRNGKey(4), (3, 1, 16))
        a = sample_logits(jax.random.PRNGKey(5), logits, temperature=0.0)
        b = sample_logits(jax.random.PRNGKey(5), logits, temperature=0.0,
                          vocab_size=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_generate_zero_steps_returns_empty(self):
        cfg = get_config("qwen15_05b").reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(3))
        prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
        out, cache = generate(params, cfg, prompt, steps=0,
                              cache=init_cache(cfg, 1, 3, jnp.float32),
                              temperature=0.0)
        assert out.shape == (1, 0) and out.dtype == jnp.int32
        assert cache is not None

    def test_generate_deterministic_greedy(self):
        cfg = get_config("qwen15_05b").reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(3))
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        total = 4 + 6
        out1, _ = generate(params, cfg, prompt, steps=6,
                           cache=init_cache(cfg, 1, total, jnp.float32),
                           temperature=0.0)
        out2, _ = generate(params, cfg, prompt, steps=6,
                           cache=init_cache(cfg, 1, total, jnp.float32),
                           temperature=0.0)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert out1.shape == (1, 6)
