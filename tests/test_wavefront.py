"""Differential suite for the topological wavefront scheduler.

Acceptance bar (ISSUE 9): wavefront evaluation must be **bit-identical**
to a sequential per-node NumPy oracle across all six schedules x both
execution paths on four DAG classes (chain, balanced tree, random DAG,
skewed forest), plus build-time cycle rejection, ragged-forest batching
equivalence, and the packing guards the forest path rides on.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.packing import pack_documents
from repro.models import (init_treelstm, tree_roots, treelstm_embed,
                          treelstm_forest)
from repro.sparse import (CSR, Graph, build_wavefront, pack_forest,
                          topological_levels, wavefront_eval)
from _conformance import (assert_bitwise_equal, check_wavefront_conformance,
                          np_topo_levels, np_wavefront, wavefront_dags)

DAGS = wavefront_dags(seed=0)


def dag_of(w) -> Graph:
    return Graph(CSR.from_dense(np.asarray(w, np.float32)))


def exact_fixtures(V, K=4, O=3, seed=1):
    rng = np.random.default_rng(seed)
    x = rng.integers(-4, 5, (V, K)).astype(np.float32)
    W = rng.integers(-2, 3, (O, K, K)).astype(np.float32)
    b = rng.integers(-3, 4, (O, K)).astype(np.float32)
    ops = rng.integers(0, O, V).astype(np.int32)
    return x, ops, W, b


clip_j = lambda z: jnp.clip(z, -16.0, 16.0)
clip_n = lambda z: np.clip(z, np.float32(-16.0), np.float32(16.0))


class TestWavefrontConformance:
    """wavefront_eval == sequential oracle, bit for bit, full matrix."""

    @pytest.mark.parametrize("name", sorted(DAGS))
    def test_schedule_path_matrix(self, name):
        check_wavefront_conformance(DAGS[name], num_blocks=4, seed=0)

    def test_auto_schedule_routes_wavefront_family(self):
        # the family is registered end to end: cost-model coefficient,
        # autotune atom work, and the push-direction sibling mapping
        from repro.core.autotune import WORKLOAD_ATOM_WORK
        from repro.core.balance import WORKLOAD_ATOM_COEF
        from repro.sparse.advance import _PUSH_WORKLOADS
        assert "wavefront" in WORKLOAD_ATOM_WORK
        assert "wavefront_push" in WORKLOAD_ATOM_WORK
        assert "wavefront" in WORKLOAD_ATOM_COEF
        assert _PUSH_WORKLOADS["wavefront"] == "wavefront_push"
        w = DAGS["skewed_forest"]
        wp = build_wavefront(dag_of(w), schedule="auto")
        x, ops, W, b = exact_fixtures(w.shape[0])
        got = wavefront_eval(wp, x, ops, W, bias=b, activation=clip_j)
        want = np_wavefront(w, x, ops, W, bias=b, act=clip_n)
        assert_bitwise_equal(got, want, "auto-selected wavefront plan")

    def test_segmm_policy_overrides_are_bitwise_invariant(self):
        w = DAGS["balanced_tree"]
        wp = build_wavefront(dag_of(w), schedule="merge_path", num_blocks=4)
        x, ops, W, b = exact_fixtures(w.shape[0])
        want = np_wavefront(w, x, ops, W, bias=b, act=clip_n)
        for sched, path in [("group_mapped", "pure"),
                            ("chunked_lpt", "pure"),
                            ("chunked_lpt", "native")]:
            got = wavefront_eval(wp, x, ops, W, bias=b, activation=clip_j,
                                 segmm_schedule=sched, segmm_path=path)
            assert_bitwise_equal(got, want, f"segmm {sched}/{path}")


class TestLeveling:
    """Host-side Kahn leveling: the inspector half of the contract."""

    @pytest.mark.parametrize("name", sorted(DAGS))
    def test_levels_match_independent_oracle(self, name):
        w = DAGS[name]
        g = dag_of(w)
        got = topological_levels(g.csr.row_offsets, g.csr.col_indices,
                                 g.num_vertices)
        np.testing.assert_array_equal(got, np_topo_levels(w))

    def test_chain_depth(self):
        lv = np_topo_levels(DAGS["chain"])
        np.testing.assert_array_equal(lv, np.arange(DAGS["chain"].shape[0]))

    def test_cycle_raises_at_build_time(self):
        w = np.zeros((3, 3), np.float32)
        w[0, 1] = w[1, 2] = w[2, 0] = 1.0   # 3-cycle
        with pytest.raises(ValueError, match="cycle"):
            build_wavefront(dag_of(w))

    def test_self_loop_raises(self):
        w = np.zeros((2, 2), np.float32)
        w[0, 1] = w[1, 1] = 1.0
        with pytest.raises(ValueError, match="cycle"):
            build_wavefront(dag_of(w))

    def test_single_node(self):
        w = np.zeros((1, 1), np.float32)
        wp = build_wavefront(dag_of(w), schedule="thread_mapped")
        assert wp.num_levels == 1 and wp.level_counts.tolist() == [1]
        x, ops, W, b = exact_fixtures(1)
        got = wavefront_eval(wp, x, ops, W, bias=b, activation=clip_j)
        assert_bitwise_equal(got, np_wavefront(w, x, ops, W, bias=b,
                                               act=clip_n), "single node")

    def test_diamond(self):
        # 0 -> {1, 2} -> 3: node 3 must see BOTH middle states summed
        w = np.zeros((4, 4), np.float32)
        w[0, 1] = w[0, 2] = w[1, 3] = w[2, 3] = 1.0
        wp = build_wavefront(dag_of(w), schedule="merge_path", num_blocks=2)
        assert wp.num_levels == 3
        x, ops, W, b = exact_fixtures(4)
        got, lv = wavefront_eval(wp, x, ops, W, bias=b, activation=clip_j,
                                 return_levels=True)
        assert int(lv) == 3
        assert_bitwise_equal(got, np_wavefront(w, x, ops, W, bias=b,
                                               act=clip_n), "diamond")


class TestWavefrontValidation:
    def test_bad_op_ids_raise(self):
        w = DAGS["chain"]
        wp = build_wavefront(dag_of(w), schedule="thread_mapped")
        x, ops, W, b = exact_fixtures(w.shape[0])
        with pytest.raises(ValueError, match="out of range"):
            wavefront_eval(wp, x, np.full(w.shape[0], 99, np.int32), W)

    def test_non_square_weights_raise(self):
        wp = build_wavefront(dag_of(DAGS["chain"]),
                             schedule="thread_mapped")
        V = DAGS["chain"].shape[0]
        with pytest.raises(ValueError, match="square"):
            wavefront_eval(wp, np.zeros((V, 4), np.float32),
                           np.zeros(V, np.int32),
                           np.zeros((2, 4, 3), np.float32))

    def test_bad_activation_name_raises(self):
        wp = build_wavefront(dag_of(DAGS["chain"]),
                             schedule="thread_mapped")
        V = DAGS["chain"].shape[0]
        with pytest.raises(ValueError, match="unknown activation"):
            wavefront_eval(wp, np.zeros((V, 4), np.float32),
                           np.zeros(V, np.int32),
                           np.zeros((2, 4, 4), np.float32),
                           activation="swish")


class TestForestBatching:
    """pack_forest: one block-diagonal wavefront == per-tree evaluation."""

    def _trees(self):
        cherry = np.zeros((3, 3), np.float32)
        cherry[0, 2] = cherry[1, 2] = 1.0
        deep = np.zeros((5, 5), np.float32)
        for v in range(4):
            deep[v, v + 1] = 1.0
        single = np.zeros((1, 1), np.float32)
        return [cherry, deep, single]

    def test_packed_eval_matches_per_tree(self):
        trees = self._trees()
        packed = pack_forest([dag_of(t) for t in trees], num_rows=2)
        assert packed.num_trees == 3
        assert packed.node_offsets.tolist() == [0, 3, 8, 9]
        V = int(packed.node_offsets[-1])
        x, ops, W, b = exact_fixtures(V)
        wp = build_wavefront(packed.dag, schedule="chunked_lpt",
                             num_blocks=4)
        # packed levels interleave the trees: depth == deepest tree
        assert wp.num_levels == 5
        packed_h = np.asarray(wavefront_eval(wp, x, ops, W, bias=b,
                                             activation=clip_j))
        for t, w in enumerate(trees):
            s = packed.tree_slice(t)
            solo = np_wavefront(w, x[s], ops[s], W, bias=b, act=clip_n)
            assert_bitwise_equal(packed_h[s], solo, f"tree {t}")

    def test_row_split_is_balanced(self):
        trees = [dag_of(t) for t in self._trees()]
        packed = pack_forest(trees, num_rows=2)
        per_row = np.diff(np.asarray(packed.row_node_starts))
        assert int(per_row.sum()) == 9
        # merge-path split: within one tree boundary of the even split
        assert int(per_row.max()) - int(per_row.min()) <= 5

    def test_empty_forest_raises(self):
        with pytest.raises(ValueError, match="empty forest"):
            pack_forest([])

    def test_zero_node_tree_raises_via_packing_guard(self):
        empty = CSR(jnp.zeros(1, jnp.int32), jnp.zeros(0, jnp.int32),
                    jnp.zeros(0, jnp.float32), (0, 0), 0)
        with pytest.raises(ValueError, match="zero-length"):
            pack_forest([dag_of(self._trees()[0]), Graph(empty)])


class TestTreeLSTM:
    def test_forest_roots_and_shapes(self):
        cherry = np.zeros((3, 3), np.float32)
        cherry[0, 2] = cherry[1, 2] = 1.0
        chain = np.zeros((4, 4), np.float32)
        for v in range(3):
            chain[v, v + 1] = 1.0
        trees = [dag_of(cherry), dag_of(chain)]
        F = 4
        params = init_treelstm(jax.random.PRNGKey(0), F, num_ops=2)
        x = jnp.asarray(np.random.default_rng(3).normal(size=(7, F)),
                        jnp.float32)
        ops = jnp.zeros(7, jnp.int32)
        roots_h, packed = treelstm_forest(params, trees, x, ops)
        assert roots_h.shape == (2, F)
        # roots are each tree's dependency sink: nodes 2 and 3+3=6
        wp = build_wavefront(packed.dag)
        assert tree_roots(wp).tolist() == [2, 6]
        # per-node embed agrees with the forest path at the roots
        h = treelstm_embed(params, wp, x, ops)
        assert_bitwise_equal(roots_h, h[jnp.asarray([2, 6])], "roots")

    def test_non_tree_forest_raises(self):
        # two sinks in one component -> not child->parent trees
        w = np.zeros((3, 3), np.float32)
        w[0, 1] = w[0, 2] = 1.0
        params = init_treelstm(jax.random.PRNGKey(1), 4)
        x = jnp.zeros((3, 4), jnp.float32)
        with pytest.raises(ValueError, match="dependency sinks"):
            treelstm_forest(params, [dag_of(w)], x,
                            jnp.zeros(3, jnp.int32))


class TestPackingGuards:
    """Regression tests for the pack_documents input validation."""

    def test_zero_length_documents_raise(self):
        with pytest.raises(ValueError, match="zero-length"):
            pack_documents(jnp.asarray([3, 0, 2], jnp.int32), 2)

    def test_negative_lengths_raise(self):
        with pytest.raises(ValueError, match="negative"):
            pack_documents(jnp.asarray([3, -1, 2], jnp.int32), 2)

    def test_empty_documents_raise(self):
        with pytest.raises(ValueError, match="at least one document"):
            pack_documents(jnp.asarray([], jnp.int32), 2)

    def test_bad_num_rows_raises(self):
        with pytest.raises(ValueError, match="num_rows"):
            pack_documents(jnp.asarray([3, 2], jnp.int32), 0)

    def test_over_capacity_raises(self):
        with pytest.raises(ValueError, match="cannot fit"):
            pack_documents(jnp.asarray([8, 8], jnp.int32), 2,
                           row_capacity=7)

    def test_bad_capacity_raises(self):
        with pytest.raises(ValueError, match="row_capacity"):
            pack_documents(jnp.asarray([3, 2], jnp.int32), 2,
                           row_capacity=0)

    def test_capacity_ok_when_it_fits(self):
        starts, _ = pack_documents(jnp.asarray([4, 4, 4, 4], jnp.int32),
                                   2, row_capacity=8)
        per_row = np.diff(np.asarray(starts))
        assert int(per_row.max()) <= 8 and int(per_row.sum()) == 16


class TestWavefrontProperties:
    """Hypothesis: random DAGs respect the level contract and the oracle."""

    def test_random_dags_property(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=6, deadline=None)
        @given(params=st.tuples(st.integers(4, 28),          # nodes
                                st.floats(0.05, 0.3),        # edge prob
                                st.integers(0, 10_000)))     # seed
        def inner(params):
            n, p, seed = params
            rng = np.random.default_rng(seed)
            order = rng.permutation(n)
            w = np.zeros((n, n), np.float32)
            for i in range(n):
                for j in range(i + 1, n):
                    if rng.random() < p:
                        w[order[i], order[j]] = 1.0
            g = dag_of(w)
            wp = build_wavefront(g, schedule="chunked_lpt", num_blocks=3)
            lv = wp.level_of
            # every node leveled exactly once, in [0, num_levels)
            assert (lv >= 0).all() and int(lv.max()) + 1 == wp.num_levels
            assert int(wp.level_counts.sum()) == n
            # every dependency edge crosses strictly forward in level
            srcs, dsts = np.nonzero(w)
            assert (lv[srcs] < lv[dsts]).all()
            # evaluation: every node exactly once, after its predecessors
            x, ops, W, b = exact_fixtures(n, seed=seed % 97)
            got, run = wavefront_eval(wp, x, ops, W, bias=b,
                                      activation=clip_j,
                                      return_levels=True)
            assert int(run) == wp.num_levels
            assert_bitwise_equal(
                got, np_wavefront(w, x, ops, W, bias=b, act=clip_n),
                f"random dag n={n} p={p:.2f} seed={seed}")

        inner()
