"""Window-sizing audit: exact-once atom coverage in the Pallas kernels.

PR 1 fixed a seed bug where ``blocked_tile_reduce`` sized a block's local
tile window from its *atom* count, silently dropping atoms when a
non-tile-aligned block spanned many **empty** tiles.  This file audits the
Pallas kernels for the same hazard and pins the conclusions:

* the **chunk-walking kernels** size their windows from the partition's
  ``atom_span``/``tile_span`` hints (``tile_span`` counts tiles, not atoms,
  so empty-tile spans are included) — adversarial empty-tile workloads below
  must reduce every atom exactly once;
* the **merge-path stream kernel** is structurally immune: the stream
  carries one end-marker per row, so a window of ``block_items`` stream
  items touches at most ``block_items + 1`` rows *even when the rows are
  empty* (empty rows still occupy marker slots), and ``r_loc`` is sized
  from ``block_items + 1``;
* the **plain segmm kernel** is structurally immune: group-padding makes
  every M-block map to exactly one expert, so there is no multi-tile window
  to undersize (empty experts contribute zero M-blocks).

"Exactly once" is asserted by counting: with ``atom_fn = 1`` the per-tile
result must equal the tile sizes bit-for-bit; any dropped or duplicated
atom shows up as a count mismatch.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    Schedule, make_partition, native_chunk_tile_reduce,
)
from _conformance import HAZARD_WORKLOADS, spec_from_sizes


class TestChunkWalkCoverage:
    @pytest.mark.parametrize("name", sorted(HAZARD_WORKLOADS))
    @pytest.mark.parametrize("schedule",
                             [Schedule.CHUNKED, Schedule.ADAPTIVE,
                              Schedule.NONZERO_SPLIT])
    def test_exact_once_atom_coverage(self, name, schedule):
        sizes = HAZARD_WORKLOADS[name]
        spec = spec_from_sizes(sizes)
        part = make_partition(spec, schedule, 3)
        ones = lambda a: jnp.ones_like(a, jnp.float32)
        counts = np.asarray(native_chunk_tile_reduce(spec, part, ones))
        np.testing.assert_array_equal(
            counts, np.asarray(sizes, np.float32),
            err_msg=f"atoms dropped/duplicated: {schedule}/{name}")

    def test_tile_span_hint_covers_empty_runs(self):
        # the hazard mechanism itself: a single nonzero-split block whose
        # two atoms sit 30 empty tiles apart needs tile_span ~ num_tiles,
        # far beyond what its atom count (2) suggests
        spec = spec_from_sizes(HAZARD_WORKLOADS["empties_between"])
        part = make_partition(spec, Schedule.NONZERO_SPLIT, 1)
        assert part.tile_span is not None
        assert part.tile_span >= spec.num_tiles


class TestMergeStreamCoverage:
    @pytest.mark.parametrize("name", sorted(HAZARD_WORKLOADS))
    def test_exact_once_row_counts(self, name):
        # dense-x SpMV with unit values: y must equal the row sizes
        from repro.kernels.spmv_merge import ops as spmv_ops
        from repro.sparse.formats import CSR
        sizes = np.asarray(HAZARD_WORKLOADS[name], np.int64)
        rows, cols = len(sizes), 8
        dens = np.zeros((rows, cols), np.float32)
        rng = np.random.default_rng(0)
        for r, n in enumerate(sizes):
            dens[r, rng.choice(cols, size=min(int(n), cols),
                               replace=False)] = 1.0
            # row sizes beyond cols wrap via repeated columns
            for extra in range(int(n) - cols):
                dens[r, extra % cols] += 1.0
        A = CSR.from_dense(jnp.asarray(dens))
        x = jnp.ones((cols,), jnp.float32)
        got = np.asarray(spmv_ops.spmv_merge_path(A, x, block_items=128))
        np.testing.assert_array_equal(got, dens.sum(1))

    def test_oversplit_chunk_granularity(self):
        # the PR-1 chunked fallback oversplits the stream into tiny blocks;
        # window sizing must stay exact at the finest granularity too
        from repro.kernels.spmv_merge import ops as spmv_ops
        from repro.sparse.formats import CSR
        rng = np.random.default_rng(1)
        dens = (rng.random((64, 32)) < 0.1).astype(np.float32)
        dens[5] = 1.0                                     # heavy row
        A = CSR.from_dense(jnp.asarray(dens))
        x = jnp.ones((32,), jnp.float32)
        got = np.asarray(spmv_ops.spmv_merge_path(
            A, x, schedule="chunked_lpt", num_blocks=8,
            execution_path="pure"))
        np.testing.assert_array_equal(got, dens.sum(1))


class TestSegmmCoverage:
    def test_empty_expert_runs(self):
        # many empty experts between populated ones: every token must hit
        # its expert's weights exactly once on both execution paths
        from repro.kernels.segmm import ops as segmm_ops
        rng = np.random.default_rng(2)
        T, K, N, E = 48, 8, 4, 16
        tokens = jnp.ones((T, K), jnp.float32)
        # experts 0 and 15 only: 14 empty tiles between them
        eot = jnp.asarray(np.where(rng.random(T) < 0.5, 0, 15)
                          .astype(np.int32))
        rhs = jnp.asarray(
            np.arange(1, E + 1, dtype=np.float32)[:, None, None]
            * np.ones((E, K, N), np.float32))
        want = np.asarray(rhs)[np.asarray(eot)].sum(1) * 1.0  # [T, N]
        for path in ("native", "pure"):
            got = np.asarray(segmm_ops.grouped_matmul(
                tokens, eot, rhs, num_experts=E, bm=8,
                schedule="chunked_lpt", execution_path=path))
            np.testing.assert_array_equal(got, want, err_msg=path)
