"""Coverage for core.segops (MXU-shaped reductions) and core.balance."""
import numpy as np
import jax.numpy as jnp

from repro.core import ImbalanceStats, Schedule, landscape, modeled_cost
from repro.core.segops import (exclusive_cumsum, onehot_segment_sum,
                               segment_softmax, segment_sum)
from repro.core.work import WorkSpec


def spec_from_sizes(sizes):
    sizes = np.asarray(sizes, np.int32)
    off = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(off),
                                         num_atoms=int(off[-1]))


class TestSegops:
    def test_onehot_segsum_matches_scatter(self):
        rng = np.random.default_rng(0)
        vals = jnp.asarray(rng.standard_normal(64).astype(np.float32))
        ids = jnp.asarray(rng.integers(0, 9, 64).astype(np.int32))
        got = onehot_segment_sum(vals, ids, 9)
        want = segment_sum(vals, ids, 9)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_onehot_segsum_oob_ids_drop(self):
        vals = jnp.ones((4,), jnp.float32)
        ids = jnp.asarray([0, 1, 7, -3], jnp.int32)  # 7/-3 out of range
        got = onehot_segment_sum(vals, ids, 2)
        np.testing.assert_array_equal(np.asarray(got), [1.0, 1.0])

    def test_onehot_segsum_2d_values(self):
        rng = np.random.default_rng(1)
        vals = jnp.asarray(rng.standard_normal((16, 3)).astype(np.float32))
        ids = jnp.asarray(rng.integers(0, 4, 16).astype(np.int32))
        got = onehot_segment_sum(vals, ids, 4)
        want = np.zeros((4, 3), np.float32)
        for i, s in enumerate(np.asarray(ids)):
            want[s] += np.asarray(vals)[i]
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-6)

    def test_segment_softmax_normalizes(self):
        logits = jnp.asarray([1.0, 2.0, 3.0, -1.0, 5.0], jnp.float32)
        ids = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)
        probs = np.asarray(segment_softmax(logits, ids, 2))
        np.testing.assert_allclose(probs[:3].sum(), 1.0, rtol=1e-5)
        np.testing.assert_allclose(probs[3:].sum(), 1.0, rtol=1e-5)

    def test_exclusive_cumsum(self):
        x = jnp.asarray([3, 1, 4, 1], jnp.int32)
        np.testing.assert_array_equal(np.asarray(exclusive_cumsum(x)),
                                      [0, 3, 4, 8])


class TestBalance:
    def test_imbalance_stats_uniform_vs_skewed(self):
        uni = ImbalanceStats.measure(spec_from_sizes([10] * 50))
        skew = ImbalanceStats.measure(spec_from_sizes([1] * 49 + [451]))
        assert uni.cv_atoms_per_tile < 1e-6
        assert skew.cv_atoms_per_tile > 5.0
        assert skew.gini > uni.gini
        assert skew.max_atoms_per_tile == 451

    def test_modeled_cost_skew_hurts_thread_mapped_only(self):
        uni = spec_from_sizes([16] * 512)
        skew = spec_from_sizes([1] * 511 + [7681])  # same total atoms
        for sched in (Schedule.MERGE_PATH, Schedule.NONZERO_SPLIT):
            assert modeled_cost(skew, sched, 8) <= modeled_cost(
                uni, sched, 8) * 1.5, sched
        assert modeled_cost(skew, Schedule.THREAD_MAPPED, 8) > 10 * (
            modeled_cost(uni, Schedule.THREAD_MAPPED, 8))

    def test_landscape_keys(self):
        spec = spec_from_sizes([5, 1, 9, 0, 3])
        land = landscape(spec, 4)
        assert set(land) == {"thread_mapped", "group_mapped",
                             "nonzero_split", "merge_path"}
        assert all(v >= 0 for v in land.values())
