"""Tests: optimizer, train step, checkpointing (+elastic), compression, data."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.data.synthetic import DataConfig, batch_at, for_model
from repro.data.packing import pack_documents, packing_efficiency
from repro.train import checkpoint as ckpt
from repro.train.compress import (compress_roundtrip, ef_compress,
                                  init_error_state)
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.step import make_train_step, param_specs, shardings_for


def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestOptimizer:
    def test_adamw_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = init_opt_state(params)
        cfg = OptConfig(lr=0.2, warmup_steps=0, total_steps=200,
                        weight_decay=0.0, clip_norm=10.0)
        loss = lambda p: jnp.sum(jnp.square(p["w"]))
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(params, g, opt, cfg)
        assert float(loss(params)) < 1e-2

    def test_lr_schedule(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_frac=0.1)
        assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=0.1)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1,
                                                                  rel=0.01)

    def test_grad_clipping_bounds_update(self):
        params = {"w": jnp.zeros((4,))}
        opt = init_opt_state(params)
        cfg = OptConfig(lr=0.1, warmup_steps=0, clip_norm=1.0,
                        weight_decay=0.0)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw_update(params, g, opt, cfg)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


class TestTrainStep:
    def test_loss_decreases_with_microbatching(self):
        cfg = get_config("qwen15_05b").reduced()
        mesh = tiny_mesh()
        params, _ = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        step, psh, osh = make_train_step(
            cfg, OptConfig(warmup_steps=2, total_steps=50), mesh,
            num_microbatches=2, dtype=jnp.float32)
        params = jax.device_put(params, psh)
        opt = jax.device_put(opt, osh)
        dcfg = for_model(cfg, seq_len=32, global_batch=4)
        losses = []
        for i in range(8):
            params, opt, m = step(params, opt, batch_at(dcfg, i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_microbatched_grads_match_full_batch(self):
        from repro.train.step import loss_and_grads
        cfg = get_config("qwen15_05b").reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(1))
        batch = batch_at(for_model(cfg, seq_len=16, global_batch=4), 0)
        l1, _, g1 = loss_and_grads(params, cfg, batch, 1, jnp.float32)
        l2, _, g2 = loss_and_grads(params, cfg, batch, 4, jnp.float32)
        # microbatch losses are per-microbatch token means; close but not
        # identical when mask counts differ -> compare loosely, grads tight
        # after normalizing by the same convention.
        np.testing.assert_allclose(float(l1), float(l2), rtol=0.05)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0.1, atol=2e-2)

    def test_moe_arch_trains(self):
        cfg = get_config("olmoe_1b_7b").reduced()
        mesh = tiny_mesh()
        params, _ = init_params(cfg, jax.random.PRNGKey(2))
        opt = init_opt_state(params)
        step, psh, osh = make_train_step(
            cfg, OptConfig(warmup_steps=1, total_steps=20), mesh,
            dtype=jnp.float32)
        params = jax.device_put(params, psh)
        opt = jax.device_put(opt, osh)
        dcfg = for_model(cfg, seq_len=16, global_batch=2)
        for i in range(3):
            params, opt, m = step(params, opt, batch_at(dcfg, i))
            assert np.isfinite(float(m["loss"]))


class TestCheckpoint:
    def _setup(self, tmp_path):
        cfg = get_config("qwen15_05b").reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(3))
        opt = init_opt_state(params)
        return cfg, params, opt, str(tmp_path / "ckpt")

    def test_roundtrip(self, tmp_path):
        cfg, params, opt, d = self._setup(tmp_path)
        ckpt.save(d, 7, params, opt, extra={"arch": cfg.name})
        assert ckpt.latest_step(d) == 7
        p2, o2, meta = ckpt.restore(d, 7, params, opt)
        assert meta["arch"] == cfg.name
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k_gc_and_latest(self, tmp_path):
        cfg, params, opt, d = self._setup(tmp_path)
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, params, opt, keep=2)
        assert sorted(ckpt.all_steps(d)) == [4, 5]
        assert ckpt.latest_step(d) == 5

    def test_partial_save_is_invisible(self, tmp_path):
        """A checkpoint dir without committed rename must be ignored —
        models the node-died-mid-save failure."""
        cfg, params, opt, d = self._setup(tmp_path)
        ckpt.save(d, 1, params, opt)
        os.makedirs(os.path.join(d, "tmp.2"))  # simulated dead partial save
        assert ckpt.latest_step(d) == 1

    def test_elastic_resharding(self, tmp_path):
        """Save from a (1,1) mesh; restore onto a different mesh layout —
        the elastic-scaling path."""
        cfg, params, opt, d = self._setup(tmp_path)
        ckpt.save(d, 3, params, opt)
        mesh2 = jax.make_mesh((1,), ("model",))  # different topology
        psh = shardings_for(mesh2, param_specs(cfg))
        p2, _, _ = ckpt.restore(d, 3, params, opt, param_sh=psh)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        cfg, params, opt, d = self._setup(tmp_path)
        t = ckpt.save(d, 9, params, opt, async_save=True)
        t.join(timeout=60)
        assert ckpt.latest_step(d) == 9


class TestCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(10_000).astype(np.float32))
        out = compress_roundtrip(g)
        err = float(jnp.max(jnp.abs(out - g)))
        assert err <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6

    def test_error_feedback_accumulates(self):
        """EF: the sum of compressed sends converges to the sum of grads."""
        rng = np.random.default_rng(1)
        grads = {"w": jnp.asarray(rng.standard_normal(512)
                                  .astype(np.float32))}
        e = init_error_state(grads)
        sent_total = jnp.zeros(512)
        for _ in range(30):
            sent, e = ef_compress(grads, e)
            sent_total = sent_total + sent["w"]
        target = 30 * grads["w"]
        resid = float(jnp.max(jnp.abs(sent_total - target)))
        assert resid <= float(jnp.max(jnp.abs(grads["w"]))) / 127.0 + 1e-5


class TestData:
    def test_deterministic_across_restart(self):
        dcfg = DataConfig(seed=11, vocab_size=1000, seq_len=64,
                          global_batch=4)
        b1 = batch_at(dcfg, 42)
        b2 = batch_at(dcfg, 42)  # "restarted host" recomputes
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = batch_at(dcfg, 43)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_balanced_packing(self):
        rng = np.random.default_rng(2)
        lens = (rng.pareto(1.2, 200) * 50 + 1).astype(np.int64)
        starts, _ = pack_documents(jnp.asarray(lens), 16)
        per_row = np.diff(np.asarray(starts))
        assert per_row.max() - per_row.min() <= per_row.mean() * 0.1 + 16
        stats = packing_efficiency(lens, 16)
        assert stats["balanced_efficiency"] > stats["naive_efficiency"]
        assert stats["balanced_efficiency"] > 0.9
