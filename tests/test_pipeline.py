"""Pipeline-parallelism tests — run in a subprocess with 8 fake devices so
the main pytest process keeps seeing 1 CPU device (per the dry-run rules)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import AxisType, make_mesh
    from repro.train.pipeline import (make_pipeline_apply, reference_apply,
                                      split_stages)

    P_STAGES, NUM_MICRO, MB, D = 4, 6, 2, 16
    mesh = make_mesh((P_STAGES, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto, AxisType.Auto))

    rng = np.random.default_rng(0)
    layers = {
        "w": jnp.asarray(rng.standard_normal((8, D, D)).astype(np.float32))
             * 0.3,
        "b": jnp.asarray(rng.standard_normal((8, D)).astype(np.float32))
             * 0.1,
    }
    stage_params = split_stages(layers, P_STAGES)

    def stage_fn(p, x):
        for i in range(p["w"].shape[0]):
            x = jnp.tanh(x @ p["w"][i] + p["b"][i])
        return x

    xs = jnp.asarray(rng.standard_normal((NUM_MICRO, MB, D))
                     .astype(np.float32))

    apply = make_pipeline_apply(stage_fn, mesh, P_STAGES, NUM_MICRO)
    got = jax.jit(apply)(stage_params, xs)
    want = reference_apply(stage_fn, stage_params, xs, P_STAGES)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("forward OK")

    # differentiability: grads through the pipeline match the reference
    def loss_pipe(sp):
        return jnp.sum(jnp.square(apply(sp, xs)))
    def loss_ref(sp):
        return jnp.sum(jnp.square(reference_apply(stage_fn, sp, xs,
                                                  P_STAGES)))
    g1 = jax.jit(jax.grad(loss_pipe))(stage_params)
    g2 = jax.grad(loss_ref)(stage_params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)
    print("backward OK")
""")


def test_pipeline_parallel_forward_backward():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert "forward OK" in res.stdout, res.stdout + res.stderr
    assert "backward OK" in res.stdout, res.stdout + res.stderr
