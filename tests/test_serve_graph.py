"""Continuous-batching graph serving: bitwise identity + no-retrace.

The serving contract (serve/graph.py) is that every query retired off the
lane batch carries exactly the bits the single-query driver would have
produced for it — regardless of admission order, lane width, kind mix, or
where retire/backfill boundaries fall — and that the whole stream is
served with exactly ONE trace of the step and admit functions.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.sparse import CSR, Graph
from repro.sparse.graph import bfs, pagerank, sssp
from repro.serve.graph import GraphServer


def _graph(seed=0, V=20, density=0.18):
    rng = np.random.default_rng(seed)
    w = np.where(rng.random((V, V)) < density,
                 rng.random((V, V)).astype(np.float32) + 0.1,
                 0.0).astype(np.float32)
    np.fill_diagonal(w, 0.0)
    return Graph(CSR.from_dense(w))


def _driver_answer(g, plan, kind, source, direction="pull"):
    if kind == "bfs":
        return np.asarray(bfs(g, source, plan=plan, direction=direction))
    if kind == "sssp":
        return np.asarray(sssp(g, source, plan=plan, direction=direction))
    return np.asarray(pagerank(g, plan=plan, direction=direction))


def _assert_bitwise(server, results, queries, qids, direction="pull"):
    g = server.graph
    for qid, q in zip(qids, queries):
        kind, source = (q, 0) if isinstance(q, str) else q
        r = results[qid]
        ref = _driver_answer(g, server.plan, kind, source, direction)
        got = np.asarray(r.value)
        assert got.dtype == ref.dtype, (kind, got.dtype, ref.dtype)
        np.testing.assert_array_equal(
            got, ref, err_msg=f"qid {qid} kind {kind} source {source}")


MIXED = [("bfs", 5), ("sssp", 2), "pagerank", ("bfs", 0),
         "pagerank", ("sssp", 7), ("bfs", 11)]


class TestBitwiseIdentity:
    def test_mixed_stream_matches_drivers(self):
        srv = GraphServer(_graph(), lanes=3)
        qids = [srv.submit(*(q if isinstance(q, tuple) else (q,)))
                for q in MIXED]
        results = {r.qid: r for r in srv.drain()}
        assert len(results) == len(MIXED)
        _assert_bitwise(srv, results, MIXED, qids)

    @pytest.mark.parametrize("order", [
        list(range(7)), list(reversed(range(7))), [3, 0, 6, 2, 5, 1, 4]])
    def test_admission_order_invariant(self, order):
        g = _graph(seed=1)
        srv = GraphServer(g, lanes=2)
        queries = [MIXED[i] for i in order]
        qids = [srv.submit(*(q if isinstance(q, tuple) else (q,)))
                for q in queries]
        results = {r.qid: r for r in srv.drain()}
        _assert_bitwise(srv, results, queries, qids)

    @pytest.mark.parametrize("lanes", [1, 2, 7, 16])
    def test_lane_width_invariant(self, lanes):
        g = _graph(seed=2)
        srv = GraphServer(g, lanes=lanes)
        qids = [srv.submit(*(q if isinstance(q, tuple) else (q,)))
                for q in MIXED]
        results = {r.qid: r for r in srv.drain()}
        _assert_bitwise(srv, results, MIXED, qids)

    def test_more_queries_than_lanes_backfills(self):
        # 12 queries through 2 lanes forces repeated retire/backfill
        # boundaries mid-stream; every answer must still be driver bits
        g = _graph(seed=3, V=16)
        srv = GraphServer(g, lanes=2)
        queries = [("bfs", i) for i in range(5)] + \
                  [("sssp", i) for i in range(5)] + ["pagerank", "pagerank"]
        qids = [srv.submit(*(q if isinstance(q, tuple) else (q,)))
                for q in queries]
        results = {r.qid: r for r in srv.drain()}
        assert len(results) == 12 and srv.served == 12
        _assert_bitwise(srv, results, queries, qids)

    def test_staggered_arrivals_mid_flight(self):
        # submissions interleaved with ticks: lanes free up and are
        # backfilled while earlier queries are still converging
        g = _graph(seed=4)
        srv = GraphServer(g, lanes=2)
        queries = [("bfs", 3), ("sssp", 1), "pagerank", ("bfs", 9)]
        qids, results = [], {}
        for q in queries:
            qids.append(srv.submit(*(q if isinstance(q, tuple) else (q,))))
            for r in srv.tick():
                results[r.qid] = r
        for r in srv.drain():
            results[r.qid] = r
        _assert_bitwise(srv, results, queries, qids)

    def test_auto_direction_matches_auto_driver(self):
        # direction="auto" switches per-lane on the measured density
        # carry; min-combiner relax is exact in both directions, so the
        # served bits still match the auto driver's
        g = _graph(seed=5)
        srv = GraphServer(g, lanes=2, direction="auto")
        queries = [("bfs", 2), ("sssp", 6)]
        qids = [srv.submit(*q) for q in queries]
        results = {r.qid: r for r in srv.drain()}
        _assert_bitwise(srv, results, queries, qids, direction="auto")


class TestLifecycle:
    def test_empty_stream(self):
        srv = GraphServer(_graph(), lanes=2)
        assert srv.drain() == []
        assert srv.serve([]) == {}
        assert srv.steps == 0 and srv.served == 0

    def test_single_trace_across_whole_stream(self):
        srv = GraphServer(_graph(seed=6), lanes=2)
        srv.serve(MIXED)
        assert srv.step_traces == 1, "serving step re-traced"
        assert srv.admit_traces == 1, "admit re-traced"

    def test_single_trace_across_separate_streams(self):
        # a second wave of queries reuses the same compiled step/admit
        srv = GraphServer(_graph(seed=7), lanes=2)
        srv.serve([("bfs", 1), "pagerank"])
        srv.serve([("sssp", 4), ("bfs", 8)])
        assert srv.step_traces == 1 and srv.admit_traces == 1

    def test_queue_and_flight_accounting(self):
        srv = GraphServer(_graph(seed=8), lanes=2)
        for q in [("bfs", 0), ("bfs", 1), ("bfs", 2)]:
            srv.submit(*q)
        assert srv.queued == 3 and srv.in_flight == 0
        srv.tick()
        assert srv.queued == 1 and srv.in_flight == 2
        srv.drain()
        assert srv.queued == 0 and srv.in_flight == 0

    def test_result_metadata(self):
        srv = GraphServer(_graph(seed=9), lanes=1)
        results = srv.serve([("sssp", 3)])
        (r,) = results.values()
        assert r.kind == "sssp" and r.source == 3
        assert r.iterations >= 1
        assert r.completed_at >= r.admitted_at >= r.submitted_at
        assert r.latency >= 0.0

    def test_bfs_depths_are_int32(self):
        srv = GraphServer(_graph(seed=10), lanes=1)
        results = srv.serve([("bfs", 0)])
        (r,) = results.values()
        assert r.value.dtype == np.int32

    def test_submit_validates(self):
        srv = GraphServer(_graph(), lanes=1)
        with pytest.raises(ValueError):
            srv.submit("pagerankk")
        with pytest.raises(ValueError):
            srv.submit("bfs", source=10_000)

    def test_constructor_validates(self):
        with pytest.raises(ValueError):
            GraphServer(_graph(), lanes=0)
        with pytest.raises(ValueError):
            GraphServer(_graph(), direction="sideways")
