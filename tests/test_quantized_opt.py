"""Int8 blockwise Adam: roundtrip accuracy + convergence vs fp32 Adam."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
from repro.train.quantized_opt import (adamw_update_int8,
                                       dequantize_blockwise,
                                       init_opt_state_int8,
                                       quantize_blockwise, state_bytes)


class TestQuantization:
    def test_roundtrip_linear(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((37, 19)).astype(np.float32))
        q = quantize_blockwise(x)
        out = dequantize_blockwise(q, x.shape)
        err = float(jnp.max(jnp.abs(out - x)))
        assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-7

    def test_roundtrip_log_space(self):
        rng = np.random.default_rng(1)
        # second-moment-like: non-negative, huge dynamic range
        x = jnp.asarray((rng.standard_normal(5000) ** 2 *
                         10.0 ** rng.uniform(-8, 0, 5000)).astype(np.float32))
        q = quantize_blockwise(x, log_space=True)
        out = dequantize_blockwise(q, x.shape, log_space=True)
        # rsqrt (what Adam consumes) must stay accurate for non-tiny v
        big = np.asarray(x) > 1e-6
        got = 1 / np.sqrt(np.asarray(out)[big] + 1e-8)
        want = 1 / np.sqrt(np.asarray(x)[big] + 1e-8)
        np.testing.assert_allclose(got, want, rtol=0.15)

    def test_state_bytes_8x(self):
        params = {"w": jnp.zeros((1024, 1024))}
        fp32 = state_bytes(params, int8=False)
        q8 = state_bytes(params, int8=True)
        assert fp32 / q8 > 3.8  # ~3.9x including scales


class TestConvergence:
    def test_quadratic_matches_fp32_adam(self):
        cfg = OptConfig(lr=0.05, warmup_steps=0, total_steps=300,
                        weight_decay=0.0, clip_norm=100.0)
        target = jnp.asarray(np.random.default_rng(2)
                             .standard_normal(512).astype(np.float32))
        loss = lambda p: jnp.sum(jnp.square(p["w"] - target))

        p32 = {"w": jnp.zeros(512)}
        s32 = init_opt_state(p32)
        p8 = {"w": jnp.zeros(512)}
        s8 = init_opt_state_int8(p8)
        for _ in range(200):
            g32 = jax.grad(loss)(p32)
            p32, s32, _ = adamw_update(p32, g32, s32, cfg)
            g8 = jax.grad(loss)(p8)
            p8, s8, _ = adamw_update_int8(p8, g8, s8, cfg)
        l32, l8 = float(loss(p32)), float(loss(p8))
        assert l8 < 1.0, f"int8 Adam failed to converge: {l8}"
        assert l8 < max(l32 * 20, 0.5), (l32, l8)

    def test_tiny_lm_trains_with_int8_state(self):
        from repro.configs import get_config
        from repro.data.synthetic import batch_at, for_model
        from repro.models import init_params, lm_loss
        cfg = get_config("qwen15_05b").reduced()
        ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
        params, _ = init_params(cfg, jax.random.PRNGKey(0))
        state = init_opt_state_int8(params)
        dcfg = for_model(cfg, seq_len=32, global_batch=2)

        @jax.jit
        def step(params, state, batch):
            (loss, _), grads = jax.value_and_grad(
                lm_loss, has_aux=True)(params, cfg, batch, dtype=jnp.float32)
            params, state, _ = adamw_update_int8(params, grads, state, ocfg)
            return params, state, loss

        losses = []
        for i in range(10):
            params, state, loss = step(params, state, batch_at(dcfg, i))
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
