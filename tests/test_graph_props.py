"""Property tests for the graph advance subsystem (requires hypothesis).

For arbitrary random digraphs — including isolated vertices, self-loops and
zero-degree tails, which the generator produces naturally — the balanced
advance and the traversals built on it must satisfy the structural laws of
frontier computation:

* **exact-once edge coverage** — a full-frontier sum-advance of unit edge
  values returns every vertex's in-degree, bit for bit, on both execution
  paths (any dropped or duplicated edge atom shows up as a count mismatch);
* **monotone frontier convergence** — BFS frontiers are disjoint level
  sets; labels only ever move from unreached (-1) to a final depth, and the
  loop terminates in at most |V| iterations;
* **SSSP triangle inequality** — for every edge (u, v, w) with reached u:
  ``dist[v] <= dist[u] + w``, and every finite ``dist[v]`` is realised by
  at least one in-edge (tightness at v's predecessor) or v is the source;
* **direction equivalence** — the push-direction advance scatters the same
  candidate multiset the pull direction reduces, so a direction-optimizing
  BFS (measured-density push/pull switching, any threshold) visits the same
  vertex set at the same depths as a pull-only BFS, and full-frontier push
  counts in-degrees exactly once.
"""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import Schedule
from repro.sparse import (CSR, Graph, advance, advance_push, bfs,
                          build_advance, delta_stepping, sssp)
from _conformance import (assert_bitwise_equal, np_bfs, np_delta_stepping,
                          np_sssp)

SCHEDULES = [Schedule.CHUNKED, Schedule.ADAPTIVE, Schedule.MERGE_PATH,
             Schedule.NONZERO_SPLIT, Schedule.THREAD_MAPPED,
             Schedule.GROUP_MAPPED]


def random_digraph(V: int, density: float, seed: int) -> np.ndarray:
    """Dense weight matrix; integer weights; self-loops kept at ~10%."""
    rng = np.random.default_rng(seed)
    w = (rng.random((V, V)) < density) * rng.integers(1, 6, (V, V))
    keep_loops = rng.random(V) < 0.1
    diag = np.diag(np.diag(w) * keep_loops)
    np.fill_diagonal(w, 0)
    return (w + diag).astype(np.float32)


graph_params = st.tuples(st.integers(min_value=1, max_value=18),
                         st.floats(min_value=0.0, max_value=0.5),
                         st.integers(min_value=0, max_value=2**31 - 1))


class TestExactOnceEdgeCoverage:
    @pytest.mark.parametrize("schedule", SCHEDULES)
    @given(params=graph_params,
           num_blocks=st.integers(min_value=1, max_value=6))
    @settings(max_examples=6, deadline=None)
    def test_full_frontier_unit_advance_counts_in_degrees(
            self, schedule, params, num_blocks):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        in_deg = (w > 0).sum(axis=0).astype(np.float32)
        frontier = jnp.ones((V,), bool)
        for path in ("pure", "native"):
            plan = build_advance(g, schedule=schedule,
                                 num_blocks=num_blocks, path=path)
            got = advance(plan, frontier,
                          lambda e: jnp.ones(e.shape, jnp.float32),
                          combiner="sum")
            assert_bitwise_equal(got, in_deg,
                                 f"edges dropped/duplicated: {schedule}/{path}")


class TestMonotoneFrontierConvergence:
    @given(params=graph_params)
    @settings(max_examples=8, deadline=None)
    def test_bfs_levels_partition_reachable_set(self, params):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        depth = np.asarray(bfs(g, 0, schedule="chunked_lpt", num_blocks=3))
        want, _ = np_bfs(w, 0)
        np.testing.assert_array_equal(depth, want)
        # monotone convergence: running with a tighter iteration budget
        # yields a prefix of the final labelling (labels never regress)
        for cap in range(int(depth.max()) + 1):
            partial = np.asarray(bfs(g, 0, schedule="chunked_lpt",
                                     num_blocks=3, max_iters=cap))
            settled = partial >= 0
            np.testing.assert_array_equal(partial[settled], depth[settled])
            assert np.all(partial[depth == -1] == -1)

    @given(params=graph_params)
    @settings(max_examples=8, deadline=None)
    def test_bfs_parent_edges_step_one_level(self, params):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        depth, parent = bfs(g, 0, schedule="adaptive", num_blocks=3,
                            return_parents=True)
        depth, parent = np.asarray(depth), np.asarray(parent)
        for v in range(V):
            if parent[v] >= 0:
                assert w[parent[v], v] > 0, "parent must be an in-neighbour"
                assert depth[v] == depth[parent[v]] + 1


class TestDirectionEquivalence:
    @given(params=graph_params,
           threshold=st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=8, deadline=None)
    def test_direction_optimizing_bfs_matches_pull_only(self, params,
                                                        threshold):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        plan = build_advance(g, schedule="merge_path", num_blocks=3,
                             direction_threshold=threshold)
        pull = np.asarray(bfs(g, 0, plan=plan, direction="pull"))
        auto = np.asarray(bfs(g, 0, plan=plan, direction="auto"))
        push = np.asarray(bfs(g, 0, plan=plan, direction="push"))
        want, _ = np_bfs(w, 0)
        np.testing.assert_array_equal(pull, want)
        np.testing.assert_array_equal(auto, want)
        np.testing.assert_array_equal(push, want)
        # identical visited sets by construction of the equality above
        assert set(np.flatnonzero(auto >= 0)) == set(np.flatnonzero(
            pull >= 0))

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @given(params=graph_params,
           num_blocks=st.integers(min_value=1, max_value=6))
    @settings(max_examples=6, deadline=None)
    def test_push_full_frontier_unit_advance_counts_in_degrees(
            self, schedule, params, num_blocks):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        in_deg = (w > 0).sum(axis=0).astype(np.float32)
        frontier = jnp.ones((V,), bool)
        for path in ("pure", "native"):
            plan = build_advance(g, schedule=schedule,
                                 num_blocks=num_blocks, path=path)
            got = advance_push(plan, frontier,
                               lambda e: jnp.ones(e.shape, jnp.float32),
                               combiner="sum")
            assert_bitwise_equal(got, in_deg,
                                 f"push dropped/duplicated edges: "
                                 f"{schedule}/{path}")


class TestDeltaSteppingEquivalence:
    """Delta-stepping == frontier Bellman-Ford, bitwise, for *arbitrary*
    bucket widths on random weighted digraphs (the bucketed traversal runs
    every relaxation to quiescence, so the f32 fixed point is the same no
    matter how distances were binned)."""

    @given(params=graph_params,
           delta=st.floats(min_value=0.05, max_value=24.0))
    @settings(max_examples=8, deadline=None)
    def test_delta_matches_bellman_ford_bitwise(self, params, delta):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        plan = build_advance(g, schedule="chunked_lpt", num_blocks=3,
                             delta=delta, compact=True)
        bf = np.asarray(sssp(g, 0, plan=plan, direction="pull"))
        for direction in ("pull", "push", "auto"):
            ds = np.asarray(delta_stepping(g, 0, plan=plan,
                                           direction=direction))
            assert_bitwise_equal(ds, bf, f"direction={direction}, "
                                         f"delta={delta}")
        assert_bitwise_equal(np_delta_stepping(w, 0, delta), bf,
                             f"np oracle, delta={delta}")

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @given(params=graph_params)
    @settings(max_examples=4, deadline=None)
    def test_delta_default_width_matches_across_schedules(self, schedule,
                                                          params):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        bf = np.asarray(sssp(g, 0, schedule=schedule, num_blocks=3))
        ds = np.asarray(delta_stepping(g, 0, schedule=schedule,
                                       num_blocks=3))
        assert_bitwise_equal(ds, bf, str(schedule))


class TestSsspTriangleInequality:
    @given(params=graph_params)
    @settings(max_examples=8, deadline=None)
    def test_relaxed_distances_are_stable(self, params):
        V, density, seed = params
        w = random_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        dist = np.asarray(sssp(g, 0, schedule="chunked_rr", num_blocks=3))
        np.testing.assert_allclose(dist, np_sssp(w, 0), rtol=1e-6)
        us, vs = np.nonzero(w)
        for u, v in zip(us, vs):
            if np.isfinite(dist[u]):
                assert dist[v] <= dist[u] + w[u, v] + 1e-6
        # tightness: every finite distance is witnessed by an in-edge
        for v in range(V):
            if v != 0 and np.isfinite(dist[v]):
                preds = np.nonzero(w[:, v])[0]
                assert any(np.isclose(dist[p] + w[p, v], dist[v], rtol=1e-6)
                           for p in preds)


def _skewed_digraph(V: int, density: float, seed: int) -> np.ndarray:
    """A random digraph with a planted in-hub at vertex 0 — the skew
    degree-aware boundary schedules exist for."""
    w = random_digraph(V, density, seed)
    if V > 1:
        rng = np.random.default_rng(seed + 1)
        w[1:, 0] = rng.integers(1, 6, V - 1).astype(np.float32)
    return w


class TestShardedHaloExactOnce:
    """Sharding is a pure decomposition of the edge set: for arbitrary
    random skewed digraphs and *every* boundary schedule, the per-shard
    local CSR views must cover every edge exactly once (any halo
    duplication or drop shows up as a mask-count mismatch), and the
    halo-exchanging sharded traversals must land on the same fixed point
    as the unsharded drivers, bit for bit."""

    @given(params=graph_params)
    @settings(max_examples=4, deadline=None)
    def test_shard_views_partition_edge_set(self, params):
        import jax
        from repro.sparse import (SHARD_SCHEDULES, build_sharded_advance,
                                  sharded_bfs)
        V, density, seed = params
        w = _skewed_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        S = max(s for s in (1, 2, 4)
                if s <= len(jax.devices()) and s <= V)
        E = g.csr.nnz
        want, _ = np_bfs(w, 0)
        for boundary in SHARD_SCHEDULES:
            splan = build_sharded_advance(g, S, schedule="merge_path",
                                          path="pure", num_blocks=3,
                                          shard_schedule=boundary)
            # exact-once: the valid masks over both directions' padded
            # local views sum to the global edge count — no edge is owned
            # by two shards, none falls into the padding
            assert int(np.asarray(splan.arrays["pull_valid"]).sum()) == E
            assert int(np.asarray(splan.arrays["push_valid"]).sum()) == E
            assert int(np.asarray(splan.arrays["out_degrees"]).sum()) == E
            np.testing.assert_array_equal(np.asarray(sharded_bfs(splan, 0)),
                                          want)

    @given(params=graph_params)
    @settings(max_examples=2, deadline=None)
    def test_sharded_traversals_bitwise_any_boundary(self, params):
        import jax
        from repro.sparse import (SHARD_SCHEDULES, build_sharded_advance,
                                  sharded_delta_stepping, sharded_sssp)
        V, density, seed = params
        w = _skewed_digraph(V, density, seed)
        g = Graph(CSR.from_dense(w))
        want_s = sssp(g, 0, schedule="merge_path", path="pure", num_blocks=3)
        want_d = delta_stepping(g, 0, schedule="merge_path", path="pure",
                                num_blocks=3, compact=None)
        for boundary in SHARD_SCHEDULES:
            for S in (1, 2, 4):
                if S > len(jax.devices()) or S > V:
                    continue
                splan = build_sharded_advance(g, S, schedule="merge_path",
                                              path="pure", num_blocks=3,
                                              shard_schedule=boundary,
                                              delta="auto")
                assert_bitwise_equal(sharded_sssp(splan, 0), want_s,
                                     f"sssp {boundary} s{S}")
                assert_bitwise_equal(sharded_delta_stepping(splan, 0),
                                     want_d, f"delta {boundary} s{S}")
